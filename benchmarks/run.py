"""Benchmark harness entry point: one function per paper table/figure.

  table1          -- the paper's Table I (II/MII/util/time/speedup, 6 kernels)
  mapper_sweep    -- II vs MII across cluster variants (the architecture-
                     exploration use-case of the ADL)
  kernel_micro    -- Pallas kernels: us/call in interpret mode (correctness
                     harness timing; real perf comes from the roofline)
  sim_throughput  -- JAX simulator cycles/s (the Verilator-replacement claim)
  toolchain_cache -- cold vs warm Toolchain.compile over the Table-I kernel
                     set (the content-addressed artifact cache)
  verify_batched  -- per-seed sequential verify vs the batched verification
                     engine (vmapped multi-seed simulation) at batch=8
  dse_sweep       -- tiny design-space sweep (repro.dse): 4 architecture
                     variants x the ten-kernel library; rows are modeled
                     suite latency per variant (deterministic), so the
                     regression gate tracks mapper/cost-model quality
  dse_search      -- cross-architecture stacked simulation (simulate_multi)
                     vs one launch per (variant, kernel): evaluated points
                     per second, the DSE search evaluator's perf core
  check_static    -- static legality audit (repro.check) throughput over
                     the kernel library, vs one batch-1 dynamic verify

Each benchmark prints ``name,us_per_call,derived`` CSV rows *and* returns
machine-readable rows; ``main`` writes one ``BENCH_<name>.json`` artifact
per benchmark (schema: ``{"bench", "schema", "git_sha", "rows": [{"name",
"us", "derived": {...}}]}``) so the perf trajectory is tracked PR-over-PR.

CLI:  python -m benchmarks.run [--only sim_throughput,toolchain_cache]
                               [--out DIR]
      python -m benchmarks.run --check-regression before.json after.json
                               [--tol 0.15]
The output directory defaults to ``$MORPHER_BENCH_DIR`` or the cwd; the
regression comparator accepts files or directories of BENCH artifacts and
exits nonzero when any benchmark row slows beyond the tolerance.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import subprocess
import tempfile
import time
from typing import Dict, List, Optional

import numpy as np

BENCH_SCHEMA = 1


def _row(name: str, us: float, **derived) -> Dict:
    return {"name": name, "us": round(us, 1), "derived": derived}


def _simcache_derived(st: Optional[Dict] = None) -> Dict:
    """The executable-cache counters every verify/DSE bench row carries
    (how many XLA builds the run paid vs how many launches it served) —
    informational only, the regression comparator gates ``us``."""
    from repro.core import simcache
    st = st if st is not None else simcache.stats()
    return {"sim_cache_entries": st["entries"], "sim_cache_hits": st["hits"],
            "sim_cache_misses": st["misses"]}


def _print_rows(rows: List[Dict]) -> None:
    for r in rows:
        d = ";".join(f"{k}={v}" for k, v in r["derived"].items())
        print(f"{r['name']},{r['us']:.0f},{d}")


def _git_sha() -> Optional[str]:
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            text=True, stderr=subprocess.DEVNULL).strip()
    except (OSError, subprocess.CalledProcessError):
        return None


def bench_table1() -> List[Dict]:
    from . import table1
    return table1.main()


def bench_mapper_sweep() -> List[Dict]:
    from repro.core.adl import cluster_4x4
    from repro.core.kernels_lib import build_gemm
    from repro.core.mapper import MapError, MapperOptions
    from repro.core.toolchain import Toolchain

    # use_cache=False: this benchmark measures real mapper search time
    tc = Toolchain(options=MapperOptions(ii_max=24, seeds=(0, 1, 2, 3),
                                         time_budget_s=60))
    rows = []
    for rf in (4, 8, 16):
        for unroll in (1, 2, 4):
            arch = cluster_4x4(regfile=rf)
            spec = build_gemm(TI=6, TK=8, TJ=6, unroll=unroll, arch=arch)
            t0 = time.time()
            try:
                ck = tc.compile(spec, use_cache=False)
                rows.append(_row(f"mapper_rf{rf}_u{unroll}",
                                 (time.time() - t0) * 1e6, II=ck.II,
                                 MII=ck.mii,
                                 util=round(ck.utilization, 3)))
            except MapError:
                rows.append(_row(f"mapper_rf{rf}_u{unroll}",
                                 (time.time() - t0) * 1e6, unmapped=1))
    _print_rows(rows)
    return rows


def bench_kernel_micro() -> List[Dict]:
    import jax.numpy as jnp
    from repro.kernels.gemm_os.ops import gemm_os
    from repro.kernels.decode_attn.ops import decode_attn

    rng = np.random.default_rng(0)
    rows = []
    a = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    gemm_os(a, b, interpret=True).block_until_ready()
    t0 = time.time()
    for _ in range(3):
        gemm_os(a, b, interpret=True).block_until_ready()
    rows.append(_row("gemm_os_256_interpret", (time.time() - t0) / 3 * 1e6,
                     flops=2 * 256 ** 3))

    q = jnp.asarray(rng.normal(size=(2, 8, 64)), jnp.float32)
    kv = jnp.asarray(rng.normal(size=(2, 2, 512, 64)), jnp.float32)
    lens = jnp.asarray([512, 300])
    decode_attn(q, kv, kv, lens, bs=128, interpret=True).block_until_ready()
    t0 = time.time()
    for _ in range(3):
        decode_attn(q, kv, kv, lens, bs=128,
                    interpret=True).block_until_ready()
    rows.append(_row("decode_attn_interpret", (time.time() - t0) / 3 * 1e6,
                     kv=512))
    _print_rows(rows)
    return rows


def bench_sim_throughput() -> List[Dict]:
    from repro.core.kernels_lib import build_gemm
    from repro.core.toolchain import Toolchain
    from repro.core.verify import generate_test_data

    spec = build_gemm(TI=6, TK=8, TJ=6, unroll=1)
    ck = Toolchain(cache_dir="").compile(spec)
    data = generate_test_data(spec)
    n_cycles = ck.cfg.n_cycles(spec.mapped_iters) * len(spec.invocations)
    ck.run(data.init_banks)
    dt = float("inf")                 # best of 3: shields against noise
    for _ in range(3):
        t0 = time.time()
        ck.run(data.init_banks)
        dt = min(dt, time.time() - t0)
    rows = [_row("simulator_gemm", dt * 1e6, cycles=n_cycles,
                 cycles_per_s=round(n_cycles / dt))]
    _print_rows(rows)
    return rows


def bench_toolchain_cache() -> List[Dict]:
    """Cold vs warm compile of the Table-I kernel set through the content-
    addressed artifact cache (small dims, identical DFG structure)."""
    from repro.core.kernels_lib import table1_kernels
    from repro.core.mapper import MapperOptions
    from repro.core.toolchain import Toolchain

    # no per-kernel wall-clock budget: the cold pass measures full mapper
    # cost, and budgets misfire under CPU oversubscription anyway
    opts = MapperOptions(seeds=tuple(range(8)))
    cache = tempfile.mkdtemp(prefix="morpher-cache-bench-")
    try:
        specs = list(table1_kernels(small=True).values())
        t0 = time.time()
        Toolchain(options=opts, cache_dir=cache).compile_many(specs)
        cold = time.time() - t0
        # fresh Toolchain: no in-process memo, artifacts come off disk
        t0 = time.time()
        warm_cks = Toolchain(options=opts, cache_dir=cache).compile_many(
            list(table1_kernels(small=True).values()))
        warm = time.time() - t0
        assert all(ck.from_cache for ck in warm_cks)
        rows = [_row("toolchain_cache", cold * 1e6,
                     warm_us=round(warm * 1e6), kernels=len(specs),
                     speedup=round(cold / warm, 1))]
        _print_rows(rows)
        return rows
    finally:
        shutil.rmtree(cache, ignore_errors=True)


def bench_verify_batched() -> List[Dict]:
    """Aggregate verification throughput over the Table-I (small dims) +
    DSL kernel set: per-seed sequential ``verify`` vs one ``verify_batch``
    per kernel at batch=8 (the batched engine: vectorized test-data
    generation, batched numpy DFG oracle, vmapped simulator through the
    process-wide executable cache).  Target: >= 3x."""
    from repro.core import simcache
    from repro.core.kernels_lib import table1_kernels
    from repro.core.toolchain import Toolchain
    from repro.frontend.library import dsl_kernels

    seeds = list(range(8))
    specs = {**table1_kernels(small=True), **dsl_kernels()}
    cks = Toolchain(cache_dir="").compile_many(list(specs.values()))
    # warm both paths once so XLA traces (amortized by the persistent
    # executable cache in any real verification fleet) are off the clock
    for ck in cks:
        ck.verify(seed=seeds[0])
        ck.verify_batch(seeds)
    trace_stats = simcache.stats()

    t0 = time.time()
    for ck in cks:
        for s in seeds:
            ck.verify(seed=s)
    seq = time.time() - t0
    t0 = time.time()
    for ck in cks:
        ck.verify_batch(seeds)
    bat = time.time() - t0

    n = len(cks) * len(seeds)
    rows = [_row("verify_batched", bat * 1e6,
                 seq_us=round(seq * 1e6), kernels=len(cks),
                 batch=len(seeds), verifies=n,
                 seq_verifies_per_s=round(n / seq, 1),
                 batch_verifies_per_s=round(n / bat, 1),
                 speedup=round(seq / bat, 2),
                 **_simcache_derived(trace_stats))]
    _print_rows(rows)
    return rows


def bench_frontend_trace() -> List[Dict]:
    """Front-end tracing overhead: time to trace each Table-I kernel
    through the ``repro.frontend`` DSL vs a warm-cache Toolchain.compile
    of the same kernel (target: trace < 5% of the warm compile)."""
    from repro.core.adl import cluster_4x4
    from repro.core.kernels_lib import build_conv, build_gemm
    from repro.core.mapper import MapperOptions
    from repro.core.toolchain import Toolchain

    # arch is shared across kernels (as in any real sweep): what's timed
    # below is tracing + spec assembly, not ADL construction
    g = dict(TI=6, TK=8, TJ=6, arch=cluster_4x4())
    c = dict(OH=5, OW=5, K=3, arch=cluster_4x4())
    builders = {
        "GEMM": lambda: build_gemm(**g, unroll=1),
        "GEMM-U": lambda: build_gemm(**g, unroll=4),
        "GEMM-U-C": lambda: build_gemm(**g, unroll=4, coalesced=True),
        "CONV": lambda: build_conv(**c, variant="base"),
        "CONV-U-C-1": lambda: build_conv(**c, variant="uc1"),
        "CONV-U-C-2": lambda: build_conv(**c, variant="uc2"),
    }
    opts = MapperOptions()
    cache = tempfile.mkdtemp(prefix="morpher-frontend-bench-")
    try:
        Toolchain(options=opts, cache_dir=cache).compile_many(
            [b() for b in builders.values()])       # warm the disk cache
        rows = []
        for name, build in builders.items():
            trace_us = float("inf")
            for _ in range(20):                      # best-of: shields noise
                t0 = time.perf_counter()
                spec = build()
                trace_us = min(trace_us, (time.perf_counter() - t0) * 1e6)
            warm_us = float("inf")
            for _ in range(10):
                tc = Toolchain(options=opts, cache_dir=cache)  # no memo
                t0 = time.perf_counter()
                ck = tc.compile(spec)
                warm_us = min(warm_us, (time.perf_counter() - t0) * 1e6)
                assert ck.from_cache
            rows.append(_row(f"trace_{name}", trace_us,
                             warm_compile_us=round(warm_us),
                             nodes=spec.dfg.n_nodes,
                             ratio=round(trace_us / warm_us, 3)))
        _print_rows(rows)
        return rows
    finally:
        shutil.rmtree(cache, ignore_errors=True)


def bench_dse_sweep() -> List[Dict]:
    """Tiny design-space sweep end to end: every ``tiny`` architecture
    variant compiles + verifies the ten-kernel library and is scored by
    the cost model.  Rows carry modeled (deterministic) latency, so the
    regression comparator gates mapping quality rather than wall clock;
    the sweep wall time is printed for the log only."""
    from repro.core.mapper import MapperOptions
    from repro.core.toolchain import Toolchain
    from repro.dse import get_space, run_sweep, sweep_bench_rows

    cache = tempfile.mkdtemp(prefix="morpher-dse-bench-")
    try:
        tc = Toolchain(options=MapperOptions(ii_max=20), cache_dir=cache)
        t0 = time.time()
        results = run_sweep(get_space("tiny"), toolchain=tc)
        print(f"# tiny sweep wall time {time.time() - t0:.1f}s "
              f"({len(results)} variants)")
        rows = sweep_bench_rows(results)
        for r in rows:
            r["derived"].update(_simcache_derived())
        _print_rows(rows)
        return rows
    finally:
        shutil.rmtree(cache, ignore_errors=True)


def bench_dse_search() -> List[Dict]:
    """Cross-architecture batched simulation throughput — the DSE search
    evaluator's perf core.  A cohort of homogeneous 4x4 wide-space
    variants (spanning RF 4/8/16 — the provisioning axis a search
    explores hardest) compiles a kernel subset (off the clock, warm
    cache), then the same verification batches are simulated two ways:

      exhaustive  one XLA launch per (variant, kernel) with exact-shape
                  executables — the per-arch dispatch a sweep pays
      stacked     variants sharing a shape bucket (``stack_signature``:
                  cycle, row and register-file widths bucketed) stack
                  their config planes into one executable
                  (``simulate_multi``) — one launch per group

    Outputs are asserted word-for-word identical, then each path is
    timed *cold* (``simcache.clear()`` + ``jax.clear_caches()`` first,
    best of 2): evaluating a fresh cohort is the search's steady state —
    every generation meets new shape buckets — and executable builds,
    not launches, dominate that cost on the compute-bound CPU backend.
    RF bucketing collapses the per-RF executable classes (builds_* in
    the row), which is where the >= 2x pinned by the committed
    before/after baselines comes from.  Warm launches are reported too
    (warm_*): stacked pays row/RF padding there, the price of the merged
    executables — the cold win is the net.  Note the cache clears force
    benches run after this one in the same process to retrace."""
    import jax

    from repro.core import simcache
    from repro.core.mapper import MapperOptions
    from repro.core.simulator import simulate_multi, stack_signature
    from repro.core.toolchain import Toolchain, _batch_oracle
    from repro.dse import get_space, kernel_suite

    points = [p for p in get_space("wide")
              if p.rows == 4 and p.cols == 4 and p.het == "none"][:12]
    kernels = ("GEMM", "CONV", "dwconv", "requant-int8")
    seeds = list(range(4))
    cache = tempfile.mkdtemp(prefix="morpher-dse-search-bench-")
    try:
        tc = Toolchain(options=MapperOptions(ii_max=20), cache_dir=cache)
        units = []                                # (ck, init_banks_batch)
        for p in points:
            suite = kernel_suite(p.build())
            cks = tc.compile_many([suite[k] for k in kernels],
                                  allow_unmapped=True)
            units += [(ck, _batch_oracle(ck, seeds, check_dfg=False)[0])
                      for ck in cks if ck is not None]

        def exhaustive():
            return [ck.run_batch(init) for ck, init in units]

        def stacked():
            groups: Dict[tuple, List[int]] = {}
            for i, (ck, _init) in enumerate(units):
                sig = stack_signature(ck.cfg, ck.mapped_iters,
                                      len(ck.invocations))
                groups.setdefault(sig, []).append(i)
            outs: List = [None] * len(units)
            for sig in sorted(groups):
                idxs = groups[sig]
                finals = simulate_multi(
                    [(units[i][0].cfg, units[i][1], units[i][0].invocations)
                     for i in idxs],
                    n_iters=units[idxs[0]][0].mapped_iters)
                for i, f in zip(idxs, finals):
                    outs[i] = f
            return outs

        a, b = exhaustive(), stacked()       # warm traces + bit-exactness
        for fa, fb in zip(a, b):             # per unit: [seed][bank] arrays
            for da, db in zip(fa, fb):
                assert set(da) == set(db)
                for k in da:
                    np.testing.assert_array_equal(np.asarray(da[k]),
                                                  np.asarray(db[k]))
        warm_exh = warm_flat = float("inf")  # best of 2: shields noise
        for _ in range(2):
            t0 = time.time()
            exhaustive()
            warm_exh = min(warm_exh, time.time() - t0)
            t0 = time.time()
            stacked()
            warm_flat = min(warm_flat, time.time() - t0)

        def cold(fn):
            simcache.clear()
            jax.clear_caches()
            t0 = time.time()
            fn()
            return time.time() - t0

        exh = flat = float("inf")
        builds_exh = builds_flat = 0
        for _ in range(2):
            exh = min(exh, cold(exhaustive))
            builds_exh = simcache.stats()["misses"]
            flat = min(flat, cold(stacked))
            builds_flat = simcache.stats()["misses"]

        rows = [_row("dse_search_eval", flat * 1e6,
                     points=len(points), kernels=len(kernels),
                     seeds=len(seeds), units=len(units),
                     builds_exhaustive=builds_exh,
                     builds_stacked=builds_flat,
                     evals_per_s=round(len(points) / flat, 1),
                     exhaustive_us=round(exh * 1e6),
                     exhaustive_evals_per_s=round(len(points) / exh, 1),
                     speedup=round(exh / flat, 2),
                     warm_us=round(warm_flat * 1e6),
                     warm_exhaustive_us=round(warm_exh * 1e6),
                     **_simcache_derived())]
        _print_rows(rows)
        return rows
    finally:
        shutil.rmtree(cache, ignore_errors=True)


def bench_isa_export() -> List[Dict]:
    """Instruction-stream backend throughput over the ten-kernel library:
    export (encode all three artifacts) and the standalone-interpreter
    cross-validation against ``simulate()`` (one seed).  The export row's
    wall time is what an ``--emit-streams`` deploy pays per kernel; the
    xval row is the cost of the second oracle inside a verify fleet."""
    from repro.core.kernels_lib import table1_kernels
    from repro.core.toolchain import Toolchain
    from repro.frontend.library import dsl_kernels
    from repro.isa.encode import encode_kernel
    from repro.isa.xval import cross_validate, stream_for

    specs = {**table1_kernels(small=True), **dsl_kernels()}
    cks = Toolchain(cache_dir="").compile_many(list(specs.values()))
    insns = sum(ck.cfg.II * ck.cfg.P for ck in cks)

    for ck in cks:                       # warm: imports, one sim trace each
        encode_kernel(ck)
        cross_validate(ck, seeds=(0,))

    exp = float("inf")                   # best of 3: shields against noise
    for _ in range(3):
        t0 = time.time()
        arts = [encode_kernel(ck) for ck in cks]
        exp = min(exp, time.time() - t0)
    streams = [stream_for(ck) for ck in cks]
    xval = float("inf")
    for _ in range(3):
        t0 = time.time()
        for ck, st in zip(cks, streams):
            cross_validate(ck, seeds=(0,), stream=st)
        xval = min(xval, time.time() - t0)

    rows = [_row("isa_export", exp * 1e6, kernels=len(cks), insns=insns,
                 bytes=sum(len(t) for a in arts for t in a.values()),
                 insns_per_s=round(insns / exp)),
            _row("isa_xval", xval * 1e6, kernels=len(cks), seeds=1,
                 kernels_per_s=round(len(cks) / xval, 1))]
    _print_rows(rows)
    return rows


def bench_check_static() -> List[Dict]:
    """Static legality audit throughput (repro.check) over the Table-I
    (small dims) + DSL kernel set: all three layers (mapping, config,
    re-derived instruction stream) per kernel, best of 3.  The derived
    ``verify_us`` column is one batch-1 dynamic verify over the same set
    — the cost the MORPHER_CHECK=1 pre-screen lets a fleet skip for
    artifacts that are corrupt on paper."""
    from repro.check import check_kernel, errors
    from repro.core.kernels_lib import table1_kernels
    from repro.core.toolchain import Toolchain
    from repro.frontend.library import dsl_kernels

    specs = {**table1_kernels(small=True), **dsl_kernels()}
    cks = Toolchain(cache_dir="").compile_many(list(specs.values()))
    for ck in cks:                       # warm: imports + one XLA trace each
        assert not errors(check_kernel(ck))
        ck.verify(seed=0)

    chk = float("inf")                   # best of 3: shields against noise
    for _ in range(3):
        t0 = time.time()
        n_diags = sum(len(check_kernel(ck)) for ck in cks)
        chk = min(chk, time.time() - t0)
    ver = float("inf")
    for _ in range(3):
        t0 = time.time()
        for ck in cks:
            ck.verify(seed=0)
        ver = min(ver, time.time() - t0)

    rows = [_row("check_static", chk * 1e6, kernels=len(cks),
                 diagnostics=n_diags,
                 kernels_per_s=round(len(cks) / chk, 1),
                 verify_us=round(ver * 1e6),
                 verify_ratio=round(ver / chk, 1))]
    _print_rows(rows)
    return rows


def bench_serve_decode() -> List[Dict]:
    """End-to-end CGRA-backed serving on shrunken configs: build a
    ServePlan (feasible tiles, compile_many, one site spot-checked
    bit-exactly against the cycle-accurate simulator), then run a seeded
    Poisson traffic episode through the engine on plan-derived latency.
    Rows carry the *modeled* episode duration and throughput —
    byte-deterministic given the seed, so the regression comparator gates
    plan/cost-model quality, not host wall clock."""
    import jax
    from repro.configs.registry import serve_smoke_config
    from repro.core.toolchain import Toolchain
    from repro.models.zoo import build_model
    from repro.serve.engine import Engine
    from repro.serve.plan import CGRAExecutionModel, build_serve_plan
    from repro.serve.traffic import (TrafficConfig, report_bench_rows,
                                     run_traffic)

    cache = tempfile.mkdtemp(prefix="morpher-serve-bench-")
    rows: List[Dict] = []
    try:
        tc = Toolchain(cache_dir=cache)
        for arch_id in ("llama3.2-1b", "rwkv6-1.6b"):
            cfg = serve_smoke_config(arch_id)
            plan = build_serve_plan(cfg, toolchain=tc)
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            eng = Engine(model, params, batch=4, max_len=48,
                         exec_model=CGRAExecutionModel(plan))
            report = run_traffic(
                eng, TrafficConfig(seed=0, n_requests=12,
                                   arrival_rate=100.0), cfg.vocab)
            rows += report_bench_rows(report,
                                      name=f"serve_decode_{arch_id}",
                                      sites=len(plan.sites),
                                      tiles=len(plan.kernels))
        _print_rows(rows)
        return rows
    finally:
        shutil.rmtree(cache, ignore_errors=True)


BENCHES = {
    "table1": ("Table I (paper reproduction)", bench_table1),
    "frontend_trace": ("frontend DSL tracing overhead (vs warm compile)",
                       bench_frontend_trace),
    "mapper_sweep": ("mapper sweep (ADL design-space exploration)",
                     bench_mapper_sweep),
    "kernel_micro": ("Pallas kernel micro (interpret mode)",
                     bench_kernel_micro),
    "sim_throughput": ("simulator throughput", bench_sim_throughput),
    "toolchain_cache": ("toolchain artifact cache (cold vs warm)",
                        bench_toolchain_cache),
    "verify_batched": ("batched vs sequential verification throughput",
                       bench_verify_batched),
    "dse_sweep": ("tiny design-space sweep (repro.dse, modeled latency)",
                  bench_dse_sweep),
    "dse_search": ("cross-architecture stacked simulation throughput "
                   "(evaluated points per second)", bench_dse_search),
    "serve_decode": ("CGRA-backed serving traffic episode (modeled)",
                     bench_serve_decode),
    "isa_export": ("instruction-stream export + interpreter xval",
                   bench_isa_export),
    "check_static": ("static legality audit throughput (repro.check)",
                     bench_check_static),
}


def check_regression(before: str, after: str, tol: float = 0.15) -> int:
    """Compare two BENCH_<name>.json artifacts (or two directories of
    them): any row whose ``us`` grew by more than ``tol`` (relative) is a
    throughput regression.  Returns a nonzero exit status if any row
    regressed; rows present on only one side are reported but never fail.
    """
    def load_rows(path: str) -> Dict[str, Dict]:
        files = (sorted(os.path.join(path, f) for f in os.listdir(path)
                        if f.startswith("BENCH_") and f.endswith(".json"))
                 if os.path.isdir(path) else [path])
        rows: Dict[str, Dict] = {}
        for fn in files:
            with open(fn, "r", encoding="utf-8") as f:
                d = json.load(f)
                for r in d["rows"]:
                    # key by (bench, row): same-named rows from different
                    # benchmarks must not shadow each other
                    rows[f"{d['bench']}/{r['name']}"] = r
        return rows

    b_rows, a_rows = load_rows(before), load_rows(after)
    failed = []
    for name in sorted(set(b_rows) | set(a_rows)):
        if name not in b_rows:
            print(f"NEW       {name}: {a_rows[name]['us']}us")
            continue
        if name not in a_rows:
            print(f"REMOVED   {name} (was {b_rows[name]['us']}us)")
            continue
        b_us, a_us = b_rows[name]["us"], a_rows[name]["us"]
        if b_us is None or a_us is None:
            # informational rows (e.g. an unmapped table1 kernel) carry
            # no duration; report, never gate
            print(f"{'n/a':9s} {name}: {b_us}us -> {a_us}us")
            continue
        rel = (a_us - b_us) / b_us if b_us else 0.0
        verdict = "REGRESSED" if rel > tol else "ok"
        print(f"{verdict:9s} {name}: {b_us:.0f}us -> {a_us:.0f}us "
              f"({rel:+.1%}, tol {tol:.0%})")
        if rel > tol:
            failed.append(name)
    if failed:
        print(f"# {len(failed)} row(s) regressed beyond {tol:.0%}: "
              f"{', '.join(failed)}")
        return 1
    print("# no regressions")
    return 0


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names "
                         f"(default: all of {', '.join(BENCHES)})")
    ap.add_argument("--out", default=None,
                    help="directory for BENCH_<name>.json artifacts "
                         "(default: $MORPHER_BENCH_DIR or cwd)")
    ap.add_argument("--check-regression", nargs=2,
                    metavar=("BEFORE", "AFTER"),
                    help="compare two BENCH json files (or directories of "
                         "them) instead of running benchmarks; exits "
                         "nonzero if any row slowed beyond --tol")
    ap.add_argument("--tol", type=float, default=0.15,
                    help="relative slowdown tolerated by "
                         "--check-regression (default 0.15)")
    args = ap.parse_args(argv)
    if args.check_regression:
        raise SystemExit(check_regression(*args.check_regression,
                                          tol=args.tol))
    names = list(BENCHES) if not args.only else [
        n.strip() for n in args.only.split(",") if n.strip()]
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        ap.error(f"unknown benchmark(s): {', '.join(unknown)}")
    out_dir = args.out or os.environ.get("MORPHER_BENCH_DIR") or "."
    os.makedirs(out_dir, exist_ok=True)
    sha = _git_sha()
    for name in names:
        title, fn = BENCHES[name]
        print(f"# === {title} ===")
        rows = fn()
        path = os.path.join(out_dir, f"BENCH_{name}.json")
        with open(path, "w", encoding="utf-8") as f:
            json.dump({"bench": name, "schema": BENCH_SCHEMA,
                       "git_sha": sha, "rows": rows}, f, indent=1)
            f.write("\n")
        print(f"# wrote {path}")


if __name__ == "__main__":
    main()
