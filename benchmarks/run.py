"""Benchmark harness entry point: one function per paper table/figure.

  table1          -- the paper's Table I (II/MII/util/time/speedup, 6 kernels)
  mapper_sweep    -- II vs MII across cluster variants (the architecture-
                     exploration use-case of the ADL)
  kernel_micro    -- Pallas kernels: us/call in interpret mode (correctness
                     harness timing; real perf comes from the roofline)
  sim_throughput  -- JAX simulator cycles/s (the Verilator-replacement claim)
  toolchain_cache -- cold vs warm Toolchain.compile over the Table-I kernel
                     set (the content-addressed artifact cache)

Prints ``name,us_per_call,derived`` CSV rows per benchmark.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np


def bench_table1() -> None:
    from . import table1
    table1.main()


def bench_mapper_sweep() -> None:
    from repro.core.adl import cluster_4x4
    from repro.core.kernels_lib import build_gemm
    from repro.core.mapper import MapError, MapperOptions
    from repro.core.toolchain import Toolchain

    # use_cache=False: this benchmark measures real mapper search time
    tc = Toolchain(options=MapperOptions(ii_max=24, seeds=(0, 1, 2, 3),
                                         time_budget_s=60))
    for rf in (4, 8, 16):
        for unroll in (1, 2, 4):
            arch = cluster_4x4(regfile=rf)
            spec = build_gemm(TI=6, TK=8, TJ=6, unroll=unroll, arch=arch)
            t0 = time.time()
            try:
                ck = tc.compile(spec, use_cache=False)
                print(f"mapper_rf{rf}_u{unroll},"
                      f"{(time.time()-t0)*1e6:.0f},"
                      f"II={ck.II};MII={ck.mii};util={ck.utilization:.3f}")
            except MapError:
                print(f"mapper_rf{rf}_u{unroll},"
                      f"{(time.time()-t0)*1e6:.0f},unmapped")


def bench_kernel_micro() -> None:
    import jax.numpy as jnp
    from repro.kernels.gemm_os.ops import gemm_os
    from repro.kernels.decode_attn.ops import decode_attn

    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    gemm_os(a, b, interpret=True).block_until_ready()
    t0 = time.time()
    for _ in range(3):
        gemm_os(a, b, interpret=True).block_until_ready()
    print(f"gemm_os_256_interpret,{(time.time()-t0)/3*1e6:.0f},"
          f"flops={2*256**3}")

    q = jnp.asarray(rng.normal(size=(2, 8, 64)), jnp.float32)
    kv = jnp.asarray(rng.normal(size=(2, 2, 512, 64)), jnp.float32)
    lens = jnp.asarray([512, 300])
    decode_attn(q, kv, kv, lens, bs=128, interpret=True).block_until_ready()
    t0 = time.time()
    for _ in range(3):
        decode_attn(q, kv, kv, lens, bs=128,
                    interpret=True).block_until_ready()
    print(f"decode_attn_interpret,{(time.time()-t0)/3*1e6:.0f},kv=512")


def bench_sim_throughput() -> None:
    from repro.core.kernels_lib import build_gemm
    from repro.core.toolchain import Toolchain
    from repro.core.verify import generate_test_data

    spec = build_gemm(TI=6, TK=8, TJ=6, unroll=1)
    ck = Toolchain(cache_dir="").compile(spec)
    data = generate_test_data(spec)
    n_cycles = ck.cfg.n_cycles(spec.mapped_iters) * len(spec.invocations)
    ck.run(data.init_banks)
    t0 = time.time()
    ck.run(data.init_banks)
    dt = time.time() - t0
    print(f"simulator_gemm,{dt*1e6:.0f},cycles={n_cycles};"
          f"cycles_per_s={n_cycles/dt:.0f}")


def bench_toolchain_cache() -> None:
    """Cold vs warm compile of the Table-I kernel set through the content-
    addressed artifact cache (small dims, identical DFG structure)."""
    from repro.core.kernels_lib import table1_kernels
    from repro.core.mapper import MapperOptions
    from repro.core.toolchain import Toolchain

    # no per-kernel wall-clock budget: the cold pass measures full mapper
    # cost, and budgets misfire under CPU oversubscription anyway
    opts = MapperOptions(seeds=tuple(range(8)))
    cache = tempfile.mkdtemp(prefix="morpher-cache-bench-")
    try:
        specs = list(table1_kernels(small=True).values())
        t0 = time.time()
        Toolchain(options=opts, cache_dir=cache).compile_many(specs)
        cold = time.time() - t0
        # fresh Toolchain: no in-process memo, artifacts come off disk
        t0 = time.time()
        warm_cks = Toolchain(options=opts, cache_dir=cache).compile_many(
            list(table1_kernels(small=True).values()))
        warm = time.time() - t0
        assert all(ck.from_cache for ck in warm_cks)
        print(f"toolchain_cache,{cold*1e6:.0f},"
              f"warm_us={warm*1e6:.0f};kernels={len(specs)};"
              f"speedup={cold/warm:.1f}x")
    finally:
        shutil.rmtree(cache, ignore_errors=True)


def main() -> None:
    print("# === Table I (paper reproduction) ===")
    bench_table1()
    print("# === mapper sweep (ADL design-space exploration) ===")
    bench_mapper_sweep()
    print("# === Pallas kernel micro (interpret mode) ===")
    bench_kernel_micro()
    print("# === simulator throughput ===")
    bench_sim_throughput()
    print("# === toolchain artifact cache (cold vs warm) ===")
    bench_toolchain_cache()


if __name__ == "__main__":
    main()
