"""Benchmark harness entry point: one function per paper table/figure.

  table1        -- the paper's Table I (II/MII/util/time/speedup, 6 kernels)
  mapper_sweep  -- II vs MII across cluster variants (the architecture-
                   exploration use-case of the ADL)
  kernel_micro  -- Pallas kernels: us/call in interpret mode (correctness
                   harness timing; real perf comes from the roofline)
  sim_throughput-- JAX simulator cycles/s (the Verilator-replacement claim)

Prints ``name,us_per_call,derived`` CSV rows per benchmark.
"""
from __future__ import annotations

import time

import numpy as np


def bench_table1() -> None:
    from . import table1
    table1.main()


def bench_mapper_sweep() -> None:
    from repro.core.adl import cluster_4x4
    from repro.core.kernels_lib import build_gemm
    from repro.core.mapper import MapError, map_kernel

    for rf in (4, 8, 16):
        for unroll in (1, 2, 4):
            arch = cluster_4x4(regfile=rf)
            spec = build_gemm(TI=6, TK=8, TJ=6, unroll=unroll, arch=arch)
            t0 = time.time()
            try:
                m = map_kernel(spec.dfg, arch, spec.layout, ii_max=24,
                               seeds=range(4), time_budget_s=60)
                print(f"mapper_rf{rf}_u{unroll},"
                      f"{(time.time()-t0)*1e6:.0f},"
                      f"II={m.II};MII={m.mii};util={m.utilization:.3f}")
            except MapError:
                print(f"mapper_rf{rf}_u{unroll},"
                      f"{(time.time()-t0)*1e6:.0f},unmapped")


def bench_kernel_micro() -> None:
    import jax.numpy as jnp
    from repro.kernels.gemm_os.ops import gemm_os
    from repro.kernels.decode_attn.ops import decode_attn

    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    gemm_os(a, b, interpret=True).block_until_ready()
    t0 = time.time()
    for _ in range(3):
        gemm_os(a, b, interpret=True).block_until_ready()
    print(f"gemm_os_256_interpret,{(time.time()-t0)/3*1e6:.0f},"
          f"flops={2*256**3}")

    q = jnp.asarray(rng.normal(size=(2, 8, 64)), jnp.float32)
    kv = jnp.asarray(rng.normal(size=(2, 2, 512, 64)), jnp.float32)
    lens = jnp.asarray([512, 300])
    decode_attn(q, kv, kv, lens, bs=128, interpret=True).block_until_ready()
    t0 = time.time()
    for _ in range(3):
        decode_attn(q, kv, kv, lens, bs=128,
                    interpret=True).block_until_ready()
    print(f"decode_attn_interpret,{(time.time()-t0)/3*1e6:.0f},kv=512")


def bench_sim_throughput() -> None:
    from repro.core.config_gen import generate_config
    from repro.core.kernels_lib import build_gemm
    from repro.core.mapper import map_kernel
    from repro.core.simulator import simulate
    from repro.core.verify import generate_test_data

    spec = build_gemm(TI=6, TK=8, TJ=6, unroll=1)
    m = map_kernel(spec.dfg, spec.arch, spec.layout)
    cfg = generate_config(m, spec.layout)
    data = generate_test_data(spec)
    n_cycles = cfg.n_cycles(spec.mapped_iters) * len(spec.invocations)
    simulate(cfg, data.init_banks, spec.invocations, spec.mapped_iters)
    t0 = time.time()
    simulate(cfg, data.init_banks, spec.invocations, spec.mapped_iters)
    dt = time.time() - t0
    print(f"simulator_gemm,{dt*1e6:.0f},cycles={n_cycles};"
          f"cycles_per_s={n_cycles/dt:.0f}")


def main() -> None:
    print("# === Table I (paper reproduction) ===")
    bench_table1()
    print("# === mapper sweep (ADL design-space exploration) ===")
    bench_mapper_sweep()
    print("# === Pallas kernel micro (interpret mode) ===")
    bench_kernel_micro()
    print("# === simulator throughput ===")
    bench_sim_throughput()


if __name__ == "__main__":
    main()
