"""Table I reproduction: map all six kernels on the 4x4 cluster, verify
each mapping by cycle-accurate simulation (small dims, identical DFG
structure), and evaluate the paper's cost model on the full problem
(GEMM 64^3, CONV 64^3 x 3^2) at 100 MHz / 50 MB/s.

Output: CSV rows name,us_per_call,derived plus a side-by-side markdown
table vs the paper's numbers.
"""
from __future__ import annotations

import time
from typing import Dict, Optional

from repro.core.costmodel import (F_CLK_HZ, KernelCost, conv_traffic_bytes,
                                  gemm_traffic_bytes, kernel_cost)
from repro.core.kernels_lib import table1_kernels
from repro.core.mapper import MapError, MapperOptions
from repro.core.toolchain import Toolchain

PAPER = {  # Table I of the paper
    "GEMM":       dict(nodes=26, II=4, mii=4, util=40.63, compute=0.56,
                       transfer=2.13, total=2.69, speedup=1.0),
    "GEMM-U":     dict(nodes=58, II=6, mii=4, util=60.42, compute=0.25,
                       transfer=2.13, total=2.38, speedup=1.1),
    "GEMM-U-C":   dict(nodes=79, II=8, mii=8, util=61.72, compute=0.27,
                       transfer=0.49, total=0.76, speedup=3.5),
    "CONV":       dict(nodes=27, II=4, mii=4, util=42.19, compute=8.32,
                       transfer=306.38, total=314.70, speedup=1.0),
    "CONV-U-C-1": dict(nodes=100, II=12, mii=7, util=52.08, compute=1.53,
                       transfer=12.75, total=14.28, speedup=22.0),
    "CONV-U-C-2": dict(nodes=153, II=11, mii=10, util=86.93, compute=1.26,
                       transfer=11.19, total=12.45, speedup=25.2),
}

# off-chip traffic per kernel (full problem, output-stationary schedule)
TRAFFIC = {
    "GEMM": gemm_traffic_bytes(),
    "GEMM-U": gemm_traffic_bytes(),
    "GEMM-U-C": gemm_traffic_bytes(),
    "CONV": conv_traffic_bytes(),
    "CONV-U-C-1": conv_traffic_bytes(),
    "CONV-U-C-2": conv_traffic_bytes(),
}
PROBLEM_SCALE = {   # sequential tile steps per cluster for the full problem
    "GEMM": 4, "GEMM-U": 4, "GEMM-U-C": 4,        # K/TK = 64/16
    "CONV": 16, "CONV-U-C-1": 16, "CONV-U-C-2": 16,  # Co / clusters
}
HANDSHAKE_US = 20.0   # per-invocation host handshake (calibrated: CONV base)


def run(verify: bool = True, options: Optional[MapperOptions] = None
        ) -> Dict[str, Optional[KernelCost]]:
    options = options or MapperOptions(seeds=tuple(range(8)),
                                       time_budget_s=120.0)
    toolchain = Toolchain(options=options)
    small = table1_kernels(small=True)
    full = table1_kernels(small=False)
    results: Dict[str, Optional[KernelCost]] = {}
    base_total = {}
    for name, spec in full.items():
        try:
            ck = toolchain.compile(spec)
        except MapError as e:
            print(f"# {name}: MAPPING FAILED ({e})")
            results[name] = None
            continue
        if verify:
            # verify with the structurally-identical small-dims variant
            toolchain.compile(small[name]).verify()
        cost = kernel_cost(
            spec, ck.mapping, problem_scale=PROBLEM_SCALE[name],
            array_bytes_moved=TRAFFIC[name], handshake_us=HANDSHAKE_US)
        base = "GEMM" if name.startswith("GEMM") else "CONV"
        if name == base:
            base_total[base] = cost.total_ms
        if base in base_total:
            cost.speedup = base_total[base] / cost.total_ms
        results[name] = cost
    return results


def print_table(results: Dict[str, Optional[KernelCost]]) -> None:
    hdr = (f"{'Kernel':<12} {'Nodes':>5} {'II(MII)':>8} {'Util':>8} "
           f"{'Compute':>9} {'Transfer':>9} {'Total':>9} {'Speedup':>8}"
           f"   | paper: II(MII) Util Total Speedup")
    print(hdr)
    print("-" * len(hdr))
    for name, c in results.items():
        p = PAPER[name]
        if c is None:
            print(f"{name:<12} {'—':>5} {'unmapped':>8}"
                  f"{'':>36}   | {p['II']}({p['mii']}) "
                  f"{p['util']:.1f}% {p['total']:.2f}ms {p['speedup']}x")
            continue
        print(f"{name:<12} {c.nodes:>5} {c.II:>4}({c.mii:>2}) "
              f"{c.utilization*100:7.2f}% {c.compute_ms:8.2f}m "
              f"{c.transfer_ms:8.2f}m {c.total_ms:8.2f}m "
              f"{c.speedup:7.2f}x   | {p['II']}({p['mii']}) "
              f"{p['util']:.1f}% {p['total']:.2f}ms {p['speedup']}x")


def main() -> list:
    """Run + print the table; returns machine-readable benchmark rows
    (same shape as the other ``benchmarks.run`` benchmarks)."""
    t0 = time.time()
    results = run()
    print_table(results)
    rows = []
    for name, c in results.items():
        if c is None:
            rows.append({"name": name, "us": None,
                         "derived": {"unmapped": 1}})
            continue
        us = c.total_ms * 1e3
        rows.append({"name": name, "us": round(us, 1),
                     "derived": {"II": c.II, "MII": c.mii,
                                 "util": round(c.utilization, 4),
                                 "speedup": round(c.speedup, 2)}})
        print(f"{name},{us:.1f},II={c.II};MII={c.mii};"
              f"util={c.utilization:.3f};speedup={c.speedup:.2f}")
    print(f"# table1 done in {time.time() - t0:.0f}s")
    return rows


if __name__ == "__main__":
    main()
