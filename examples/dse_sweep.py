"""Design-space sweep CLI: explore CGRA architecture variants with the
full compile/verify flow and report the Pareto frontier.

For every variant of the chosen space (grid size, mesh/torus, register-
file size, bank count/size, heterogeneous ALU-lite interiors) the sweep
compiles the ten-kernel library (six Table-I kernels at verification
dims + four DSL kernels) through the unified Toolchain, verifies each
mapping with the batched IV-C engine, scores it with the cost model
against a deterministic area proxy, and writes:

  <out>/dse_frontier.json      full deterministic sweep report
  <out>/BENCH_dse_sweep.json   per-variant benchmark rows (modeled
                               latency; feeds --check-regression)

Per-(variant, kernel) compiles are memoized through the content-
addressed mapping cache, and finished variants checkpoint to
``<out>/dse_checkpoint.json`` — re-running a finished sweep is all cache
hits, and an interrupted sweep resumes where it stopped.  Two runs of
the same sweep produce byte-identical reports.

``--search nsga2|halving`` switches from exhaustive sweep to seeded
multi-objective search (repro.dse.search): the space becomes the
candidate universe (use ``--space wide``), evaluation batches whole
populations per XLA launch, and the artifacts gain the search
trajectory.  Search runs are byte-deterministic for a given
``--search-seed`` — cold, warm and checkpoint-resumed runs emit
identical ``dse_frontier.json`` bytes (CI's search-smoke job enforces
this with ``cmp``).

Run:  PYTHONPATH=src python examples/dse_sweep.py --space small
      add --space tiny for the 4-variant CI smoke sweep
      add --fresh to ignore an existing checkpoint
      add --search nsga2 --generations 4 --population 12 to search
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

from repro.core import MapperOptions, Toolchain
from repro.dse import (SEARCH_ALGOS, SPACE_NAMES, SearchConfig, frontier,
                       frontier_table, get_space, run_search, run_sweep,
                       write_artifacts)


def main():
    ap = argparse.ArgumentParser(
        description="CGRA architecture design-space explorer")
    ap.add_argument("--space", default="small", metavar="NAME",
                    help=f"variant set to sweep (one of "
                         f"{', '.join(SPACE_NAMES)}; default: small)")
    ap.add_argument("--search", default=None, choices=SEARCH_ALGOS,
                    metavar="ALGO",
                    help="search the space instead of sweeping it "
                         f"exhaustively (one of {', '.join(SEARCH_ALGOS)})")
    ap.add_argument("--generations", type=int, default=4, metavar="N",
                    help="search rounds: NSGA-II generations / halving "
                         "rungs (default: 4)")
    ap.add_argument("--population", type=int, default=12, metavar="N",
                    help="NSGA-II population per generation / halving "
                         "finalists (default: 12)")
    ap.add_argument("--search-seed", type=int, default=0, metavar="S",
                    help="search RNG seed; the whole trajectory is a pure "
                         "function of it (default: 0)")
    ap.add_argument("--mutation", type=float, default=0.25, metavar="P",
                    help="per-knob mutation probability (default: 0.25)")
    ap.add_argument("--out", default=".", metavar="DIR",
                    help="directory for report artifacts (default: cwd)")
    ap.add_argument("--seeds", type=int, default=1, metavar="N",
                    help="verification seeds per kernel (default: 1)")
    ap.add_argument("--jobs", type=int, default=None,
                    help="compile fan-out width (default: auto)")
    ap.add_argument("--ii-max", type=int, default=20,
                    help="mapper II escalation cap (default: 20)")
    ap.add_argument("--checkpoint", default=None, metavar="PATH",
                    help="checkpoint file (default: <out>/"
                         "dse_checkpoint.json; '' disables)")
    ap.add_argument("--fresh", action="store_true",
                    help="ignore any existing checkpoint")
    ap.add_argument("--no-verify", action="store_true",
                    help="skip simulation-based verification (score only)")
    ap.add_argument("--workers", type=int, default=None, metavar="N",
                    help="shard compile work units across N supervised "
                         "worker groups (repro.dist.fleet: deadlines, "
                         "retry, killed-worker recovery, work stealing)")
    ap.add_argument("--inject-faults", action="store_true",
                    help="deterministically kill one compile worker and "
                         "delay one straggler past its deadline "
                         "(repro.dist.faults); the sweep must still emit "
                         "byte-identical artifacts")
    ap.add_argument("--task-timeout-s", type=float, default=None,
                    metavar="S",
                    help="per-work-unit deadline (default: "
                         "$MORPHER_TASK_TIMEOUT_S or 300; --inject-faults "
                         "defaults it to 15 so the straggler is visible)")
    ap.add_argument("--cache-dir", default=None,
                    help="mapping cache dir (default: $MORPHER_CACHE_DIR "
                         "or ~/.cache/morpher-toolchain)")
    args = ap.parse_args()
    if args.seeds < 1:
        ap.error("--seeds must be >= 1 (use --no-verify to skip "
                 "simulation-based verification explicitly)")
    if args.search and (args.generations < 1 or args.population < 2):
        ap.error("--search needs --generations >= 1 and --population >= 2")

    try:
        points = get_space(args.space)
    except ValueError as e:
        ap.error(str(e))  # unknown --space: list the valid SPACE_NAMES
    checkpoint = args.checkpoint
    if checkpoint is None:
        checkpoint = f"{args.out}/dse_checkpoint.json"
    elif checkpoint == "":
        checkpoint = None
    if args.fresh and checkpoint:
        import os
        if os.path.exists(checkpoint):
            os.unlink(checkpoint)

    fleet_cfg = None
    if args.workers or args.inject_faults:
        from repro.dist.faults import FaultPlan
        from repro.dist.fleet import FleetConfig
        timeout_s = args.task_timeout_s
        faults = None
        if args.inject_faults:
            # one killed worker + one straggler sleeping past its
            # deadline, fire-once each — the canonical disturbance the
            # dist-smoke CI job byte-compares against the undisturbed
            # baseline
            timeout_s = timeout_s if timeout_s is not None else 15.0
            faults = FaultPlan(kill_units=(1,),
                               delay_units=((2, 2.5 * timeout_s),)).armed()
            print(f"# fault injection: kill unit 1, delay unit 2 by "
                  f"{2.5 * timeout_s:g}s (deadline {timeout_s:g}s)")
        fleet_cfg = FleetConfig(groups=args.workers or 2,
                                timeout_s=timeout_s, faults=faults)

    tc = Toolchain(options=MapperOptions(ii_max=args.ii_max),
                   cache_dir=args.cache_dir)
    seeds = list(range(args.seeds))
    search_extra = None
    bench_name = "dse_sweep"
    t0 = time.time()
    if args.search:
        cfg = SearchConfig(algo=args.search, seed=args.search_seed,
                           generations=args.generations,
                           population=args.population,
                           mutation=args.mutation)
        print(f"# searching {len(points)}-point universe with "
              f"{cfg.algo} (seed={cfg.seed}, generations="
              f"{cfg.generations}, population={cfg.population}"
              + (f", workers={fleet_cfg.groups}" if fleet_cfg else "") + ")")
        sr = run_search(points, cfg, seeds=seeds, toolchain=tc,
                        checkpoint=checkpoint, jobs=args.jobs,
                        verify=not args.no_verify, fleet=fleet_cfg,
                        log=print)
        results = sr.evaluated
        bench_name = "dse_search"
        search_extra = {"search": {"config": cfg.to_json_dict(),
                                   "population": sr.population,
                                   "history": sr.history,
                                   "n_requested": sr.n_requested,
                                   "n_partial": sr.n_partial}}
    else:
        print(f"# sweeping {len(points)} variants x ten kernels "
              f"(space={args.space}, seeds={seeds}"
              + (f", workers={fleet_cfg.groups}" if fleet_cfg else "") + ")")
        results = run_sweep(points, seeds=seeds, toolchain=tc,
                            checkpoint=checkpoint, jobs=args.jobs,
                            verify=not args.no_verify, fleet=fleet_cfg,
                            log=print)
    dt = time.time() - t0

    print()
    print(frontier_table(results))
    front = frontier(results)
    ok = sum(1 for r in results if r.ok)
    verb = "searched" if args.search else "swept"
    print(f"\n# {ok}/{len(results)} variants fully verified, "
          f"{len(front)} on the Pareto frontier, {verb} in {dt:.1f}s "
          f"(warm re-runs are cache hits)")
    paths = write_artifacts(results, args.out, space=args.space,
                            seeds=seeds, verified=not args.no_verify,
                            bench_name=bench_name, extra=search_extra)
    for name, path in paths.items():
        print(f"# wrote {path}")


if __name__ == "__main__":
    main()
