"""Edge-deployment analyzer: apply the paper's CGRA compilation flow to
the GEMM micro-kernels of any assigned LM architecture.

For each projection/FFN GEMM site of the model, tile it onto the Morpher
4x4 cluster (output-stationary, paper section IV-A), run the real modulo-
scheduling mapper, and report II / MII / utilization / estimated tile
latency — Table-I methodology applied to the model zoo.

Run:  PYTHONPATH=src python examples/edge_deploy.py --arch llama3.2-1b
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs.registry import ARCH_IDS
from repro.core.offload import analyze_arch_gemms, model_gemm_sites
from repro.configs.registry import get_config


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=ARCH_IDS)
    ap.add_argument("--tokens", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    print(f"arch: {args.arch} ({cfg.family}); "
          f"per-layer GEMM sites at {args.tokens} tokens:")
    for s in model_gemm_sites(cfg, args.tokens):
        print(f"  {s.name:<10} {s.M}x{s.K}x{s.N}  x{s.count_per_layer}")

    print("\nCGRA mapping of the shared on-chip tile "
          "(16x8x16, output-stationary, unroll 4):")
    reports = analyze_arch_gemms(args.arch, tokens=args.tokens)
    print(f"{'site':<10} {'nodes':>5} {'II':>3} {'MII':>4} {'util':>7} "
          f"{'tile_us':>8}")
    for r in reports:
        print(f"{r.site:<10} {r.nodes:>5} {r.II:>3} {r.mii:>4} "
              f"{r.utilization*100:6.1f}% {r.est_tile_us:8.1f}")


if __name__ == "__main__":
    main()
