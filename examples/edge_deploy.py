"""Edge-deployment analyzer: apply the paper's CGRA compilation flow to
the GEMM micro-kernels of any assigned LM architecture.

For each projection/FFN GEMM site of the model, tile it onto the Morpher
4x4 cluster (output-stationary, paper section IV-A), compile the tile
through the unified Toolchain (real modulo-scheduling mapper + config
generation), and report II / MII / utilization / estimated tile latency —
Table-I methodology applied to the model zoo.

All sites share one compiled tile artifact: the Toolchain's content-
addressed cache makes every compile after the first — including sweeps
over the whole zoo, and re-runs in later sessions — a cache hit.

The target CGRA defaults to the paper's 4x4 cluster; pass a user-defined
architecture as ``--arch-file <adl.json>`` (the ADL JSON produced by
``CGRAArch.to_json`` — see ``examples/cluster_4x4.adl.json``) to retarget
the whole analysis, the paper's architecture-adaptive claim from the
command line.

Run:  PYTHONPATH=src python examples/edge_deploy.py --arch llama3.2-1b
      add --all to sweep the whole model zoo off one warm cache
      add --arch-file examples/cluster_4x4.adl.json for a custom target
      add --emit-streams DIR to export every distinct compiled tile as
      a per-PE instruction-stream artifact family (repro.isa)
"""
import argparse
import os
import sys
import time

sys.path.insert(0, "src")

from repro.configs.registry import ARCH_IDS, get_config
from repro.core import CGRAArch, MapperOptions, Toolchain
from repro.core.mapper import MapError
from repro.core.offload import (analyze_gemm_tile, analyze_arch_gemms,
                                choose_gemm_tile, model_gemm_sites)


def load_arch_file(path: str) -> CGRAArch:
    """Load and validate a user-defined ADL architecture from JSON."""
    with open(path, "r", encoding="utf-8") as f:
        arch = CGRAArch.from_json(f.read())
    arch.validate()
    return arch


def report_arch(arch_id: str, tokens: int, toolchain: Toolchain) -> None:
    cfg = get_config(arch_id)
    print(f"arch: {arch_id} ({cfg.family}); "
          f"GEMM sites at {tokens} tokens:")
    for s in model_gemm_sites(cfg, tokens):
        print(f"  {s.name:<14} {s.M}x{s.K}x{s.N}  x{s.count_per_layer} "
              f"in {s.n_layers(cfg)} layers")

    print("\nCGRA mapping (per-site bank-capacity-feasible tiles, "
          "output-stationary):")
    t0 = time.time()
    reports = analyze_arch_gemms(arch_id, tokens=tokens,
                                 toolchain=toolchain)
    dt = time.time() - t0
    print(f"{'site':<14} {'tile':>8} {'II':>3} {'MII':>4} {'util':>7} "
          f"{'tile_us':>8} {'tiles':>7} {'xinst':>6} {'site_ms':>10}")
    for r in reports:
        tile = "x".join(str(t) for t in r.tile)
        print(f"{r.site:<14} {tile:>8} {r.II:>3} {r.mii:>4} "
              f"{r.utilization*100:6.1f}% {r.est_tile_us:8.1f} "
              f"{r.tiles:>7} {r.instances:>6} {r.est_site_ms:10.3f}")
    print(f"# analyzed in {dt*1e3:.0f} ms (compiles are cache hits after "
          f"the first)")


def emit_streams(arch_id: str, tokens: int, out_dir: str,
                 toolchain: Toolchain) -> None:
    """Export every distinct compiled tile of the model's GEMM sites as a
    deployable instruction-stream family (``repro.isa``) — the artifacts
    a CGRA control memory actually consumes.  Tiles shared across sites
    (the common case) export once; compiles are warm-cache hits after the
    analysis pass."""
    cfg = get_config(arch_id)
    arch = toolchain.arch or None
    from repro.core.adl import cluster_4x4
    arch = arch or cluster_4x4()
    done = set()
    for s in model_gemm_sites(cfg, tokens):
        tile = choose_gemm_tile(arch, s)
        if tile in done:
            continue
        done.add(tile)
        try:
            ck = analyze_gemm_tile(*tile, arch=arch, toolchain=toolchain)
        except MapError:
            continue
        dest = os.path.join(out_dir, arch_id,
                            "gemm_" + "x".join(str(t) for t in tile))
        paths = toolchain.export_streams(ck, dest)
        print(f"  emitted {ck.name} (II={ck.II}) -> {dest} "
              f"({len(paths)} files)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=ARCH_IDS)
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--all", action="store_true",
                    help="sweep every model in the zoo (one shared cache)")
    ap.add_argument("--arch-file", default=None, metavar="ADL_JSON",
                    help="user-defined CGRA architecture (ADL JSON, "
                         "as written by CGRAArch.to_json)")
    ap.add_argument("--emit-streams", default=None, metavar="DIR",
                    help="export each distinct compiled tile as per-PE "
                         "instruction streams (instructions.csv / "
                         "kernel.asm / stream_manifest.json) under "
                         "DIR/<model>/<tile>/")
    args = ap.parse_args()

    cgra = load_arch_file(args.arch_file) if args.arch_file else None
    if cgra is not None:
        print(f"target CGRA (from {args.arch_file}): {cgra.name}, "
              f"{cgra.rows}x{cgra.cols} PEs, {len(cgra.banks)} banks, "
              f"{cgra.datapath_bits}-bit datapath")

    # one Toolchain for the whole sweep: the tile compile happens once
    toolchain = Toolchain(arch=cgra, options=MapperOptions())
    for arch_id in (ARCH_IDS if args.all else [args.arch]):
        report_arch(arch_id, args.tokens, toolchain)
        if args.emit_streams:
            print(f"\ninstruction streams ({args.emit_streams}):")
            emit_streams(arch_id, args.tokens, args.emit_streams, toolchain)
        if args.all:
            print()


if __name__ == "__main__":
    main()
