"""Quickstart: the complete Morpher flow through the unified compile API.

The paper's pipeline (Fig. 3) — ADL architecture, annotated-loop DFG,
modulo-scheduling mapper, configuration generation, cycle-accurate JAX
simulation, functional verification — is exposed as one staged object:

    Toolchain(arch, options).compile(spec) -> CompiledKernel

`CompiledKernel` is the serializable compiled artifact: it bundles the
DFG, the data layout, the mapping and the generated configuration, and
carries `run(init_banks)` / `verify(seed)` / `to_json()` methods.  Compiles
are memoized through a content-addressed on-disk cache (keyed by DFG +
arch ADL JSON + MapperOptions), so re-compiling the same kernel — in this
process, another process, or a later session — returns in milliseconds
without re-running placement and routing.  Cache location:
$MORPHER_CACHE_DIR (default ~/.cache/morpher-toolchain; set it to "" to
disable).

Run:  PYTHONPATH=src python examples/quickstart.py
      (or `pip install -e .` once and drop the PYTHONPATH)
"""
import sys
import time

sys.path.insert(0, "src")

from repro.core import (CompiledKernel, MapperOptions, Toolchain,
                        build_gemm, cluster_4x4)
from repro.core.verify import generate_test_data


def main():
    # 1. architecture (ADL): 4x4 PEs, two 8 kB banks, 16-bit datapath
    arch = cluster_4x4()
    print(f"target: {arch.name}, {arch.rows}x{arch.cols} PEs, "
          f"{len(arch.banks)} banks, {arch.datapath_bits}-bit datapath")

    # 2. kernel: O[i][j] += W[i][k] * I[k][j], innermost k-loop mapped
    spec = build_gemm(TI=6, TK=8, TJ=6, unroll=1, arch=arch)
    print(f"kernel: {spec.name}, DFG nodes={spec.dfg.n_nodes} "
          f"(mem={spec.dfg.n_mem_nodes})")

    # 3. compile: map (II escalation from MII) + configuration generation,
    #    memoized through the content-addressed artifact cache
    tc = Toolchain(arch, MapperOptions())
    t0 = time.time()
    ck = tc.compile(spec)
    print(f"compiled in {(time.time()-t0)*1e3:.0f} ms "
          f"({'cache hit' if ck.from_cache else 'cold'}): II={ck.II} "
          f"(MII={ck.mii}, {ck.mapping.mii_parts}), "
          f"utilization={ck.utilization:.1%}, pipeline depth={ck.depth}")
    print(f"artifact key: {ck.cache_key[:16]}…  "
          f"config: {ck.cfg.II} slots x {ck.cfg.P} PEs")

    # 4. test data -> simulate -> verify (paper section IV-C, one call)
    ck.verify()
    print("verification: post-simulation memory == golden model: True")

    # ... run() alone for custom inputs:
    data = generate_test_data(spec)
    final = ck.run(data.init_banks)
    assert all((final[k] == data.expected_banks[k]).all() for k in final)

    # 5. the artifact round-trips through JSON and still verifies
    #    bit-exactly — no Python closures needed on the consuming side
    art = ck.to_json()
    ck2 = CompiledKernel.from_json(art)
    ck2.verify()
    print(f"artifact: {len(art)} bytes JSON; reloaded copy verifies "
          f"bit-exactly")

    # 6. a second compile of the same spec is a cache hit
    t0 = time.time()
    again = Toolchain(arch).compile(build_gemm(TI=6, TK=8, TJ=6, unroll=1,
                                               arch=arch))
    print(f"recompile: {(time.time()-t0)*1e3:.0f} ms, "
          f"from_cache={again.from_cache}")
    print("quickstart OK")


if __name__ == "__main__":
    main()
