"""Quickstart: the complete Morpher flow through the unified compile API,
with the kernel authored in the traced Pallas-style DSL.

The paper's pipeline (Fig. 3) — ADL architecture, DFG generation, modulo-
scheduling mapper, configuration generation, cycle-accurate JAX simulation,
functional verification — is exposed as one staged object:

    Toolchain(arch, options).compile(spec) -> CompiledKernel

Kernels are no longer hand-wired DFGs: ``repro.frontend`` traces a
restricted-Python loop body (array-ref loads/stores, traced arithmetic,
counter primitives) into the DFG + data layout + invocation schedule the
toolchain consumes.  `CompiledKernel` is the serializable compiled
artifact (DFG, layout, mapping, configuration) with `run(init_banks)` /
`verify(seed)` / `to_json()`.  Compiles are memoized through a
content-addressed on-disk cache keyed by the *canonical* DFG form + arch
ADL JSON + MapperOptions ($MORPHER_CACHE_DIR, default
~/.cache/morpher-toolchain; "" disables).

Run:  PYTHONPATH=src python examples/quickstart.py
      (or `pip install -e .` once and drop the PYTHONPATH)
"""
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core import (CompiledKernel, KernelSpec, MapperOptions, Toolchain,
                        assign_layout, build_gemm, cluster_4x4)
from repro.core.layout import ArrayDecl
from repro.core.verify import generate_test_data
from repro.frontend import KernelContext


def main():
    # 1. architecture (ADL): 4x4 PEs, two 8 kB banks, 16-bit datapath
    arch = cluster_4x4()
    print(f"target: {arch.name}, {arch.rows}x{arch.cols} PEs, "
          f"{len(arch.banks)} banks, {arch.datapath_bits}-bit datapath")

    # 2. write a kernel in the DSL: Y[n] = 3 * X[n] over one mapped loop.
    #    The tracer lowers the Python body to the DFG IR; layout declares
    #    where each array lives in the banked memories.
    N = 32
    layout = assign_layout(arch, [ArrayDecl("Y", N, bank_pref=0),
                                  ArrayDecl("X", N, bank_pref=1)])
    ctx = KernelContext("triple", layout)
    X, Y = ctx.arrays("X", "Y")
    n = ctx.counter(stop=N - 1, name="n")     # the mapped loop variable
    Y[n] = X[n] * 3
    dfg = ctx.build()
    print(f"DSL kernel 'triple': {dfg.n_nodes} DFG nodes "
          f"(mem={dfg.n_mem_nodes}) traced from 3 lines of Python")

    px, py = layout.placements["X"], layout.placements["Y"]

    def init_banks(rng):
        banks = {f"bank{bid}": np.zeros(w, dtype=np.int64)
                 for bid, w in layout.bank_image_size().items()}
        banks[px.bank_array][px.base:px.base + N] = rng.integers(-99, 99, N)
        return banks

    def golden(banks):
        out = {k: v.copy() for k, v in banks.items()}
        out[py.bank_array][py.base:py.base + N] = \
            3 * banks[px.bank_array][px.base:px.base + N]
        return out

    spec = KernelSpec(name=dfg.name, dfg=dfg, arch=arch, layout=layout,
                      mapped_iters=N, invocations=[{}],
                      golden=golden, init_banks=init_banks)

    # 3. compile: map (II escalation from MII) + configuration generation,
    #    memoized through the content-addressed artifact cache
    tc = Toolchain(arch, MapperOptions())
    t0 = time.time()
    ck = tc.compile(spec)
    print(f"compiled in {(time.time()-t0)*1e3:.0f} ms "
          f"({'cache hit' if ck.from_cache else 'cold'}): II={ck.II} "
          f"(MII={ck.mii}), utilization={ck.utilization:.1%}")

    # 4. test data -> simulate -> verify (paper section IV-C, one call)
    ck.verify()
    print("verification: post-simulation memory == golden model: True")

    # 5. the library kernels go through the same front end: base GEMM
    #    (Listing 1) is itself a traced DSL kernel now
    spec_g = build_gemm(TI=6, TK=8, TJ=6, unroll=1, arch=arch)
    ck_g = tc.compile(spec_g)
    print(f"library kernel {spec_g.name}: nodes={spec_g.dfg.n_nodes}, "
          f"II={ck_g.II} (MII={ck_g.mii}, {ck_g.mapping.mii_parts}), "
          f"depth={ck_g.depth}")
    ck_g.verify()

    # ... run() alone for custom inputs:
    data = generate_test_data(spec_g)
    final = ck_g.run(data.init_banks)
    assert all((final[k] == data.expected_banks[k]).all() for k in final)

    # 6. batched verification: all seeds' test vectors up front, one
    #    vmapped-style simulator launch through the process-wide
    #    executable cache — bit-identical to per-seed verify()
    t0 = time.time()
    ck_g.verify_batch(seeds=range(8))
    print(f"batched verify: 8 seeds in one launch "
          f"({(time.time()-t0)*1e3:.0f} ms), bit-identical to sequential")

    # 7. the artifact round-trips through JSON and still verifies
    #    bit-exactly — no Python closures needed on the consuming side
    art = ck_g.to_json()
    ck2 = CompiledKernel.from_json(art)
    ck2.verify()
    print(f"artifact: {len(art)} bytes JSON; reloaded copy verifies "
          f"bit-exactly")

    # 8. a second compile of the same traced kernel is a cache hit
    t0 = time.time()
    again = Toolchain(arch).compile(build_gemm(TI=6, TK=8, TJ=6, unroll=1,
                                               arch=arch))
    print(f"recompile: {(time.time()-t0)*1e3:.0f} ms, "
          f"from_cache={again.from_cache}")
    print("quickstart OK")


if __name__ == "__main__":
    main()
