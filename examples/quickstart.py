"""Quickstart: the complete Morpher flow on one GEMM micro-kernel.

  1. describe the target CGRA with the ADL (paper's 4x4 cluster),
  2. build the annotated-loop DFG (Listing 1),
  3. map it (modulo scheduling on the MRRG),
  4. generate the cycle-by-cycle configuration,
  5. generate test data, simulate cycle-accurately in JAX, verify memory.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

from repro.core.adl import cluster_4x4
from repro.core.config_gen import generate_config
from repro.core.kernels_lib import build_gemm
from repro.core.mapper import map_kernel
from repro.core.simulator import simulate
from repro.core.verify import generate_test_data, verify_mapping


def main():
    # 1. architecture (ADL): 4x4 PEs, two 8 kB banks, 16-bit datapath
    arch = cluster_4x4()
    print(f"target: {arch.name}, {arch.rows}x{arch.cols} PEs, "
          f"{len(arch.banks)} banks, {arch.datapath_bits}-bit datapath")

    # 2. kernel: O[i][j] += W[i][k] * I[k][j], innermost k-loop mapped
    spec = build_gemm(TI=6, TK=8, TJ=6, unroll=1, arch=arch)
    print(f"kernel: {spec.name}, DFG nodes={spec.dfg.n_nodes} "
          f"(mem={spec.dfg.n_mem_nodes})")

    # 3. map (II escalation from MII)
    mapping = map_kernel(spec.dfg, arch, spec.layout)
    print(f"mapped: II={mapping.II} (MII={mapping.mii}, "
          f"{mapping.mii_parts}), utilization={mapping.utilization:.1%}, "
          f"pipeline depth={mapping.depth}")

    # 4. configuration bitstream
    cfg = generate_config(mapping, spec.layout)
    print(f"config: {cfg.II} slots x {cfg.P} PEs, "
          f"{len(cfg.to_json())} bytes serialized")

    # 5. test data -> simulate -> verify (paper section IV-C)
    data = generate_test_data(spec)
    final = simulate(cfg, data.init_banks, spec.invocations,
                     spec.mapped_iters)
    ok = all((final[k] == data.expected_banks[k]).all()
             for k in final)
    print(f"verification: post-simulation memory == golden model: {ok}")
    assert ok
    # or in one call:
    verify_mapping(spec, mapping=mapping, cfg=cfg)
    print("quickstart OK")


if __name__ == "__main__":
    main()
