"""Batched serving example: continuous-batching decode with the Engine.

Loads a small llama-family model, admits a few requests, and decodes them
token-by-token in one shared batch (KV caches per slot).

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models.zoo import build_model
from repro.serve.engine import Engine, Request


def main():
    cfg = dataclasses.replace(
        get_config("llama3.2-1b"), n_layers=4, d_model=256, n_heads=8,
        n_kv_heads=4, head_dim=32, d_ff=512, vocab=1024,
        dtype=jnp.float32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    eng = Engine(model, params, batch=4, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=(8,)),
                    max_new=8) for i in range(3)]
    for r in reqs:
        assert eng.admit(r)
        print(f"admitted request {r.rid} (prompt len {len(r.prompt)})")

    step = 0
    while any(not r.done for r in reqs):
        toks = eng.step()
        step += 1
        print(f"engine step {step}: {toks}")
    for r in reqs:
        print(f"request {r.rid}: generated {r.out}")
    print("serve_decode OK")


if __name__ == "__main__":
    main()
