"""CGRA-backed serving example: continuous-batching decode with the
Engine, offload plans and the synthetic traffic harness.

Default run admits a few requests and decodes them token-by-token in one
shared batch (KV caches per slot).  With ``--cgra`` the model's GEMM
sites are compiled into a :class:`ServePlan` (every site tiled onto the
target CGRA, one site spot-checked bit-exactly against the
cycle-accurate simulator) and the engine's clock runs on plan-derived
per-step latency.  With ``--traffic`` a seeded Poisson episode drives the
engine — admission under slot pressure with queueing — and reports
tokens/s, per-request latency percentiles and slot occupancy; ``--out``
writes the byte-deterministic ``BENCH_serve_decode.json`` artifact.

Run:  PYTHONPATH=src python examples/serve_decode.py
      PYTHONPATH=src python examples/serve_decode.py --cgra --traffic --seed 0
"""
import argparse
import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config, serve_smoke_config
from repro.core import CGRAArch, MapperOptions, Toolchain
from repro.models.zoo import build_model
from repro.serve.engine import Engine, Request
from repro.serve.plan import CGRAExecutionModel, ServePlan, build_serve_plan
from repro.serve.traffic import (TrafficConfig, report_bench_rows,
                                 report_json, run_traffic)


def demo_cfg(arch_id: str, smoke: bool):
    if smoke:
        return serve_smoke_config(arch_id)
    return dataclasses.replace(
        get_config(arch_id), n_layers=4, d_model=256, n_heads=8,
        n_kv_heads=4, head_dim=32, d_ff=512, vocab=1024,
        dtype=jnp.float32)


def load_arch_file(path: str) -> CGRAArch:
    with open(path, "r", encoding="utf-8") as f:
        arch = CGRAArch.from_json(f.read())
    arch.validate()
    return arch


def plain_demo(eng: Engine, vocab: int, seed: int) -> None:
    rng = np.random.default_rng(seed)
    reqs = [Request(rid=i, prompt=rng.integers(0, vocab, size=(8,)),
                    max_new=8) for i in range(3)]
    for r in reqs:
        assert eng.admit(r)
        print(f"admitted request {r.rid} (prompt len {len(r.prompt)})")
    step = 0
    while any(not r.done for r in reqs):
        toks = eng.step()
        step += 1
        print(f"engine step {step}: {toks}")
    for r in reqs:
        print(f"request {r.rid}: generated {r.out}")
    if eng.exec_model is not None:
        print(f"modeled CGRA time: {eng.clock_s * 1e3:.3f} ms")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=ARCH_IDS)
    ap.add_argument("--cgra", action="store_true",
                    help="compile a ServePlan and run the engine clock on "
                         "plan-derived CGRA latency (spot-checks one site "
                         "against the cycle-accurate simulator)")
    ap.add_argument("--traffic", action="store_true",
                    help="drive the engine with a seeded Poisson episode")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rate", type=float, default=100.0,
                    help="traffic arrival rate, requests / modeled second")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--smoke", action="store_true",
                    help="shrunken reduced config (CI serve-smoke)")
    ap.add_argument("--arch-file", default=None, metavar="ADL_JSON",
                    help="user-defined CGRA architecture (ADL JSON)")
    ap.add_argument("--out", default=None,
                    help="write BENCH_serve_decode.json + serve_plan.json "
                         "to this directory")
    args = ap.parse_args()

    cfg = demo_cfg(args.arch, args.smoke)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    exec_model = None
    plan = None
    if args.cgra:
        cgra = load_arch_file(args.arch_file) if args.arch_file else None
        tc = Toolchain(arch=cgra, options=MapperOptions())
        t0 = time.time()
        plan = build_serve_plan(cfg, toolchain=tc, spot_check=False)
        print(f"# plan compiled in {time.time() - t0:.1f}s "
              f"(content-addressed cache makes re-runs warm)")
        print(plan.summary())
        checked = plan.spot_check(seeds=(0, 1))
        print(f"# spot-checked bit-exact vs cycle-accurate simulator: "
              f"{', '.join(checked)}")
        exec_model = CGRAExecutionModel(plan)

    eng = Engine(model, params, batch=args.batch, max_len=args.max_len,
                 exec_model=exec_model)
    if not args.traffic:
        plain_demo(eng, cfg.vocab, args.seed)
        print("serve_decode OK")
        return

    if exec_model is None:
        from repro.serve.traffic import FixedLatencyModel
        eng.exec_model = FixedLatencyModel()
        print("# no --cgra: traffic clock uses the fixed-latency baseline")
    traffic = TrafficConfig(seed=args.seed, n_requests=args.requests,
                            arrival_rate=args.rate)
    report = run_traffic(eng, traffic, cfg.vocab)
    print(report_json(report), end="")
    if args.out:
        os.makedirs(args.out, exist_ok=True)
        rows = report_bench_rows(report, name=f"serve_decode_{cfg.name}")
        path = os.path.join(args.out, "BENCH_serve_decode.json")
        with open(path, "w", encoding="utf-8") as f:
            json.dump({"bench": "serve_decode", "schema": 1,
                       "git_sha": None, "rows": rows}, f, indent=1)
            f.write("\n")
        print(f"# wrote {path}")
        if plan is not None:
            ppath = os.path.join(args.out, "serve_plan.json")
            with open(ppath, "w", encoding="utf-8") as f:
                f.write(plan.to_json())
            print(f"# wrote {ppath}")
    print("serve_decode OK")


if __name__ == "__main__":
    main()
