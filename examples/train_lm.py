"""End-to-end training driver: train a ~100M-param llama-style model for a
few hundred steps on CPU with the full production substrate — sharded data
pipeline, AdamW (fp32 master), remat, async checkpointing with resume, and
the elastic mesh.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, Prefetcher, TokenSource
from repro.models.zoo import build_model
from repro.train import optimizer as optim
from repro.train.step import TrainState, init_train_state, make_train_step


def small_100m(tiny: bool = False):
    """~100M-param member of the llama3.2 family (tiny: ~23M CI variant)."""
    cfg = get_config("llama3.2-1b")
    if tiny:
        return dataclasses.replace(
            cfg, n_layers=6, d_model=512, n_heads=8, n_kv_heads=4,
            head_dim=64, d_ff=1536, vocab=8192, dtype=jnp.float32)
    return dataclasses.replace(
        cfg, n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
        head_dim=64, d_ff=2048, vocab=16384, dtype=jnp.float32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--tiny", action="store_true",
                    help="~23M CI variant (default is ~100M)")
    args = ap.parse_args()

    cfg = small_100m(tiny=args.tiny)
    model = build_model(cfg)
    n_params = None

    ckpt = Checkpointer(args.ckpt_dir, keep=2)
    state = init_train_state(model, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"model: {cfg.name} variant, {n_params/1e6:.1f}M params")

    start = 0
    latest = ckpt.latest_step()
    if latest is not None:
        print(f"resuming from checkpoint step {latest}")
        state = ckpt.restore(latest, state)
        start = latest

    opt_cfg = optim.OptConfig(lr=3e-4, warmup_steps=20,
                              total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, opt_cfg))

    data = TokenSource(DataConfig(seq_len=args.seq,
                                  global_batch=args.batch, vocab=cfg.vocab))
    prefetch = Prefetcher(data, start_step=start)

    t0 = time.time()
    try:
        for i in range(start, args.steps):
            _, batch = next(prefetch)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            state, metrics = step_fn(state, batch)
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:4d}  loss={float(metrics['loss']):.4f}  "
                      f"gnorm={float(metrics['grad_norm']):.3f}  "
                      f"lr={float(metrics['lr']):.2e}  "
                      f"({(time.time()-t0):.0f}s)", flush=True)
            if i and i % args.ckpt_every == 0:
                ckpt.save(i, state)      # async, off the critical path
    finally:
        prefetch.close()
        ckpt.wait()
    ckpt.save(args.steps, state, blocking=True)
    print(f"done: {args.steps} steps in {time.time()-t0:.0f}s; "
          f"final loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
