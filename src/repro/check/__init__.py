"""repro.check — static legality checking for compiled CGRA artifacts.

Proves an artifact structurally and temporally legal *without running
it*, across all three toolchain layers:

* :func:`check_mapping` — placement, routing adjacency/continuity,
  (resource, II-slot) exclusivity over a re-derived occupancy map;
* :func:`check_config` — mux-select ranges, RF write ports, load-pipeline
  hazards, validity windows, bank bindings, live-in initialization;
* :func:`check_stream` — the same temporal facts re-derived from the raw
  ``instructions.csv`` / manifest text (an independent auditor of
  ``isa.encode``);
* :func:`check_kernel` / :func:`assert_clean` — all layers over one
  ``CompiledKernel``; clean artifacts are diagnostic-free (the
  ``MORPHER_CHECK=1`` contract).

The checker is pure — no simulation, no RNG, no wall clock — and its
reports are byte-deterministic (:mod:`repro.check.report`).  The seeded
corruption harness that proves the rules have teeth lives in
:mod:`repro.check.mutate`; the CLI in ``python -m repro.check``.
"""
from .config import check_config
from .diagnostics import Diagnostic, ERROR, RULES, WARNING
from .mapping import check_mapping
from .report import (LAYERS, REPORT_SCHEMA, assert_clean, check_kernel,
                     errors, report_dict, report_json)
from .stream import check_stream

__all__ = [
    "Diagnostic", "RULES", "ERROR", "WARNING", "LAYERS", "REPORT_SCHEMA",
    "check_mapping", "check_config", "check_stream", "check_kernel",
    "assert_clean", "errors", "report_dict", "report_json",
]
