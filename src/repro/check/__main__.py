"""CLI: static legality checking over the kernel library.

    python -m repro.check --out report                # ten kernels, small
    python -m repro.check --out report --table1       # six Table-I kernels
    python -m repro.check --out report --arch cluster_4x4,torus_4x4
    python -m repro.check --out report --mutate       # + corruption gate

Writes ``<out>/check_report.json`` — the byte-deterministic audit of
every kernel's mapping, configuration and instruction stream (two runs
``cmp`` identical; the CI ``check-smoke`` determinism check).  With
``--mutate`` also runs the seeded corruption corpus
(:mod:`repro.check.mutate`) and writes ``<out>/mutation_report.json``;
the exit code is non-zero if any diagnostic fires on the clean library
or the mutation gate fails.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import List, Optional

_ARCHS = ("cluster_4x4", "torus_4x4", "morpher_8x8")


def _build_arch(name: str):
    import dataclasses

    from repro.core.adl import cluster_4x4, morpher_8x8
    if name == "cluster_4x4":
        return cluster_4x4()
    if name == "torus_4x4":
        return dataclasses.replace(cluster_4x4(),
                                   name="morpher-cluster-4x4-torus",
                                   torus=True)
    if name == "morpher_8x8":
        return morpher_8x8()
    raise ValueError(f"unknown arch {name!r}; have {_ARCHS}")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.check",
        description="static legality audit of compiled kernel artifacts")
    ap.add_argument("--out", default=".",
                    help="directory for check_report.json (default: .)")
    ap.add_argument("--kernels", default=None,
                    help="comma-separated subset (default: the full "
                         "ten-kernel library)")
    ap.add_argument("--table1", action="store_true",
                    help="restrict to the six Table-I kernels")
    ap.add_argument("--arch", default="cluster_4x4",
                    help=f"comma-separated target(s) from {_ARCHS} "
                         f"(default: cluster_4x4; multi-arch entries are "
                         f"keyed '<arch>/<kernel>')")
    ap.add_argument("--mutate", action="store_true",
                    help="also run the seeded corruption corpus and "
                         "enforce the mutation gate")
    ap.add_argument("--seed", type=int, default=0,
                    help="corpus seed for --mutate (default: 0)")
    ap.add_argument("--per-class", type=int, default=2,
                    help="mutants per (kernel, class) for --mutate")
    args = ap.parse_args(argv)

    from repro.check import check_kernel, errors, report_json
    from repro.core.kernels_lib import table1_kernels
    from repro.core.toolchain import Toolchain
    from repro.frontend.library import dsl_kernels

    arch_names = args.arch.split(",")
    for a in arch_names:
        if a not in _ARCHS:
            ap.error(f"unknown arch {a!r}; have {_ARCHS}")

    tc = Toolchain()
    per_kernel = {}
    n_errors = 0
    all_cks = []
    for aname in arch_names:
        arch = _build_arch(aname)
        if aname == "cluster_4x4":
            suite = dict(table1_kernels(small=True))
            if not args.table1:
                suite.update(dsl_kernels())
        else:
            # non-default targets take the arch-parameterized DSE suite
            from repro.dse.explore import kernel_suite
            suite = kernel_suite(arch)
            if args.table1:
                suite = {k: v for k, v in suite.items()
                         if k.lower().startswith(("gemm", "conv"))
                         and "bias" not in k.lower()}
        if args.kernels:
            names = args.kernels.split(",")
            unknown = [n for n in names if n not in suite]
            if unknown:
                ap.error(f"unknown kernels {unknown}; have {sorted(suite)}")
            suite = {n: suite[n] for n in names}
        t0 = time.time()
        cks = tc.compile_many(list(suite.values()))
        all_cks.extend(cks)
        for name, ck in zip(suite, cks):
            t1 = time.time()
            diags = check_kernel(ck)
            bad = errors(diags)
            n_errors += len(bad)
            key = name if len(arch_names) == 1 else f"{aname}/{name}"
            per_kernel[key] = {"II": ck.II, "cache_key": ck.cache_key,
                               "diagnostics": diags}
            print(f"{key:<28} II={ck.II:<3d} diagnostics={len(bad)} "
                  f"({(time.time() - t1) * 1e3:.1f} ms)")
            for d in bad[:5]:
                print(f"    {d}")
        print(f"# {aname}: {len(suite)} kernel(s) in "
              f"{time.time() - t0:.2f}s")

    os.makedirs(args.out, exist_ok=True)
    report_path = os.path.join(args.out, "check_report.json")
    with open(report_path, "w") as f:
        f.write(report_json(per_kernel))
    print(f"# wrote {report_path} ({n_errors} error diagnostic(s))")

    rc = 0 if n_errors == 0 else 1
    if args.mutate:
        from repro.check.mutate import MIN_SCORE, mutation_gate, run_corpus
        t0 = time.time()
        try:
            rep = mutation_gate(all_cks, seed=args.seed,
                                per_class=args.per_class)
            gate = "PASS"
        except AssertionError as e:
            rep = run_corpus(all_cks, seed=args.seed,
                             per_class=args.per_class)
            gate = "FAIL"
            print(e)
            rc = 1
        mut_path = os.path.join(args.out, "mutation_report.json")
        with open(mut_path, "w") as f:
            f.write(json.dumps(rep.to_json_dict(), sort_keys=True,
                               separators=(",", ":")) + "\n")
        print(f"# mutation gate {gate}: score {rep.score:.3f} "
              f"(>= {MIN_SCORE} required) over {rep.total} mutants, "
              f"{len(rep.live_misses)} live miss(es) "
              f"({time.time() - t0:.1f}s) -> {mut_path}")
    return rc


if __name__ == "__main__":
    sys.exit(main())
