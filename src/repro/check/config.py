"""Config/timing legality: audit a :class:`~repro.core.config_gen.SimConfig`
against its :class:`~repro.core.adl.CGRAArch` without simulating it.

Everything here is decidable from the configuration planes and the
architecture tables alone:

* shapes and scalar parameters agree with the ADL (``CFG-SHAPE``),
* every opcode and mux select is representable on the fabric
  (``CFG-OPC-RANGE`` / ``CFG-MUX-RANGE`` / ``CFG-NBR``),
* the register file is written within its port budget (``CFG-RF-WPORTS``),
* the 2-cycle load pipeline never clobbers a same-PE ALU result
  (``CFG-LOAD-HAZARD``),
* validity windows sit on their II slot inside the schedule depth
  (``CFG-STORE-WINDOW``),
* memory bindings name real banks, on the bank's bus, one access per bank
  per slot (``CFG-BANK-RANGE`` / ``CFG-BANK-PORT``),
* live-in reads hit host-initialized registers (``CFG-LIVEIN``).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from ..core.adl import CGRAArch, DIRS
from ..core.config_gen import (
    INDEXED_KINDS, KIND_IN_N, KIND_IN_W, KIND_LIREG, KIND_MNEMONIC,
    KIND_NONE, KIND_REG, MNEMONIC, OPC_LOAD, OPC_NONE, OPC_STORE, SimConfig,
)

from .diagnostics import Diagnostic, ERROR, cell_locus, sort_diagnostics

# opcodes whose result lands in the FU output register at t+1 (everything
# except nop, load — which lands at t+2 via the load pipeline — and store)
_RESULT_OPCS = frozenset(c for c in MNEMONIC
                         if c not in (OPC_NONE, OPC_LOAD, OPC_STORE))


def _declared_banks(arch: CGRAArch) -> Dict[int, Tuple[int, int]]:
    """bank id -> (global word offset, words), in declaration order — the
    exact layout ``generate_config`` materializes."""
    out: Dict[int, Tuple[int, int]] = {}
    off = 0
    for b in arch.banks:
        out[b.id] = (off, b.words)
        off += b.words
    return out


def check_config(cfg: SimConfig, arch: CGRAArch) -> List[Diagnostic]:
    """Audit config/timing legality; returns sorted diagnostics."""
    diags: List[Diagnostic] = []

    def err(rule: str, locus: str, message: str):
        diags.append(Diagnostic(rule, ERROR, locus, message))

    II, P, RF, LI = cfg.II, cfg.P, cfg.RF, cfg.LI

    # ------------------------------------------------------------ CFG-SHAPE
    banks = _declared_banks(arch)
    exp_total = sum(w for _off, w in banks.values()) + 1
    shape_problems = []
    if P != arch.n_pes:
        shape_problems.append(f"P={P} but the arch has {arch.n_pes} PEs")
    if RF != arch.regfile_size:
        shape_problems.append(f"RF={RF} != regfile_size {arch.regfile_size}")
    if LI != max(1, arch.livein_regs):
        shape_problems.append(
            f"LI={LI} != livein_regs {max(1, arch.livein_regs)}")
    if cfg.bits != arch.datapath_bits:
        shape_problems.append(
            f"bits={cfg.bits} != datapath_bits {arch.datapath_bits}")
    if II < 1 or cfg.depth < 2:
        shape_problems.append(f"degenerate II={II} / depth={cfg.depth}")
    if dict(cfg.bank_offsets) != {b: off for b, (off, _w) in banks.items()}:
        shape_problems.append(
            f"bank_offsets {dict(cfg.bank_offsets)} disagree with the "
            f"declared layout {{id: offset}} "
            f"{ {b: off for b, (off, _w) in banks.items()} }")
    if cfg.total_words != exp_total:
        shape_problems.append(
            f"total_words={cfg.total_words} != declared {exp_total} "
            f"(banks + scratch)")
    expected_shapes = {
        "op": (II, P), "imm": (II, P), "src_kind": (II, P, 3),
        "src_idx": (II, P, 3), "force_before": (II, P, 3),
        "force_val": (II, P, 3), "xo_kind": (II, P, 4), "xo_idx": (II, P, 4),
        "rf_kind": (II, P, RF), "rf_idx": (II, P, RF), "mem_off": (II, P),
        "mem_words": (II, P), "valid_start": (II, P), "nbr_idx": (P, 4),
        "nbr_ok": (P, 4),
    }
    for name, shape in expected_shapes.items():
        plane = getattr(cfg, name)
        if tuple(plane.shape) != shape:
            shape_problems.append(
                f"{name} plane has shape {tuple(plane.shape)}, "
                f"expected {shape}")
    if shape_problems:
        for p in shape_problems:
            err("CFG-SHAPE", "config", p)
        # planes cannot be trusted past a shape mismatch
        return sort_diagnostics(diags)

    # -------------------------------------------------------------- CFG-NBR
    for pe in range(P):
        for di, d in enumerate(DIRS):
            q = arch.neighbor(pe, d)
            ok = bool(cfg.nbr_ok[pe, di])
            idx = int(cfg.nbr_idx[pe, di])
            if ok != (q is not None) or (q is not None and idx != q) \
                    or (q is None and idx != 0):
                err("CFG-NBR", f"pe{pe}",
                    f"neighbour table entry {d}=({idx}, ok={ok}) disagrees "
                    f"with the topology ({q})")

    lireg_cells = {}
    for name in sorted(cfg.lireg_assign):
        pe, idx = cfg.lireg_assign[name]
        if not (0 <= pe < P) or not (0 <= idx < LI):
            err("CFG-LIVEIN", f"livein({name})",
                f"assignment (pe{pe}, li{idx}) outside the fabric's "
                f"{LI} live-in registers")
            continue
        prev = lireg_cells.setdefault((pe, idx), name)
        if prev != name:
            err("CFG-LIVEIN", f"pe{pe}/li{idx}",
                f"live-in register double-booked by {prev!r} and {name!r}")
    assigned = set(lireg_cells)

    def check_sel(slot: int, pe: int, what: str, kind: int, idx: int):
        locus = cell_locus(slot, pe)
        if kind not in KIND_MNEMONIC:
            err("CFG-MUX-RANGE", locus,
                f"{what} select kind {kind} is not a mux input")
            return
        if KIND_IN_N <= kind <= KIND_IN_W:
            di = kind - KIND_IN_N
            if not bool(cfg.nbr_ok[pe, di]):
                err("CFG-MUX-RANGE", locus,
                    f"{what} reads in_{DIRS[di].lower()} but pe{pe} has no "
                    f"{DIRS[di]} neighbour wire")
        if kind == KIND_REG and not (0 <= idx < RF):
            err("CFG-MUX-RANGE", locus,
                f"{what} reads reg{idx}, outside the {RF}-entry register "
                f"file")
        elif kind == KIND_LIREG:
            if not (0 <= idx < LI):
                err("CFG-MUX-RANGE", locus,
                    f"{what} reads li{idx}, outside the {LI} live-in "
                    f"registers")
            elif (pe, idx) not in assigned:
                err("CFG-LIVEIN", locus,
                    f"{what} reads li{idx} on pe{pe}, which no live-in "
                    f"initializes")
        elif kind not in INDEXED_KINDS and idx != 0:
            err("CFG-MUX-RANGE", locus,
                f"{what} select {KIND_MNEMONIC[kind]} carries stray "
                f"index {idx}")

    # per-cell scan: opcodes, selects, windows, memory, write ports
    load_cells = set()      # (slot, pe) holding a LOAD
    result_cells = {}       # (slot, pe) -> opcode producing an FU result
    mem_cells = []          # (slot, pe, opc)
    for slot in range(II):
        for pe in range(P):
            locus = cell_locus(slot, pe)
            opc = int(cfg.op[slot, pe])
            if opc not in MNEMONIC:
                err("CFG-OPC-RANGE", locus,
                    f"opcode {opc} is outside the opcode table")
                opc = OPC_NONE
            if opc == OPC_LOAD:
                load_cells.add((slot, pe))
            if opc in _RESULT_OPCS:
                result_cells[(slot, pe)] = opc
            if opc in (OPC_LOAD, OPC_STORE):
                mem_cells.append((slot, pe, opc))
            # validity window: an active cell fires at valid_start,
            # valid_start + II, ... so its residue must be this slot and
            # the first firing must sit inside the schedule depth
            vs = int(cfg.valid_start[slot, pe])
            if opc != OPC_NONE:
                if vs < 0 or vs > cfg.depth - 2 or vs % II != slot:
                    err("CFG-STORE-WINDOW", locus,
                        f"{MNEMONIC[opc]} window starts at t{vs}, which is "
                        f"not on slot {slot} within depth {cfg.depth}")
            elif vs != 0:
                err("CFG-STORE-WINDOW", locus,
                    f"inactive cell carries stray window start t{vs}")
            # operand / crossbar / RF selects
            for o in range(3):
                check_sel(slot, pe, f"operand {o}",
                          int(cfg.src_kind[slot, pe, o]),
                          int(cfg.src_idx[slot, pe, o]))
            for di in range(4):
                check_sel(slot, pe, f"xo_{DIRS[di].lower()}",
                          int(cfg.xo_kind[slot, pe, di]),
                          int(cfg.xo_idx[slot, pe, di]))
            writes = 0
            for r in range(RF):
                k = int(cfg.rf_kind[slot, pe, r])
                if k != KIND_NONE:
                    writes += 1
                check_sel(slot, pe, f"rf{r}", k, int(cfg.rf_idx[slot, pe, r]))
            if writes > arch.rf_write_ports:
                err("CFG-RF-WPORTS", locus,
                    f"{writes} register-file writes exceed "
                    f"{arch.rf_write_ports} write ports")
            # memory binding
            moff = int(cfg.mem_off[slot, pe])
            mwords = int(cfg.mem_words[slot, pe])
            if opc in (OPC_LOAD, OPC_STORE):
                match = [b for b, (off, w) in banks.items()
                         if (off, w) == (moff, mwords)]
                if not match:
                    err("CFG-BANK-RANGE", locus,
                        f"{MNEMONIC[opc]} binding (off={moff}, "
                        f"words={mwords}) matches no declared bank")
                elif pe not in arch.bank(match[0]).pes:
                    err("CFG-BANK-RANGE", locus,
                        f"pe{pe} is not on bank{match[0]}'s shared bus")
            elif (moff, mwords) != (0, 1):
                err("CFG-BANK-RANGE", locus,
                    f"non-memory cell carries stray binding (off={moff}, "
                    f"words={mwords})")

    # ------------------------------------------------------- CFG-BANK-PORT
    off_to_bank = {off: b for b, (off, _w) in banks.items()}
    port: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
    for slot, pe, _opc in mem_cells:
        b = off_to_bank.get(int(cfg.mem_off[slot, pe]))
        if b is not None:
            port.setdefault((b, slot), []).append((slot, pe))
    for (b, slot), cells in sorted(port.items()):
        if len(cells) > 1:
            err("CFG-BANK-PORT", f"slot{slot}/bank{b}",
                f"{len(cells)} memory ops share bank{b}'s port: "
                f"{[f'pe{pe}' for _s, pe in cells]}")

    # ------------------------------------------------------ CFG-LOAD-HAZARD
    # a load issued at slot s owns the FU output register at (s+2); a
    # 1-cycle result issued at slot s+1 lands there the same cycle and is
    # silently discarded by the load pipeline (simulator: completing loads
    # win).  With II == 1 the pattern is inexpressible (s+1 is s itself).
    if II > 1:
        for (slot, pe) in sorted(load_cells):
            nxt = ((slot + 1) % II, pe)
            if nxt in result_cells:
                err("CFG-LOAD-HAZARD", cell_locus(nxt[0], pe),
                    f"{MNEMONIC[result_cells[nxt]]} result is clobbered by "
                    f"the load completing from slot {slot}")

    return sort_diagnostics(diags)
