"""Structured diagnostics for the static legality checker.

Every rule the checker can fire lives in the :data:`RULES` catalog — one
stable id per structural/temporal property, grouped by the artifact layer
it audits (``MAP-*`` over :class:`~repro.core.mapper.Mapping`, ``CFG-*``
over :class:`~repro.core.config_gen.SimConfig`, ``STR-*`` over the
exported ``instructions.csv`` / manifest family).  Rule ids are part of
the public contract: tests pin them, the mutation corpus asserts each
corruption class trips its intended id, and generator errors
(``ConfigConflict`` / ``StreamError``) reference them so static
diagnostics and dynamic failures read the same way.

Diagnostics are plain frozen records with a canonical sort order, so a
report assembled from them is byte-deterministic by construction (no
wall-clock, no RNG, no iteration-order dependence).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

ERROR = "error"
WARNING = "warning"

# rule id -> one-line description (the README rule-catalog table renders
# from this mapping; keep descriptions single-line and self-contained)
RULES: Dict[str, str] = {
    # ---------------------------------------------- mapping legality (a)
    "MAP-NODE-RANGE": "DFG node unplaced, or placed outside the PE grid / "
                      "at a negative schedule time",
    "MAP-OP-SUPPORT": "node op unsupported by its PE's functional unit "
                      "(per_pe_ops interiors, memory-bus membership)",
    "MAP-FU-OVERLAP": "two nodes share one FU issue slot or FU output "
                      "register (resource, II-slot) cell",
    "MAP-ROUTE-CONT": "route endpoints/steps inconsistent with the "
                      "placement or schedule times (incl. unrouted edges)",
    "MAP-ROUTE-ADJ": "route hops between physically non-adjacent PEs",
    "MAP-ROUTE-OVERLAP": "two value instances occupy one routing resource "
                         "(crossbar port, register slot, or RF write ports "
                         "over capacity)",
    "MAP-REG-RANGE": "register-resident route step without a register "
                     "assignment, or assignment outside the register file",
    "MAP-BANK-BUS": "memory node bound to an unknown bank or placed on a "
                    "PE that is not on the bank's shared bus",
    "MAP-BANK-PORT": "two memory nodes access one bank in the same II "
                     "slot (one access port per bank per cycle)",
    "MAP-LIREG": "live-in register assignment missing, out of range, or "
                 "over the per-PE live-in register count",
    # ----------------------------------------- config/timing legality (b)
    "CFG-SHAPE": "SimConfig dimensions/planes inconsistent with the "
                 "architecture (II/P/RF/LI/bits, plane shapes, depth)",
    "CFG-OPC-RANGE": "FU opcode outside the opcode table",
    "CFG-MUX-RANGE": "mux select kind/index out of range for the fabric, "
                     "or a read through a missing neighbour wire",
    "CFG-RF-WPORTS": "register-file writes in one (slot, pe) exceed "
                     "rf_write_ports",
    "CFG-LOAD-HAZARD": "result-producing op scheduled in a load's shadow "
                       "slot (the completing load clobbers the FU output "
                       "register)",
    "CFG-STORE-WINDOW": "validity window inconsistent: tstart residue "
                        "differs from the II slot, or lies outside the "
                        "schedule depth",
    "CFG-BANK-RANGE": "memory binding (mem_off, mem_words) does not match "
                      "a declared bank, or bank offsets disagree with the "
                      "ADL",
    "CFG-BANK-PORT": "two memory ops bound to one bank in the same II "
                     "slot",
    "CFG-LIVEIN": "live-in register read without a host initialization, "
                  "or assignment out of range / double-booked",
    "CFG-NBR": "neighbour table disagrees with the ADL topology",
    # ----------------------------------------------- stream legality (c)
    "STR-PARSE": "CSV/manifest malformed: format version, header, record "
                 "count (truncation), duplicate or out-of-range records",
    "STR-OPC": "unknown opcode mnemonic",
    "STR-SEL-RANGE": "mux select unparseable, out of range, or reading a "
                     "missing neighbour wire",
    "STR-RF-WPORTS": "register-file writebacks in one record exceed "
                     "rf_write_ports",
    "STR-LOAD-HAZARD": "result-producing mnemonic in a load's shadow slot",
    "STR-STORE-WINDOW": "tstart residue differs from the record's slot, or "
                        "lies outside the schedule depth",
    "STR-BANK-RANGE": "memory binding does not match a bank derivable "
                      "from the manifest offsets",
    "STR-BANK-PORT": "two memory ops bound to one bank in the same II "
                     "slot",
    "STR-LIVEIN": "live-in select reads a register the manifest never "
                  "initializes",
}


@dataclass(frozen=True)
class Diagnostic:
    """One checker finding: a rule id, a severity, the (slot, pe)/node
    locus it anchors to, and a human-readable message."""
    rule: str
    severity: str            # ERROR | WARNING
    locus: str               # "slot2/pe5", "node7", "route(3->9#0)", ...
    message: str

    def __post_init__(self):
        if self.rule not in RULES:
            raise ValueError(f"unknown rule id {self.rule!r}")

    def __str__(self) -> str:
        return f"[{self.rule}] {self.locus}: {self.message}"

    def to_json_dict(self) -> dict:
        return {"rule": self.rule, "severity": self.severity,
                "locus": self.locus, "message": self.message}

    @property
    def sort_key(self):
        return (self.rule, self.locus, self.message)


def cell_locus(slot: int, pe: int) -> str:
    """The canonical (slot, pe) locus spelling — shared with the enriched
    ``ConfigConflict`` / ``StreamError`` messages so generator errors and
    checker diagnostics read the same way."""
    return f"slot{slot}/pe{pe}"


def sort_diagnostics(diags: List[Diagnostic]) -> List[Diagnostic]:
    """Canonical report order (stable, content-only)."""
    return sorted(diags, key=lambda d: d.sort_key)
