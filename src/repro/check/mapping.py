"""Mapping legality: audit a :class:`~repro.core.mapper.Mapping` against
its DFG and :class:`~repro.core.adl.CGRAArch` without touching the mapper's
own ``usage`` bookkeeping.

The checker re-derives every resource claim from first principles — the
placement table, the route step lists and the topology tables — and then
applies the MRRG capacity model (fu/fuout/xo/bank are exclusive per
II-slot, register pools hold ``regfile_size`` values, ``rf_write_ports``
writes per cycle, one live-in register per name).  Fan-out sharing is
honoured exactly as in the router: identical ``(value, abs_time)``
instances may share a resource cell; distinct instances on a capacity-1
cell are a conflict.
"""
from __future__ import annotations

from typing import Dict, List, Set, Tuple

from ..core.adl import DIRS
from ..core.dfg import Op, latency
from ..core.router import F, R

from .diagnostics import Diagnostic, ERROR, cell_locus, sort_diagnostics

Inst = Tuple[int, int]


def check_mapping(mapping) -> List[Diagnostic]:
    """Audit mapping legality; returns sorted diagnostics (empty = legal)."""
    diags: List[Diagnostic] = []
    dfg, arch, II = mapping.dfg, mapping.arch, mapping.II
    P = arch.n_pes
    place = mapping.place
    bank_ids = {b.id for b in arch.banks}

    def err(rule: str, locus: str, message: str):
        diags.append(Diagnostic(rule, ERROR, locus, message))

    # re-derived occupancy: typed resource key -> set of (value, abs_t)
    occ: Dict[Tuple, Set[Inst]] = {}

    def claim(key: Tuple, inst: Inst):
        occ.setdefault(key, set()).add(inst)

    # ------------------------------------------------------------ placement
    for nid in sorted(dfg.nodes):
        node = dfg.nodes[nid]
        locus = f"node{nid}"
        if nid not in place:
            err("MAP-NODE-RANGE", locus, "node has no placement")
            continue
        pe, t = place[nid]
        if not (0 <= pe < P) or t < 0:
            err("MAP-NODE-RANGE", locus,
                f"placed at pe{pe} t{t}, outside the {P}-PE grid / schedule")
            continue
        if not arch.supports(pe, node.op):
            err("MAP-OP-SUPPORT", locus,
                f"op {node.op.name} is not supported by pe{pe}'s FU")
        claim(("fu", pe, t % II), (nid, t))
        if node.op is not Op.STORE:
            tf = t + node.lat
            claim(("fuout", pe, tf % II), (nid, tf))
        if node.is_mem:
            b = mapping.bank_of.get(nid)
            if b is None or b not in bank_ids:
                err("MAP-BANK-BUS", locus, f"bound to unknown bank {b}")
            else:
                if pe not in arch.bank(b).pes:
                    err("MAP-BANK-BUS", locus,
                        f"pe{pe} is not on bank{b}'s shared bus")
                claim(("bank", b, t % II), (nid, t))
        if node.op is Op.LIVEIN:
            asn = mapping.lireg_assign.get(node.livein)
            if asn is None:
                err("MAP-LIREG", locus,
                    f"live-in {node.livein!r} has no register assignment")
            elif asn[0] != pe:
                err("MAP-LIREG", locus,
                    f"live-in {node.livein!r} assigned to pe{asn[0]} but the "
                    f"node is placed on pe{pe}")

    # live-in register file: per-PE capacity and double-booking
    lireg_cells: Dict[Tuple[int, int], List[str]] = {}
    per_pe_names: Dict[int, Set[str]] = {}
    for name in sorted(mapping.lireg_assign):
        pe, idx = mapping.lireg_assign[name]
        locus = f"livein({name})"
        if not (0 <= pe < P) or not (0 <= idx < max(1, arch.livein_regs)):
            err("MAP-LIREG", locus,
                f"assignment (pe{pe}, li{idx}) outside the fabric's "
                f"{arch.livein_regs} live-in registers")
            continue
        lireg_cells.setdefault((pe, idx), []).append(name)
        per_pe_names.setdefault(pe, set()).add(name)
    for (pe, idx), names in sorted(lireg_cells.items()):
        if len(names) > 1:
            err("MAP-LIREG", f"pe{pe}/li{idx}",
                f"live-in register double-booked by {names}")
    for pe, names in sorted(per_pe_names.items()):
        if len(names) > arch.livein_regs:
            err("MAP-LIREG", f"pe{pe}",
                f"{len(names)} live-ins assigned but only "
                f"{arch.livein_regs} live-in registers exist")

    # ----------------------------------------------------- routes and edges
    routed = set(mapping.routes)
    for src, dst, slot, opnd in dfg.data_edges():
        if (src, dst, slot) not in routed:
            err("MAP-ROUTE-CONT", f"route({src}->{dst}#{slot})",
                "data edge has no route")

    for (src, dst, eslot) in sorted(mapping.routes):
        r = mapping.routes[(src, dst, eslot)]
        locus = f"route({src}->{dst}#{eslot})"
        # endpoint consistency with the placement / schedule
        if src in place and dst in place and src in dfg.nodes \
                and dst in dfg.nodes:
            spe, st = place[src]
            dpe, dt = place[dst]
            opnds = dfg.nodes[dst].operands
            dist = opnds[eslot].dist if eslot < len(opnds) else 0
            exp_tsrc = st + latency(dfg.nodes[src].op)
            exp_tdst = dt + II * dist
            if (r.value != src or r.src_pe != spe or r.t_src != exp_tsrc
                    or r.dst_pe != dpe or r.t_dst != exp_tdst):
                err("MAP-ROUTE-CONT", locus,
                    f"endpoints (v{r.value} pe{r.src_pe}@t{r.t_src} -> "
                    f"pe{r.dst_pe}@t{r.t_dst}) disagree with the schedule "
                    f"(v{src} pe{spe}@t{exp_tsrc} -> pe{dpe}@t{exp_tdst})")
        steps = r.steps
        if not steps:
            err("MAP-ROUTE-CONT", locus, "route has no steps")
            continue
        if tuple(steps[0]) != (F, r.src_pe, r.t_src):
            err("MAP-ROUTE-CONT", locus,
                f"first step {tuple(steps[0])} is not the fresh source "
                f"state (pe{r.src_pe}, t{r.t_src})")
        if steps[-1][1] != r.dst_pe or steps[-1][2] != r.t_dst:
            err("MAP-ROUTE-CONT", locus,
                f"last step {tuple(steps[-1])} does not reach the consumer "
                f"at (pe{r.dst_pe}, t{r.t_dst})")
        for i in range(len(steps) - 1):
            k0, p0, t0 = steps[i]
            k1, p1, t1 = steps[i + 1]
            if t1 != t0 + 1:
                err("MAP-ROUTE-CONT", locus,
                    f"step {i}: time jumps t{t0} -> t{t1}")
                continue
            if not (0 <= p0 < P and 0 <= p1 < P):
                err("MAP-ROUTE-CONT", locus,
                    f"step {i}: pe{p0} -> pe{p1} outside the grid")
                continue
            if p1 != p0:
                # crossbar hop: must land on an adjacent PE, fresh
                if k1 != F:
                    err("MAP-ROUTE-CONT", locus,
                        f"step {i}: hop pe{p0} -> pe{p1} must arrive fresh")
                di = next((j for j, d in enumerate(DIRS)
                           if arch.neighbor(p0, d) == p1), None)
                if di is None:
                    err("MAP-ROUTE-ADJ", locus,
                        f"step {i}: pe{p0} and pe{p1} are not adjacent")
                else:
                    claim(("xo", p0, di, t0 % II), (r.value, t0))
            else:
                if k1 == R:
                    # register hold; entering from F costs a write port
                    claim(("regpool", p0, t1 % II), (r.value, t1))
                    if k0 == F:
                        claim(("wr", p0, t0 % II), (r.value, t0))
                else:
                    err("MAP-ROUTE-CONT", locus,
                        f"step {i}: illegal same-PE transition "
                        f"{'F' if k0 == F else 'R'}->F at pe{p0} t{t0}")
        # register-resident steps must be colored into physical registers
        for (k, p, t) in steps:
            if k != R:
                continue
            ridx = mapping.reg_assign.get((p, r.value, t))
            if ridx is None:
                err("MAP-REG-RANGE", locus,
                    f"register-resident at pe{p} t{t} but no register "
                    f"assignment exists")
            elif not (0 <= ridx < arch.regfile_size):
                err("MAP-REG-RANGE", locus,
                    f"value v{r.value} at pe{p} t{t} colored into r{ridx}, "
                    f"outside the {arch.regfile_size}-entry register file")
            else:
                claim(("reg", p, ridx, t % II), (r.value, t))

    # --------------------------------------------- capacity over re-derived occ
    rule_by_kind = {"fu": "MAP-FU-OVERLAP", "fuout": "MAP-FU-OVERLAP",
                    "xo": "MAP-ROUTE-OVERLAP", "reg": "MAP-ROUTE-OVERLAP",
                    "regpool": "MAP-ROUTE-OVERLAP",
                    "wr": "MAP-ROUTE-OVERLAP", "bank": "MAP-BANK-PORT"}
    for key in sorted(occ, key=repr):
        insts = occ[key]
        kind = key[0]
        if kind == "regpool":
            cap = arch.regfile_size
        elif kind == "wr":
            cap = arch.rf_write_ports
        else:
            cap = 1
        if len(insts) <= cap:
            continue
        who = sorted(insts)[:4]
        if kind == "fu":
            _, pe, slot = key
            err("MAP-FU-OVERLAP", cell_locus(slot, pe),
                f"{len(insts)} nodes issue on one FU slot: {who}")
        elif kind == "fuout":
            _, pe, slot = key
            err("MAP-FU-OVERLAP", cell_locus(slot, pe),
                f"{len(insts)} results land in one FU output register "
                f"slot: {who}")
        elif kind == "bank":
            _, b, slot = key
            err("MAP-BANK-PORT", f"slot{slot}/bank{b}",
                f"{len(insts)} memory nodes share bank{b}'s port: {who}")
        elif kind == "xo":
            _, pe, di, slot = key
            err("MAP-ROUTE-OVERLAP", cell_locus(slot, pe),
                f"{len(insts)} values share the {DIRS[di]} crossbar "
                f"port: {who}")
        elif kind == "reg":
            _, pe, ridx, slot = key
            err("MAP-ROUTE-OVERLAP", cell_locus(slot, pe),
                f"{len(insts)} values colored into register r{ridx}: {who}")
        elif kind == "regpool":
            _, pe, slot = key
            err("MAP-ROUTE-OVERLAP", cell_locus(slot, pe),
                f"{len(insts)} live values exceed the "
                f"{arch.regfile_size}-entry register pool")
        elif kind == "wr":
            _, pe, slot = key
            err("MAP-ROUTE-OVERLAP", cell_locus(slot, pe),
                f"{len(insts)} RF writes exceed {arch.rf_write_ports} "
                f"write ports")

    return sort_diagnostics(diags)
