"""Seeded corruption harness: proves the checker's rules have teeth.

Every mutation class below takes a *clean* compiled kernel, applies one
realistic corruption to a clone of one of its artifacts (never the
original — simulated ``SimConfig``\\ s freeze their planes), and records
which rules fire.  The gate asserts each class is caught by its
*intended* rule id (extra rules co-firing is fine — one corruption can
violate several properties) and that the corpus mutation score is at
least :data:`MIN_SCORE`.

Any mutant the checker misses is cross-checked dynamically: if the
original and the mutant produce bit-identical final memory over the
probe seeds, the corrupted lane was dead (the mutation changed bits the
execution never observes) and the miss is a non-event, not a false
negative.  A live miss — observable corruption the checker waved
through — fails the gate outright.

Mutation sites are chosen with ``random.Random(seed_string)`` (string
seeding is process-stable), so the corpus is reproducible run-over-run
and across machines.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core.config_gen import (KIND_FUOUT, KIND_IN_N, KIND_LIREG, KIND_NONE,
                               KIND_REG, MNEMONIC, OPC, OPC_LOAD, OPC_NONE,
                               OPC_STORE, SimConfig)
from ..core.dfg import Op
from ..core.mapper import Mapping

from .config import check_config
from .diagnostics import Diagnostic
from .mapping import check_mapping
from .report import errors
from .stream import check_stream

MIN_SCORE = 0.95

# mutation class -> (layer, intended rule id)
CLASSES: Dict[str, Tuple[str, str]] = {
    "mux_select":       ("config", "CFG-MUX-RANGE"),
    "store_window":     ("config", "CFG-STORE-WINDOW"),
    "bank_clobber":     ("config", "CFG-BANK-RANGE"),
    "rf_overcommit":    ("config", "CFG-RF-WPORTS"),
    "load_hazard":      ("config", "CFG-LOAD-HAZARD"),
    "opcode_clobber":   ("config", "CFG-OPC-RANGE"),
    "livein_clobber":   ("config", "CFG-LIVEIN"),
    "nbr_clobber":      ("config", "CFG-NBR"),
    "fu_alias":         ("mapping", "MAP-FU-OVERLAP"),
    "route_alias":      ("mapping", "MAP-ROUTE-OVERLAP"),
    "reg_clobber":      ("mapping", "MAP-REG-RANGE"),
    "op_unsupported":   ("mapping", "MAP-OP-SUPPORT"),
    "node_eject":       ("mapping", "MAP-NODE-RANGE"),
    "stream_truncate":  ("stream", "STR-PARSE"),
    "stream_select":    ("stream", "STR-SEL-RANGE"),
    "stream_opcode":    ("stream", "STR-OPC"),
    "stream_tstart":    ("stream", "STR-STORE-WINDOW"),
    "stream_bank":      ("stream", "STR-BANK-RANGE"),
}


@dataclass
class MutationOutcome:
    kernel: str
    cls: str
    layer: str
    intended_rule: str
    description: str
    caught: bool
    fired: List[str]
    dead: Optional[bool] = None      # only probed for missed mutants

    def to_json_dict(self) -> dict:
        return {"kernel": self.kernel, "class": self.cls,
                "layer": self.layer, "intended_rule": self.intended_rule,
                "description": self.description, "caught": self.caught,
                "fired": sorted(set(self.fired)), "dead": self.dead}


@dataclass
class CorpusReport:
    outcomes: List[MutationOutcome] = field(default_factory=list)

    @property
    def total(self) -> int:
        return len(self.outcomes)

    @property
    def caught(self) -> int:
        return sum(1 for o in self.outcomes if o.caught)

    @property
    def missed(self) -> List[MutationOutcome]:
        return [o for o in self.outcomes if not o.caught]

    @property
    def live_misses(self) -> List[MutationOutcome]:
        return [o for o in self.missed if o.dead is not True]

    @property
    def score(self) -> float:
        return self.caught / self.total if self.total else 1.0

    def by_class(self) -> Dict[str, Dict[str, int]]:
        out: Dict[str, Dict[str, int]] = {}
        for o in self.outcomes:
            c = out.setdefault(o.cls, {"total": 0, "caught": 0, "dead": 0})
            c["total"] += 1
            c["caught"] += int(o.caught)
            c["dead"] += int(o.dead is True)
        return out

    def to_json_dict(self) -> dict:
        return {"total": self.total, "caught": self.caught,
                "score": round(self.score, 4),
                "by_class": self.by_class(),
                "outcomes": [o.to_json_dict() for o in self.outcomes]}


# ------------------------------------------------------------------ clones
def _clone_cfg(cfg: SimConfig) -> SimConfig:
    # JSON round-trip: fresh writable planes (a simulated config's planes
    # are frozen read-only by the host-plane cache)
    return SimConfig.from_json(cfg.to_json())


def _clone_mapping(ck) -> Mapping:
    return Mapping.from_json_dict(ck.mapping.to_json_dict(), ck.dfg, ck.arch)


# --------------------------------------------------------- config mutators
# each returns (mutated object, description) or None when the kernel has
# no site for this class (e.g. no loads, II == 1)

def _mut_mux_select(ck, rng) -> Optional[Tuple[SimConfig, str]]:
    cfg = _clone_cfg(ck.cfg)
    sites = [(s, p, o) for s in range(cfg.II) for p in range(cfg.P)
             for o in range(3) if cfg.src_kind[s, p, o] != KIND_NONE]
    if not sites:
        return None
    s, p, o = rng.choice(sites)
    variant = rng.randrange(3)
    if variant == 0:
        # dangle the select off the register file
        cfg.src_kind[s, p, o] = KIND_REG
        cfg.src_idx[s, p, o] = cfg.RF + rng.randrange(1, 4)
        what = f"op{o} -> reg{int(cfg.src_idx[s, p, o])} (RF={cfg.RF})"
    elif variant == 1:
        # invalid select kind entirely
        cfg.src_kind[s, p, o] = 17 + rng.randrange(4)
        what = f"op{o} -> kind {int(cfg.src_kind[s, p, o])}"
    else:
        # read through a missing neighbour wire, if the fabric has one
        missing = [(pp, di) for pp in range(cfg.P) for di in range(4)
                   if not bool(cfg.nbr_ok[pp, di])]
        if not missing:
            cfg.src_kind[s, p, o] = KIND_REG
            cfg.src_idx[s, p, o] = cfg.RF + 1
            what = f"op{o} -> reg{cfg.RF + 1} (RF={cfg.RF})"
        else:
            p, di = rng.choice(missing)
            o = rng.randrange(3)
            cfg.src_kind[s, p, o] = KIND_IN_N + di
            cfg.src_idx[s, p, o] = 0
            what = f"op{o} reads missing neighbour wire dir{di} on pe{p}"
    return cfg, f"slot{s}/pe{p}: {what}"


def _mut_store_window(ck, rng) -> Optional[Tuple[SimConfig, str]]:
    cfg = _clone_cfg(ck.cfg)
    sites = [(s, p) for s in range(cfg.II) for p in range(cfg.P)
             if cfg.op[s, p] == OPC_STORE]
    if not sites:
        sites = [(s, p) for s in range(cfg.II) for p in range(cfg.P)
                 if cfg.op[s, p] != OPC_NONE]
    if not sites:
        return None
    s, p = rng.choice(sites)
    old = int(cfg.valid_start[s, p])
    if cfg.II > 1 and rng.random() < 0.5:
        cfg.valid_start[s, p] = old + 1          # off its II slot
    else:
        cfg.valid_start[s, p] = -(old + 1)       # before the schedule
    return cfg, (f"slot{s}/pe{p}: window start {old} -> "
                 f"{int(cfg.valid_start[s, p])}")


def _mut_bank_clobber(ck, rng) -> Optional[Tuple[SimConfig, str]]:
    cfg = _clone_cfg(ck.cfg)
    sites = [(s, p) for s in range(cfg.II) for p in range(cfg.P)
             if cfg.op[s, p] in (OPC_LOAD, OPC_STORE)]
    if not sites:
        return None
    s, p = rng.choice(sites)
    if rng.random() < 0.5:
        cfg.mem_off[s, p] = int(cfg.mem_off[s, p]) + rng.randrange(1, 4)
        what = f"mem_off -> {int(cfg.mem_off[s, p])}"
    else:
        cfg.mem_words[s, p] = int(cfg.mem_words[s, p]) - rng.randrange(1, 4)
        what = f"mem_words -> {int(cfg.mem_words[s, p])}"
    return cfg, f"slot{s}/pe{p}: {what}"


def _mut_rf_overcommit(ck, rng) -> Optional[Tuple[SimConfig, str]]:
    cfg = _clone_cfg(ck.cfg)
    ports = ck.arch.rf_write_ports
    if cfg.RF <= ports:
        return None
    s = rng.randrange(cfg.II)
    p = rng.randrange(cfg.P)
    for r in range(ports + 1):
        cfg.rf_kind[s, p, r] = KIND_FUOUT
        cfg.rf_idx[s, p, r] = 0
    return cfg, (f"slot{s}/pe{p}: {ports + 1} simultaneous RF writes "
                 f"(ports={ports})")


def _mut_load_hazard(ck, rng) -> Optional[Tuple[SimConfig, str]]:
    cfg = _clone_cfg(ck.cfg)
    if cfg.II <= 1:
        return None
    sites = []
    for s in range(cfg.II):
        for p in range(cfg.P):
            if cfg.op[s, p] == OPC_LOAD \
                    and cfg.op[(s + 1) % cfg.II, p] == OPC_NONE:
                sites.append((s, p))
    if not sites:
        return None
    s, p = rng.choice(sites)
    nxt = (s + 1) % cfg.II
    cfg.op[nxt, p] = OPC[Op.ADD]
    cfg.valid_start[nxt, p] = nxt        # keep the window itself legal
    return cfg, (f"slot{nxt}/pe{p}: add scheduled in the shadow of the "
                 f"load at slot {s}")


def _mut_opcode_clobber(ck, rng) -> Optional[Tuple[SimConfig, str]]:
    cfg = _clone_cfg(ck.cfg)
    sites = [(s, p) for s in range(cfg.II) for p in range(cfg.P)
             if cfg.op[s, p] != OPC_NONE]
    if not sites:
        return None
    s, p = rng.choice(sites)
    cfg.op[s, p] = max(MNEMONIC) + 1 + rng.randrange(16)
    return cfg, f"slot{s}/pe{p}: opcode -> {int(cfg.op[s, p])}"


def _mut_livein_clobber(ck, rng) -> Optional[Tuple[SimConfig, str]]:
    cfg = _clone_cfg(ck.cfg)
    reads = [(s, p, o) for s in range(cfg.II) for p in range(cfg.P)
             for o in range(3) if cfg.src_kind[s, p, o] == KIND_LIREG]
    if not reads or not cfg.lireg_assign:
        return None
    s, p, o = rng.choice(reads)
    # drop the host initialization the read depends on
    victims = [name for name, (pe, idx) in sorted(cfg.lireg_assign.items())
               if (pe, idx) == (p, int(cfg.src_idx[s, p, o]))]
    if not victims:
        return None
    del cfg.lireg_assign[victims[0]]
    victim = victims[0]
    return cfg, (f"slot{s}/pe{p}: live-in {victim!r} no longer "
                 f"host-initialized but still read by op{o}")


def _mut_nbr_clobber(ck, rng) -> Optional[Tuple[SimConfig, str]]:
    cfg = _clone_cfg(ck.cfg)
    p = rng.randrange(cfg.P)
    di = rng.randrange(4)
    cfg.nbr_ok[p, di] = not bool(cfg.nbr_ok[p, di])
    return cfg, f"pe{p}: neighbour wire dir{di} flipped"


# -------------------------------------------------------- mapping mutators
def _mut_fu_alias(ck, rng) -> Optional[Tuple[Mapping, str]]:
    m = _clone_mapping(ck)
    II = m.II
    by_slot: Dict[int, List[int]] = {}
    for nid, (pe, t) in sorted(m.place.items()):
        by_slot.setdefault(t % II, []).append(nid)
    pairs = [(a, b) for nids in by_slot.values()
             for a in nids for b in nids
             if a != b and m.place[a][0] != m.place[b][0]]
    if not pairs:
        return None
    a, b = rng.choice(pairs)
    pe_a = m.place[a][0]
    t_b = m.place[b][1]
    m.place[b] = (pe_a, t_b)
    return m, f"node{b} moved onto node{a}'s FU at pe{pe_a}"


def _mut_route_alias(ck, rng) -> Optional[Tuple[Mapping, str]]:
    m = _clone_mapping(ck)
    keys = sorted(m.routes)
    donors = [k for k in keys
              if any(m.routes[k].steps[i][1] != m.routes[k].steps[i + 1][1]
                     for i in range(len(m.routes[k].steps) - 1))]
    if not donors:
        return None
    dk = rng.choice(donors)
    donor = m.routes[dk]
    victims = [k for k in keys if m.routes[k].value != donor.value]
    if not victims:
        return None
    vk = rng.choice(victims)
    m.routes[vk].steps = [tuple(s) for s in donor.steps]
    return m, (f"route({vk[0]}->{vk[1]}#{vk[2]}) aliased onto "
               f"route({dk[0]}->{dk[1]}#{dk[2]})'s steps")


def _mut_reg_clobber(ck, rng) -> Optional[Tuple[Mapping, str]]:
    m = _clone_mapping(ck)
    if not m.reg_assign:
        return None
    key = rng.choice(sorted(m.reg_assign))
    m.reg_assign[key] = m.arch.regfile_size + rng.randrange(1, 4)
    pe, val, t = key
    return m, (f"value v{val} at pe{pe} t{t} colored into "
               f"r{m.reg_assign[key]}")


def _mut_op_unsupported(ck, rng) -> Optional[Tuple[Mapping, str]]:
    m = _clone_mapping(ck)
    off_bus = sorted(set(range(m.arch.n_pes)) - set(m.arch.mem_pes))
    mem_nodes = [nid for nid in sorted(m.place) if m.dfg.nodes[nid].is_mem]
    if not off_bus or not mem_nodes:
        return None
    nid = rng.choice(mem_nodes)
    pe = rng.choice(off_bus)
    m.place[nid] = (pe, m.place[nid][1])
    return m, f"memory node{nid} moved off the bus onto pe{pe}"


def _mut_node_eject(ck, rng) -> Optional[Tuple[Mapping, str]]:
    m = _clone_mapping(ck)
    nid = rng.choice(sorted(m.place))
    m.place[nid] = (m.arch.n_pes + rng.randrange(1, 4), m.place[nid][1])
    return m, f"node{nid} placed outside the grid at pe{m.place[nid][0]}"


# --------------------------------------------------------- stream mutators
# each returns ((csv_text, manifest), description)

def _stream_pair(ck) -> Tuple[str, dict]:
    from ..isa.encode import manifest_dict, to_csv
    return to_csv(ck.cfg), manifest_dict(ck.cfg, ck.name)


def _mut_stream_truncate(ck, rng) -> Optional[Tuple[Tuple[str, dict], str]]:
    csv_text, manifest = _stream_pair(ck)
    lines = csv_text.splitlines()
    k = rng.randrange(1, min(4, len(lines) - 1))
    return ("\n".join(lines[:-k]) + "\n", manifest), f"last {k} record(s) dropped"


def _pick_row(lines: List[str], rng, pred: Callable[[List[str]], bool]
              ) -> Optional[int]:
    rows = [i for i in range(1, len(lines)) if pred(lines[i].split(","))]
    return rng.choice(rows) if rows else None


def _mut_stream_select(ck, rng) -> Optional[Tuple[Tuple[str, dict], str]]:
    csv_text, manifest = _stream_pair(ck)
    lines = csv_text.splitlines()
    header = lines[0].split(",")
    sel_names = {"op0", "op1", "op2"} | {f"xo_{d}" for d in "nesw"} \
        | {f"rf{r}" for r in range(int(manifest["RF"]))}
    op_cols = [i for i, c in enumerate(header) if c in sel_names]
    sites = [(r, c) for r in range(1, len(lines))
             for c in op_cols if lines[r].split(",")[c] != "none"]
    if not sites:
        return None
    r, c = rng.choice(sites)
    rec = lines[r].split(",")
    old = rec[c]
    rec[c] = rng.choice([f"reg{int(manifest['RF']) + 5}", "fu3", "warp"])
    lines[r] = ",".join(rec)
    return (("\n".join(lines) + "\n", manifest),
            f"{header[c]} {old!r} -> {rec[c]!r}")


def _mut_stream_opcode(ck, rng) -> Optional[Tuple[Tuple[str, dict], str]]:
    csv_text, manifest = _stream_pair(ck)
    lines = csv_text.splitlines()
    header = lines[0].split(",")
    oc = header.index("opcode")
    r = _pick_row(lines, rng, lambda rec: rec[oc] != "nop")
    if r is None:
        return None
    rec = lines[r].split(",")
    old = rec[oc]
    rec[oc] = "frob"
    lines[r] = ",".join(rec)
    return (("\n".join(lines) + "\n", manifest), f"opcode {old!r} -> 'frob'")


def _mut_stream_tstart(ck, rng) -> Optional[Tuple[Tuple[str, dict], str]]:
    csv_text, manifest = _stream_pair(ck)
    lines = csv_text.splitlines()
    header = lines[0].split(",")
    oc, tc = header.index("opcode"), header.index("tstart")
    r = _pick_row(lines, rng, lambda rec: rec[oc] != "nop")
    if r is None:
        return None
    rec = lines[r].split(",")
    old = int(rec[tc])
    # +1 knocks the window off its II slot; with II == 1 that stays legal,
    # so push it before the schedule instead
    rec[tc] = str(old + 1 if int(manifest["II"]) > 1 else -(old + 1))
    lines[r] = ",".join(rec)
    return (("\n".join(lines) + "\n", manifest),
            f"tstart {old} -> {rec[tc]}")


def _mut_stream_bank(ck, rng) -> Optional[Tuple[Tuple[str, dict], str]]:
    csv_text, manifest = _stream_pair(ck)
    lines = csv_text.splitlines()
    header = lines[0].split(",")
    oc, mc = header.index("opcode"), header.index("mem_off")
    r = _pick_row(lines, rng, lambda rec: rec[oc] in ("load", "store"))
    if r is None:
        return None
    rec = lines[r].split(",")
    old = int(rec[mc])
    rec[mc] = str(old + rng.randrange(1, 4))
    lines[r] = ",".join(rec)
    return (("\n".join(lines) + "\n", manifest),
            f"mem_off {old} -> {rec[mc]}")


_MUTATORS: Dict[str, Callable] = {
    "mux_select": _mut_mux_select,
    "store_window": _mut_store_window,
    "bank_clobber": _mut_bank_clobber,
    "rf_overcommit": _mut_rf_overcommit,
    "load_hazard": _mut_load_hazard,
    "opcode_clobber": _mut_opcode_clobber,
    "livein_clobber": _mut_livein_clobber,
    "nbr_clobber": _mut_nbr_clobber,
    "fu_alias": _mut_fu_alias,
    "route_alias": _mut_route_alias,
    "reg_clobber": _mut_reg_clobber,
    "op_unsupported": _mut_op_unsupported,
    "node_eject": _mut_node_eject,
    "stream_truncate": _mut_stream_truncate,
    "stream_select": _mut_stream_select,
    "stream_opcode": _mut_stream_opcode,
    "stream_tstart": _mut_stream_tstart,
    "stream_bank": _mut_stream_bank,
}


def mutate_one(ck, cls: str, seed: int = 0, index: int = 0):
    """One seeded mutant of ``ck`` for mutation class ``cls``; returns
    (mutated artifact, description) or None when the kernel offers no
    site for this class (no loads, II == 1, ...)."""
    rng = random.Random(f"{seed}:{ck.name}:{cls}:{index}")
    return _MUTATORS[cls](ck, rng)


def _check_mutant(ck, layer: str, artifact) -> List[Diagnostic]:
    if layer == "config":
        return errors(check_config(artifact, ck.arch))
    if layer == "mapping":
        return errors(check_mapping(artifact))
    csv_text, manifest = artifact
    return errors(check_stream(csv_text, manifest,
                               rf_write_ports=ck.arch.rf_write_ports))


def _probe_dead(ck, layer: str, artifact, seeds=(0, 1)) -> bool:
    """True iff the mutant is execution-equivalent to the original over
    the probe seeds (a dead lane) — the only acceptable excuse for a
    checker miss."""
    try:
        if layer == "config":
            from ..core.simulator import simulate
            for seed in seeds:
                banks = ck.random_banks(seed)
                ref = simulate(ck.cfg, banks, ck.invocations, ck.mapped_iters)
                got = simulate(artifact, banks, ck.invocations,
                               ck.mapped_iters)
                for k in ref:
                    if not np.array_equal(np.asarray(ref[k]),
                                          np.asarray(got[k])):
                        return False
            return True
        if layer == "stream":
            from ..isa.interp import interpret, parse_stream
            from ..isa.encode import manifest_dict, to_csv
            orig = parse_stream(to_csv(ck.cfg), manifest_dict(ck.cfg, ck.name))
            mut = parse_stream(*artifact)
            for seed in seeds:
                banks = ck.random_banks(seed)
                ref = interpret(orig, banks, ck.invocations, ck.mapped_iters)
                got = interpret(mut, banks, ck.invocations, ck.mapped_iters)
                for k in ref:
                    if not np.array_equal(np.asarray(ref[k]),
                                          np.asarray(got[k])):
                        return False
            return True
        # mapping layer: regenerate the config; identical bytes mean the
        # corruption never reaches an executable artifact
        from ..core.config_gen import generate_config
        cfg = generate_config(artifact, ck.layout)
        return cfg.to_json() == ck.cfg.to_json()
    except Exception:
        # the mutant does not even execute/regenerate: visibly corrupt,
        # hence a live miss
        return False


def run_corpus(cks, seed: int = 0, per_class: int = 2,
               probe_dead: bool = True) -> CorpusReport:
    """The full corpus over ``cks``: ``per_class`` seeded mutants of every
    class for every kernel (classes without a site on a kernel are
    skipped, not counted)."""
    report = CorpusReport()
    for ck in cks:
        for cls in CLASSES:
            layer, intended = CLASSES[cls]
            for i in range(per_class):
                made = mutate_one(ck, cls, seed=seed, index=i)
                if made is None:
                    break
                artifact, desc = made
                fired = [d.rule for d in _check_mutant(ck, layer, artifact)]
                caught = intended in fired
                dead = None
                if not caught and probe_dead:
                    dead = _probe_dead(ck, layer, artifact)
                report.outcomes.append(MutationOutcome(
                    kernel=ck.name, cls=cls, layer=layer,
                    intended_rule=intended, description=desc,
                    caught=caught, fired=fired, dead=dead))
    return report


def mutation_gate(cks, seed: int = 0, per_class: int = 2,
                  min_score: float = MIN_SCORE) -> CorpusReport:
    """Run the corpus and enforce the PR-10 acceptance bar: score >=
    ``min_score``, every class caught at least once by its intended rule,
    and no live (simulator-visible) miss.  Raises AssertionError with the
    offending outcomes; returns the report."""
    report = run_corpus(cks, seed=seed, per_class=per_class)
    problems: List[str] = []
    if report.score < min_score:
        problems.append(f"mutation score {report.score:.3f} < {min_score}")
    produced = {o.cls for o in report.outcomes}
    for cls in produced:
        if not any(o.caught for o in report.outcomes if o.cls == cls):
            problems.append(f"class {cls!r} never caught by its intended "
                            f"rule {CLASSES[cls][1]}")
    for o in report.live_misses:
        problems.append(f"LIVE MISS {o.kernel}/{o.cls}: {o.description} "
                        f"(fired: {sorted(set(o.fired))})")
    if problems:
        raise AssertionError("mutation gate failed:\n  " +
                             "\n  ".join(problems))
    return report
