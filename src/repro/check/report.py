"""Whole-artifact checking and the byte-deterministic ``check_report.json``.

:func:`check_kernel` runs all three layers over one
:class:`~repro.core.toolchain.CompiledKernel` — mapping, config, and the
in-memory encoding of its instruction stream — and is pure: no
simulation, no RNG, no wall clock, no filesystem reads.  Reports built
from it serialize with sorted keys and compact separators, so two runs
over the same artifacts produce byte-identical files (the CI
``check-smoke`` job ``cmp``'s them).
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Sequence

from .config import check_config
from .diagnostics import Diagnostic, ERROR, RULES
from .mapping import check_mapping
from .stream import check_stream

REPORT_SCHEMA = 1

LAYERS = ("mapping", "config", "stream")


def check_kernel(ck, layers: Sequence[str] = LAYERS) -> List[Diagnostic]:
    """All static diagnostics for one compiled kernel, in canonical order
    (mapping first, then config, then stream; sorted within each layer)."""
    diags: List[Diagnostic] = []
    if "mapping" in layers:
        diags += check_mapping(ck.mapping)
    if "config" in layers:
        diags += check_config(ck.cfg, ck.arch)
    if "stream" in layers:
        from ..isa.encode import manifest_dict, to_csv
        try:
            csv_text = to_csv(ck.cfg)
            manifest = manifest_dict(ck.cfg, ck.name)
        except Exception as e:
            # a config too corrupt to even encode (e.g. an opcode with no
            # mnemonic) has no stream to audit; report the encode failure
            # rather than crash — the config layer names the root cause
            diags.append(Diagnostic(
                rule="STR-PARSE", severity=ERROR, locus="stream",
                message=f"instruction stream cannot be encoded: {e}"))
        else:
            diags += check_stream(csv_text, manifest,
                                  rf_write_ports=ck.arch.rf_write_ports)
    return diags


def errors(diags: Iterable[Diagnostic]) -> List[Diagnostic]:
    return [d for d in diags if d.severity == ERROR]


def assert_clean(ck) -> None:
    """The MORPHER_CHECK=1 contract: a clean compiled artifact is
    diagnostic-free.  Raises ``AssertionError`` naming every rule that
    fired."""
    found = errors(check_kernel(ck))
    if found:
        listing = "\n".join(f"  {d}" for d in found[:20])
        more = "" if len(found) <= 20 else f"\n  ... and {len(found) - 20} more"
        raise AssertionError(
            f"static check: {ck.name} has {len(found)} diagnostic(s):\n"
            f"{listing}{more}")


def report_dict(per_kernel: "Dict[str, dict]") -> dict:
    """Assemble the ``check_report.json`` payload.

    ``per_kernel`` maps a report key (kernel name, or ``arch/kernel``) to
    ``{"II": int, "cache_key": str, "diagnostics": [Diagnostic, ...]}``.
    """
    kernels = {}
    total = 0
    for key in sorted(per_kernel):
        entry = per_kernel[key]
        diags = entry["diagnostics"]
        total += len(errors(diags))
        kernels[key] = {
            "II": entry.get("II"),
            "cache_key": entry.get("cache_key"),
            "n_diagnostics": len(diags),
            "diagnostics": [d.to_json_dict() for d in diags],
        }
    return {
        "schema": REPORT_SCHEMA,
        "rules": dict(RULES),
        "kernels": kernels,
        "n_kernels": len(kernels),
        "n_errors": total,
        "clean": total == 0,
    }


def report_json(per_kernel: "Dict[str, dict]") -> str:
    return json.dumps(report_dict(per_kernel), sort_keys=True,
                      separators=(",", ":")) + "\n"
