"""Stream legality: audit the exported ``instructions.csv`` / manifest
pair from the raw text alone.

This is a deliberately independent re-derivation of the config checker's
facts from the CSV mnemonics — it shares the mnemonic *tables* with
``core.config_gen`` (the single source of truth for spellings) but not
the :class:`SimConfig` planes, the encoder, or the interpreter's parsed
``Insn`` form, so it doubles as a structural auditor of ``isa.encode``:
a bug that makes the encoder emit an illegal stream fires here even when
the in-memory config was legal.

Bank extents are reconstructed the way a deployment target would: sort
the manifest's declared word offsets; each bank spans from its offset to
the next (the last bank ends at ``total_words - 1``, the trailing
scratch word).
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

from ..core.config_gen import (KIND_BY_MNEMONIC, KIND_LIREG, KIND_NONE,
                               KIND_REG, OPC_BY_MNEMONIC, OPC_LOAD,
                               OPC_NONE, OPC_STORE)
from ..isa.encode import DIRS, STREAM_FORMAT

from .diagnostics import Diagnostic, ERROR, cell_locus, sort_diagnostics

_SEL_RE = re.compile(r"^([a-z_]+?)(\d*)$")

_IN_KINDS = {f"in_{d}": di for di, d in enumerate(DIRS)}

# mnemonics whose result lands in the FU output register one cycle after
# issue (everything except nop, load and store)
_RESULT_MNEMONICS = frozenset(
    m for m, c in OPC_BY_MNEMONIC.items()
    if c not in (OPC_NONE, OPC_LOAD, OPC_STORE))

_MANIFEST_KEYS = ("stream_format", "II", "P", "RF", "LI", "depth",
                  "total_words", "bank_offsets", "liveins", "neighbors",
                  "columns")


def _bank_extents(manifest: dict) -> Dict[int, int]:
    """word offset -> words, reconstructed from the manifest's declared
    offsets and total_words (scratch word excluded)."""
    offs = sorted(int(off) for off in manifest["bank_offsets"].values())
    end = int(manifest["total_words"]) - 1
    extents: Dict[int, int] = {}
    for i, off in enumerate(offs):
        nxt = offs[i + 1] if i + 1 < len(offs) else end
        extents[off] = nxt - off
    return extents


def check_stream(csv_text: str, manifest: dict, *,
                 rf_write_ports: Optional[int] = None) -> List[Diagnostic]:
    """Audit a CSV/manifest pair; returns sorted diagnostics.

    ``rf_write_ports`` is optional because the manifest does not carry it;
    pass the architecture's value to enable the ``STR-RF-WPORTS`` rule.
    """
    diags: List[Diagnostic] = []

    def err(rule: str, locus: str, message: str):
        diags.append(Diagnostic(rule, ERROR, locus, message))

    missing = [k for k in _MANIFEST_KEYS if k not in manifest]
    if missing:
        err("STR-PARSE", "manifest", f"manifest lacks keys {missing}")
        return sort_diagnostics(diags)
    if manifest["stream_format"] != STREAM_FORMAT:
        err("STR-PARSE", "manifest",
            f"stream_format {manifest['stream_format']} != supported "
            f"{STREAM_FORMAT}")
        return sort_diagnostics(diags)

    II, P, RF, LI = (int(manifest[k]) for k in ("II", "P", "RF", "LI"))
    depth = int(manifest["depth"])
    neighbors = manifest["neighbors"]
    liveins = {(int(pe), int(idx))
               for pe, idx in manifest["liveins"].values()}

    lines = csv_text.splitlines()
    if not lines:
        err("STR-PARSE", "stream", "empty CSV")
        return sort_diagnostics(diags)
    header = lines[0].split(",")
    if header != list(manifest["columns"]):
        err("STR-PARSE", "stream",
            "CSV header does not match the manifest column list")
        return sort_diagnostics(diags)
    records = [ln.split(",") for ln in lines[1:] if ln]
    if len(records) != II * P:
        err("STR-PARSE", "stream",
            f"{len(records)} records for an II={II} x P={P} stream "
            f"(expected {II * P}; truncated or padded)")
        return sort_diagnostics(diags)

    col = {c: i for i, c in enumerate(header)}

    def field(rec: List[str], name: str) -> str:
        return rec[col[name]]

    def int_field(rec: List[str], name: str, locus: str) -> Optional[int]:
        v = field(rec, name)
        try:
            return int(v)
        except ValueError:
            err("STR-PARSE", locus, f"column {name} is not an integer: {v!r}")
            return None

    extents = _bank_extents(manifest)
    seen: Dict[Tuple[int, int], bool] = {}
    load_cells = set()
    result_cells: Dict[Tuple[int, int], str] = {}
    bank_port: Dict[Tuple[int, int], List[int]] = {}

    def check_sel(locus: str, pe: int, what: str, text: str):
        m = _SEL_RE.match(text)
        if not m or m.group(1) not in KIND_BY_MNEMONIC:
            err("STR-SEL-RANGE", locus, f"{what} select unparseable: {text!r}")
            return
        mnem, idx_s = m.group(1), m.group(2)
        kind = KIND_BY_MNEMONIC[mnem]
        if kind in (KIND_REG, KIND_LIREG):
            if not idx_s:
                err("STR-SEL-RANGE", locus,
                    f"{what} select {mnem!r} needs an index")
                return
            idx = int(idx_s)
            bound = RF if kind == KIND_REG else LI
            if not (0 <= idx < bound):
                err("STR-SEL-RANGE", locus,
                    f"{what} reads {text}, outside the {bound}-entry "
                    f"{'register file' if kind == KIND_REG else 'live-in registers'}")
            elif kind == KIND_LIREG and (pe, idx) not in liveins:
                err("STR-LIVEIN", locus,
                    f"{what} reads {text} on pe{pe}, which the manifest "
                    f"never initializes")
        else:
            if idx_s:
                err("STR-SEL-RANGE", locus,
                    f"{what} select {text!r} carries a stray index")
            elif mnem in _IN_KINDS and kind != KIND_NONE:
                di = _IN_KINDS[mnem]
                if neighbors[pe][di] is None:
                    err("STR-SEL-RANGE", locus,
                        f"{what} reads {mnem} but pe{pe} has no "
                        f"{DIRS[di]} neighbour wire")

    for rec in records:
        if len(rec) != len(header):
            err("STR-PARSE", "stream",
                f"record has {len(rec)} fields, header has {len(header)}")
            continue
        slot = int_field(rec, "slot", "stream")
        pe = int_field(rec, "pe", "stream")
        if slot is None or pe is None:
            continue
        locus = cell_locus(slot, pe)
        if not (0 <= slot < II and 0 <= pe < P):
            err("STR-PARSE", locus, "record outside the (II, P) grid")
            continue
        if (slot, pe) in seen:
            err("STR-PARSE", locus, "duplicate record")
            continue
        seen[(slot, pe)] = True

        opcode = field(rec, "opcode")
        if opcode not in OPC_BY_MNEMONIC:
            err("STR-OPC", locus, f"unknown opcode mnemonic {opcode!r}")
            opcode = "nop"
        if opcode == "load":
            load_cells.add((slot, pe))
        if opcode in _RESULT_MNEMONICS:
            result_cells[(slot, pe)] = opcode

        tstart = int_field(rec, "tstart", locus)
        if tstart is not None:
            if opcode != "nop":
                if tstart < 0 or tstart > depth - 2 or tstart % II != slot:
                    err("STR-STORE-WINDOW", locus,
                        f"{opcode} window starts at t{tstart}, which is not "
                        f"on slot {slot} within depth {depth}")
            elif tstart != 0:
                err("STR-STORE-WINDOW", locus,
                    f"nop record carries stray window start t{tstart}")

        moff = int_field(rec, "mem_off", locus)
        mwords = int_field(rec, "mem_words", locus)
        if moff is not None and mwords is not None:
            if opcode in ("load", "store"):
                if extents.get(moff) != mwords:
                    err("STR-BANK-RANGE", locus,
                        f"{opcode} binding (off={moff}, words={mwords}) "
                        f"matches no bank derivable from the manifest")
                else:
                    bank_port.setdefault((moff, slot), []).append(pe)
            elif (moff, mwords) != (0, 1):
                err("STR-BANK-RANGE", locus,
                    f"non-memory record carries stray binding "
                    f"(off={moff}, words={mwords})")

        for o in range(3):
            check_sel(locus, pe, f"op{o}", field(rec, f"op{o}"))
            int_field(rec, f"op{o}_fb", locus)
            int_field(rec, f"op{o}_fv", locus)
        for d in DIRS:
            check_sel(locus, pe, f"xo_{d}", field(rec, f"xo_{d}"))
        writes = 0
        for r in range(RF):
            text = field(rec, f"rf{r}")
            if text != "none":
                writes += 1
            check_sel(locus, pe, f"rf{r}", text)
        if rf_write_ports is not None and writes > rf_write_ports:
            err("STR-RF-WPORTS", locus,
                f"{writes} register-file writebacks exceed "
                f"{rf_write_ports} write ports")
        int_field(rec, "imm", locus)

    if len(seen) != II * P:
        err("STR-PARSE", "stream",
            f"only {len(seen)} of {II * P} (slot, pe) cells are present")

    for (off, slot), pes in sorted(bank_port.items()):
        if len(pes) > 1:
            err("STR-BANK-PORT", f"slot{slot}/off{off}",
                f"{len(pes)} memory ops share the bank at word offset "
                f"{off}: {[f'pe{p}' for p in pes]}")

    if II > 1:
        for (slot, pe) in sorted(load_cells):
            nxt = ((slot + 1) % II, pe)
            if nxt in result_cells:
                err("STR-LOAD-HAZARD", cell_locus(nxt[0], pe),
                    f"{result_cells[nxt]} result is clobbered by the load "
                    f"completing from slot {slot}")

    return sort_diagnostics(diags)
