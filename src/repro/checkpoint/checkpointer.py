"""Fault-tolerant checkpointing: async, sharded, mesh-independent restore.

Layout per step:
    <dir>/step_<N>.tmp/ -> atomically renamed to <dir>/step_<N>/
        manifest.json            (pytree structure + shapes + dtypes + step)
        shard_<host>.npz         (this host's param/opt leaves, gathered
                                  per-leaf to host-local addressable shards)

Properties required at 1000+ nodes:
  * async: `save` snapshots device arrays to host memory synchronously
    (cheap) and writes to disk on a background thread — training continues;
  * atomic: tmp-dir + rename, so a node failure mid-write never corrupts
    the latest checkpoint;
  * elastic restore: the manifest stores *logical* arrays; `restore` loads
    onto ANY mesh via jax.make_array_from_callback with the new sharding —
    scale up/down without conversion (dist/elastic.py drives this);
  * keep-k GC.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flat_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        self.wait()
        # synchronous device->host snapshot (consistency point)
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        treedef = jax.tree.structure(tree)

        def write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            leaves = _flat_with_paths(host_tree)
            manifest = {
                "step": step,
                "leaves": [{"path": p, "shape": list(np.shape(l)),
                            "dtype": str(np.asarray(l).dtype)}
                           for p, l in leaves],
            }
            np.savez(os.path.join(tmp, "shard_0.npz"),
                     **{f"leaf_{i}": np.asarray(l)
                        for i, (_p, l) in enumerate(leaves)})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any,
                sharding_fn: Optional[Callable] = None) -> Any:
        """Restore into the structure of `like`; if sharding_fn(leaf_path,
        shape) returns a Sharding, build global arrays on the current mesh
        (elastic restore onto any topology)."""
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "shard_0.npz"))
        paths = [l["path"] for l in manifest["leaves"]]
        arrays = [data[f"leaf_{i}"] for i in range(len(paths))]

        like_leaves = _flat_with_paths(like)
        assert len(like_leaves) == len(arrays), \
            f"leaf count mismatch {len(like_leaves)} != {len(arrays)}"
        by_path = dict(zip(paths, arrays))
        out_leaves = []
        for path, leaf in like_leaves:
            arr = by_path[path]
            if sharding_fn is not None:
                sh = sharding_fn(path, arr.shape)
                arr = jax.make_array_from_callback(
                    arr.shape, sh, lambda idx, a=arr: a[idx])
            out_leaves.append(arr)
        treedef = jax.tree.structure(like)
        return jax.tree.unflatten(treedef, out_leaves)
