"""codeqwen1.5-7b — qwen1.5-arch (MHA).  [hf:Qwen/CodeQwen1.5-7B; hf]
32L d_model=4096 32H (GQA kv=32) d_ff=13440 vocab=92416."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=13440, vocab=92416, head_dim=128,
)
