"""deepseek-v3-671b — MLA, 1 shared + 256 routed top-8, first 3 dense,
MTP auxiliary head.  [arXiv:2412.19437; hf]
61L d_model=7168 128H d_ff=2048(expert) vocab=129280."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=2048, vocab=129280, head_dim=128,
    moe=True, n_experts=256, top_k=8, n_shared_experts=1,
    moe_d_ff=2048, first_k_dense=3, dense_d_ff=18432,
    mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    mtp=True,
)
