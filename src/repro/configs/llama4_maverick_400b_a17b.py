"""llama4-maverick-400b-a17b — MoE 128e top-1 (+1 shared expert), early
fusion dropped (text backbone per assignment).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=8192, vocab=202048, head_dim=128,
    moe=True, n_experts=128, top_k=1, n_shared_experts=1,
    moe_d_ff=8192, first_k_dense=0, dense_d_ff=0,
)
