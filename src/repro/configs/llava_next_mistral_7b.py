"""llava-next-mistral-7b — mistral backbone, anyres vision stub.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.  The anyres tiling
vision tower is a stub: input_specs() provides patch embeddings already
projected to d_model, concatenated with text embeddings upstream."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, head_dim=128, input_mode="embeddings",
)
