"""musicgen-large — decoder-only over EnCodec tokens.  [arXiv:2306.05284; hf]
48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048.  Modality frontend is a
stub: input_specs() provides precomputed frame embeddings (input_mode
= embeddings); the EnCodec tokenizer/codebook interleaving stays upstream."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=2048, head_dim=64, input_mode="embeddings",
)
