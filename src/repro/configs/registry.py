"""Architecture registry: --arch <id> resolves here.

Each config file defines CONFIG (exact assigned dims, sources in the
assignment block) and the registry maps ids -> ModelConfig.  Input-shape
cells (seq_len x global_batch) are defined here too.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from ..models.common import ModelConfig

ARCH_IDS = [
    "rwkv6-1.6b",
    "llama3.2-1b",
    "llama3.2-3b",
    "granite-34b",
    "codeqwen1.5-7b",
    "zamba2-1.2b",
    "musicgen-large",
    "llava-next-mistral-7b",
    "llama4-maverick-400b-a17b",
    "deepseek-v3-671b",
]

_MODULE = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULE:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULE[arch_id]}")
    return mod.CONFIG


def serve_smoke_config(arch_id: str) -> ModelConfig:
    """Shrunken same-family config for serve smoke runs (CI serve-smoke,
    the serve_decode benchmark, tests): the reduced() CPU config, renamed
    so serve-plan artifacts can't be mistaken for the full model's."""
    import dataclasses
    cfg = get_config(arch_id).reduced()
    return dataclasses.replace(cfg, name=f"{cfg.name}-serve-smoke")


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def runnable(cfg: ModelConfig, shape: ShapeCell) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic attention (O(1)-state recurrence):
    skip for full-attention archs, run for SSM/hybrid (DESIGN.md section 5)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("skip: full quadratic attention cannot decode at "
                       "524288 context; arch defines no sub-quadratic path")
    return True, ""


def all_cells() -> List[Tuple[str, str]]:
    return [(a, s) for a in ARCH_IDS for s in SHAPES]
