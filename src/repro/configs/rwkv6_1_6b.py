"""rwkv6-1.6b — Finch: attention-free, data-dependent decay.
[arXiv:2404.05892; unverified]  24L d_model=2048 d_ff=7168 vocab=65536."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab=65536, head_dim=64,
)
