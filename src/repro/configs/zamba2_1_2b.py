"""zamba2-1.2b — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; hf]  38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000,
ssm_state=64.  Shared transformer block applied every 6 mamba layers
(Zamba2-style; per-application LoRA simplified to shared weights —
DESIGN.md section 5)."""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000, head_dim=64,
    ssm_state=64, attn_every=6, conv_kernel=4,
)
