"""Morpher reproduction core: the integrated CGRA flow (paper Fig. 3).

The whole compile pipeline is re-exported here so callers can write

    from repro.core import Toolchain, MapperOptions, build_gemm

    ck = Toolchain().compile(build_gemm(TI=6, TK=8, TJ=6))
    ck.verify()

Attributes resolve lazily (PEP 562) so importing ``repro.core`` does not
pull in JAX until the simulator is actually used.
"""
from __future__ import annotations

import importlib

# public name -> submodule providing it
_FLOW = {
    # staged toolchain (the canonical API)
    "Toolchain": ".toolchain",
    "CompiledKernel": ".toolchain",
    "default_toolchain": ".toolchain",
    "default_cache_dir": ".toolchain",
    "spec_cache_key": ".toolchain",
    # mapper
    "MapperOptions": ".mapper",
    "Mapping": ".mapper",
    "MapError": ".mapper",
    "map_kernel": ".mapper",          # deprecated shim
    "map_kernel_opts": ".mapper",
    "compute_mii": ".mapper",
    # architecture description
    "CGRAArch": ".adl",
    "cluster_4x4": ".adl",
    "morpher_8x8": ".adl",
    # kernels / IR / layout
    "KernelSpec": ".kernels_lib",
    "build_gemm": ".kernels_lib",
    "build_conv": ".kernels_lib",
    "table1_kernels": ".kernels_lib",
    "DFG": ".dfg",
    "DFGBuilder": ".dfg",
    "DataLayout": ".layout",
    "assign_layout": ".layout",
    # configuration + simulation + verification
    "SimConfig": ".config_gen",
    "generate_config": ".config_gen",
    "narrowed_planes": ".config_gen",
    "simulate": ".simulator",
    "simulate_batch": ".simulator",
    "generate_test_data": ".verify",
    "generate_test_data_batch": ".verify",
    "check_dfg_semantics": ".verify",
    "verify_mapping": ".verify",      # deprecated shim
    # cost model
    "kernel_cost": ".costmodel",
    "KernelCost": ".costmodel",
}

__all__ = sorted(_FLOW)


def __getattr__(name: str):
    try:
        modname = _FLOW[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    return getattr(importlib.import_module(modname, __name__), name)


def __dir__():
    return sorted(set(globals()) | set(_FLOW))
