"""Architecture Description Language (ADL) for CGRAs.

Analogue of Morpher's JSON ADL (paper Fig. 3 piece 2 / section III).  A
``CGRAArch`` captures everything the mapper, configuration generator and
simulator need:

  * an R x C grid of PEs, each with a functional unit (op set), a small
    routing register file, four registered crossbar output ports (N/E/S/W)
    and a live-in scalar register file pre-loaded by the host,
  * multi-banked data memories attached to boundary PEs via shared buses
    (one access port per bank per cycle),
  * datapath bit-width (the paper's target is 16-bit),
  * logical clustering (the 8x8 target = 4 clusters of 4x4, two 8 kB banks
    per cluster).

The ADL is (de)serializable to JSON so user-defined architectures can be
swapped in, mirroring Morpher's architecture-adaptive design.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field, asdict
from typing import Dict, FrozenSet, List, Optional, Tuple

from .dfg import Op, ALU_OPS, MEM_OPS

# Directions: index into the crossbar output ports of each PE.
DIRS = ("N", "E", "S", "W")
OPP = {"N": "S", "S": "N", "E": "W", "W": "E"}
DIR_IDX = {d: i for i, d in enumerate(DIRS)}


@dataclass(frozen=True)
class MemBank:
    id: int
    size_bytes: int
    # PEs (flat ids) that may issue LOAD/STORE to this bank (shared bus).
    pes: Tuple[int, ...]

    @property
    def words(self) -> int:
        return self.size_bytes // 2  # 16-bit words


@dataclass
class CGRAArch:
    name: str
    rows: int
    cols: int
    datapath_bits: int = 16
    regfile_size: int = 8          # routing registers per PE
    livein_regs: int = 4           # host-preloaded scalar registers per PE
    rf_write_ports: int = 2
    banks: List[MemBank] = field(default_factory=list)
    torus: bool = False
    # ops supported by every PE FU (homogeneous by default; heterogeneous
    # grids override per_pe_ops)
    fu_ops: FrozenSet[str] = frozenset(o.value for o in (ALU_OPS | MEM_OPS |
                                                         {Op.CONST, Op.LIVEIN}))
    per_pe_ops: Dict[int, FrozenSet[str]] = field(default_factory=dict)
    clusters: List[List[int]] = field(default_factory=list)

    # ------------------------------------------------------------- topology
    @property
    def n_pes(self) -> int:
        return self.rows * self.cols

    def pe_id(self, r: int, c: int) -> int:
        return r * self.cols + c

    def pe_rc(self, p: int) -> Tuple[int, int]:
        return divmod(p, self.cols)

    def neighbor(self, p: int, d: str) -> Optional[int]:
        r, c = self.pe_rc(p)
        if d == "N":
            r -= 1
        elif d == "S":
            r += 1
        elif d == "E":
            c += 1
        elif d == "W":
            c -= 1
        if self.torus:
            r %= self.rows
            c %= self.cols
        elif not (0 <= r < self.rows and 0 <= c < self.cols):
            return None
        return self.pe_id(r, c)

    def neighbors(self, p: int) -> List[Tuple[str, int]]:
        out = []
        for d in DIRS:
            q = self.neighbor(p, d)
            if q is not None:
                out.append((d, q))
        return out

    def manhattan(self, p: int, q: int) -> int:
        pr, pc = self.pe_rc(p)
        qr, qc = self.pe_rc(q)
        return abs(pr - qr) + abs(pc - qc)

    # --------------------------------------------------------------- memory
    @property
    def mem_pes(self) -> FrozenSet[int]:
        s: set = set()
        for b in self.banks:
            s.update(b.pes)
        return frozenset(s)

    def bank(self, bank_id: int) -> MemBank:
        """The bank with ``MemBank.id == bank_id``.  Banks are identified by
        their declared id everywhere (layout placements, ``bank<id>`` memory
        images, mapper bus constraints), never by list position — a user ADL
        may declare banks in any order.

        The id map is memoized against the identity of ``self.banks`` (the
        mapper calls this in placement inner loops); rebinding the list —
        how tests and programmatic edits mutate an arch — invalidates it.
        """
        cached = self.__dict__.get("_bank_by_id")
        if cached is None or cached[0] is not self.banks:
            cached = (self.banks, {b.id: b for b in self.banks})
            self.__dict__["_bank_by_id"] = cached
        try:
            return cached[1][bank_id]
        except KeyError:
            raise KeyError(f"{self.name}: no memory bank with id "
                           f"{bank_id}") from None

    def banks_of_pe(self, p: int) -> List[int]:
        return [b.id for b in self.banks if p in b.pes]

    def pes_of_bank(self, bank_id: int) -> Tuple[int, ...]:
        return self.bank(bank_id).pes

    def supports(self, p: int, op: Op) -> bool:
        ops = self.per_pe_ops.get(p, self.fu_ops)
        if op in MEM_OPS and p not in self.mem_pes:
            return False
        return op.value in ops

    # --------------------------------------------------------- serialization
    def to_json(self) -> str:
        d = asdict(self)
        d["fu_ops"] = sorted(self.fu_ops)
        d["per_pe_ops"] = {str(k): sorted(v) for k, v in self.per_pe_ops.items()}
        d["banks"] = [{"id": b.id, "size_bytes": b.size_bytes,
                       "pes": list(b.pes)} for b in self.banks]
        return json.dumps(d, indent=2)

    @staticmethod
    def from_json(s: str) -> "CGRAArch":
        """Deserialize (and validate) an ADL JSON architecture.

        Validation happens here so malformed user ADL files
        (``edge_deploy.py --arch-file``, DSE inputs) fail loudly at load
        time instead of flowing into the mapper as opaque errors."""
        d = json.loads(s)
        banks = [MemBank(b["id"], b["size_bytes"], tuple(b["pes"]))
                 for b in d.pop("banks")]
        d["fu_ops"] = frozenset(d["fu_ops"])
        d["per_pe_ops"] = {int(k): frozenset(v)
                           for k, v in d.pop("per_pe_ops", {}).items()}
        arch = CGRAArch(banks=banks, **d)
        arch.validate()
        return arch

    def validate(self) -> None:
        """Raises ValueError on an inconsistent architecture (real errors,
        not asserts: this guards untrusted user ADL files, e.g.
        ``edge_deploy.py --arch-file``, and must survive ``python -O``)."""
        if self.rows <= 0 or self.cols <= 0:
            raise ValueError(f"{self.name}: grid {self.rows}x{self.cols} "
                             f"must be positive")
        if self.torus and (self.rows < 2 or self.cols < 2):
            # a 1-wide torus wraps a PE's N/S (or E/W) wires back onto
            # itself: neighbor() would return the PE as its own neighbour,
            # an out-of-range reference the router cannot represent (today
            # this only surfaces deep in config generation)
            raise ValueError(f"{self.name}: torus grid {self.rows}x"
                             f"{self.cols} wraps a PE onto itself; tori "
                             f"need rows >= 2 and cols >= 2")
        seen_ids: set = set()
        for b in self.banks:
            if b.id in seen_ids:
                raise ValueError(f"{self.name}: duplicate memory bank id "
                                 f"{b.id}")
            seen_ids.add(b.id)
            if b.size_bytes <= 0 or b.size_bytes % 2:
                # a zero/odd-sized bank collapses to 0 words: its derived
                # word interval is empty and its global offset aliases the
                # next bank's in every SimConfig built on this arch
                raise ValueError(f"{self.name}: bank {b.id} size_bytes "
                                 f"{b.size_bytes} must be a positive "
                                 f"multiple of 2 (16-bit words), else its "
                                 f"word offsets overlap the next bank's")
            if len(set(b.pes)) != len(b.pes):
                raise ValueError(f"{self.name}: bank {b.id} lists a PE "
                                 f"more than once on its bus: {b.pes}")
            for p in b.pes:
                if not 0 <= p < self.n_pes:
                    raise ValueError(f"{self.name}: bank {b.id} references "
                                     f"PE {p} outside the {self.n_pes}-PE grid")
        if self.regfile_size < 1 or self.livein_regs < 0:
            raise ValueError(f"{self.name}: regfile_size must be >= 1 and "
                             f"livein_regs >= 0")
        for ci, cluster in enumerate(self.clusters):
            for p in cluster:
                if not 0 <= p < self.n_pes:
                    raise ValueError(
                        f"{self.name}: cluster {ci} references PE {p} "
                        f"outside the {self.n_pes}-PE grid")
        for p in self.per_pe_ops:
            if not 0 <= p < self.n_pes:
                raise ValueError(
                    f"{self.name}: per_pe_ops references PE {p} outside "
                    f"the {self.n_pes}-PE grid")


# ----------------------------------------------------------- stock designs
def cluster_4x4(bank_kb: int = 8, regfile: int = 8,
                name: str = "morpher-cluster-4x4") -> CGRAArch:
    """One cluster of the paper's target: 4x4 PEs, two 8 kB banks, memory
    access from the left and right boundary columns (shared bus per bank)."""
    rows = cols = 4
    left = tuple(r * cols + 0 for r in range(rows))
    right = tuple(r * cols + (cols - 1) for r in range(rows))
    arch = CGRAArch(
        name=name, rows=rows, cols=cols, datapath_bits=16,
        regfile_size=regfile,
        banks=[MemBank(0, bank_kb * 1024, left),
               MemBank(1, bank_kb * 1024, right)],
        clusters=[list(range(16))],
    )
    arch.validate()
    return arch


def morpher_8x8(bank_kb: int = 8) -> CGRAArch:
    """The paper's full target CGRA: 8x8 PEs = 4 logical clusters of 4x4,
    8 data memories on the left/right boundary PEs (2 banks per cluster)."""
    rows = cols = 8
    banks: List[MemBank] = []
    clusters: List[List[int]] = []
    bid = 0
    for cr in range(2):
        for cc in range(2):
            pes = [ (cr * 4 + r) * cols + (cc * 4 + c)
                    for r in range(4) for c in range(4) ]
            clusters.append(pes)
            # the cluster's boundary column that coincides with the chip
            # boundary hosts its two banks
            col = 0 if cc == 0 else cols - 1
            side = tuple((cr * 4 + r) * cols + col for r in range(4))
            banks.append(MemBank(bid, bank_kb * 1024, side[:2]))
            banks.append(MemBank(bid + 1, bank_kb * 1024, side[2:]))
            bid += 2
    arch = CGRAArch(name="morpher-8x8", rows=rows, cols=cols,
                    datapath_bits=16, banks=banks, clusters=clusters)
    arch.validate()
    return arch
