"""Configuration generation (paper Fig. 3 piece 6, adapted).

Morpher's architecture generator emits Verilog RTL plus per-PE control
memories; the artifact the control memories consume is the cycle-by-cycle
configuration.  This module generates exactly that artifact from a Mapping:
for each of the II slots and each PE — FU opcode, operand mux selects,
immediate, crossbar output selects, register-file write selects, memory
bank binding, and store-validity windows (the control-module iteration
counters that gate prologue/epilogue side effects).

The output `SimConfig` is a dense numpy struct-of-arrays, directly
consumed by the JAX cycle-accurate simulator and serializable to JSON for
inspection (the "mapping configuration file" of the paper).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from .adl import CGRAArch, DIRS, OPP, DIR_IDX
from .dfg import DFG, Op, wrap
from .layout import DataLayout
from .mapper import Mapping
from .mrrg import F, R

# operand-source mux kinds
KIND_NONE, KIND_IN_N, KIND_IN_E, KIND_IN_S, KIND_IN_W = 0, 1, 2, 3, 4
KIND_REG, KIND_FUOUT, KIND_IMM, KIND_LIREG = 5, 6, 7, 8
KIND_IN = {d: 1 + DIR_IDX[d] for d in DIRS}

# simulator opcodes
OPC = {None: 0, "pass": 1, Op.ADD: 2, Op.SUB: 3, Op.MUL: 4, Op.SHL: 5,
       Op.SHR: 6, Op.AND: 7, Op.OR: 8, Op.XOR: 9, Op.CMPGE: 10,
       Op.CMPEQ: 11, Op.CMPLT: 12, Op.SELECT: 13, Op.LOAD: 14, Op.STORE: 15}
OPC_NONE, OPC_PASS = 0, 1
OPC_LOAD, OPC_STORE = OPC[Op.LOAD], OPC[Op.STORE]

# bidirectional opcode <-> mnemonic map shared by the simulator, the
# instruction-stream exporter (repro.isa.encode) and the standalone
# interpreter (repro.isa.interp), so the three can never drift: the
# exporter writes MNEMONIC[code] into instructions.csv and the
# interpreter dispatches on those names
MNEMONIC = {code: ("nop" if key is None
                   else key if isinstance(key, str) else key.value)
            for key, code in OPC.items()}
OPC_BY_MNEMONIC = {m: c for c, m in MNEMONIC.items()}


def opcode_of(op: Optional[Op]) -> int:
    """FU opcode for a DFG node op.  CONST and LIVEIN lower to PASS (the
    value enters through the imm / live-in-register operand mux, not the
    ALU); every other op must have an explicit encoding — raising here is
    what keeps a newly added ``Op`` member from dying as a bare KeyError
    deep inside config generation."""
    if op in (Op.CONST, Op.LIVEIN):
        return OPC_PASS
    try:
        return OPC[op]
    except KeyError:
        raise NotImplementedError(
            f"op {op!r} has no simulator opcode encoding — add it to "
            f"config_gen.OPC") from None


# operand/writeback mux-kind <-> mnemonic map (same drift-proofing as
# MNEMONIC).  KIND_REG and KIND_LIREG selects carry an index; the CSV
# spelling is mnemonic+index ("reg3", "li0"), the rest are bare.
KIND_MNEMONIC = {KIND_NONE: "none", KIND_IN_N: "in_n", KIND_IN_E: "in_e",
                 KIND_IN_S: "in_s", KIND_IN_W: "in_w", KIND_REG: "reg",
                 KIND_FUOUT: "fu", KIND_IMM: "imm", KIND_LIREG: "li"}
KIND_BY_MNEMONIC = {m: k for k, m in KIND_MNEMONIC.items()}
INDEXED_KINDS = (KIND_REG, KIND_LIREG)


@dataclass
class SimConfig:
    II: int
    P: int
    RF: int
    LI: int
    bits: int
    op: np.ndarray            # [II,P]
    imm: np.ndarray           # [II,P]
    src_kind: np.ndarray      # [II,P,3]
    src_idx: np.ndarray       # [II,P,3]
    force_before: np.ndarray  # [II,P,3]  (operand := force_val while t < this)
    force_val: np.ndarray     # [II,P,3]
    xo_kind: np.ndarray       # [II,P,4]
    xo_idx: np.ndarray        # [II,P,4]
    rf_kind: np.ndarray       # [II,P,RF]
    rf_idx: np.ndarray        # [II,P,RF]
    mem_off: np.ndarray       # [II,P]  global word offset of the bank
    mem_words: np.ndarray     # [II,P]
    valid_start: np.ndarray   # [II,P]  absolute schedule time of the node
    nbr_idx: np.ndarray       # [P,4]   pe index of neighbour in DIRS order
    nbr_ok: np.ndarray        # [P,4]
    bank_offsets: Dict[int, int]  # bank id -> global word offset
    total_words: int          # incl. trailing scratch word
    depth: int
    lireg_assign: Dict[str, Tuple[int, int]] = field(default_factory=dict)

    def livein_array(self, values: Dict[str, int]) -> np.ndarray:
        li = np.zeros((self.P, max(1, self.LI)), dtype=np.int32)
        for name, (pe, idx) in self.lireg_assign.items():
            li[pe, idx] = wrap(values.get(name, 0), self.bits)
        return li

    def n_cycles(self, n_iters: int) -> int:
        return (n_iters - 1) * self.II + self.depth

    def to_json(self) -> str:
        # underscore attributes are transient caches (e.g. the simulator's
        # device-resident plane copies), not part of the artifact.
        # Canonical form (sorted keys, compact separators): the same
        # byte-determinism contract as ServePlan.to_json, so artifacts
        # embedding a SimConfig are byte-stable across runs and machines.
        d = {k: (v.tolist() if isinstance(v, np.ndarray) else v)
             for k, v in self.__dict__.items() if not k.startswith("_")}
        return json.dumps(d, sort_keys=True, separators=(",", ":"))

    _ARRAY_DTYPES = {
        "op": np.int32, "imm": np.int32, "src_kind": np.int32,
        "src_idx": np.int32, "force_before": np.int32, "force_val": np.int32,
        "xo_kind": np.int32, "xo_idx": np.int32, "rf_kind": np.int32,
        "rf_idx": np.int32, "mem_off": np.int32, "mem_words": np.int32,
        "valid_start": np.int32, "nbr_idx": np.int32, "nbr_ok": bool,
    }

    @staticmethod
    def from_json(s: str) -> "SimConfig":
        d = json.loads(s)
        for k, dt in SimConfig._ARRAY_DTYPES.items():
            d[k] = np.asarray(d[k], dtype=dt)
        d["lireg_assign"] = {name: tuple(v)
                             for name, v in d["lireg_assign"].items()}
        # JSON object keys are strings; bank ids are ints
        d["bank_offsets"] = {int(k): v
                             for k, v in d["bank_offsets"].items()}
        return SimConfig(**d)


# the configuration planes the simulator consumes, in a stable order (the
# 13 II-slot-indexed planes first, then the static neighbour table)
SIM_PLANES = ("op", "imm", "src_kind", "src_idx", "force_before",
              "force_val", "xo_kind", "xo_idx", "rf_kind", "rf_idx",
              "mem_off", "mem_words", "valid_start", "nbr_idx")


def _fit_dtype(a: np.ndarray) -> np.dtype:
    """Smallest of int8/int16/int32 that represents every value exactly."""
    if a.size:
        lo, hi = int(a.min()), int(a.max())
        for dt in (np.int8, np.int16):
            info = np.iinfo(dt)
            if info.min <= lo and hi <= info.max:
                return np.dtype(dt)
    else:
        return np.dtype(np.int8)
    return np.dtype(np.int32)


def narrowed_planes(cfg: SimConfig) -> Dict[str, np.ndarray]:
    """Per-plane dtype narrowing for the simulator's config streams.

    Mux kinds, opcodes and register indices are tiny enumerations and
    addresses/immediates are bounded by the bank sizes and the datapath
    width, so most planes fit int8/int16.  The simulator pre-tiles these
    planes into per-cycle scan streams; narrowing shrinks those streams
    (and the executable's constant footprint) by ~4x, letting the tiling
    cap admit proportionally longer simulations.  Narrowing is exact —
    a plane is only demoted when every value round-trips — and planes
    that feed arithmetic are re-widened inside the simulator body, so
    simulation results are bit-identical to the int32 planes.
    """
    return {k: (lambda a: a.astype(_fit_dtype(a)))(np.asarray(getattr(cfg, k)))
            for k in SIM_PLANES}


def plane_dtypes(cfg: SimConfig) -> Dict[str, str]:
    """The narrowed dtype chosen for each simulator plane (introspection;
    derived from ``narrowed_planes`` so the two can never disagree)."""
    return {k: str(v.dtype) for k, v in narrowed_planes(cfg).items()}


class ConfigConflict(RuntimeError):
    pass


def generate_config(mapping: Mapping, layout: DataLayout) -> SimConfig:
    arch, dfg, II = mapping.arch, mapping.dfg, mapping.II
    P, RF, LI = arch.n_pes, arch.regfile_size, max(1, arch.livein_regs)
    bits = arch.datapath_bits

    op = np.zeros((II, P), dtype=np.int32)
    imm = np.zeros((II, P), dtype=np.int32)
    src_kind = np.zeros((II, P, 3), dtype=np.int32)
    src_idx = np.zeros((II, P, 3), dtype=np.int32)
    force_before = np.zeros((II, P, 3), dtype=np.int32)
    force_val = np.zeros((II, P, 3), dtype=np.int32)
    xo_kind = np.zeros((II, P, 4), dtype=np.int32)
    xo_idx = np.zeros((II, P, 4), dtype=np.int32)
    rf_kind = np.zeros((II, P, RF), dtype=np.int32)
    rf_idx = np.zeros((II, P, RF), dtype=np.int32)
    mem_off = np.zeros((II, P), dtype=np.int32)
    mem_words = np.ones((II, P), dtype=np.int32)
    valid_start = np.zeros((II, P), dtype=np.int32)

    # global memory image: banks concatenated in declaration order, each
    # addressed by its declared id
    bank_offsets: Dict[int, int] = {}
    off = 0
    for b in arch.banks:
        bank_offsets[b.id] = off
        off += b.words
    total_words = off + 1  # + scratch word for masked stores
    scratch = total_words - 1

    # provenance of mux-config cells: cell -> (value, abs_t) for conflict check
    xo_owner: Dict[Tuple[int, int, int], Tuple[int, int]] = {}
    rf_owner: Dict[Tuple[int, int, int], Tuple[int, int]] = {}

    def resolve(route, step_i: int) -> Tuple[int, int]:
        """(kind, idx) with which PE ``steps[step_i].pe`` reads the value at
        time steps[step_i].t."""
        kind, pe, t = route.steps[step_i]
        if kind == R:
            ridx = mapping.reg_assign.get((pe, route.value, t))
            if ridx is None:
                raise ConfigConflict(
                    f"slot{t % II}/pe{pe}: no register for value "
                    f"{route.value} at t{t} (rule MAP-REG-RANGE)")
            return KIND_REG, ridx
        # fresh: either straight off the producing FU, or an inbound wire
        if step_i == 0:
            return KIND_FUOUT, 0
        _pk, ppe, _pt = route.steps[step_i - 1]
        if ppe == pe:
            # F can only be entered from the source or a hop; same-PE
            # predecessor implies source state
            return KIND_FUOUT, 0
        for d in DIRS:
            if arch.neighbor(pe, d) == ppe:
                return KIND_IN[d], 0
        raise ConfigConflict(
            f"slot{t % II}/pe{pe}: inbound value {route.value} from pe{ppe}, "
            f"which is not adjacent (rule MAP-ROUTE-ADJ)")

    def set_xo(pe: int, d: int, slot: int, kind: int, idx: int,
               owner: Tuple[int, int]) -> None:
        cell = (pe, d, slot)
        if cell in xo_owner:
            if xo_owner[cell] == owner:
                return
            raise ConfigConflict(
                f"slot{slot}/pe{pe}: xo_{DIRS[d].lower()} crossbar port "
                f"double-driven, xo conflict at {cell} "
                f"(rule MAP-ROUTE-OVERLAP)")
        xo_owner[cell] = owner
        xo_kind[slot, pe, d] = kind
        xo_idx[slot, pe, d] = idx

    def set_rf(pe: int, r: int, slot: int, kind: int, idx: int,
               owner: Tuple[int, int]) -> None:
        cell = (pe, r, slot)
        if cell in rf_owner:
            if rf_owner[cell] == owner:
                return
            raise ConfigConflict(
                f"slot{slot}/pe{pe}: rf{r} writeback double-driven, rf "
                f"write conflict at {cell} (rule MAP-ROUTE-OVERLAP)")
        rf_owner[cell] = owner
        rf_kind[slot, pe, r] = kind
        rf_idx[slot, pe, r] = idx

    # ------------------------------------------------------------- FU slots
    for vid, (pe, t) in mapping.place.items():
        n = dfg.nodes[vid]
        slot = t % II
        valid_start[slot, pe] = t
        if n.op == Op.CONST:
            op[slot, pe] = OPC_PASS
            src_kind[slot, pe, 0] = KIND_IMM
            imm[slot, pe] = wrap(n.imm, bits)
        elif n.op == Op.LIVEIN:
            op[slot, pe] = OPC_PASS
            src_kind[slot, pe, 0] = KIND_LIREG
            src_idx[slot, pe, 0] = mapping.lireg_assign[n.livein][1]
        else:
            op[slot, pe] = opcode_of(n.op)
        if n.is_mem:
            b = mapping.bank_of[vid]
            mem_off[slot, pe] = bank_offsets[b]
            mem_words[slot, pe] = arch.bank(b).words

    # ------------------------------------------------- routes -> mux configs
    for (src, dst, oslot), route in mapping.routes.items():
        dnode = dfg.nodes[dst]
        dpe, dt = mapping.place[dst]
        dslot = dt % II
        # consumer operand select
        kind, idx = resolve(route, len(route.steps) - 1)
        cur_k = src_kind[dslot, dpe, oslot]
        if cur_k != KIND_NONE and (cur_k, src_idx[dslot, dpe, oslot]) != (kind, idx):
            raise ConfigConflict(
                f"slot{dslot}/pe{dpe}: operand mux conflict node {dst} "
                f"port {oslot} (rule MAP-ROUTE-OVERLAP)")
        src_kind[dslot, dpe, oslot] = kind
        src_idx[dslot, dpe, oslot] = idx
        # loop-carried init forcing (host-preloaded prologue values)
        opnd = dnode.operands[oslot]
        if opnd.dist > 0:
            force_before[dslot, dpe, oslot] = dt + opnd.dist * II
            force_val[dslot, dpe, oslot] = wrap(opnd.init, bits)
        # intermediate steps
        for i in range(len(route.steps) - 1):
            k0, p0, t0 = route.steps[i]
            k1, p1, t1 = route.steps[i + 1]
            owner = (route.value, t0)
            if p1 != p0:  # crossbar hop
                d = next(d for d in DIRS if arch.neighbor(p0, d) == p1)
                kk, ii_ = resolve(route, i)
                set_xo(p0, DIR_IDX[d], t0 % II, kk, ii_, owner)
            elif k1 == R and k0 == F:  # RF write
                ridx = mapping.reg_assign.get((p0, route.value, t1))
                if ridx is None:
                    raise ConfigConflict(
                        f"slot{t1 % II}/pe{p0}: no register for value "
                        f"{route.value} at t{t1} (rule MAP-REG-RANGE)")
                kk, ii_ = resolve(route, i)
                set_rf(p0, ridx, t0 % II, kk, ii_, owner)
            # R->R same pe: value stays put, no config needed

    nbr_idx = np.zeros((P, 4), dtype=np.int32)
    nbr_ok = np.zeros((P, 4), dtype=bool)
    for p in range(P):
        for di, d in enumerate(DIRS):
            q = arch.neighbor(p, d)
            nbr_idx[p, di] = q if q is not None else 0
            nbr_ok[p, di] = q is not None

    return SimConfig(
        II=II, P=P, RF=RF, LI=LI, bits=bits,
        op=op, imm=imm, src_kind=src_kind, src_idx=src_idx,
        force_before=force_before, force_val=force_val,
        xo_kind=xo_kind, xo_idx=xo_idx, rf_kind=rf_kind, rf_idx=rf_idx,
        mem_off=mem_off, mem_words=mem_words, valid_start=valid_start,
        nbr_idx=nbr_idx, nbr_ok=nbr_ok, bank_offsets=bank_offsets,
        total_words=total_words, depth=mapping.depth,
        lireg_assign=dict(mapping.lireg_assign),
    )
