"""Performance/cost model for Table I of the paper.

Fully-specified first-principles model (the paper's own constants):
  * CGRA clock: 100 MHz,
  * host->CGRA link: 50 MB/s,
  * per-invocation host handshake latency `handshake_us` — the kernel
    invocation overhead the paper highlights for CONV ("transferring outer
    loop iteration variables from the host processor", pipeline drain);
    0 by default, calibrated in benchmarks/table1.py,
  * 16-bit words.

Formulas (documented in EXPERIMENTS.md - Table I):
  cycles/invocation = (n_iters - 1) * II + depth         (fill + steady + drain)
  compute_time      = ceil(invocations / clusters) * cycles/inv / f_clk
  transfer_time     = (array_bytes + livein_bytes) / BW + handshake * invocations
  total             = compute + transfer  (sequential host<->CGRA, worst case)

``clusters`` models data-parallel execution across the target's logical
clusters (the paper's 8x8 = 4 clusters of 4x4): invocations are divided
round-robin across clusters, so compute time shrinks by ~clusters while
transfer and handshake stay whole-problem (the host link is shared).

Utilization follows the paper's definition: DFG nodes per II across the
PE array = nodes / (n_pes * II).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from .kernels_lib import KernelSpec
from .mapper import Mapping

F_CLK_HZ = 100e6
LINK_BYTES_PER_S = 50e6
WORD_BYTES = 2


@dataclass
class KernelCost:
    name: str
    nodes: int
    II: int
    mii: int
    fu_only_mii: int
    utilization: float
    invocations: int
    iters_per_inv: int
    cycles_per_inv: int
    compute_ms: float
    transfer_ms: float
    total_ms: float
    speedup: float = 1.0
    mii_parts: Dict[str, int] = field(default_factory=dict)
    clusters: int = 1

    def row(self) -> str:
        return (f"{self.name:<12} {self.nodes:>5} {self.II:>3} ({self.mii})"
                f" {self.utilization*100:7.2f}% {self.compute_ms:10.3f}"
                f" {self.transfer_ms:10.3f} {self.total_ms:10.3f}"
                f" {self.speedup:7.2f}x")


def kernel_cost(spec: KernelSpec, mapping: Mapping, *,
                problem_scale: int = 1,
                array_bytes_moved: float = 0.0,
                handshake_us: float = 0.0,
                clusters: int = 1) -> KernelCost:
    """Cost of executing the full problem on `clusters` data-parallel
    copies of this kernel's mapping (one per logical cluster).

    ``invocations = len(spec.invocations) * problem_scale`` is the
    whole-problem invocation count; compute time is divided across
    clusters — the slowest cluster runs ``ceil(invocations / clusters)``
    of them — while array transfer and per-invocation handshakes stay
    whole-problem (the host<->CGRA link and the invoking host loop are
    shared by all clusters).

    Do not divide twice: callers that pre-scale ``problem_scale`` to
    per-cluster tile steps (the Table-I harness, whose PROBLEM_SCALE is
    ``Co / clusters``) must keep ``clusters=1``.  Likewise a mapping that
    already spans the whole multi-cluster fabric is one configured
    instance — score it with ``clusters=1`` (as the DSE sweep does).

    array_bytes_moved: total off-chip<->on-chip array traffic for the
    whole problem (already accounting for reuse).
    """
    if clusters < 1:
        raise ValueError(f"clusters must be >= 1, got {clusters}")
    II, depth = mapping.II, mapping.depth
    n_inv = len(spec.invocations) * problem_scale
    iters = spec.mapped_iters
    cyc_inv = (iters - 1) * II + depth
    inv_slowest_cluster = -(-n_inv // clusters)
    compute_s = inv_slowest_cluster * cyc_inv / F_CLK_HZ

    livein_bytes = (spec.meta.get("liveins_per_inv", 0) * WORD_BYTES * n_inv)
    transfer_s = ((array_bytes_moved + livein_bytes) / LINK_BYTES_PER_S
                  + handshake_us * 1e-6 * n_inv)

    return KernelCost(
        name=spec.name, nodes=spec.dfg.n_nodes, II=II, mii=mapping.mii,
        fu_only_mii=mapping.mii_parts.get("fu_only_mii", mapping.mii),
        utilization=mapping.utilization,
        invocations=n_inv, iters_per_inv=iters, cycles_per_inv=cyc_inv,
        compute_ms=compute_s * 1e3, transfer_ms=transfer_s * 1e3,
        total_ms=(compute_s + transfer_s) * 1e3,
        mii_parts=dict(mapping.mii_parts),
        clusters=clusters,
    )


# ------------------------------------------------------- Table I problems
def gemm_traffic_bytes(M: int = 64, N: int = 64, K: int = 64,
                       TI: int = 64, TK: int = 16, TJ: int = 64) -> float:
    """Output-stationary schedule (Listing 1): O resident on chip across
    the k-chunks; W and I chunks streamed per step; O in+out once."""
    k_steps = K // TK
    w = TI * TK * WORD_BYTES * k_steps            # one W chunk per k step
    i = TK * TJ * WORD_BYTES * k_steps
    o = TI * TJ * WORD_BYTES * 2                  # load once, store once
    return float(w + i + o)


def conv_traffic_bytes(O1: int = 64, O2: int = 64, Co: int = 64, K: int = 3,
                       per_channel_input: bool = False) -> float:
    """Single-input-channel CONV (Listing 2): I resident (streamed once
    unless per_channel_input), W once, O streamed out per output channel."""
    i1 = (O1 + K - 1) * (O2 + K - 1) * WORD_BYTES
    i = i1 * (Co if per_channel_input else 1)
    w = K * K * Co * WORD_BYTES
    o = O1 * O2 * Co * WORD_BYTES
    return float(i + w + o)
