"""Dataflow-graph IR for CGRA loop kernels.

This is the analogue of Morpher's DFG generator output (paper Fig. 3, piece
4).  A DFG describes the body of one loop iteration of the *mapped* loop
level; loop-carried dependences are expressed as operand references with an
iteration ``dist`` >= 1 (plus an ``init`` value consumed for the first
``dist`` iterations, which models the host pre-loading live-in registers —
the paper's "transferring outer loop iteration variables from the host").

Node ops (all execute on a CGRA PE functional unit):
  CONST   -- materialize an immediate from configuration memory (lat 1)
  LIVEIN  -- read a host-preloaded live-in scalar register        (lat 1)
  ADD/SUB/MUL/SHL/SHR/AND/OR/XOR/CMPGE/CMPEQ/CMPLT  -- ALU        (lat 1)
  SELECT  -- predicated select: operands (cond, a, b)             (lat 1)
  LOAD    -- read a word from a memory bank: operands (addr,)     (lat 2)
  STORE   -- write a word to a memory bank: operands (addr, val)  (lat 1)

A pure sequential ``reference_execute`` gives the oracle semantics used by
the verification flow (paper section IV-C): the modulo-scheduled, pipelined
CGRA simulation must produce the same final memory state.
"""
from __future__ import annotations

import enum
import heapq
import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DATAPATH_BITS = 16


def wrap(x: int, bits: int = DATAPATH_BITS) -> int:
    """Two's-complement wraparound to the CGRA datapath width."""
    m = 1 << bits
    x &= m - 1
    if x >= m >> 1:
        x -= m
    return x


class Op(enum.Enum):
    CONST = "const"
    LIVEIN = "livein"
    ADD = "add"
    SUB = "sub"
    MUL = "mul"
    SHL = "shl"
    SHR = "shr"
    AND = "and"
    OR = "or"
    XOR = "xor"
    CMPGE = "cmpge"
    CMPEQ = "cmpeq"
    CMPLT = "cmplt"
    SELECT = "select"
    LOAD = "load"
    STORE = "store"


ALU_OPS = {Op.ADD, Op.SUB, Op.MUL, Op.SHL, Op.SHR, Op.AND, Op.OR, Op.XOR,
           Op.CMPGE, Op.CMPEQ, Op.CMPLT, Op.SELECT}
MEM_OPS = {Op.LOAD, Op.STORE}

LATENCY = {Op.LOAD: 2}
DEFAULT_LATENCY = 1

# operand arity per op (binary ALU ops default to 2)
_N_OPERANDS = {Op.CONST: 0, Op.LIVEIN: 0, Op.LOAD: 1, Op.STORE: 2,
               Op.SELECT: 3}


def latency(op: Op) -> int:
    return LATENCY.get(op, DEFAULT_LATENCY)


@dataclass(frozen=True, slots=True)
class Operand:
    """A data edge src -> consumer.

    dist: iteration distance (0 = same iteration, d>=1 = loop-carried: the
          consumer in iteration n reads the producer's value from iteration
          n - d; for n < d it reads ``init``).
    """
    src: int
    dist: int = 0
    init: int = 0


@dataclass(slots=True)
class Node:
    id: int
    op: Op
    operands: Tuple[Operand, ...] = ()
    imm: Optional[int] = None       # CONST value
    livein: Optional[str] = None    # LIVEIN symbolic name
    array: Optional[str] = None     # LOAD/STORE target array
    name: str = ""

    @property
    def lat(self) -> int:
        return latency(self.op)

    @property
    def is_mem(self) -> bool:
        return self.op in MEM_OPS


@dataclass(frozen=True)
class MemDep:
    """Ordering-only loop-carried memory dependence (e.g. the
    output-stationary O[i][j] store -> next-iteration load)."""
    src: int    # store node
    dst: int    # load node
    dist: int = 1


@dataclass
class DFG:
    name: str
    nodes: Dict[int, Node] = field(default_factory=dict)
    mem_deps: List[MemDep] = field(default_factory=list)

    # ---------------------------------------------------------------- util
    def __len__(self) -> int:
        return len(self.nodes)

    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    @property
    def n_mem_nodes(self) -> int:
        return sum(1 for n in self.nodes.values() if n.is_mem)

    def consumers(self) -> Dict[int, List[Tuple[int, int]]]:
        """node id -> list of (consumer id, operand slot)."""
        out: Dict[int, List[Tuple[int, int]]] = {i: [] for i in self.nodes}
        for n in self.nodes.values():
            for slot, opnd in enumerate(n.operands):
                out[opnd.src].append((n.id, slot))
        return out

    def data_edges(self) -> List[Tuple[int, int, int, Operand]]:
        """(src, dst, slot, operand) for every data edge."""
        edges = []
        for n in self.nodes.values():
            for slot, opnd in enumerate(n.operands):
                edges.append((opnd.src, n.id, slot, opnd))
        return edges

    def topo_order(self) -> List[int]:
        """Topological order over dist==0 edges (loop body DAG).

        Ready nodes resolve lowest-id-first (a min-heap; order-identical
        to the historical sort-per-step implementation, without its
        quadratic re-sorting — this sits on the tracing and reference-
        execution hot paths)."""
        indeg = {i: 0 for i in self.nodes}
        succ: Dict[int, List[int]] = {i: [] for i in self.nodes}
        for n in self.nodes.values():
            for opnd in n.operands:
                if opnd.dist == 0:
                    indeg[n.id] += 1
                    succ[opnd.src].append(n.id)
        ready = [i for i, d in indeg.items() if d == 0]
        heapq.heapify(ready)
        order: List[int] = []
        while ready:
            v = heapq.heappop(ready)
            order.append(v)
            for s in succ[v]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    heapq.heappush(ready, s)
        if len(order) != len(self.nodes):
            raise ValueError(f"DFG {self.name}: cycle through dist-0 edges")
        return order

    def validate(self) -> None:
        for n in self.nodes.values():
            for opnd in n.operands:
                if opnd.src not in self.nodes:
                    raise ValueError(f"node {n.id} references missing {opnd.src}")
            if n.op == Op.CONST and n.imm is None:
                raise ValueError(f"CONST node {n.id} missing imm")
            if n.op == Op.LIVEIN and n.livein is None:
                raise ValueError(f"LIVEIN node {n.id} missing name")
            if n.op in MEM_OPS and n.array is None:
                raise ValueError(f"mem node {n.id} missing array")
            nops = _N_OPERANDS.get(n.op, 2)
            if len(n.operands) != nops:
                raise ValueError(
                    f"node {n.id} op {n.op} expects {nops} operands, "
                    f"got {len(n.operands)}")
        self.topo_order()  # raises on dist-0 cycles

    # --------------------------------------------------------- serialization
    def to_json_dict(self) -> dict:
        """JSON-able structural form (same idiom as the ADL round-trip)."""
        nodes = []
        for nid in sorted(self.nodes):
            n = self.nodes[nid]
            nodes.append({
                "id": n.id, "op": n.op.value,
                "operands": [[o.src, o.dist, o.init] for o in n.operands],
                "imm": n.imm, "livein": n.livein, "array": n.array,
                "name": n.name,
            })
        return {"name": self.name, "nodes": nodes,
                "mem_deps": [[m.src, m.dst, m.dist] for m in self.mem_deps]}

    @staticmethod
    def from_json_dict(d: dict) -> "DFG":
        dfg = DFG(d["name"])
        for nd in d["nodes"]:
            dfg.nodes[nd["id"]] = Node(
                id=nd["id"], op=Op(nd["op"]),
                operands=tuple(Operand(src, dist, init)
                               for src, dist, init in nd["operands"]),
                imm=nd["imm"], livein=nd["livein"], array=nd["array"],
                name=nd["name"])
        dfg.mem_deps = [MemDep(src, dst, dist)
                        for src, dst, dist in d["mem_deps"]]
        return dfg

    def canonical_dict(self) -> dict:
        """Structural canonical form — the content-addressing identity.

        Node ids are compacted to a dense 0..n-1 numbering (emission
        order) and cosmetic node ``name`` labels are dropped: two DFGs
        describing the same program through different front ends (the
        hand-built :class:`DFGBuilder` wiring vs the ``repro.frontend``
        tracer) canonicalize identically, while any semantic difference —
        ops, operand wiring, loop-carried dists/inits, immediates, live-in
        names, target arrays, memory ordering edges — still changes the
        form (and therefore the compile cache key).
        """
        order = sorted(self.nodes)
        remap = {nid: i for i, nid in enumerate(order)}
        nodes = []
        for nid in order:
            n = self.nodes[nid]
            nodes.append({
                "id": remap[nid], "op": n.op.value,
                "operands": [[remap[o.src], o.dist, o.init]
                             for o in n.operands],
                "imm": n.imm, "livein": n.livein, "array": n.array,
            })
        return {"name": self.name, "nodes": nodes,
                "mem_deps": sorted([remap[m.src], remap[m.dst], m.dist]
                                   for m in self.mem_deps)}

    def canonical_json(self) -> str:
        """Stable canonical form — the content-addressing key component."""
        return json.dumps(self.canonical_dict(), sort_keys=True,
                          separators=(",", ":"))

    # ------------------------------------------------------- oracle semantics
    def reference_execute(self, n_iters: int, arrays: Dict[str, List[int]],
                          liveins: Dict[str, int],
                          bits: int = DATAPATH_BITS) -> Dict[str, List[int]]:
        """Sequential (non-pipelined) execution: the verification oracle.

        arrays: name -> flat word list (mutated copy returned).
        liveins: live-in scalar values for this invocation.
        """
        mem = {k: list(v) for k, v in arrays.items()}
        order = self.topo_order()
        # history[node][d] = value produced d iterations ago (d=1..maxdist)
        maxdist = max([o.dist for _s, _d, _sl, o in
                       [(e[0], e[1], e[2], e[3]) for e in self.data_edges()]]
                      + [0])
        hist: Dict[int, List[int]] = {i: [] for i in self.nodes}

        def read(opnd: Operand, cur: Dict[int, int]) -> int:
            if opnd.dist == 0:
                return cur[opnd.src]
            h = hist[opnd.src]
            if len(h) < opnd.dist:
                return wrap(opnd.init, bits)
            return h[-opnd.dist]

        for _it in range(n_iters):
            cur: Dict[int, int] = {}
            for vid in order:
                n = self.nodes[vid]
                if n.op == Op.CONST:
                    cur[vid] = wrap(n.imm, bits)
                elif n.op == Op.LIVEIN:
                    cur[vid] = wrap(liveins[n.livein], bits)
                elif n.op == Op.LOAD:
                    addr = read(n.operands[0], cur)
                    buf = mem[n.array]
                    cur[vid] = buf[addr] if 0 <= addr < len(buf) else 0
                elif n.op == Op.STORE:
                    addr = read(n.operands[0], cur)
                    val = read(n.operands[1], cur)
                    buf = mem[n.array]
                    if 0 <= addr < len(buf):
                        buf[addr] = val
                    cur[vid] = 0
                else:
                    a = read(n.operands[0], cur)
                    b = read(n.operands[1], cur) if len(n.operands) > 1 else 0
                    if n.op == Op.ADD:
                        r = a + b
                    elif n.op == Op.SUB:
                        r = a - b
                    elif n.op == Op.MUL:
                        r = a * b
                    elif n.op == Op.SHL:
                        r = a << (b & (bits - 1))
                    elif n.op == Op.SHR:
                        r = a >> (b & (bits - 1))
                    elif n.op == Op.AND:
                        r = a & b
                    elif n.op == Op.OR:
                        r = a | b
                    elif n.op == Op.XOR:
                        r = a ^ b
                    elif n.op == Op.CMPGE:
                        r = 1 if a >= b else 0
                    elif n.op == Op.CMPEQ:
                        r = 1 if a == b else 0
                    elif n.op == Op.CMPLT:
                        r = 1 if a < b else 0
                    elif n.op == Op.SELECT:
                        c = read(n.operands[2], cur)
                        r = b if a != 0 else c  # operands (cond, a_true, b_false)
                    else:
                        raise NotImplementedError(n.op)
                    cur[vid] = wrap(r, bits)
            for vid in order:
                h = hist[vid]
                h.append(cur[vid])
                if len(h) > maxdist:
                    h.pop(0)
        return mem

    def reference_execute_batch(self, n_iters: int, arrays, invocations,
                                bits: int = DATAPATH_BITS):
        """``reference_execute`` vectorized over a leading batch axis and
        folded over all invocations in one call.

        arrays: name -> int array of shape [batch, words] (one row per
        test vector); invocations: the host outer-loop livein dicts; a
        fresh dict of final images is returned.  Per row the result is
        bit-identical to folding the scalar oracle over the invocations:
        every node value becomes a [batch] int64 vector, wrapped to the
        datapath width after each op exactly as the scalar path wraps its
        Python ints (operands are always in 16-bit range, so int64
        intermediates never overflow).  The node program (topological
        order, operand bindings) is compiled once for the whole sweep,
        which together with the batch vectorization keeps the numpy
        oracle off the critical path when the batched verification engine
        checks many seeds at once.
        """
        import numpy as np
        mem = {k: np.array(v, dtype=np.int64) for k, v in arrays.items()}
        B = next(iter(mem.values())).shape[0] if mem else 1
        rows = np.arange(B)
        half, full = 1 << (bits - 1), 1 << bits

        def awrap(x):
            return ((x + half) & (full - 1)) - half

        order = self.topo_order()
        prog = [(vid, self.nodes[vid]) for vid in order]
        maxdist = max([o.dist for _s, _d, _sl, o in self.data_edges()] + [0])

        def read(opnd: Operand, cur, hist):
            if opnd.dist == 0:
                return cur[opnd.src]
            h = hist[opnd.src]
            if len(h) < opnd.dist:
                return np.full(B, wrap(opnd.init, bits), dtype=np.int64)
            return h[-opnd.dist]

        for inv in invocations:
            hist: Dict[int, List] = {i: [] for i in self.nodes}
            for _it in range(n_iters):
                cur: Dict[int, "np.ndarray"] = {}
                for vid, n in prog:
                    if n.op == Op.CONST:
                        cur[vid] = np.full(B, wrap(n.imm, bits),
                                           dtype=np.int64)
                    elif n.op == Op.LIVEIN:
                        cur[vid] = np.full(B, wrap(inv[n.livein], bits),
                                           dtype=np.int64)
                    elif n.op == Op.LOAD:
                        addr = read(n.operands[0], cur, hist)
                        buf = mem[n.array]
                        ok = (addr >= 0) & (addr < buf.shape[1])
                        cur[vid] = np.where(
                            ok, buf[rows, np.clip(addr, 0,
                                                  buf.shape[1] - 1)], 0)
                    elif n.op == Op.STORE:
                        addr = read(n.operands[0], cur, hist)
                        val = read(n.operands[1], cur, hist)
                        buf = mem[n.array]
                        ok = (addr >= 0) & (addr < buf.shape[1])
                        buf[rows[ok], addr[ok]] = val[ok]
                        cur[vid] = np.zeros(B, dtype=np.int64)
                    else:
                        a = read(n.operands[0], cur, hist)
                        b = read(n.operands[1], cur, hist) \
                            if len(n.operands) > 1 \
                            else np.zeros(B, dtype=np.int64)
                        if n.op == Op.ADD:
                            r = a + b
                        elif n.op == Op.SUB:
                            r = a - b
                        elif n.op == Op.MUL:
                            r = a * b
                        elif n.op == Op.SHL:
                            r = a << (b & (bits - 1))
                        elif n.op == Op.SHR:
                            r = a >> (b & (bits - 1))
                        elif n.op == Op.AND:
                            r = a & b
                        elif n.op == Op.OR:
                            r = a | b
                        elif n.op == Op.XOR:
                            r = a ^ b
                        elif n.op == Op.CMPGE:
                            r = (a >= b).astype(np.int64)
                        elif n.op == Op.CMPEQ:
                            r = (a == b).astype(np.int64)
                        elif n.op == Op.CMPLT:
                            r = (a < b).astype(np.int64)
                        elif n.op == Op.SELECT:
                            c = read(n.operands[2], cur, hist)
                            r = np.where(a != 0, b, c)
                        else:
                            raise NotImplementedError(n.op)
                        cur[vid] = awrap(r)
                if maxdist:
                    for vid in order:
                        h = hist[vid]
                        h.append(cur[vid])
                        if len(h) > maxdist:
                            h.pop(0)
        return mem


class DFGBuilder:
    """Small builder DSL — the stand-in for Morpher's LLVM DFG pass."""

    def __init__(self, name: str):
        self.dfg = DFG(name)
        self._next = 0
        self._const_cache: Dict[int, int] = {}
        self._livein_cache: Dict[str, int] = {}

    def _add(self, op: Op, operands=(), **kw) -> int:
        nid = self._next
        self._next += 1
        ops = tuple(o if isinstance(o, Operand) else Operand(o)
                    for o in operands)
        self.dfg.nodes[nid] = Node(nid, op, ops, **kw)
        return nid

    # SSA-ish helpers. Constants / live-ins are cached (the LLVM pass also
    # CSEs these), which keeps node counts in the paper's ballpark.
    def const(self, v: int, name: str = "") -> int:
        if v not in self._const_cache:
            self._const_cache[v] = self._add(Op.CONST, imm=v,
                                             name=name or f"c{v}")
        return self._const_cache[v]

    def livein(self, nm: str) -> int:
        if nm not in self._livein_cache:
            self._livein_cache[nm] = self._add(Op.LIVEIN, livein=nm, name=nm)
        return self._livein_cache[nm]

    def add(self, a, b, name=""):
        return self._add(Op.ADD, (a, b), name=name)

    def sub(self, a, b, name=""):
        return self._add(Op.SUB, (a, b), name=name)

    def mul(self, a, b, name=""):
        return self._add(Op.MUL, (a, b), name=name)

    def cmpge(self, a, b, name=""):
        return self._add(Op.CMPGE, (a, b), name=name)

    def cmpeq(self, a, b, name=""):
        return self._add(Op.CMPEQ, (a, b), name=name)

    def select(self, cond, a, b, name=""):
        return self._add(Op.SELECT, (cond, a, b), name=name)

    def load(self, array: str, addr, name=""):
        return self._add(Op.LOAD, (addr,), array=array, name=name)

    def store(self, array: str, addr, val, name=""):
        return self._add(Op.STORE, (addr, val), array=array, name=name)

    def op(self, op: Op, *operands, name=""):
        return self._add(op, operands, name=name)

    def carried(self, src: int, dist: int = 1, init: int = 0) -> Operand:
        """Reference to ``src``'s value from ``dist`` iterations ago."""
        return Operand(src, dist=dist, init=init)

    def mem_dep(self, store_id: int, load_id: int, dist: int = 1) -> None:
        self.dfg.mem_deps.append(MemDep(store_id, load_id, dist))

    def build(self) -> DFG:
        self.dfg.validate()
        return self.dfg
