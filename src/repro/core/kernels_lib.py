"""ML micro-kernel library: the paper's Listings 1-5 as traced DSL kernels.

Each builder returns a :class:`KernelSpec` — the DFG of the mapped loop
level, the bank data layout, the host-side invocation schedule (outer
sequential loops that stay on the host processor, exactly as in the paper's
tiled dataflow), and a numpy golden model.

The DFGs are produced by the ``repro.frontend`` tracer: the mapped loop
body is written as restricted Python over a :class:`KernelContext`
(array-ref loads/stores, traced arithmetic, counter primitives for the
induction chains) instead of ~60 lines of hand-wired ``DFGBuilder`` nodes
per kernel.  The traced DFGs are canonical-form-identical to the historic
hand-built ones (``tests/handbuilt_kernels.py`` pins this via
``spec_cache_key`` equality), so mappings, verify oracles and compile
cache keys are unchanged by the front-end redesign.

Variants (paper Table I):
  GEMM        base: innermost k loop mapped, (i, j) live-ins per invocation
  GEMM-U      k-loop unrolled by 4 (Listing 3)
  GEMM-U-C    all three loops coalesced into one (Listing 4)
  CONV        base: innermost k2 loop mapped, (c, i, j, k1) live-ins
  CONV-U-C-1  k1/k2 fully unrolled (K=3), innermost spatial loop mapped
  CONV-U-C-2  all loops coalesced (Listing 5)

Four further kernels — depthwise conv, average pooling, a bias+ReLU-fused
GEMM epilogue and an int8 requantize stage — live in
``repro.frontend.library``; they exist only as DSL kernels (no hand-built
counterparts).

Addressing is bank-local: LOAD/STORE nodes target ``bank<N>`` pseudo-arrays
and the data layout's base offsets are folded into the address arithmetic,
mirroring Morpher's co-generated data layout.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from ..frontend.tracer import KernelContext, unroll as _unroll_range
from .adl import CGRAArch, cluster_4x4
from .dfg import DFG
from .layout import ArrayDecl, DataLayout, assign_layout


# --------------------------------------------------------------------------
@dataclass
class KernelSpec:
    name: str
    dfg: DFG
    arch: CGRAArch
    layout: DataLayout
    mapped_iters: int                     # iterations of the mapped loop per invocation
    invocations: List[Dict[str, int]]     # live-in values per invocation
    golden: Callable[[Dict[str, np.ndarray]], Dict[str, np.ndarray]]
    init_banks: Callable[[np.random.Generator], Dict[str, np.ndarray]]
    # cost-model metadata (full-problem dims; see costmodel.py)
    meta: Dict[str, int] = field(default_factory=dict)

    def bank_images(self, rng: np.random.Generator) -> Dict[str, np.ndarray]:
        return self.init_banks(rng)


def _bank_arrays(layout: DataLayout) -> Dict[str, np.ndarray]:
    return {f"bank{bid}": np.zeros(w, dtype=np.int64)
            for bid, w in layout.bank_image_size().items()}


def _wrap16(x: np.ndarray) -> np.ndarray:
    x = np.asarray(x, dtype=np.int64)
    x = ((x + (1 << 15)) & 0xFFFF) - (1 << 15)
    return x


# ======================================================================
# GEMM  (Listings 1, 3, 4): O[TI,TJ] += W[TI,TK] @ I[TK,TJ]
# ======================================================================
def _gemm_layout(arch: CGRAArch, TI: int, TK: int, TJ: int) -> DataLayout:
    """Output-stationary layout.  Preferred: W+O on bank0, I on bank1 (the
    accumulator recurrence and the weight stream share a port budget).
    When the O tile fills a whole bank (the paper's 64x16x64 tile has an
    8 kB O == one full bank), O gets bank0 alone and W streams with I."""
    try:
        return assign_layout(arch, [
            ArrayDecl("W", TI * TK, bank_pref=0),
            ArrayDecl("O", TI * TJ, bank_pref=0),
            ArrayDecl("I", TK * TJ, bank_pref=1),
        ])
    except ValueError:
        return assign_layout(arch, [
            ArrayDecl("O", TI * TJ, bank_pref=0),
            ArrayDecl("W", TI * TK, bank_pref=1),
            ArrayDecl("I", TK * TJ, bank_pref=1),
        ])


def _gemm_init(layout: DataLayout, TI: int, TK: int, TJ: int, lo=-8, hi=8):
    def init(rng: np.random.Generator) -> Dict[str, np.ndarray]:
        banks = _bank_arrays(layout)
        W = rng.integers(lo, hi, size=TI * TK)
        I = rng.integers(lo, hi, size=TK * TJ)
        pw, pi, po = (layout.placements[k] for k in ("W", "I", "O"))
        banks[pw.bank_array][pw.base:pw.base + pw.words] = W
        banks[pi.bank_array][pi.base:pi.base + pi.words] = I
        banks[po.bank_array][po.base:po.base + po.words] = 0
        return banks
    return init


def _gemm_golden(layout: DataLayout, TI: int, TK: int, TJ: int):
    def golden(banks: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        out = {k: v.copy() for k, v in banks.items()}
        pw, pi, po = (layout.placements[k] for k in ("W", "I", "O"))
        W = banks[pw.bank_array][pw.base:pw.base + pw.words].reshape(TI, TK)
        I = banks[pi.bank_array][pi.base:pi.base + pi.words].reshape(TK, TJ)
        O = banks[po.bank_array][po.base:po.base + po.words].reshape(TI, TJ)
        O = _wrap16(O + W @ I)
        out[po.bank_array][po.base:po.base + po.words] = O.reshape(-1)
        return out
    return golden


def build_gemm(TI: int = 64, TK: int = 16, TJ: int = 64,
               arch: Optional[CGRAArch] = None,
               unroll: int = 1, coalesced: bool = False) -> KernelSpec:
    """GEMM micro-kernel on one CGRA cluster (output-stationary).

    unroll=1, coalesced=False  -> base GEMM (map the k loop)
    unroll=4, coalesced=False  -> GEMM-U   (Listing 3)
    unroll=4, coalesced=True   -> GEMM-U-C (Listing 4)
    """
    arch = arch or cluster_4x4()
    assert TK % unroll == 0
    layout = _gemm_layout(arch, TI, TK, TJ)
    U = unroll

    ctx = KernelContext(
        f"gemm{'-u' if U > 1 else ''}{'-c' if coalesced else ''}", layout)
    W, I, O = ctx.arrays("W", "I", "O")
    if not coalesced:
        cU = ctx.const(U)
        i, j = ctx.livein("i"), ctx.livein("j")
        k = ctx.counter(step=cU, init=-U, stop=TK - U, name="k")
    else:
        # Listing 4: single coalesced loop; i/j/k are register-carried.
        i, j, k = ctx.coalesce(TI, TJ, (TK, U))

    # ---- body: O[i][j] += sum_u W[i][k+u] * I[k+u][j]
    wa = W.addr(i * TK + k)
    wl = [W.at(a) for a in [wa + u for u in _unroll_range(U)]]
    ia = I.addr(k * TJ + j)
    il = [I.at(a) for a in [ia + u * TJ for u in _unroll_range(U)]]
    psum = ctx.treesum(w * x for w, x in zip(wl, il))
    ctx.accumulate(O, O.addr(i * TJ + j), psum)
    dfg = ctx.build()

    if coalesced:
        mapped_iters = TI * TJ * (TK // U)
        invocations: List[Dict[str, int]] = [{}]
    else:
        mapped_iters = TK // U
        invocations = [{"i": ii, "j": jj} for ii in range(TI) for jj in range(TJ)]

    return KernelSpec(
        name=dfg.name, dfg=dfg, arch=arch, layout=layout,
        mapped_iters=mapped_iters, invocations=invocations,
        golden=_gemm_golden(layout, TI, TK, TJ),
        init_banks=_gemm_init(layout, TI, TK, TJ),
        meta=dict(TI=TI, TK=TK, TJ=TJ, unroll=U, coalesced=int(coalesced),
                  macs_per_iter=U, liveins_per_inv=0 if coalesced else 2),
    )


# ======================================================================
# CONV (Listing 2, 5): O[c,i,j] += I[i+k1, j+k2] * W[c,k1,k2]   (valid)
#   tile: one output channel resident at a time (TCo = 1 in Table I).
# ======================================================================
def _conv_layout(arch: CGRAArch, IH: int, IW: int, OH: int, OW: int,
                 K: int) -> DataLayout:
    return assign_layout(arch, [
        ArrayDecl("O", OH * OW, bank_pref=0),
        ArrayDecl("W", K * K, bank_pref=0),
        ArrayDecl("I", IH * IW, bank_pref=1),
    ])


def _conv_init(layout: DataLayout, IH: int, IW: int, OH: int, OW: int,
               K: int):
    pw, pi, po = (layout.placements[k] for k in ("W", "I", "O"))

    def init(rng: np.random.Generator) -> Dict[str, np.ndarray]:
        banks = _bank_arrays(layout)
        banks[pi.bank_array][pi.base:pi.base + pi.words] = \
            rng.integers(-8, 8, size=IH * IW)
        banks[pw.bank_array][pw.base:pw.base + pw.words] = \
            rng.integers(-4, 4, size=K * K)
        banks[po.bank_array][po.base:po.base + po.words] = 0
        return banks
    return init


def _conv_golden(layout: DataLayout, IH: int, IW: int, OH: int, OW: int,
                 K: int):
    pw, pi, po = (layout.placements[k] for k in ("W", "I", "O"))

    def golden(banks: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        out = {k: v.copy() for k, v in banks.items()}
        I = banks[pi.bank_array][pi.base:pi.base + pi.words].reshape(IH, IW)
        W = banks[pw.bank_array][pw.base:pw.base + pw.words].reshape(K, K)
        O = banks[po.bank_array][po.base:po.base + po.words].reshape(OH, OW)
        O = O.astype(np.int64)
        for kk1 in range(K):
            for kk2 in range(K):
                O = O + I[kk1:kk1 + OH, kk2:kk2 + OW] * W[kk1, kk2]
        out[po.bank_array][po.base:po.base + po.words] = _wrap16(O).reshape(-1)
        return out
    return golden


def build_conv(OH: int = 62, OW: int = 62, K: int = 3,
               IH: Optional[int] = None, IW: Optional[int] = None,
               arch: Optional[CGRAArch] = None,
               variant: str = "base") -> KernelSpec:
    """CONV micro-kernel (single input channel -> one output channel tile).

    variant: "base"  -- map the innermost k2 loop (live-ins i, j, k1)
             "uc1"   -- k1/k2 fully unrolled, map the j loop (live-in i)
             "uc2"   -- all spatial loops coalesced (Listing 5)
    """
    arch = arch or cluster_4x4()
    IH = IH if IH is not None else OH + K - 1
    IW = IW if IW is not None else OW + K - 1
    layout = _conv_layout(arch, IH, IW, OH, OW, K)

    ctx = KernelContext(f"conv-{variant}", layout)
    W, I, O = ctx.arrays("W", "I", "O")

    if variant == "base":
        i, j, k1 = ctx.livein("i"), ctx.livein("j"), ctx.livein("k1")
        k2 = ctx.counter(stop=K - 1, name="k2")

        ival = I[(i + k1) * IW + (j + k2)]
        prod = ival * W[k1 * K + k2]
        ctx.accumulate(O, O.addr(i * OW + j), prod)

        mapped_iters = K
        invocations = [{"i": ii, "j": jj, "k1": kk}
                       for ii in range(OH) for jj in range(OW)
                       for kk in range(K)]
        liveins_per_inv = 3

    elif variant in ("uc1", "uc2"):
        c1, c0 = ctx.const(1), ctx.const(0)
        if variant == "uc1":
            i = ctx.livein("i")
            j = ctx.counter(step=c1, init=-1, stop=OW - 1, name="j")
        else:
            # Listing 5: coalesce (i, j) into one induction chain.
            j, jwrap = ctx.wrapping_counter(c1, OW, init=-1, name="j")
            i = ctx.gated_counter(c1, jwrap, name="i")

        # fully unrolled K x K MACs against the resident accumulator word
        oa = O.addr(i * OW + j)
        oval = O.at(oa, name="oval")
        prods = []
        for kk1 in _unroll_range(K):
            rm = (i + kk1) * IW
            for kk2 in _unroll_range(K):
                iv = I.at(I.addr(rm + (j + kk2)), name=f"iv{kk1}{kk2}")
                prods.append(iv * W[kk1 * K + kk2])
        st = O.store_at(oa, oval + ctx.treesum(prods), name="ost")
        ctx.loop_carried(st, oval)

        if variant == "uc1":
            mapped_iters = OW
            invocations = [{"i": ii} for ii in range(OH)]
            liveins_per_inv = 1
        else:
            mapped_iters = OH * OW
            invocations = [{}]
            liveins_per_inv = 0
    else:
        raise ValueError(variant)

    dfg = ctx.build()

    return KernelSpec(
        name=dfg.name, dfg=dfg, arch=arch, layout=layout,
        mapped_iters=mapped_iters, invocations=invocations,
        golden=_conv_golden(layout, IH, IW, OH, OW, K),
        init_banks=_conv_init(layout, IH, IW, OH, OW, K),
        meta=dict(OH=OH, OW=OW, K=K, IH=IH, IW=IW,
                  liveins_per_inv=liveins_per_inv),
    )


# ----------------------------------------------------------------- registry
def table1_kernels(small: bool = False,
                   arch: Optional[CGRAArch] = None) -> Dict[str, KernelSpec]:
    """The six Table-I kernels.  ``small=True`` returns reduced dims for
    fast simulation-based verification (DFG structure identical);
    ``arch`` retargets the whole set (default: the paper's 4x4 cluster),
    the entry point design-space sweeps build their suites from."""
    if small:
        g = dict(TI=6, TK=8, TJ=6)
        c = dict(OH=5, OW=5, K=3)
    else:
        g = dict(TI=64, TK=16, TJ=64)
        c = dict(OH=62, OW=62, K=3)
    g["arch"] = c["arch"] = arch
    return {
        "GEMM": build_gemm(**g, unroll=1, coalesced=False),
        "GEMM-U": build_gemm(**g, unroll=4, coalesced=False),
        "GEMM-U-C": build_gemm(**g, unroll=4, coalesced=True),
        "CONV": build_conv(**c, variant="base"),
        "CONV-U-C-1": build_conv(**c, variant="uc1"),
        "CONV-U-C-2": build_conv(**c, variant="uc2"),
    }
