"""ML micro-kernel library: the paper's Listings 1-5 as DFG builders.

Each builder returns a :class:`KernelSpec` — the DFG of the mapped loop
level, the bank data layout, the host-side invocation schedule (outer
sequential loops that stay on the host processor, exactly as in the paper's
tiled dataflow), and a numpy golden model.

Variants (paper Table I):
  GEMM        base: innermost k loop mapped, (i, j) live-ins per invocation
  GEMM-U      k-loop unrolled by 4 (Listing 3)
  GEMM-U-C    all three loops coalesced into one (Listing 4)
  CONV        base: innermost k2 loop mapped, (c, i, j, k1) live-ins
  CONV-U-C-1  k1/k2 fully unrolled (K=3), innermost spatial loop mapped
  CONV-U-C-2  all loops coalesced (Listing 5)

Addressing is bank-local: LOAD/STORE nodes target ``bank<N>`` pseudo-arrays
and the data layout's base offsets are folded into the address arithmetic,
mirroring Morpher's co-generated data layout.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .adl import CGRAArch, cluster_4x4
from .dfg import DFG, DFGBuilder, Op, Operand
from .layout import ArrayDecl, DataLayout, Placement, assign_layout


# --------------------------------------------------------------------------
@dataclass
class KernelSpec:
    name: str
    dfg: DFG
    arch: CGRAArch
    layout: DataLayout
    mapped_iters: int                     # iterations of the mapped loop per invocation
    invocations: List[Dict[str, int]]     # live-in values per invocation
    golden: Callable[[Dict[str, np.ndarray]], Dict[str, np.ndarray]]
    init_banks: Callable[[np.random.Generator], Dict[str, np.ndarray]]
    # cost-model metadata (full-problem dims; see costmodel.py)
    meta: Dict[str, int] = field(default_factory=dict)

    def bank_images(self, rng: np.random.Generator) -> Dict[str, np.ndarray]:
        return self.init_banks(rng)


def _bank_arrays(layout: DataLayout) -> Dict[str, np.ndarray]:
    return {f"bank{i}": np.zeros(w, dtype=np.int64)
            for i, w in enumerate(layout.bank_image_size())}


def _wrap16(x: np.ndarray) -> np.ndarray:
    x = np.asarray(x, dtype=np.int64)
    x = ((x + (1 << 15)) & 0xFFFF) - (1 << 15)
    return x


# ======================================================================
# GEMM  (Listings 1, 3, 4): O[TI,TJ] += W[TI,TK] @ I[TK,TJ]
# ======================================================================
def _gemm_layout(arch: CGRAArch, TI: int, TK: int, TJ: int) -> DataLayout:
    """Output-stationary layout.  Preferred: W+O on bank0, I on bank1 (the
    accumulator recurrence and the weight stream share a port budget).
    When the O tile fills a whole bank (the paper's 64x16x64 tile has an
    8 kB O == one full bank), O gets bank0 alone and W streams with I."""
    try:
        return assign_layout(arch, [
            ArrayDecl("W", TI * TK, bank_pref=0),
            ArrayDecl("O", TI * TJ, bank_pref=0),
            ArrayDecl("I", TK * TJ, bank_pref=1),
        ])
    except ValueError:
        return assign_layout(arch, [
            ArrayDecl("O", TI * TJ, bank_pref=0),
            ArrayDecl("W", TI * TK, bank_pref=1),
            ArrayDecl("I", TK * TJ, bank_pref=1),
        ])


def _gemm_init(layout: DataLayout, TI: int, TK: int, TJ: int, lo=-8, hi=8):
    def init(rng: np.random.Generator) -> Dict[str, np.ndarray]:
        banks = _bank_arrays(layout)
        W = rng.integers(lo, hi, size=TI * TK)
        I = rng.integers(lo, hi, size=TK * TJ)
        pw, pi, po = (layout.placements[k] for k in ("W", "I", "O"))
        banks[pw.bank_array][pw.base:pw.base + pw.words] = W
        banks[pi.bank_array][pi.base:pi.base + pi.words] = I
        banks[po.bank_array][po.base:po.base + po.words] = 0
        return banks
    return init


def _gemm_golden(layout: DataLayout, TI: int, TK: int, TJ: int):
    def golden(banks: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        out = {k: v.copy() for k, v in banks.items()}
        pw, pi, po = (layout.placements[k] for k in ("W", "I", "O"))
        W = banks[pw.bank_array][pw.base:pw.base + pw.words].reshape(TI, TK)
        I = banks[pi.bank_array][pi.base:pi.base + pi.words].reshape(TK, TJ)
        O = banks[po.bank_array][po.base:po.base + po.words].reshape(TI, TJ)
        O = _wrap16(O + W @ I)
        out[po.bank_array][po.base:po.base + po.words] = O.reshape(-1)
        return out
    return golden


def build_gemm(TI: int = 64, TK: int = 16, TJ: int = 64,
               arch: Optional[CGRAArch] = None,
               unroll: int = 1, coalesced: bool = False) -> KernelSpec:
    """GEMM micro-kernel on one CGRA cluster (output-stationary).

    unroll=1, coalesced=False  -> base GEMM (map the k loop)
    unroll=4, coalesced=False  -> GEMM-U   (Listing 3)
    unroll=4, coalesced=True   -> GEMM-U-C (Listing 4)
    """
    arch = arch or cluster_4x4()
    assert TK % unroll == 0
    layout = _gemm_layout(arch, TI, TK, TJ)
    pw, pi, po = (layout.placements[k] for k in ("W", "I", "O"))
    U = unroll

    b = DFGBuilder(f"gemm{'-u' if U > 1 else ''}{'-c' if coalesced else ''}")
    cU = b.const(U)

    if not coalesced:
        i = b.livein("i")
        j = b.livein("j")
        # induction: k = prev + U  (init -U so iteration 0 sees k=0)
        k = b.add(Operand(0, 0), cU, name="k")  # placeholder, patched below
        b.dfg.nodes[k].operands = (Operand(k, dist=1, init=-U), Operand(cU))
        # loop guard (the exit branch the LLVM pass would emit)
        b.cmpge(k, b.const(TK - U), name="exit")
    else:
        # Listing 4: single coalesced loop; i/j/k are register-carried.
        cTK = b.const(TK)
        cTJ_b = b.const(TJ)
        c0 = b.const(0)
        c1 = b.const(1)
        knew = b.add(Operand(0, 0), cU, name="knew")
        kwrap = b.cmpge(knew, cTK, name="kwrap")
        k = b.select(kwrap, c0, knew, name="k")
        b.dfg.nodes[knew].operands = (Operand(k, dist=1, init=-U), Operand(cU))
        jnew = b.add(Operand(0, 0), c1, name="jnew")
        jwrap = b.cmpge(jnew, cTJ_b, name="jwrap")
        jsel = b.select(jwrap, c0, jnew, name="jsel")
        j = b.select(kwrap, jsel, Operand(0, 0), name="j")
        b.dfg.nodes[jnew].operands = (Operand(j, dist=1, init=0), Operand(c1))
        b.dfg.nodes[j].operands = (b.dfg.nodes[j].operands[0],
                                   b.dfg.nodes[j].operands[1],
                                   Operand(j, dist=1, init=0))
        land = b.op(Op.AND, kwrap, jwrap, name="ijcarry")
        inew = b.add(Operand(0, 0), c1, name="inew")
        i = b.select(land, inew, Operand(0, 0), name="i")
        b.dfg.nodes[inew].operands = (Operand(i, dist=1, init=0), Operand(c1))
        b.dfg.nodes[i].operands = (b.dfg.nodes[i].operands[0],
                                   b.dfg.nodes[i].operands[1],
                                   Operand(i, dist=1, init=0))

    # ---- body: O[i][j] += sum_u W[i][k+u] * I[k+u][j]
    wrow = b.mul(i, b.const(TK), name="wrow")
    wa0 = b.add(wrow, k, name="wa0")
    if pw.base:
        wa0 = b.add(wa0, b.const(pw.base))
    waddrs = [wa0] + [b.add(wa0, b.const(u), name=f"wa{u}") for u in range(1, U)]
    wl = [b.load(pw.bank_array, wa, name=f"w{u}") for u, wa in enumerate(waddrs)]

    irow = b.mul(k, b.const(TJ), name="irow")
    ia0 = b.add(irow, j, name="ia0")
    if pi.base:
        ia0 = b.add(ia0, b.const(pi.base))
    iaddrs = [ia0] + [b.add(ia0, b.const(u * TJ), name=f"ia{u}")
                      for u in range(1, U)]
    il = [b.load(pi.bank_array, ia, name=f"i{u}") for u, ia in enumerate(iaddrs)]

    prods = [b.mul(wl[u], il[u], name=f"p{u}") for u in range(U)]
    # reduction tree
    while len(prods) > 1:
        nxt = [b.add(prods[t], prods[t + 1]) for t in range(0, len(prods) - 1, 2)]
        if len(prods) % 2:
            nxt.append(prods[-1])
        prods = nxt
    psum = prods[0]

    orow = b.mul(i, b.const(TJ), name="orow")
    oaddr = b.add(orow, j, name="oaddr")
    if po.base:
        oaddr = b.add(oaddr, b.const(po.base))
    oval = b.load(po.bank_array, oaddr, name="oval")
    acc = b.add(oval, psum, name="acc")
    st = b.store(po.bank_array, oaddr, acc, name="ost")
    b.mem_dep(st, oval, dist=1)

    dfg = b.build()

    if coalesced:
        mapped_iters = TI * TJ * (TK // U)
        invocations: List[Dict[str, int]] = [{}]
    else:
        mapped_iters = TK // U
        invocations = [{"i": ii, "j": jj} for ii in range(TI) for jj in range(TJ)]

    return KernelSpec(
        name=dfg.name, dfg=dfg, arch=arch, layout=layout,
        mapped_iters=mapped_iters, invocations=invocations,
        golden=_gemm_golden(layout, TI, TK, TJ),
        init_banks=_gemm_init(layout, TI, TK, TJ),
        meta=dict(TI=TI, TK=TK, TJ=TJ, unroll=U, coalesced=int(coalesced),
                  macs_per_iter=U, liveins_per_inv=0 if coalesced else 2),
    )


# ======================================================================
# CONV (Listing 2, 5): O[c,i,j] += I[i+k1, j+k2] * W[c,k1,k2]   (valid)
#   tile: one output channel resident at a time (TCo = 1 in Table I).
# ======================================================================
def _conv_layout(arch: CGRAArch, IH: int, IW: int, OH: int, OW: int,
                 K: int) -> DataLayout:
    return assign_layout(arch, [
        ArrayDecl("O", OH * OW, bank_pref=0),
        ArrayDecl("W", K * K, bank_pref=0),
        ArrayDecl("I", IH * IW, bank_pref=1),
    ])


def build_conv(OH: int = 62, OW: int = 62, K: int = 3,
               IH: Optional[int] = None, IW: Optional[int] = None,
               arch: Optional[CGRAArch] = None,
               variant: str = "base") -> KernelSpec:
    """CONV micro-kernel (single input channel -> one output channel tile).

    variant: "base"  -- map the innermost k2 loop (live-ins i, j, k1)
             "uc1"   -- k1/k2 fully unrolled, map the j loop (live-in i)
             "uc2"   -- all spatial loops coalesced (Listing 5)
    """
    arch = arch or cluster_4x4()
    IH = IH if IH is not None else OH + K - 1
    IW = IW if IW is not None else OW + K - 1
    layout = _conv_layout(arch, IH, IW, OH, OW, K)
    pw, pi, po = (layout.placements[k] for k in ("W", "I", "O"))

    b = DFGBuilder(f"conv-{variant}")

    if variant == "base":
        i = b.livein("i")
        j = b.livein("j")
        k1 = b.livein("k1")
        c1 = b.const(1)
        k2 = b.add(Operand(0, 0), c1, name="k2")
        b.dfg.nodes[k2].operands = (Operand(k2, dist=1, init=-1), Operand(c1))
        b.cmpge(k2, b.const(K - 1), name="exit")

        r = b.add(i, k1, name="r")
        rm = b.mul(r, b.const(IW), name="rm")
        cc = b.add(j, k2, name="cc")
        ia = b.add(rm, cc, name="ia")
        if pi.base:
            ia = b.add(ia, b.const(pi.base))
        ival = b.load(pi.bank_array, ia, name="ival")

        wr = b.mul(k1, b.const(K), name="wr")
        wa = b.add(wr, k2, name="wa")
        if pw.base:
            wa = b.add(wa, b.const(pw.base))
        wval = b.load(pw.bank_array, wa, name="wval")

        prod = b.mul(ival, wval, name="prod")
        om = b.mul(i, b.const(OW), name="om")
        oa = b.add(om, j, name="oa")
        if po.base:
            oa = b.add(oa, b.const(po.base))
        oval = b.load(po.bank_array, oa, name="oval")
        acc = b.add(oval, prod, name="acc")
        st = b.store(po.bank_array, oa, acc, name="ost")
        b.mem_dep(st, oval, dist=1)

        mapped_iters = K
        invocations = [{"i": ii, "j": jj, "k1": kk}
                       for ii in range(OH) for jj in range(OW)
                       for kk in range(K)]
        liveins_per_inv = 3

    elif variant in ("uc1", "uc2"):
        c1 = b.const(1)
        c0 = b.const(0)
        if variant == "uc1":
            i = b.livein("i")
            j = b.add(Operand(0, 0), c1, name="j")
            b.dfg.nodes[j].operands = (Operand(j, dist=1, init=-1), Operand(c1))
            b.cmpge(j, b.const(OW - 1), name="exit")
        else:
            # Listing 5: coalesce (i, j) into one induction chain.
            jnew = b.add(Operand(0, 0), c1, name="jnew")
            jwrap = b.cmpge(jnew, b.const(OW), name="jwrap")
            j = b.select(jwrap, c0, jnew, name="j")
            b.dfg.nodes[jnew].operands = (Operand(j, dist=1, init=-1),
                                          Operand(c1))
            inew = b.add(Operand(0, 0), c1, name="inew")
            i = b.select(jwrap, inew, Operand(0, 0), name="i")
            b.dfg.nodes[inew].operands = (Operand(i, dist=1, init=0),
                                          Operand(c1))
            b.dfg.nodes[i].operands = (b.dfg.nodes[i].operands[0],
                                       b.dfg.nodes[i].operands[1],
                                       Operand(i, dist=1, init=0))

        # fully unrolled K x K MACs
        om = b.mul(i, b.const(OW), name="om")
        oa = b.add(om, j, name="oa")
        if po.base:
            oa = b.add(oa, b.const(po.base))
        oval = b.load(po.bank_array, oa, name="oval")

        prods = []
        for kk1 in range(K):
            r = b.add(i, b.const(kk1), name=f"r{kk1}") if kk1 else i
            rm = b.mul(r, b.const(IW), name=f"rm{kk1}")
            for kk2 in range(K):
                cc = b.add(j, b.const(kk2), name=f"cc{kk2}") if kk2 else j
                ia = b.add(rm, cc, name=f"ia{kk1}{kk2}")
                if pi.base:
                    ia = b.add(ia, b.const(pi.base))
                ival = b.load(pi.bank_array, ia, name=f"iv{kk1}{kk2}")
                widx = pw.base + kk1 * K + kk2
                wval = b.load(pw.bank_array, b.const(widx),
                              name=f"wv{kk1}{kk2}")
                prods.append(b.mul(ival, wval, name=f"p{kk1}{kk2}"))
        while len(prods) > 1:
            nxt = [b.add(prods[t], prods[t + 1])
                   for t in range(0, len(prods) - 1, 2)]
            if len(prods) % 2:
                nxt.append(prods[-1])
            prods = nxt

        acc = b.add(oval, prods[0], name="acc")
        st = b.store(po.bank_array, oa, acc, name="ost")
        b.mem_dep(st, oval, dist=1)

        if variant == "uc1":
            mapped_iters = OW
            invocations = [{"i": ii} for ii in range(OH)]
            liveins_per_inv = 1
        else:
            mapped_iters = OH * OW
            invocations = [{}]
            liveins_per_inv = 0
    else:
        raise ValueError(variant)

    dfg = b.build()

    def init_banks(rng: np.random.Generator) -> Dict[str, np.ndarray]:
        banks = _bank_arrays(layout)
        banks[pi.bank_array][pi.base:pi.base + pi.words] = \
            rng.integers(-8, 8, size=IH * IW)
        banks[pw.bank_array][pw.base:pw.base + pw.words] = \
            rng.integers(-4, 4, size=K * K)
        banks[po.bank_array][po.base:po.base + po.words] = 0
        return banks

    def golden(banks: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        out = {k: v.copy() for k, v in banks.items()}
        I = banks[pi.bank_array][pi.base:pi.base + pi.words].reshape(IH, IW)
        W = banks[pw.bank_array][pw.base:pw.base + pw.words].reshape(K, K)
        O = banks[po.bank_array][po.base:po.base + po.words].reshape(OH, OW)
        O = O.astype(np.int64)
        for kk1 in range(K):
            for kk2 in range(K):
                O = O + I[kk1:kk1 + OH, kk2:kk2 + OW] * W[kk1, kk2]
        out[po.bank_array][po.base:po.base + po.words] = _wrap16(O).reshape(-1)
        return out

    return KernelSpec(
        name=dfg.name, dfg=dfg, arch=arch, layout=layout,
        mapped_iters=mapped_iters, invocations=invocations,
        golden=golden, init_banks=init_banks,
        meta=dict(OH=OH, OW=OW, K=K, IH=IH, IW=IW,
                  liveins_per_inv=liveins_per_inv),
    )


# ----------------------------------------------------------------- registry
def table1_kernels(small: bool = False) -> Dict[str, KernelSpec]:
    """The six Table-I kernels.  ``small=True`` returns reduced dims for
    fast simulation-based verification (DFG structure identical)."""
    if small:
        g = dict(TI=6, TK=8, TJ=6)
        c = dict(OH=5, OW=5, K=3)
    else:
        g = dict(TI=64, TK=16, TJ=64)
        c = dict(OH=62, OW=62, K=3)
    return {
        "GEMM": build_gemm(**g, unroll=1, coalesced=False),
        "GEMM-U": build_gemm(**g, unroll=4, coalesced=False),
        "GEMM-U-C": build_gemm(**g, unroll=4, coalesced=True),
        "CONV": build_conv(**c, variant="base"),
        "CONV-U-C-1": build_conv(**c, variant="uc1"),
        "CONV-U-C-2": build_conv(**c, variant="uc2"),
    }
