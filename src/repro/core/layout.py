"""Multi-bank data-layout generation (paper Fig. 3, piece 4, second half).

Variables of the mapped loop kernel are allocated to the on-chip memory
banks of the target CGRA.  Each array gets (bank, base) — bank-local word
addressing — subject to bank capacity; the DFG builder folds ``base`` into
the address arithmetic, and LOAD/STORE nodes are constrained by the mapper
to PEs that can reach the assigned bank over the shared bus.

Banks are identified by their declared ``MemBank.id`` throughout (the
``bank`` field of a :class:`Placement`, the ``bank<id>`` memory-image
names, the simulator's bank offsets), never by position in
``CGRAArch.banks`` — user ADL files may declare banks in any order.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence

from .adl import CGRAArch


@dataclass(frozen=True)
class ArrayDecl:
    name: str
    words: int
    bank_pref: Optional[int] = None   # preferred bank (balance hint)


@dataclass(frozen=True)
class Placement:
    name: str
    words: int
    bank: int
    base: int   # word offset within the bank

    @property
    def bank_array(self) -> str:
        return f"bank{self.bank}"


@dataclass
class DataLayout:
    arch: CGRAArch
    placements: Dict[str, Placement]

    def bank_words(self, bank: int) -> int:
        return self.arch.bank(bank).words

    def bank_image_size(self) -> Dict[int, int]:
        """{bank id: words} in bank declaration order."""
        return {b.id: b.words for b in self.arch.banks}

    def addr(self, name: str, flat_index: int) -> int:
        p = self.placements[name]
        assert 0 <= flat_index < p.words, (name, flat_index, p.words)
        return p.base + flat_index

    # --------------------------------------------------------- serialization
    def to_json_dict(self) -> dict:
        """Placements only; the arch is serialized separately (ADL JSON)."""
        return {"placements": {name: [p.words, p.bank, p.base]
                               for name, p in sorted(self.placements.items())}}

    @staticmethod
    def from_json_dict(d: dict, arch: CGRAArch) -> "DataLayout":
        return DataLayout(arch, {
            name: Placement(name, words, bank, base)
            for name, (words, bank, base) in d["placements"].items()})


def assign_layout(arch: CGRAArch, arrays: Sequence[ArrayDecl],
                  banks: Optional[Sequence[int]] = None) -> DataLayout:
    """Greedy capacity-aware allocation honouring bank preferences.

    Arrays with an explicit ``bank_pref`` go there (error if they overflow);
    the rest are placed largest-first onto the emptiest bank.  ``banks``
    holds bank *ids* (``MemBank.id``, default: every bank in declaration
    order); ``bank_pref`` is a *position* into that sequence — an
    arch-agnostic balance hint ("first bank", "second bank") that kernel
    builders can use without knowing the target's id scheme.  The resolved
    :class:`Placement` always records the bank id.
    """
    banks = list(banks if banks is not None else (b.id for b in arch.banks))
    used = {b: 0 for b in banks}
    placements: Dict[str, Placement] = {}

    def place(a: ArrayDecl, b: int) -> None:
        cap = arch.bank(b).words
        if used[b] + a.words > cap:
            raise ValueError(
                f"array {a.name} ({a.words} words) overflows bank {b} "
                f"({cap - used[b]} free)")
        placements[a.name] = Placement(a.name, a.words, b, used[b])
        used[b] += a.words

    for a in arrays:
        if a.bank_pref is not None:
            if not 0 <= a.bank_pref < len(banks):
                raise ValueError(
                    f"array {a.name}: bank_pref {a.bank_pref} out of range "
                    f"for {len(banks)} usable banks")
            place(a, banks[a.bank_pref])
    for a in sorted([a for a in arrays if a.bank_pref is None],
                    key=lambda a: -a.words):
        b = min(banks, key=lambda b: used[b])
        place(a, b)
    return DataLayout(arch, placements)
