"""Multi-bank data-layout generation (paper Fig. 3, piece 4, second half).

Variables of the mapped loop kernel are allocated to the on-chip memory
banks of the target CGRA.  Each array gets (bank, base) — bank-local word
addressing — subject to bank capacity; the DFG builder folds ``base`` into
the address arithmetic, and LOAD/STORE nodes are constrained by the mapper
to PEs that can reach the assigned bank over the shared bus.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .adl import CGRAArch


@dataclass(frozen=True)
class ArrayDecl:
    name: str
    words: int
    bank_pref: Optional[int] = None   # preferred bank (balance hint)


@dataclass(frozen=True)
class Placement:
    name: str
    words: int
    bank: int
    base: int   # word offset within the bank

    @property
    def bank_array(self) -> str:
        return f"bank{self.bank}"


@dataclass
class DataLayout:
    arch: CGRAArch
    placements: Dict[str, Placement]

    def bank_words(self, bank: int) -> int:
        return self.arch.banks[bank].words

    def bank_image_size(self) -> List[int]:
        return [b.words for b in self.arch.banks]

    def addr(self, name: str, flat_index: int) -> int:
        p = self.placements[name]
        assert 0 <= flat_index < p.words, (name, flat_index, p.words)
        return p.base + flat_index

    # --------------------------------------------------------- serialization
    def to_json_dict(self) -> dict:
        """Placements only; the arch is serialized separately (ADL JSON)."""
        return {"placements": {name: [p.words, p.bank, p.base]
                               for name, p in sorted(self.placements.items())}}

    @staticmethod
    def from_json_dict(d: dict, arch: CGRAArch) -> "DataLayout":
        return DataLayout(arch, {
            name: Placement(name, words, bank, base)
            for name, (words, bank, base) in d["placements"].items()})


def assign_layout(arch: CGRAArch, arrays: Sequence[ArrayDecl],
                  banks: Optional[Sequence[int]] = None) -> DataLayout:
    """Greedy capacity-aware allocation honouring bank preferences.

    Arrays with an explicit ``bank_pref`` go there (error if they overflow);
    the rest are placed largest-first onto the emptiest bank.
    """
    banks = list(banks if banks is not None else range(len(arch.banks)))
    used = {b: 0 for b in banks}
    placements: Dict[str, Placement] = {}

    def place(a: ArrayDecl, b: int) -> None:
        cap = arch.banks[b].words
        if used[b] + a.words > cap:
            raise ValueError(
                f"array {a.name} ({a.words} words) overflows bank {b} "
                f"({cap - used[b]} free)")
        placements[a.name] = Placement(a.name, a.words, b, used[b])
        used[b] += a.words

    for a in arrays:
        if a.bank_pref is not None:
            place(a, a.bank_pref)
    for a in sorted([a for a in arrays if a.bank_pref is None],
                    key=lambda a: -a.words):
        b = min(banks, key=lambda b: used[b])
        place(a, b)
    return DataLayout(arch, placements)
