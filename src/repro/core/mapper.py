"""CGRA mapper: iterative modulo scheduling + placement + routing on the
MRRG (paper Fig. 3 piece 5).

Pipeline per candidate II (starting at MII, escalating on failure):
  1. priority order: recurrence-cycle nodes first, then by DAG height;
  2. unified slot+PE assignment: for each node scan a (time x PE) candidate
     window ordered by a cheap lower bound, place at the first candidate
     from which *all* edges to already-placed neighbours route conflict-free
     on the MRRG (strict, no-overuse routing with free fan-out sharing);
  3. limited rip-up: on failure evict the blocking neighbourhood and retry;
  4. register-file assignment: residency intervals from the routes are
     coloured onto the R physical registers per PE (cyclic-interval greedy).

MII = max(ResMII, RecMII):
  ResMII = max( ceil(#ops / #PEs), max_bank #accesses(bank),
                ceil(#mem-ops / #mem-PEs) )
  RecMII = smallest II with no positive cycle of (lat(u) - II*dist) —
           Bellman-Ford feasibility test (Rau'94).
"""
from __future__ import annotations

import json
import random
import warnings
from collections import deque
from concurrent.futures import TimeoutError as _FuturesTimeout
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .adl import CGRAArch
from .dfg import DFG, Node, Op, Operand, latency
from .layout import DataLayout
from .mrrg import F, R, Route, Usage, commit_route, release_route, route_value
from .pool import reset_pool, submit_all


# ----------------------------------------------------------------- options
@dataclass(frozen=True)
class MapperOptions:
    """The one place mapper search knobs live (paper's DRESC loop limits).

    Every caller of the flow — toolchain, offload analyzer, benchmarks,
    examples — goes through this dataclass instead of scattering raw
    ``ii_max``/``seeds``/``time_budget_s`` arguments.  The defaults are the
    project-wide policy: II escalation up to 32 (every Table-I kernel maps
    well below that), four placement seeds per II, no wall-clock budget.
    """
    ii_max: int = 32
    seeds: Tuple[int, ...] = (0, 1, 2, 3)
    ii_start: Optional[int] = None
    time_budget_s: Optional[float] = None

    def __post_init__(self):
        object.__setattr__(self, "seeds", tuple(self.seeds))

    # JSON round-trip (same idiom as the ADL) — feeds the content-addressed
    # compile cache key, so it must be stable and canonical.
    def to_json_dict(self) -> dict:
        return {"ii_max": self.ii_max, "seeds": list(self.seeds),
                "ii_start": self.ii_start,
                "time_budget_s": self.time_budget_s}

    @staticmethod
    def from_json_dict(d: dict) -> "MapperOptions":
        return MapperOptions(ii_max=d["ii_max"], seeds=tuple(d["seeds"]),
                             ii_start=d["ii_start"],
                             time_budget_s=d["time_budget_s"])


# --------------------------------------------------------------------- MII
def _edges_with_memdeps(dfg: DFG):
    """(src, dst, lat(src), dist) including ordering-only memory deps."""
    out = []
    for src, dst, _slot, opnd in dfg.data_edges():
        out.append((src, dst, latency(dfg.nodes[src].op), opnd.dist))
    for md in dfg.mem_deps:
        out.append((md.src, md.dst, latency(dfg.nodes[md.src].op), md.dist))
    return out


def rec_mii(dfg: DFG, ii_max: int = 128) -> int:
    edges = _edges_with_memdeps(dfg)
    ids = list(dfg.nodes)

    def feasible(ii: int) -> bool:
        # no positive cycle of weight lat - ii*dist  (longest-path relax)
        pot = {i: 0 for i in ids}
        for it in range(len(ids) + 1):
            changed = False
            for src, dst, lat, dist in edges:
                w = lat - ii * dist
                if pot[src] + w > pot[dst]:
                    pot[dst] = pot[src] + w
                    changed = True
            if not changed:
                return True
        return False

    lo, hi = 1, ii_max
    while lo < hi:
        mid = (lo + hi) // 2
        if feasible(mid):
            hi = mid
        else:
            lo = mid + 1
    return lo


def res_mii(dfg: DFG, arch: CGRAArch, bank_of: Dict[int, int]) -> int:
    n_pes = arch.n_pes
    fu = -(-dfg.n_nodes // n_pes)
    mem_nodes = [n for n in dfg.nodes.values() if n.is_mem]
    per_bank: Dict[int, int] = {}
    for n in mem_nodes:
        per_bank[bank_of[n.id]] = per_bank.get(bank_of[n.id], 0) + 1
    bank = max(per_bank.values(), default=0)
    mem_pe = -(-len(mem_nodes) // max(1, len(arch.mem_pes)))
    return max(fu, bank, mem_pe, 1)


def compute_mii(dfg: DFG, arch: CGRAArch, bank_of: Dict[int, int]
                ) -> Tuple[int, Dict[str, int]]:
    r = rec_mii(dfg)
    s = res_mii(dfg, arch, bank_of)
    fu_only = max(-(-dfg.n_nodes // arch.n_pes), r)
    return max(r, s), {"rec_mii": r, "res_mii": s, "fu_only_mii": fu_only}


# ----------------------------------------------------------------- mapping
@dataclass
class Mapping:
    dfg: DFG
    arch: CGRAArch
    II: int
    mii: int
    mii_parts: Dict[str, int]
    place: Dict[int, Tuple[int, int]]            # node -> (pe, abs time)
    routes: Dict[Tuple[int, int, int], Route]    # (src, dst, slot) -> route
    usage: Usage
    reg_assign: Dict[Tuple[int, int, int], int]  # (pe, value, t_start) -> reg
    lireg_assign: Dict[str, Tuple[int, int]]     # livein name -> (pe, index)
    bank_of: Dict[int, int]                      # mem node -> bank id

    @property
    def depth(self) -> int:
        return max(t for _pe, t in self.place.values()) + 2

    @property
    def utilization(self) -> float:
        return self.dfg.n_nodes / (self.arch.n_pes * self.II)

    def schedule_len(self, n_iters: int) -> int:
        """Cycles to run n_iters pipelined iterations (fill + steady + drain)."""
        return (n_iters - 1) * self.II + self.depth

    # --------------------------------------------------------- serialization
    def to_json_dict(self) -> dict:
        """JSON-able form of everything except dfg/arch (serialized by the
        artifact that owns this mapping)."""
        def route_dict(r: Route) -> dict:
            return {"value": r.value, "src_pe": r.src_pe, "t_src": r.t_src,
                    "dst_pe": r.dst_pe, "t_dst": r.t_dst,
                    "steps": [list(s) for s in r.steps],
                    "uses": [[list(k), list(i)] for k, i in r.uses]}

        return {
            "II": self.II, "mii": self.mii, "mii_parts": self.mii_parts,
            "place": [[v, pe, t] for v, (pe, t) in sorted(self.place.items())],
            "routes": [[src, dst, slot, route_dict(r)]
                       for (src, dst, slot), r in sorted(self.routes.items())],
            "usage": [[list(k), sorted(list(i) for i in insts)]
                      for k, insts in sorted(self.usage.map.items(),
                                             key=lambda kv: repr(kv[0]))],
            "reg_assign": [[pe, val, t, reg] for (pe, val, t), reg
                           in sorted(self.reg_assign.items())],
            "lireg_assign": {name: list(v)
                             for name, v in sorted(self.lireg_assign.items())},
            "bank_of": [[v, b] for v, b in sorted(self.bank_of.items())],
        }

    @staticmethod
    def from_json_dict(d: dict, dfg: DFG, arch: CGRAArch) -> "Mapping":
        def route_from(rd: dict) -> Route:
            return Route(value=rd["value"], src_pe=rd["src_pe"],
                         t_src=rd["t_src"], dst_pe=rd["dst_pe"],
                         t_dst=rd["t_dst"],
                         steps=[tuple(s) for s in rd["steps"]],
                         uses=[(tuple(k), tuple(i)) for k, i in rd["uses"]])

        usage = Usage(arch, d["II"])
        for k, insts in d["usage"]:
            for inst in insts:
                usage.add(tuple(k), tuple(inst))
        return Mapping(
            dfg=dfg, arch=arch, II=d["II"], mii=d["mii"],
            mii_parts=dict(d["mii_parts"]),
            place={v: (pe, t) for v, pe, t in d["place"]},
            routes={(src, dst, slot): route_from(rd)
                    for src, dst, slot, rd in d["routes"]},
            usage=usage,
            reg_assign={(pe, val, t): reg
                        for pe, val, t, reg in d["reg_assign"]},
            lireg_assign={name: tuple(v)
                          for name, v in d["lireg_assign"].items()},
            bank_of={v: b for v, b in d["bank_of"]},
        )


class MapError(RuntimeError):
    pass


DEBUG = False


def _dbg(*a):
    if DEBUG:
        print("[mapper]", *a, flush=True)


def _bank_of_nodes(dfg: DFG, layout: DataLayout) -> Dict[int, int]:
    out = {}
    for n in dfg.nodes.values():
        if n.is_mem:
            assert n.array.startswith("bank")
            out[n.id] = int(n.array[4:])
    return out


def _sccs(dfg: DFG) -> List[List[int]]:
    """Tarjan SCCs over the full dependence graph (any-dist data edges +
    memory deps).  Non-trivial SCCs = recurrence cycles."""
    succ: Dict[int, List[int]] = {i: [] for i in dfg.nodes}
    for src, dst, _s, _o in dfg.data_edges():
        succ[src].append(dst)
    for md in dfg.mem_deps:
        succ[md.src].append(md.dst)
    index: Dict[int, int] = {}
    low: Dict[int, int] = {}
    on_stack: Set[int] = set()
    stack: List[int] = []
    out: List[List[int]] = []
    counter = [0]

    def strong(v0: int) -> None:
        # iterative Tarjan
        work = [(v0, 0)]
        while work:
            v, pi = work[-1]
            if pi == 0:
                index[v] = low[v] = counter[0]
                counter[0] += 1
                stack.append(v)
                on_stack.add(v)
            recurse = False
            for i in range(pi, len(succ[v])):
                w = succ[v][i]
                if w not in index:
                    work[-1] = (v, i + 1)
                    work.append((w, 0))
                    recurse = True
                    break
                elif w in on_stack:
                    low[v] = min(low[v], index[w])
            if recurse:
                continue
            if low[v] == index[v]:
                comp = []
                while True:
                    w = stack.pop()
                    on_stack.discard(w)
                    comp.append(w)
                    if w == v:
                        break
                out.append(comp)
            work.pop()
            if work:
                pv = work[-1][0]
                low[pv] = min(low[pv], low[v])

    for v in dfg.nodes:
        if v not in index:
            strong(v)
    return out


@dataclass
class _DFGInfo:
    """Per-DFG search invariants, computed once per compile and shared by
    every (II, seed) trial.  Everything here is II- and seed-independent;
    hoisting it out of ``_try_map`` keeps the portfolio's per-trial cost to
    the placement/routing search itself."""
    edges: List[Tuple[int, int, int, int]]     # (src, dst, lat, dist)
    cons: Dict[int, List[Tuple[int, int]]]     # consumers per node
    height: Dict[int, int]                     # dist-0 DAG height
    cyc_ids: List[int]                         # priority prefix (cycles)
    rest: List[int]                            # acyclic ids, dfg.nodes order
    self_loop: Set[int]                        # dist>0 self-loop sources
    multi_cycle: Set[int]                      # members of len>1 SCCs
    comps: List[List[int]]                     # len>1 SCCs
    rank: List[int]                            # condensation longest-path
    order_c: List[int]                         # comp placement order


def _dfg_info(dfg: DFG) -> _DFGInfo:
    order = dfg.topo_order()
    topo_pos = {v: i for i, v in enumerate(order)}
    cons = dfg.consumers()
    height = {i: 0 for i in dfg.nodes}
    for v in reversed(order):
        for c, _slot in cons[v]:
            if any(o.src == v and o.dist == 0 for o in dfg.nodes[c].operands):
                height[v] = max(height[v], height[c] + 1)

    self_loop = {src for src, dst, _s, o in dfg.data_edges()
                 if src == dst and o.dist > 0}
    sccs = _sccs(dfg)
    cyc_comps = [c for c in sccs
                 if len(c) > 1 or (len(c) == 1 and c[0] in self_loop)]
    # tightest (largest) cycles first; members in dataflow order so each
    # node lands next to its already-placed cycle neighbours
    cyc_comps.sort(key=len, reverse=True)
    cyc_ids: List[int] = []
    seen: Set[int] = set()
    for comp in cyc_comps:
        for v in sorted(comp, key=lambda v: topo_pos[v]):
            cyc_ids.append(v)
            seen.add(v)
    rest = [i for i in dfg.nodes if i not in seen]

    comps = [c for c in sccs if len(c) > 1]
    multi_cycle: Set[int] = set()
    for c in comps:
        multi_cycle.update(c)
    # condensation DAG: comp A -> comp B if a dist-0 path (through glue
    # nodes) leads from A into B; stagger start margins by longest-path
    # rank so glue nodes keep non-empty windows between dependent comps.
    comp_of: Dict[int, int] = {}
    for ci, c in enumerate(comps):
        for v in c:
            comp_of[v] = ci
    succ0: Dict[int, List[int]] = {i: [] for i in dfg.nodes}
    for s, d, _sl, o in dfg.data_edges():
        if o.dist == 0:
            succ0[s].append(d)
    comp_succ: Dict[int, Set[int]] = {ci: set() for ci in range(len(comps))}
    for ci, c in enumerate(comps):
        seen_n: Set[int] = set(c)
        stack = [d for v in c for d in succ0[v] if d not in seen_n]
        while stack:
            v = stack.pop()
            if v in seen_n:
                continue
            seen_n.add(v)
            cj = comp_of.get(v)
            if cj is not None and cj != ci:
                comp_succ[ci].add(cj)
                continue
            stack.extend(succ0[v])
    rank = [0] * len(comps)
    for _ in range(len(comps) + 1):          # longest-path fixpoint
        for ci in range(len(comps)):
            for cj in comp_succ[ci]:
                rank[cj] = max(rank[cj], rank[ci] + 1)
    order_c = sorted(range(len(comps)), key=lambda ci: (rank[ci],
                                                        -len(comps[ci])))
    return _DFGInfo(edges=_edges_with_memdeps(dfg), cons=cons, height=height,
                    cyc_ids=cyc_ids, rest=rest, self_loop=self_loop,
                    multi_cycle=multi_cycle, comps=comps, rank=rank,
                    order_c=order_c)


def _priorities(info: _DFGInfo, rng: random.Random) -> List[int]:
    """Recurrence-cycle nodes first (grouped per SCC, in dependence order),
    then the acyclic remainder by DAG height (seed-jittered tie-break)."""
    jitter = {i: rng.random() for i in info.rest}
    height = info.height
    rest = sorted(info.rest, key=lambda i: (-height[i], jitter[i]))
    return info.cyc_ids + rest


def _asap(dfg: DFG, II: int,
          edges: Optional[List[Tuple[int, int, int, int]]] = None
          ) -> Dict[int, int]:
    pot = {i: 0 for i in dfg.nodes}
    if edges is None:
        edges = _edges_with_memdeps(dfg)
    for _ in range(len(pot) + 1):
        changed = False
        for src, dst, lat, dist in edges:
            w = lat - II * dist
            if pot[src] + w > pot[dst]:
                pot[dst] = pot[src] + w
                changed = True
        if not changed:
            break
    base = -min(pot.values(), default=0)
    return {i: v + base for i, v in pot.items()}


def _try_map(dfg: DFG, arch: CGRAArch, II: int, seed: int,
             bank_of: Dict[int, int], info: Optional[_DFGInfo] = None,
             asap: Optional[Dict[int, int]] = None, window_factor: int = 3,
             ripup_budget: int = 60) -> Optional[Tuple[Dict, Dict, Usage]]:
    if info is None:
        info = _dfg_info(dfg)
    rng = random.Random(seed)
    order = _priorities(info, rng)
    if asap is None:
        asap = _asap(dfg, II, info.edges)
    # recurrence cycles are internally rigid; start them late enough that
    # their feeder chains (which accrue routing hops beyond the latency-only
    # ASAP estimate) fit underneath.
    # induction-variable self-loops are chain *sources*: keep them early so
    # downstream feeders retain routing-drift slack; multi-node recurrences
    # (accumulators) are chain *sinks*: start them late enough for feeders.
    multi_cycle = info.multi_cycle
    cycle_nodes = multi_cycle | info.self_loop
    margin = II + 4
    self_margin = 1
    usage = Usage(arch, II)
    dtab = usage.tables.dist
    place: Dict[int, Tuple[int, int]] = {}
    routes: Dict[Tuple[int, int, int], Route] = {}
    cons = info.cons

    def node_claims(n: Node, pe: int, t: int) -> List:
        claims = [(("fu", pe, t % II), (n.id, t))]
        if n.op != Op.STORE:
            claims.append((("fuout", pe, (t + n.lat) % II), (n.id, t + n.lat)))
        if n.is_mem:
            claims.append((("bank", bank_of[n.id], t % II), (n.id, t)))
        if n.op == Op.LIVEIN:
            claims.append((("lireg", pe), (n.livein, -1)))
        return claims

    def claims_free(claims) -> bool:
        return all(usage.free_for(k, i) for k, i in claims)

    def edge_jobs(v: int):
        """Edges between v and already-placed nodes, plus mem-dep checks."""
        jobs = []  # (src, dst, slot, dist)
        n = dfg.nodes[v]
        for slot, opnd in enumerate(n.operands):
            if opnd.src in place or opnd.src == v:
                jobs.append((opnd.src, v, slot, opnd.dist))
        for c, cslot in cons[v]:
            if c in place and c != v:
                d = dfg.nodes[c].operands[cslot].dist
                jobs.append((v, c, cslot, d))
        return jobs

    def memdep_ok(v: int, t: int) -> bool:
        for md in dfg.mem_deps:
            if md.src == v and md.dst in place:
                if place[md.dst][1] + II * md.dist < t + dfg.nodes[v].lat:
                    return False
            if md.dst == v and md.src in place:
                su = place[md.src][1]
                if t + II * md.dist < su + dfg.nodes[md.src].lat:
                    return False
        return True

    def unplace(v: int) -> None:
        if v not in place:
            return
        pe, t = place.pop(v)
        n = dfg.nodes[v]
        for k, i in node_claims(n, pe, t):
            usage.remove(k, i)
        for key in [k for k in routes if k[0] == v or k[1] == v]:
            release_route(usage, routes.pop(key))

    def try_place(v: int) -> bool:
        n = dfg.nodes[v]
        # time window
        t_lo = asap[v]
        if v in cycle_nodes and not any(
                o.src in place for o in n.operands if o.src != v) and not any(
                c in place for c, _ in cons[v] if c != v):
            # first node of its recurrence: leave feeder room
            t_lo += margin if v in multi_cycle else self_margin
        t_hi = t_lo + window_factor * II - 1
        succ_bound = False
        for slot, opnd in enumerate(n.operands):
            if opnd.src in place and opnd.src != v:
                su = place[opnd.src][1]
                t_lo = max(t_lo, su + dfg.nodes[opnd.src].lat - II * opnd.dist)
        for c, cslot in cons[v]:
            if c in place and c != v:
                d = dfg.nodes[c].operands[cslot].dist
                t_hi = min(t_hi, place[c][1] + II * d - n.lat)
                succ_bound = True
        if t_hi < t_lo:
            _dbg(f"node {v} ({n.name or n.op.value}): empty window "
                 f"[{t_lo},{t_hi}]")
            return False
        # PE candidates
        if n.is_mem:
            pes = [p for p in arch.pes_of_bank(bank_of[v])
                   if arch.supports(p, n.op)]
        else:
            pes = [p for p in range(arch.n_pes) if arch.supports(p, n.op)]
        if not pes:
            return False
        anchors = [place[o.src][0] for o in n.operands
                   if o.src in place and o.src != v]
        anchors += [place[c][0] for c, _ in cons[v] if c in place and c != v]

        # the anchor-distance lower bound depends only on the PE, not the
        # slot: one table-lookup sum per PE instead of one per candidate
        lb_pe = {pe: sum(dtab[pe][a] for a in anchors) for pe in pes}
        cands = []
        for t in range(t_lo, t_hi + 1):
            # feeders of placed consumers want to sit close to them (long
            # waits burn registers across pipelined iterations); nodes with
            # no placed consumer prefer the earliest slot.
            tbias = 0.25 * ((t_hi - t) if succ_bound else (t - t_lo))
            for pe in pes:
                cands.append((lb_pe[pe] + tbias + rng.random() * 0.1, t, pe))
        cands.sort()

        tried_routing = 0
        for _lb, t, pe in cands:
            if tried_routing >= 64:
                break
            if not memdep_ok(v, t):
                continue
            claims = node_claims(n, pe, t)
            if not claims_free(claims):
                continue
            for k, i in claims:
                usage.add(k, i)
            place[v] = (pe, t)
            tried_routing += 1
            new_routes: List[Tuple[Tuple[int, int, int], Route]] = []
            ok = True
            for src, dst, eslot, dist in edge_jobs(v):
                spe, st_ = place[src]
                dpe, dt = place[dst]
                r = route_value(usage, arch, II, src, spe,
                                st_ + dfg.nodes[src].lat, dpe, dt + II * dist)
                if r is None:
                    ok = False
                    break
                commit_route(usage, r)
                new_routes.append(((src, dst, eslot), r))
            if ok:
                for key, r in new_routes:
                    routes[key] = r
                return True
            for _key, r in new_routes:
                release_route(usage, r)
            for k, i in claims:
                usage.remove(k, i)
            del place[v]
        _dbg(f"node {v} ({n.name or n.op.value}): no feasible candidate in "
             f"window [{t_lo},{t_hi}] x {len(pes)} PEs, "
             f"{len(place)} placed")
        return False

    def place_comp_jointly(comp: List[int], extra_margin: int) -> bool:
        """Co-locate a recurrence SCC on one PE at internal ASAP offsets.
        Removes the tight-coupling failure mode of per-node greedy search
        (e.g. the load->acc->store output-stationary cycle at II=RecMII).
        extra_margin staggers dependent comps so the acyclic glue nodes
        between them (e.g. the AND feeding a coalesced-index select) keep
        non-empty scheduling windows."""
        comp_set = set(comp)
        # internal relative offsets: longest path inside the component
        off = {v: 0 for v in comp}
        intern = [(s, d, latency(dfg.nodes[s].op), o.dist)
                  for s, d, _sl, o in dfg.data_edges()
                  if s in comp_set and d in comp_set and s != d]
        intern += [(md.src, md.dst, latency(dfg.nodes[md.src].op), md.dist)
                   for md in dfg.mem_deps
                   if md.src in comp_set and md.dst in comp_set]
        for _ in range(len(comp) + 1):
            for s, d, lat, dist in intern:
                off[d] = max(off[d], off[s] + lat - II * dist)
        base0 = min(off.values())
        off = {v: o - base0 for v, o in off.items()}
        # candidate PEs must satisfy every member's op/bank constraint
        pes = []
        for p in range(arch.n_pes):
            ok = True
            for v in comp:
                n = dfg.nodes[v]
                if not arch.supports(p, n.op):
                    ok = False
                    break
                if n.is_mem and p not in arch.pes_of_bank(bank_of[v]):
                    ok = False
                    break
            if ok:
                pes.append(p)
        # prefer PEs near already-placed comps (their values flow here
        # through at most a couple of glue nodes)
        anchors = [pe for pe, _t in place.values()]
        if anchors:
            pes.sort(key=lambda p: (sum(dtab[p][a]
                                        for a in anchors) / len(anchors)
                                    + rng.random()))
        else:
            rng.shuffle(pes)
        t0_lo = max(asap[v] - off[v] for v in comp) + margin + extra_margin
        for t0 in range(t0_lo, t0_lo + window_factor * II):
            for p in pes:
                claims = []
                for v in comp:
                    claims.extend(node_claims(dfg.nodes[v], p, t0 + off[v]))
                if not all(usage.free_for(k, i) for k, i in claims):
                    continue
                for k, i in claims:
                    usage.add(k, i)
                for v in comp:
                    place[v] = (p, t0 + off[v])
                new_routes = []
                ok = True
                # internal edges + cross edges to previously-placed comps
                jobs = [(s, d, sl, o.dist) for s, d, sl, o in dfg.data_edges()
                        if (s in comp_set and d in comp_set)
                        or (s in comp_set and d in place and d not in comp_set)
                        or (d in comp_set and s in place and s not in comp_set)]
                for s, d, eslot, dist in jobs:
                    if s not in place or d not in place:
                        continue
                    r = route_value(usage, arch, II, s, place[s][0],
                                    place[s][1] + dfg.nodes[s].lat,
                                    place[d][0], place[d][1] + II * dist)
                    if r is None:
                        ok = False
                        break
                    commit_route(usage, r)
                    new_routes.append(((s, d, eslot), r))
                if ok:
                    for key, r in new_routes:
                        routes[key] = r
                    return True
                for _key, r in new_routes:
                    release_route(usage, r)
                for k, i in claims:
                    usage.remove(k, i)
                for v in comp:
                    del place[v]
        return False

    joint_done: Set[int] = set()
    comps, rank = info.comps, info.rank
    for ci in info.order_c:
        # routing drift accrues roughly linearly along the feeder chain:
        # scale each comp's start slack with its ASAP depth (plus the DAG
        # rank so sibling comps at equal depth still stagger).
        depth_slack = max(asap[v] for v in comps[ci])
        if place_comp_jointly(comps[ci],
                              extra_margin=depth_slack + 3 * rank[ci]):
            joint_done.update(comps[ci])
        # else: fall through to per-node placement for these nodes

    pending = deque(v for v in order if v not in joint_done)
    ripups = 0
    while pending:
        v = pending.popleft()
        if try_place(v):
            continue
        # rip-up: evict placed neighbours (and a random victim) and retry
        if ripups >= ripup_budget:
            return None
        ripups += 1
        n = dfg.nodes[v]
        vic: Set[int] = set()
        for o in n.operands:
            if o.src in place and o.src != v:
                vic.add(o.src)
        for c, _ in cons[v]:
            if c in place and c != v:
                vic.add(c)
        if place:
            vic.add(rng.choice(list(place)))
        vic -= joint_done  # jointly-placed recurrences stay put
        for w in vic:
            unplace(w)
        if not try_place(v):
            # place v first in an emptier context next round
            pending.appendleft(v)
        pending.extend(sorted(vic))
    return place, routes, usage


# ------------------------------------------------------- register coloring
def _color_registers(arch: CGRAArch, II: int,
                     routes: Dict[Tuple[int, int, int], Route]
                     ) -> Optional[Dict[Tuple[int, int, int], int]]:
    """Assign physical registers to residency intervals.

    Returns {(pe, value, t): reg_index} for every resident cycle t, or
    None if > R registers would be needed on some PE.
    """
    res: Dict[Tuple[int, int], Set[int]] = {}
    for r in routes.values():
        for kind, pe, t in r.steps:
            if kind == R:
                res.setdefault((pe, r.value), set()).add(t)
    intervals: Dict[int, List[Tuple[int, int, int]]] = {}  # pe -> [(a, b, val)]
    for (pe, val), ts in res.items():
        ts = sorted(ts)
        a = prev = ts[0]
        for t in ts[1:]:
            if t == prev + 1:
                prev = t
                continue
            intervals.setdefault(pe, []).append((a, prev, val))
            a = prev = t
        intervals.setdefault(pe, []).append((a, prev, val))

    assign: Dict[Tuple[int, int, int], int] = {}
    for pe, ivs in intervals.items():
        ivs.sort()
        slot_sets = []
        for a, b, val in ivs:
            assert b - a + 1 <= II, "residency longer than II"
            slot_sets.append(frozenset(t % II for t in range(a, b + 1)))
        regs_slots: List[Set[int]] = [set() for _ in range(arch.regfile_size)]
        # values may legitimately share a register across disjoint slots;
        # identical (value) intervals overlapping in slots collide.
        for (a, b, val), slots in zip(ivs, slot_sets):
            placed = False
            for ridx in range(arch.regfile_size):
                if not (regs_slots[ridx] & slots):
                    regs_slots[ridx] |= slots
                    for t in range(a, b + 1):
                        assign[(pe, val, t)] = ridx
                    placed = True
                    break
            if not placed:
                return None
    return assign


def _assign_liregs(arch: CGRAArch, dfg: DFG,
                   place: Dict[int, Tuple[int, int]]
                   ) -> Dict[str, Tuple[int, int]]:
    per_pe: Dict[int, List[str]] = {}
    out: Dict[str, Tuple[int, int]] = {}
    for n in dfg.nodes.values():
        if n.op == Op.LIVEIN:
            pe = place[n.id][0]
            names = per_pe.setdefault(pe, [])
            if n.livein not in names:
                names.append(n.livein)
            out[n.livein] = (pe, names.index(n.livein))
    for pe, names in per_pe.items():
        assert len(names) <= arch.livein_regs
    return out


def _portfolio_worker(payload: str) -> Optional[str]:
    """Process-pool worker for one (II, seed) trial.  Returns the mapping's
    canonical JSON dict (the exact bytes the sequential path would have
    serialized) or None when the trial is infeasible."""
    d = json.loads(payload)
    arch = CGRAArch.from_json(json.dumps(d["arch"]))
    dfg = DFG.from_json_dict(d["dfg"])
    bank_of = {v: b for v, b in d["bank_of"]}
    II, seed = d["II"], d["seed"]
    got = _try_map(dfg, arch, II, seed, bank_of)
    if got is None:
        return None
    place, routes, usage = got
    regs = _color_registers(arch, II, routes)
    if regs is None:
        return None
    mapping = Mapping(dfg=dfg, arch=arch, II=II, mii=d["mii"],
                      mii_parts=d["mii_parts"], place=place, routes=routes,
                      usage=usage, reg_assign=regs,
                      lireg_assign=_assign_liregs(arch, dfg, place),
                      bank_of=bank_of)
    return json.dumps(mapping.to_json_dict())


def map_kernel_opts(dfg: DFG, arch: CGRAArch, layout: DataLayout,
                    options: Optional[MapperOptions] = None, *,
                    portfolio: Optional[bool] = None) -> Mapping:
    """Map a DFG onto the CGRA: returns the first feasible Mapping,
    escalating II from MII (DRESC/Morpher semantics).

    Search runs as a *portfolio* over the candidate seeds of each II: the
    first seed runs in-process (the common fast path) while the remaining
    seeds race on the shared worker pool.  Selection is deterministic —
    the lowest feasible II wins, ties broken by the earliest seed in
    ``options.seeds`` order — so the result is bit-identical to the
    sequential search, which also serves as the fallback whenever process
    fan-out is unavailable (single core, nested workers, REPL drivers).
    ``portfolio=False`` (or ``MORPHER_PORTFOLIO=0``) forces sequential.

    This is the canonical mapper entry point; search limits come from one
    :class:`MapperOptions`.  Prefer `repro.core.toolchain.Toolchain.compile`
    which adds configuration generation and artifact caching on top.
    """
    import os as _os
    import time as _time
    opt = options or MapperOptions()
    deadline = _time.time() + opt.time_budget_s if opt.time_budget_s else None
    dfg.validate()
    bank_of = _bank_of_nodes(dfg, layout)
    mii, parts = compute_mii(dfg, arch, bank_of)
    info = _dfg_info(dfg)
    start = max(mii, opt.ii_start or 0)
    # portfolio=True races unconditionally; auto mode races a round only
    # when its in-process seed-0 trial was expensive enough to amortize
    # the worker dispatch (cheap trials finish sequentially faster)
    force_pool = portfolio is True
    if portfolio is None:
        portfolio = _os.environ.get("MORPHER_PORTFOLIO", "1") != "0"
    use_pool = portfolio and len(opt.seeds) > 1
    min_trial_s = float(_os.environ.get("MORPHER_PORTFOLIO_MIN_TRIAL_S",
                                        "0.2"))

    def budget_left() -> Optional[float]:
        if deadline is None:
            return None
        left = deadline - _time.time()
        if left <= 0:
            raise MapError(f"{dfg.name}: time budget exhausted at "
                           f"II={II} (MII={mii})")
        return left

    def attempt(II: int, seed: int, asap: Dict[int, int]
                ) -> Optional[Mapping]:
        got = _try_map(dfg, arch, II, seed, bank_of, info, asap)
        if got is None:
            return None
        place, routes, usage = got
        regs = _color_registers(arch, II, routes)
        if regs is None:
            return None
        return Mapping(dfg=dfg, arch=arch, II=II, mii=mii,
                       mii_parts=parts, place=place, routes=routes,
                       usage=usage, reg_assign=regs,
                       lireg_assign=_assign_liregs(arch, dfg, place),
                       bank_of=bank_of)

    base_payload = None
    seeds = opt.seeds
    for II in range(start, opt.ii_max + 1):
        if not seeds:                          # degenerate options: no
            continue                           # trials, MapError below
        asap = _asap(dfg, II, info.edges)
        # the first seed always runs in-process: when it succeeds (the
        # common case) the compile pays zero fan-out overhead
        budget_left()
        t_trial = _time.time()
        m = attempt(II, seeds[0], asap)
        if m is not None:
            return m
        trial_cost = _time.time() - t_trial
        futs = None
        if use_pool and (force_pool or trial_cost >= min_trial_s):
            if base_payload is None:
                base_payload = {"dfg": dfg.to_json_dict(),
                                "arch": json.loads(arch.to_json()),
                                "bank_of": sorted(bank_of.items()),
                                "mii": mii, "mii_parts": parts}
            futs = submit_all(_portfolio_worker, [
                json.dumps({**base_payload, "II": II, "seed": s})
                for s in seeds[1:]])
        if futs is None:                       # sequential search
            for seed in seeds[1:]:
                budget_left()
                m = attempt(II, seed, asap)
                if m is not None:
                    return m
            continue
        # the remaining seeds race on the pool; consume results in seeds
        # order so the winner matches the sequential search
        try:
            for f, seed in zip(futs, seeds[1:]):
                out = f.result(timeout=budget_left())
                if out is not None:
                    m = Mapping.from_json_dict(json.loads(out), dfg, arch)
                    break
        except MapError:
            for f in futs:
                f.cancel()
            raise
        except (_FuturesTimeout, TimeoutError):
            for f in futs:
                f.cancel()
            raise MapError(f"{dfg.name}: time budget exhausted at "
                           f"II={II} (MII={mii})")
        except Exception:
            # broken pool / worker crash: finish this II sequentially
            # (seeds[0] already ran in-process) and drop back to the
            # sequential path for the remaining IIs
            reset_pool()
            use_pool = False
            for seed in seeds[1:]:
                budget_left()
                m = attempt(II, seed, asap)
                if m is not None:
                    return m
            continue
        for f in futs:
            f.cancel()
        if m is not None:
            return m
    raise MapError(f"{dfg.name}: no mapping found with II <= {opt.ii_max} "
                   f"(MII={mii}, parts={parts})")


def map_kernel(dfg: DFG, arch: CGRAArch, layout: DataLayout,
               ii_max: int = 32, seeds: Sequence[int] = (0, 1, 2, 3),
               ii_start: Optional[int] = None,
               time_budget_s: Optional[float] = None) -> Mapping:
    """Deprecated shim — use ``Toolchain.compile(spec)`` (or, for a bare
    DFG, :func:`map_kernel_opts` with a :class:`MapperOptions`).  Defaults
    mirror :class:`MapperOptions` exactly (``ii_max=32``)."""
    warnings.warn(
        "map_kernel(dfg, arch, layout, ii_max=..., ...) is deprecated; "
        "use repro.core.toolchain.Toolchain.compile(spec) or "
        "map_kernel_opts(dfg, arch, layout, MapperOptions(...))",
        DeprecationWarning, stacklevel=2)
    return map_kernel_opts(dfg, arch, layout,
                           MapperOptions(ii_max=ii_max, seeds=tuple(seeds),
                                         ii_start=ii_start,
                                         time_budget_s=time_budget_s))
