"""Modulo Routing Resource Graph (MRRG) — resources and the time-extended
router (paper Fig. 2c; Mei et al. DRESC / Rau iterative modulo scheduling).

The CGRA is modelled as a set of per-cycle-slot resources (II slots):

  ('fu',    pe, slot)        capacity 1  -- functional-unit issue slot
  ('fuout', pe, slot)        capacity 1  -- result register becomes readable
  ('xo',    pe, dir, slot)   capacity 1  -- registered crossbar output port
  ('regpool', pe, slot)      capacity R  -- values resident in the PE's RF
  ('wr',    pe, slot)        capacity W  -- RF write ports
  ('bank',  bank, slot)      capacity 1  -- memory-bank access port (shared bus)
  ('lireg', pe)              capacity L  -- host-preloaded live-in registers

A *value instance* is identified by (value_id, abs_time): the same value at
the same absolute time occupying a resource twice is one physical copy
(free fan-out sharing); the same value at two absolute times that alias the
same modulo slot would be two concurrently-live copies and is rejected —
this is exactly the MRRG modulo constraint.

Values travel through the time-extended graph via two state kinds:
  F (fresh): readable this cycle from the producing FU's output register or
             from an inbound crossbar wire — ephemeral, 1 cycle only.
  R (reg):   resident in the PE's register file (held <= II consecutive
             cycles so the periodic schedule stays single-register).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from .adl import CGRAArch, DIRS, OPP

Key = Tuple
Inst = Tuple[int, int]   # (value_id, abs_time) — or (name, -1) for liregs

F, R = 0, 1   # state kinds


class Usage:
    """Resource usage map with value-instance dedup."""

    def __init__(self, arch: CGRAArch, II: int):
        self.arch = arch
        self.II = II
        self.map: Dict[Key, Set[Inst]] = {}

    def cap(self, key: Key) -> int:
        k = key[0]
        if k in ("fu", "fuout", "xo", "bank"):
            return 1
        if k == "regpool":
            return self.arch.regfile_size
        if k == "wr":
            return self.arch.rf_write_ports
        if k == "lireg":
            return self.arch.livein_regs
        raise KeyError(key)

    def entries(self, key: Key) -> Set[Inst]:
        return self.map.get(key, set())

    def free_for(self, key: Key, inst: Inst) -> bool:
        """True if ``inst`` may occupy ``key`` (already present == free)."""
        cur = self.map.get(key)
        if cur is None:
            return True
        if inst in cur:
            return True
        # same value at a different absolute time aliasing this modulo slot
        # would be a second live copy of a periodic value: reject outright
        # for capacity-1 resources, count separately for pools.
        return len(cur) < self.cap(key)

    def has(self, key: Key, inst: Inst) -> bool:
        return inst in self.map.get(key, set())

    def add(self, key: Key, inst: Inst) -> None:
        self.map.setdefault(key, set()).add(inst)

    def remove(self, key: Key, inst: Inst) -> None:
        s = self.map.get(key)
        if s is not None:
            s.discard(inst)
            if not s:
                del self.map[key]

    def clone_shallow(self) -> "Usage":
        u = Usage(self.arch, self.II)
        u.map = {k: set(v) for k, v in self.map.items()}
        return u


@dataclass
class Route:
    """A routed data edge: value ``value`` travels from its production
    (src_pe, t_src) to consumption (dst_pe, t_dst)."""
    value: int
    src_pe: int
    t_src: int
    dst_pe: int
    t_dst: int
    # states visited: (kind, pe, t); steps[0] is the source, steps[-1] the
    # state the consumer reads from at t_dst.
    steps: List[Tuple[int, int, int]] = field(default_factory=list)
    # resource claims made for this route (excluding dedup-shared ones)
    uses: List[Tuple[Key, Inst]] = field(default_factory=list)

    @property
    def final_kind(self) -> int:
        return self.steps[-1][0]


def route_value(usage: Usage, arch: CGRAArch, II: int, value: int,
                src_pe: int, t_src: int, dst_pe: int, t_dst: int
                ) -> Optional[Route]:
    """Time-layered BFS over the routing graph.  All transitions advance
    one cycle, so every feasible route has identical cost — a forward
    frontier sweep from t_src to t_dst finds one if it exists.  Resources
    already carrying this exact value instance are reusable for free
    (fan-out sharing).  Register holds are explored before hops (they
    conserve crossbar bandwidth)."""
    if t_dst < t_src:
        return None
    if t_dst == t_src:
        if src_pe != dst_pe:
            return None
        return Route(value, src_pe, t_src, dst_pe, t_dst,
                     steps=[(F, src_pe, t_src)], uses=[])

    def usable(key: Key, inst: Inst) -> bool:
        return usage.has(key, inst) or usage.free_for(key, inst)

    # state within a layer: (kind, pe, hold)
    start = (F, src_pe, 0)
    parent: Dict[Tuple[int, Tuple], Tuple[Optional[Tuple], Tuple]] = {
        (t_src, start): (None, ())}
    frontier = [start]
    for t in range(t_src, t_dst):
        nxt: List[Tuple] = []
        seen: set = set()
        for st in frontier:
            kind, pe, hold = st
            # 1) hold in the register file (preferred: no wire pressure)
            nh = 1 if kind == F else hold + 1
            if nh <= II:
                nst = (R, pe, nh)
                if nst not in seen:
                    pool = (("regpool", pe, (t + 1) % II), (value, t + 1))
                    claims = [pool]
                    ok = usable(*pool)
                    if ok and kind == F:
                        wr = (("wr", pe, t % II), (value, t))
                        ok = usable(*wr)
                        claims.append(wr)
                    if ok:
                        seen.add(nst)
                        parent[(t + 1, nst)] = ((t, st), tuple(claims))
                        nxt.append(nst)
            # 2) crossbar hops
            for di, dname in enumerate(DIRS):
                q = arch.neighbor(pe, dname)
                if q is None:
                    continue
                nst = (F, q, 0)
                if nst in seen:
                    continue
                key = ("xo", pe, di, t % II)
                inst = (value, t)
                if usable(key, inst):
                    seen.add(nst)
                    parent[(t + 1, nst)] = ((t, st), ((key, inst),))
                    nxt.append(nst)
        if not nxt:
            return None
        frontier = nxt

    goal = None
    for st in frontier:
        if st[1] == dst_pe:
            goal = (t_dst, st)
            break
    if goal is None:
        return None

    steps: List[Tuple[int, int, int]] = []
    uses: List[Tuple[Key, Inst]] = []
    cur: Optional[Tuple[int, Tuple]] = goal
    while cur is not None:
        t, st = cur
        steps.append((st[0], st[1], t))
        prev, claims = parent[cur]
        for key, inst in claims:
            if not usage.has(key, inst):
                uses.append((key, inst))
        cur = prev
    steps.reverse()
    uses.reverse()
    return Route(value, src_pe, t_src, dst_pe, t_dst, steps=steps, uses=uses)


def commit_route(usage: Usage, route: Route) -> None:
    for key, inst in route.uses:
        usage.add(key, inst)


def release_route(usage: Usage, route: Route) -> None:
    for key, inst in route.uses:
        usage.remove(key, inst)
