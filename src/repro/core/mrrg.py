"""Modulo Routing Resource Graph (MRRG) — resources and the time-extended
router (paper Fig. 2c; Mei et al. DRESC / Rau iterative modulo scheduling).

The CGRA is modelled as a set of per-cycle-slot resources (II slots):

  ('fu',    pe, slot)        capacity 1  -- functional-unit issue slot
  ('fuout', pe, slot)        capacity 1  -- result register becomes readable
  ('xo',    pe, dir, slot)   capacity 1  -- registered crossbar output port
  ('regpool', pe, slot)      capacity R  -- values resident in the PE's RF
  ('wr',    pe, slot)        capacity W  -- RF write ports
  ('bank',  bank, slot)      capacity 1  -- memory-bank access port (shared bus)
  ('lireg', pe)              capacity L  -- host-preloaded live-in registers

A *value instance* is identified by (value_id, abs_time): the same value at
the same absolute time occupying a resource twice is one physical copy
(free fan-out sharing); the same value at two absolute times that alias the
same modulo slot would be two concurrently-live copies and is rejected —
this is exactly the MRRG modulo constraint.

Values travel through the time-extended graph via two state kinds:
  F (fresh): readable this cycle from the producing FU's output register or
             from an inbound crossbar wire — ephemeral, 1 cycle only.
  R (reg):   resident in the PE's register file (held <= II consecutive
             cycles so the periodic schedule stays single-register).

This module is the typed façade over the packed implementation in
``router.py``: resource keys stay the tuples above at the API surface, but
occupancy and the BFS run over flat integer ids (see the router module for
the packing scheme).  Route production is bit-identical to the historical
dict-of-tuples router.
"""
from __future__ import annotations

from .router import (F, Inst, Key, R, Route, RouterTables, Usage,
                     commit_route, release_route, route_value, router_tables)

__all__ = ["F", "R", "Key", "Inst", "Route", "RouterTables", "Usage",
           "commit_route", "release_route", "route_value", "router_tables"]
