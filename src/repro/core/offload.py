"""Edge-deployment analyzer: the paper's technique as a first-class
framework feature.

For any assigned LM architecture, enumerate the distinct GEMM micro-kernel
shapes its layers execute — q/k/v/o projections, MLA low-rank factors, MoE
expert FFNs and routers, RWKV time/channel-mix projections, Mamba
in/out projections — tile each one onto the Morpher CGRA model with the
paper's output-stationary dataflow (section IV-A), run the *actual* mapper
on the micro-kernel DFG, and report II / MII / utilization / estimated
latency — Table-I methodology applied to the model zoo
(`examples/edge_deploy.py --arch <id>`).

Tiles are chosen per site from a fixed ladder, taking the largest
bank-capacity-feasible tile clamped to the site's (M, K, N); a full site
then costs ``ceil(M/TI) * ceil(K/TK) * ceil(N/TJ)`` tile executions per
GEMM instance, times ``count_per_layer`` instances per layer, times the
number of layers the site appears in.  ``repro.serve.plan`` builds on the
same enumeration + tiling to hand the serving engine a complete offload
plan."""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from ..configs.registry import get_config
from ..models.common import ModelConfig
from .adl import CGRAArch, cluster_4x4
from .costmodel import F_CLK_HZ
from .kernels_lib import KernelSpec, _gemm_layout, build_gemm
from .mapper import MapError
from .toolchain import CompiledKernel, Toolchain, default_toolchain


@dataclass
class GemmSite:
    """One GEMM shape a model executes: ``M x K @ K x N``,
    ``count_per_layer`` instances per layer, present in ``layers`` layers
    (``None`` -> every layer of the model)."""
    name: str
    M: int
    K: int
    N: int
    count_per_layer: int = 1
    layers: Optional[int] = None

    def n_layers(self, cfg: ModelConfig) -> int:
        return cfg.n_layers if self.layers is None else self.layers


def model_gemm_sites(cfg: ModelConfig, tokens: int = 64) -> List[GemmSite]:
    """Every GEMM micro-kernel site of one forward pass at ``tokens``
    tokens, per architecture family (decode steps re-cost the same sites
    at M = active batch; see ``repro.serve.plan``)."""
    t = tokens
    d = cfg.d_model

    if cfg.family == "ssm":                              # rwkv6
        r = cfg.decay_lora_rank
        return [GemmSite("tmix_rkvo", t, d, d, 4),
                GemmSite("decay_lora_a", t, d, r),
                GemmSite("decay_lora_b", t, r, d),
                GemmSite("cmix_in", t, d, cfg.d_ff),
                GemmSite("cmix_out", t, cfg.d_ff, d)]

    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    if cfg.family == "hybrid":                           # zamba2
        from ..models.mamba2 import mamba_dims
        d_inner, nh, _hp, ds = mamba_dims(cfg)
        sites = [GemmSite("mamba_in", t, d, 2 * d_inner + 2 * ds + nh),
                 GemmSite("mamba_out", t, d_inner, d)]
        if cfg.attn_every:
            # ONE shared attention block, applied every attn_every layers
            n_apps = cfg.n_layers // cfg.attn_every
            sites += [GemmSite("shared_q", t, d, H * hd, layers=n_apps),
                      GemmSite("shared_kv", t, d, Hkv * hd, 2,
                               layers=n_apps),
                      GemmSite("shared_o", t, H * hd, d, layers=n_apps),
                      GemmSite("shared_ffn_in", t, d, cfg.d_ff, 2,
                               layers=n_apps),
                      GemmSite("shared_ffn_out", t, cfg.d_ff, d,
                               layers=n_apps)]
        return sites

    # transformer families: dense / moe / audio / vlm
    sites = [GemmSite("q_proj", t, d, H * hd)]
    if cfg.mla:
        sites += [GemmSite("q_lora", t, d, cfg.q_lora_rank),
                  GemmSite("kv_lora", t, d,
                           cfg.kv_lora_rank + cfg.qk_rope_dim)]
    else:
        sites += [GemmSite("kv_proj", t, d, Hkv * hd, 2)]
    sites += [GemmSite("o_proj", t, H * hd, d)]
    if cfg.moe:
        n_moe = cfg.n_layers - cfg.first_k_dense
        active = cfg.top_k + cfg.n_shared_experts
        sites += [GemmSite("router", t, d, cfg.n_experts, layers=n_moe),
                  GemmSite("expert_ffn_in", t, d, cfg.moe_d_ff, 2 * active,
                           layers=n_moe),
                  GemmSite("expert_ffn_out", t, cfg.moe_d_ff, d, active,
                           layers=n_moe)]
        if cfg.first_k_dense:
            f = cfg.dense_d_ff or cfg.d_ff
            sites += [GemmSite("dense_ffn_in", t, d, f, 2,
                               layers=cfg.first_k_dense),
                      GemmSite("dense_ffn_out", t, f, d,
                               layers=cfg.first_k_dense)]
    else:
        sites += [GemmSite("ffn_in", t, d, cfg.d_ff, 2),
                  GemmSite("ffn_out", t, cfg.d_ff, d)]
    return sites


# ----------------------------------------------------------------- tiling
# Largest-first tile ladder; the head is the paper's IV-A on-chip tile.
TILE_LADDER: Tuple[Tuple[int, int, int], ...] = (
    (16, 8, 16), (8, 8, 8), (8, 4, 8), (4, 4, 4), (2, 2, 2))


def tile_unroll(TK: int) -> int:
    """Largest k-loop unroll factor in {4, 2, 1} dividing the tile's TK."""
    for u in (4, 2, 1):
        if TK % u == 0:
            return u
    return 1


def choose_gemm_tile(arch: CGRAArch, site: Optional[GemmSite] = None,
                     ladder: Sequence[Tuple[int, int, int]] = TILE_LADDER
                     ) -> Tuple[int, int, int]:
    """The largest bank-capacity-feasible (TI, TK, TJ) GEMM tile for
    ``arch``, clamped to the site's (M, K, N) so tiny sites (low-rank
    factors, routers, decode steps) don't pay for a mostly-empty tile.
    Deterministic: first feasible entry of the ladder wins."""
    last_err: Optional[Exception] = None
    for TI, TK, TJ in ladder:
        if site is not None:
            TI = max(1, min(TI, site.M))
            TK = max(1, min(TK, site.K))
            TJ = max(1, min(TJ, site.N))
        try:
            _gemm_layout(arch, TI, TK, TJ)   # capacity check only
        except ValueError as e:
            last_err = e
            continue
        return TI, TK, TJ
    raise MapError(f"no bank-capacity-feasible GEMM tile on {arch.name} "
                   f"(ladder {list(ladder)}): {last_err}")


def site_tile_count(site: GemmSite, tile: Tuple[int, int, int],
                    M: Optional[int] = None) -> int:
    """Tile executions covering one (M, K, N) GEMM instance of the site."""
    TI, TK, TJ = tile
    m = site.M if M is None else M
    return (math.ceil(m / TI) * math.ceil(site.K / TK)
            * math.ceil(site.N / TJ))


# ----------------------------------------------------------------- reports
@dataclass
class OffloadReport:
    site: str
    tile: Tuple[int, ...]
    nodes: int
    II: int
    mii: int
    utilization: float
    est_tile_us: float          # one full tile (all host invocations)
    tiles: int = 1              # tiles per GEMM instance of the site
    instances: int = 1          # count_per_layer * layers
    est_site_ms: float = 0.0    # tiles * instances * tile latency


def analyze_kernel(kernel, arch=None,
                   toolchain: Optional[Toolchain] = None) -> OffloadReport:
    """Table-I methodology for any kernel: compile a :class:`KernelSpec`
    — or a traced ``repro.frontend`` ``KernelProgram``, bound here to the
    requested architecture — and report II / MII / utilization and the
    estimated full-kernel latency (all invocations of the mapped loop)."""
    tc = toolchain or default_toolchain()
    if hasattr(kernel, "bind") and not isinstance(kernel, KernelSpec):
        kernel = kernel.bind(arch or tc.arch)
    elif arch is not None and kernel.arch is not arch:
        raise ValueError(
            f"{kernel.name}: arch= applies only to arch-deferred kernel "
            f"programs; this KernelSpec is already bound to "
            f"{kernel.arch.name} (rebuild the spec against the target arch)")
    ck = tc.compile(kernel)
    cyc = ck.schedule_cycles()
    us = len(ck.invocations) * cyc / F_CLK_HZ * 1e6
    return OffloadReport(
        site=ck.name, tile=(), nodes=ck.dfg.n_nodes, II=ck.II, mii=ck.mii,
        utilization=ck.utilization, est_tile_us=us, est_site_ms=us / 1e3)


def analyze_gemm_tile(TI: int = 16, TK: int = 8, TJ: int = 16,
                      unroll: int = 4, arch=None,
                      toolchain: Optional[Toolchain] = None
                      ) -> CompiledKernel:
    tc = toolchain or default_toolchain()
    arch = arch or tc.arch or cluster_4x4()
    spec = build_gemm(TI=TI, TK=TK, TJ=TJ, arch=arch,
                      unroll=min(unroll, tile_unroll(TK)), coalesced=False)
    return tc.compile(spec)


def analyze_arch_gemms(arch_id: str, tokens: int = 64,
                       max_kernels: Optional[int] = None,
                       toolchain: Optional[Toolchain] = None
                       ) -> List[OffloadReport]:
    """Per-site offload reports for one model: each site gets a feasible
    tile (shared tiles dedup through the content-addressed compile cache
    across sites, models, processes and sessions), and its full-site
    latency scales the compiled tile by the site's actual tile counts —
    ``ceil(M/TI) * ceil(K/TK) * ceil(N/TJ) * count_per_layer * layers`` —
    not a fixed per-tile invocation count."""
    tc = toolchain or default_toolchain()
    cfg = get_config(arch_id)
    arch = tc.arch or cluster_4x4()
    sites = model_gemm_sites(cfg, tokens)
    if max_kernels:
        sites = sites[:max_kernels]
    out: List[OffloadReport] = []
    for s in sites:
        tile = choose_gemm_tile(arch, s)
        try:
            ck = analyze_gemm_tile(*tile, arch=arch, toolchain=tc)
        except MapError:
            continue
        tile_us = (len(ck.invocations) * ck.schedule_cycles()
                   / F_CLK_HZ * 1e6)
        tiles = site_tile_count(s, tile)
        instances = s.count_per_layer * s.n_layers(cfg)
        out.append(OffloadReport(
            site=s.name, tile=tile, nodes=ck.dfg.n_nodes, II=ck.II,
            mii=ck.mii, utilization=ck.utilization, est_tile_us=tile_us,
            tiles=tiles, instances=instances,
            est_site_ms=tiles * instances * tile_us / 1e3))
    return out
