"""Edge-deployment analyzer: the paper's technique as a first-class
framework feature.

For any assigned LM architecture, enumerate the distinct GEMM micro-kernel
shapes its layers execute (q/k/v/o projections, FFN matmuls, expert FFNs,
RWKV/Mamba projections), tile each one onto the Morpher CGRA model with the
paper's output-stationary dataflow (section IV-A), run the *actual* mapper
on the micro-kernel DFG, and report II / MII / utilization / estimated
per-tile latency — Table-I methodology applied to the model zoo
(`examples/edge_deploy.py --arch <id>`)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

from ..configs.registry import get_config
from ..models.common import ModelConfig
from .adl import cluster_4x4
from .costmodel import F_CLK_HZ
from .kernels_lib import KernelSpec, build_gemm
from .mapper import MapError
from .toolchain import CompiledKernel, Toolchain, default_toolchain


@dataclass
class GemmSite:
    name: str
    M: int
    K: int
    N: int
    count_per_layer: int = 1


def model_gemm_sites(cfg: ModelConfig, tokens: int = 64) -> List[GemmSite]:
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    sites = [GemmSite("q_proj", tokens, d, H * hd)]
    if cfg.mla:
        sites += [GemmSite("q_lora", tokens, d, cfg.q_lora_rank),
                  GemmSite("kv_lora", tokens, d,
                           cfg.kv_lora_rank + cfg.qk_rope_dim)]
    else:
        sites += [GemmSite("kv_proj", tokens, d, Hkv * hd, 2)]
    sites += [GemmSite("o_proj", tokens, H * hd, d)]
    f = cfg.moe_d_ff if cfg.moe else cfg.d_ff
    sites += [GemmSite("ffn_in", tokens, d, f, 2),
              GemmSite("ffn_out", tokens, f, d)]
    return sites


@dataclass
class OffloadReport:
    site: str
    tile: Tuple[int, ...]
    nodes: int
    II: int
    mii: int
    utilization: float
    est_tile_us: float


def analyze_kernel(kernel, arch=None,
                   toolchain: Optional[Toolchain] = None) -> OffloadReport:
    """Table-I methodology for any kernel: compile a :class:`KernelSpec`
    — or a traced ``repro.frontend`` ``KernelProgram``, bound here to the
    requested architecture — and report II / MII / utilization and the
    estimated full-kernel latency (all invocations of the mapped loop)."""
    tc = toolchain or default_toolchain()
    if hasattr(kernel, "bind") and not isinstance(kernel, KernelSpec):
        kernel = kernel.bind(arch or tc.arch)
    elif arch is not None and kernel.arch is not arch:
        raise ValueError(
            f"{kernel.name}: arch= applies only to arch-deferred kernel "
            f"programs; this KernelSpec is already bound to "
            f"{kernel.arch.name} (rebuild the spec against the target arch)")
    ck = tc.compile(kernel)
    cyc = ck.schedule_cycles()
    return OffloadReport(
        site=ck.name, tile=(), nodes=ck.dfg.n_nodes, II=ck.II, mii=ck.mii,
        utilization=ck.utilization,
        est_tile_us=len(ck.invocations) * cyc / F_CLK_HZ * 1e6)


def analyze_gemm_tile(TI: int = 16, TK: int = 8, TJ: int = 16,
                      unroll: int = 4, arch=None,
                      toolchain: Optional[Toolchain] = None
                      ) -> CompiledKernel:
    tc = toolchain or default_toolchain()
    arch = arch or tc.arch or cluster_4x4()
    spec = build_gemm(TI=TI, TK=TK, TJ=TJ, arch=arch,
                      unroll=min(unroll, TK), coalesced=False)
    return tc.compile(spec)


def analyze_arch_gemms(arch_id: str, tokens: int = 64,
                       max_kernels: Optional[int] = None,
                       toolchain: Optional[Toolchain] = None
                       ) -> List[OffloadReport]:
    tc = toolchain or default_toolchain()
    cfg = get_config(arch_id)
    sites = model_gemm_sites(cfg, tokens)
    if max_kernels:
        sites = sites[:max_kernels]
    out: List[OffloadReport] = []
    for s in sites:
        # the on-chip tile is bank-capacity bound, not site-size bound —
        # one compiled tile is reused across the whole site (paper IV-A);
        # the toolchain's content-addressed cache dedups the compile across
        # sites, models, processes and sessions.
        tile = (16, 8, 16)
        try:
            ck = analyze_gemm_tile(*tile, toolchain=tc)
        except MapError:
            continue
        cyc = ck.schedule_cycles()
        invocations = tile[0] * tile[2]  # per-(i,j) invocations per tile
        out.append(OffloadReport(
            site=s.name, tile=tile, nodes=ck.dfg.n_nodes, II=ck.II,
            mii=ck.mii, utilization=ck.utilization,
            est_tile_us=invocations * cyc / F_CLK_HZ * 1e6))
    return out
