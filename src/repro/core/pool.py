"""Shared process fan-out machinery for CPU-bound compile work.

The mapper is pure Python and therefore GIL-bound, so both fan-outs in the
compile loop — ``Toolchain.compile_many`` (independent kernels) and the
mapper's portfolio (II, seed) search — run on worker *processes*.  This
module owns the one process pool they share:

  * start method: ``forkserver`` when available (the parent often has JAX's
    thread pools loaded, and forking a threaded process can deadlock;
    ``spawn`` re-imports the caller's ``__main__`` per worker, which breaks
    REPL/stdin drivers) — else ``spawn``;
  * the pool is created lazily and kept for the life of the process, so the
    per-worker interpreter/numpy import cost is paid once, not once per
    compile;
  * workers run with ``MORPHER_POOL_WORKER=1`` so nested fan-out attempts
    (a portfolio search inside a ``compile_many`` worker) degrade to the
    sequential path instead of oversubscribing the machine;
  * every entry point degrades to ``None`` — callers always keep a
    bit-identical sequential fallback.
"""
from __future__ import annotations

import multiprocessing
import os
import sys
import threading
from concurrent.futures import Future, ProcessPoolExecutor
from concurrent.futures.process import BrokenProcessPool
from typing import Callable, List, Optional, Sequence

WORKER_ENV = "MORPHER_POOL_WORKER"

_lock = threading.Lock()
_shared: Optional[ProcessPoolExecutor] = None


def in_worker() -> bool:
    """True inside a pool worker process (nested fan-out must stay
    sequential)."""
    return os.environ.get(WORKER_ENV) == "1"


def _init_worker() -> None:
    os.environ[WORKER_ENV] = "1"


def _spawnable_main() -> bool:
    # worker processes re-import the caller's __main__; if it is not a real
    # file (REPL/stdin scripts have __file__='<stdin>'), they would crash
    # on startup — report the pool as unavailable instead
    main_file = getattr(sys.modules.get("__main__"), "__file__", None)
    return main_file is None or os.path.exists(main_file)


def shared_pool() -> Optional[ProcessPoolExecutor]:
    """The process-wide worker pool, or None when process fan-out is
    unavailable in this context (nested worker, REPL main, sandbox).

    A pool whose worker died (OS kill, OOM) marks itself broken and
    would poison every later call with ``BrokenProcessPool`` — it is
    detected here and replaced, so one lost worker costs one rebuild,
    not the rest of the process lifetime.
    """
    global _shared
    if in_worker() or not _spawnable_main():
        return None
    with _lock:
        if _shared is not None and getattr(_shared, "_broken", False):
            ex, _shared = _shared, None
            ex.shutdown(wait=False, cancel_futures=True)
        if _shared is None:
            methods = multiprocessing.get_all_start_methods()
            method = "forkserver" if "forkserver" in methods else "spawn"
            try:
                _shared = ProcessPoolExecutor(
                    max_workers=max(2, os.cpu_count() or 2),
                    mp_context=multiprocessing.get_context(method),
                    initializer=_init_worker)
            except (OSError, PermissionError):
                return None
        return _shared


def reset_pool(kill: bool = False) -> None:
    """Drop a broken pool; the next ``shared_pool()`` builds a fresh one.

    ``kill=True`` also terminates the worker processes — needed when a
    straggler is still executing an orphaned task (a sleeping worker
    would otherwise stall interpreter exit on the executor's atexit
    join).  Tasks are idempotent by contract, so a terminated worker
    loses nothing that a retry cannot recompute.
    """
    global _shared
    with _lock:
        ex, _shared = _shared, None
    if ex is None:
        return
    if kill:
        try:
            for proc in list(getattr(ex, "_processes", {}).values()):
                proc.terminate()
        except Exception:
            pass  # best effort: shutdown below still detaches the pool
    ex.shutdown(wait=False, cancel_futures=True)


def process_map(fn: Callable, payloads: Sequence, jobs: Optional[int] = None
                ) -> Optional[list]:
    """``[fn(p) for p in payloads]`` across the shared pool, or None when
    fan-out is unavailable/broken (callers fall back to sequential).

    ``jobs < 2`` forces the sequential path; a smaller ``jobs`` than the
    pool size caps *in-flight* tasks at ``jobs`` (the pool itself is sized
    to the machine, but a caller-requested concurrency limit is honored by
    windowed submission).

    A killed worker (``BrokenProcessPool``) gets one recovery attempt:
    the pool is rebuilt and the whole batch retried — payloads must be
    idempotent, which compile units are by content-addressing.  If the
    fresh pool breaks too, the fault is the workload's, not transient:
    reset and return None so the caller's sequential path decides.
    """
    if len(payloads) < 2 or (jobs is not None and jobs < 2):
        return None
    ex = shared_pool()
    if ex is None:
        return None
    for attempt in (0, 1):
        try:
            if jobs is None or jobs >= len(payloads):
                return list(ex.map(fn, payloads))
            results: list = []
            window = [ex.submit(fn, p) for p in payloads[:jobs]]
            nxt = jobs
            while window:
                results.append(window.pop(0).result())
                if nxt < len(payloads):
                    window.append(ex.submit(fn, payloads[nxt]))
                    nxt += 1
            return results
        except BrokenProcessPool:
            reset_pool(kill=True)
            if attempt == 1:
                return None
            ex = shared_pool()   # one retry on a fresh pool
            if ex is None:
                return None
    return None


def submit_all(fn: Callable, payloads: Sequence) -> Optional[List[Future]]:
    """Submit every payload to the shared pool; None when unavailable.
    Callers consume futures in submission order for deterministic
    selection and must handle ``BrokenProcessPool`` from ``.result()``."""
    ex = shared_pool()
    if ex is None:
        return None
    try:
        return [ex.submit(fn, p) for p in payloads]
    except (BrokenProcessPool, RuntimeError):
        reset_pool()
        return None
