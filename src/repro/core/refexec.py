"""Batched DFG reference execution lowered to JAX (the fast oracle).

``DFG.reference_execute`` is the verification oracle of the paper's IV-C
flow: sequential, non-pipelined dataflow execution of the mapped loop.
The pure-Python interpreter is exactly right for one seed, but a batched
verification sweep runs it over every seed of every invocation, where the
per-node Python dispatch dominates the whole verify pipeline.

This module compiles a DFG into a jitted double ``lax.scan`` — outer scan
over invocations (live-in rows as xs), inner scan over loop iterations —
with every node value a ``[batch]`` int32 vector and the bank images one
flat donated buffer.  Node semantics mirror the interpreter op for op:
values wrap to the datapath width after every node, out-of-range loads
read 0, out-of-range stores drop (they scatter into a dump cell that is
never read back), and loop-carried operands read their ``init`` value for
the first ``dist`` iterations.  ``tests/test_batched_verify.py`` pins the
result word-for-word against both the scalar interpreter and the numpy
batch interpreter for every library kernel.

Compiled executables are cached on the DFG instance keyed by the
execution shape, so re-verifying the same kernel across seed batches
reuses one XLA program.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

# module-level on purpose: importing this module asserts JAX availability,
# so callers holding a numpy fallback (verify.reference_banks_batch) can
# catch ImportError at import time rather than deep inside a call
import jax
import jax.numpy as jnp
import numpy as np

from .dfg import DFG, Op, wrap


def _lowered(dfg: DFG, *, n_iters: int, bits: int, B: int,
             banks: Tuple[Tuple[str, int], ...],
             li_names: Tuple[str, ...]):
    """Build (and jit) the executor for one execution shape."""

    order = dfg.topo_order()
    nodes = [dfg.nodes[vid] for vid in order]
    half, full = 1 << (bits - 1), 1 << bits

    off: Dict[str, int] = {}
    tot = 0
    for name, w in banks:
        off[name] = tot
        tot += w
    dump = tot                 # one never-read cell for dropped stores
    stride = tot + 1
    widths = dict(banks)
    li_pos = {n: i for i, n in enumerate(li_names)}
    # loop-carried reads: history depth needed per producing node
    maxdist = {vid: 0 for vid in order}
    for n in nodes:
        for o in n.operands:
            maxdist[o.src] = max(maxdist[o.src], o.dist)

    def awrap(x):
        return ((x + half) & (full - 1)) - half

    def run(mem0: jnp.ndarray, li_mat: jnp.ndarray) -> jnp.ndarray:
        row = jnp.arange(B) * stride                       # [B]

        def one_invocation(mem, li_row):
            hist0 = {vid: jnp.zeros((d, B), jnp.int32)
                     for vid, d in maxdist.items() if d}

            def one_iteration(carry, it):
                mem, hist = carry
                cur: Dict[int, jnp.ndarray] = {}

                def read(o):
                    if o.dist == 0:
                        return cur[o.src]
                    return jnp.where(it >= o.dist, hist[o.src][o.dist - 1],
                                     wrap(o.init, bits))

                for vid, n in zip(order, nodes):
                    if n.op == Op.CONST:
                        cur[vid] = jnp.full((B,), wrap(n.imm, bits),
                                            jnp.int32)
                    elif n.op == Op.LIVEIN:
                        cur[vid] = jnp.broadcast_to(
                            li_row[li_pos[n.livein]], (B,))
                    elif n.op == Op.LOAD:
                        addr = read(n.operands[0])
                        w = widths[n.array]
                        ok = (addr >= 0) & (addr < w)
                        fidx = row + off[n.array] + jnp.clip(addr, 0, w - 1)
                        cur[vid] = jnp.where(ok, jnp.take(mem, fidx), 0)
                    elif n.op == Op.STORE:
                        addr = read(n.operands[0])
                        val = read(n.operands[1])
                        w = widths[n.array]
                        ok = (addr >= 0) & (addr < w)
                        fidx = row + jnp.where(
                            ok, off[n.array] + jnp.clip(addr, 0, w - 1),
                            dump)
                        mem = mem.at[fidx].set(val)
                        cur[vid] = jnp.zeros((B,), jnp.int32)
                    else:
                        a = read(n.operands[0])
                        b = read(n.operands[1]) if len(n.operands) > 1 \
                            else jnp.zeros((B,), jnp.int32)
                        if n.op == Op.ADD:
                            r = a + b
                        elif n.op == Op.SUB:
                            r = a - b
                        elif n.op == Op.MUL:
                            r = a * b
                        elif n.op == Op.SHL:
                            r = a << (b & (bits - 1))
                        elif n.op == Op.SHR:
                            r = a >> (b & (bits - 1))
                        elif n.op == Op.AND:
                            r = a & b
                        elif n.op == Op.OR:
                            r = a | b
                        elif n.op == Op.XOR:
                            r = a ^ b
                        elif n.op == Op.CMPGE:
                            r = (a >= b).astype(jnp.int32)
                        elif n.op == Op.CMPEQ:
                            r = (a == b).astype(jnp.int32)
                        elif n.op == Op.CMPLT:
                            r = (a < b).astype(jnp.int32)
                        elif n.op == Op.SELECT:
                            r = jnp.where(a != 0, b, read(n.operands[2]))
                        else:
                            raise NotImplementedError(n.op)
                        cur[vid] = awrap(r)
                hist = {vid: jnp.concatenate(
                            [cur[vid][None], h[:-1]], axis=0)
                        for vid, h in hist.items()}
                return (mem, hist), 0

            (mem, _), _ = jax.lax.scan(one_iteration, (mem, hist0),
                                       jnp.arange(n_iters))
            return mem, 0

        mem, _ = jax.lax.scan(one_invocation, mem0, li_mat)
        return mem

    donate = (0,) if jax.default_backend() != "cpu" else ()
    return jax.jit(run, donate_argnums=donate)


def reference_execute_jax(dfg: DFG, n_iters: int,
                          init_banks: Dict[str, np.ndarray],
                          invocations: Sequence[Dict[str, int]],
                          bits: int) -> Dict[str, np.ndarray]:
    """Fold batched DFG reference execution over all invocations on XLA.

    init_banks: name -> [batch, words] int arrays; returns a fresh dict of
    the same shape, bit-identical per row to folding
    ``DFG.reference_execute`` over the invocations.
    """
    names = sorted(init_banks)
    banks = tuple((k, int(np.asarray(init_banks[k]).shape[1]))
                  for k in names)
    B = int(np.asarray(init_banks[names[0]]).shape[0]) if names else 1
    li_names = tuple(sorted({n.livein for n in dfg.nodes.values()
                             if n.op == Op.LIVEIN}))
    key = (n_iters, bits, B, banks, li_names, len(invocations))
    cache = getattr(dfg, "_refexec_cache", None)
    if cache is None:
        cache = dfg._refexec_cache = {}
    fn = cache.get(key)
    if fn is None:
        fn = cache[key] = _lowered(dfg, n_iters=n_iters, bits=bits, B=B,
                                   banks=banks, li_names=li_names)

    stride = sum(w for _, w in banks) + 1
    mem0 = np.zeros((B, stride), dtype=np.int32)
    pos = 0
    for k, w in banks:
        mem0[:, pos:pos + w] = np.asarray(init_banks[k])
        pos += w
    li_mat = np.array([[wrap(inv[n], bits) for n in li_names]
                       for inv in invocations],
                      dtype=np.int32).reshape(len(invocations),
                                              len(li_names))
    out = np.asarray(fn(jnp.asarray(mem0.reshape(-1)), jnp.asarray(li_mat)))
    out = out.reshape(B, stride)
    final = {}
    pos = 0
    for k, w in banks:
        final[k] = out[:, pos:pos + w].astype(np.int64)
        pos += w
    return final
