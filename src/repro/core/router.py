"""Packed MRRG routing engine (the hot path behind ``mrrg.py``).

The historical router kept resource usage in a ``Dict[Tuple, Set[Tuple]]``
and ran the time-layered BFS over ``(kind, pe, hold)`` tuples, paying a
tuple allocation plus ``CGRAArch.neighbor`` trigonometry for every explored
state.  This module packs both sides into flat integers:

  * resource keys ``('fu'|'fuout'|'xo'|'regpool'|'wr'|'bank'|'lireg', ...)``
    become indices into one dense id space (:class:`RouterTables.pack`),
  * router states become ``pe`` (fresh) or ``P + pe*II + (hold-1)``
    (register-resident),
  * per-PE neighbour/direction and Manhattan-distance tables are
    precomputed once per (topology, II) and shared across all ``Usage``
    instances (the mapper creates one per (II, seed) trial).

The exploration order of :func:`route_value` — register holds before
crossbar hops, hops in DIRS order, first-writer-wins frontier dedup —
is bit-for-bit the same as the historical implementation, so every route
(steps *and* the order of resource claims in ``uses``) is JSON-identical
to what the dict-of-tuples router produced.  ``mrrg.py`` re-exports this
module's API as the typed façade; see its docstring for the resource
model itself.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from .adl import CGRAArch, DIRS

Key = Tuple
Inst = Tuple[int, int]   # (value_id, abs_time) — or (name, -1) for liregs

F, R = 0, 1   # state kinds

# transition codes stored in the packed parent entries: 0..3 = crossbar hop
# in DIRS order, 4 = register hold from R, 5 = register hold from F (which
# additionally claims a write port).
_HOLD_R, _HOLD_F = 4, 5

_EMPTY: frozenset = frozenset()


class RouterTables:
    """Per-(topology, II) packed lookup tables shared by every ``Usage``."""

    __slots__ = ("P", "II", "fuout_base", "xo_base", "regpool_base",
                 "wr_base", "bank_base", "lireg_base", "n_resources",
                 "nbrs", "dist", "cap_regpool", "cap_wr", "cap_lireg")

    def __init__(self, arch: CGRAArch, II: int):
        P = arch.n_pes
        self.P, self.II = P, II
        n = P * II
        self.fuout_base = n                   # fu occupies [0, n)
        self.xo_base = 2 * n                  # 4 ports per PE
        self.regpool_base = 6 * n
        self.wr_base = 7 * n
        self.bank_base = 8 * n
        self.lireg_base = 8 * n + len(arch.banks) * II
        self.n_resources = self.lireg_base + P
        self.nbrs: List[Tuple[Tuple[int, int], ...]] = [
            tuple((di, q) for di, d in enumerate(DIRS)
                  if (q := arch.neighbor(p, d)) is not None)
            for p in range(P)]
        self.dist: List[List[int]] = [
            [arch.manhattan(p, q) for q in range(P)] for p in range(P)]
        self.cap_regpool = arch.regfile_size
        self.cap_wr = arch.rf_write_ports
        self.cap_lireg = arch.livein_regs

    def pack(self, key: Key) -> int:
        k = key[0]
        II = self.II
        if k == "fu":
            return key[1] * II + key[2]
        if k == "fuout":
            return self.fuout_base + key[1] * II + key[2]
        if k == "xo":
            return self.xo_base + (key[1] * 4 + key[2]) * II + key[3]
        if k == "regpool":
            return self.regpool_base + key[1] * II + key[2]
        if k == "wr":
            return self.wr_base + key[1] * II + key[2]
        if k == "bank":
            return self.bank_base + key[1] * II + key[2]
        if k == "lireg":
            return self.lireg_base + key[1]
        raise KeyError(key)


_tables_cache: Dict[Tuple, RouterTables] = {}


def router_tables(arch: CGRAArch, II: int) -> RouterTables:
    # everything the tables read off the arch, nothing else
    ck = (II, arch.rows, arch.cols, arch.torus, arch.regfile_size,
          arch.rf_write_ports, arch.livein_regs, len(arch.banks))
    t = _tables_cache.get(ck)
    if t is None:
        t = _tables_cache[ck] = RouterTables(arch, II)
    return t


class Usage:
    """Resource usage map with value-instance dedup.

    Publicly keyed by the typed tuples documented in ``mrrg.py``; backed by
    the packed id space so the router never hashes a tuple key.
    """

    __slots__ = ("arch", "II", "tables", "_sets", "_keys")

    def __init__(self, arch: CGRAArch, II: int):
        self.arch = arch
        self.II = II
        self.tables = router_tables(arch, II)
        self._sets: Dict[int, Set[Inst]] = {}   # packed key -> instances
        self._keys: Dict[int, Key] = {}         # packed key -> typed key

    @property
    def map(self) -> Dict[Key, Set[Inst]]:
        """Typed view of the occupancy map (fresh dict; sets are live)."""
        keys = self._keys
        return {keys[i]: s for i, s in self._sets.items()}

    def cap(self, key: Key) -> int:
        k = key[0]
        if k in ("fu", "fuout", "xo", "bank"):
            return 1
        if k == "regpool":
            return self.arch.regfile_size
        if k == "wr":
            return self.arch.rf_write_ports
        if k == "lireg":
            return self.arch.livein_regs
        raise KeyError(key)

    def entries(self, key: Key) -> Set[Inst]:
        """Instances occupying ``key`` — always a fresh set, so callers
        cannot corrupt the occupancy map through the return value."""
        return set(self._sets.get(self.tables.pack(key), _EMPTY))

    def free_for(self, key: Key, inst: Inst) -> bool:
        """True if ``inst`` may occupy ``key`` (already present == free)."""
        cur = self._sets.get(self.tables.pack(key))
        if cur is None or inst in cur:
            return True
        # same value at a different absolute time aliasing this modulo slot
        # would be a second live copy of a periodic value: reject outright
        # for capacity-1 resources, count separately for pools.
        return len(cur) < self.cap(key)

    def has(self, key: Key, inst: Inst) -> bool:
        return inst in self._sets.get(self.tables.pack(key), _EMPTY)

    def add(self, key: Key, inst: Inst) -> None:
        i = self.tables.pack(key)
        s = self._sets.get(i)
        if s is None:
            s = self._sets[i] = set()
            self._keys[i] = key
        s.add(inst)

    def remove(self, key: Key, inst: Inst) -> None:
        i = self.tables.pack(key)
        s = self._sets.get(i)
        if s is not None:
            s.discard(inst)
            if not s:
                del self._sets[i]
                del self._keys[i]

    def clone_shallow(self) -> "Usage":
        u = Usage(self.arch, self.II)
        u._sets = {i: set(s) for i, s in self._sets.items()}
        u._keys = dict(self._keys)
        return u


@dataclass
class Route:
    """A routed data edge: value ``value`` travels from its production
    (src_pe, t_src) to consumption (dst_pe, t_dst)."""
    value: int
    src_pe: int
    t_src: int
    dst_pe: int
    t_dst: int
    # states visited: (kind, pe, t); steps[0] is the source, steps[-1] the
    # state the consumer reads from at t_dst.
    steps: List[Tuple[int, int, int]] = field(default_factory=list)
    # resource claims made for this route (excluding dedup-shared ones)
    uses: List[Tuple[Key, Inst]] = field(default_factory=list)

    @property
    def final_kind(self) -> int:
        return self.steps[-1][0]


def route_value(usage: Usage, arch: CGRAArch, II: int, value: int,
                src_pe: int, t_src: int, dst_pe: int, t_dst: int
                ) -> Optional[Route]:
    """Time-layered BFS over the routing graph.  All transitions advance
    one cycle, so every feasible route has identical cost — a forward
    frontier sweep from t_src to t_dst finds one if it exists.  Resources
    already carrying this exact value instance are reusable for free
    (fan-out sharing).  Register holds are explored before hops (they
    conserve crossbar bandwidth)."""
    if t_dst < t_src:
        return None
    if t_dst == t_src:
        if src_pe != dst_pe:
            return None
        return Route(value, src_pe, t_src, dst_pe, t_dst,
                     steps=[(F, src_pe, t_src)], uses=[])

    T = usage.tables
    P = T.P
    sets = usage._sets
    nbrs = T.nbrs
    xo_base, rp_base, wr_base = T.xo_base, T.regpool_base, T.wr_base
    cap_rp, cap_wr = T.cap_regpool, T.cap_wr

    # state ids: F at pe -> pe; R at pe with hold h -> P + pe*II + (h-1).
    # parent layers: state id -> prev_state_id * 8 + transition code.
    frontier: List[int] = [src_pe]
    parents: List[Dict[int, int]] = []
    for t in range(t_src, t_dst):
        slot = t % II
        slot1 = (t + 1) % II
        inst_t = (value, t)
        inst_t1 = (value, t + 1)
        layer: Dict[int, int] = {}
        nxt: List[int] = []
        for sid in frontier:
            if sid < P:
                pe, nh = sid, 1
            else:
                r = sid - P
                pe = r // II
                nh = (r % II) + 2          # hold + 1
            # 1) hold in the register file (preferred: no wire pressure)
            if nh <= II:
                nst = P + pe * II + (nh - 1)
                if nst not in layer:
                    cur = sets.get(rp_base + pe * II + slot1)
                    ok = (cur is None or inst_t1 in cur
                          or len(cur) < cap_rp)
                    if ok and sid < P:
                        cur = sets.get(wr_base + pe * II + slot)
                        ok = (cur is None or inst_t in cur
                              or len(cur) < cap_wr)
                    if ok:
                        layer[nst] = sid * 8 + (_HOLD_F if sid < P
                                                else _HOLD_R)
                        nxt.append(nst)
            # 2) crossbar hops (the F state of PE q has id q)
            base_pe = xo_base + pe * 4 * II
            for di, q in nbrs[pe]:
                if q in layer:
                    continue
                cur = sets.get(base_pe + di * II + slot)
                if cur is None or inst_t in cur:   # xo capacity is 1
                    layer[q] = sid * 8 + di
                    nxt.append(q)
        if not nxt:
            return None
        parents.append(layer)
        frontier = nxt

    goal = -1
    for sid in frontier:
        if (sid if sid < P else (sid - P) // II) == dst_pe:
            goal = sid
            break
    if goal < 0:
        return None

    # backtrack goal -> source, reconstructing the typed claims from the
    # transition codes; then reverse, exactly like the historical router.
    steps: List[Tuple[int, int, int]] = []
    uses: List[Tuple[Key, Inst]] = []
    sid = goal
    for li in range(len(parents) - 1, -1, -1):
        t = t_src + li + 1
        if sid < P:
            kind, pe = F, sid
        else:
            kind, pe = R, (sid - P) // II
        steps.append((kind, pe, t))
        entry = parents[li][sid]
        prev, code = entry >> 3, entry & 7
        pt = t - 1
        if code >= _HOLD_R:
            inst = (value, t)
            if inst not in sets.get(rp_base + pe * II + t % II, _EMPTY):
                uses.append((("regpool", pe, t % II), inst))
            if code == _HOLD_F:
                inst = (value, pt)
                if inst not in sets.get(wr_base + pe * II + pt % II, _EMPTY):
                    uses.append((("wr", pe, pt % II), inst))
        else:
            ppe = prev if prev < P else (prev - P) // II
            inst = (value, pt)
            if inst not in sets.get(xo_base + (ppe * 4 + code) * II
                                    + pt % II, _EMPTY):
                uses.append((("xo", ppe, code, pt % II), inst))
        sid = prev
    steps.append((F, src_pe, t_src))
    steps.reverse()
    uses.reverse()
    return Route(value, src_pe, t_src, dst_pe, t_dst, steps=steps, uses=uses)


def commit_route(usage: Usage, route: Route) -> None:
    for key, inst in route.uses:
        usage.add(key, inst)


def release_route(usage: Usage, route: Route) -> None:
    for key, inst in route.uses:
        usage.remove(key, inst)
