"""Process-wide shape-bucketed cache of compiled simulator executables.

The batched verification engine (``simulator.simulate_batch``) compiles one
XLA executable per *shape signature* — ``(II, P, RF, bits, n_iters,
n_cycles, batch)`` — not per call.  Verifying the six Table-I kernels plus
the four DSL kernels across N seeds therefore triggers a handful of traces
instead of one per ``verify`` call, and repeated verification sweeps (CI,
architecture exploration) reuse the executables for the lifetime of the
process, across every ``Toolchain`` and ``CompiledKernel`` instance.

Three bucketing knobs cap retraces from near-miss shapes:

  * ``bucket_batch`` rounds the batch (seed count) up to the next power of
    two — padded rows are simulated and discarded by the caller;
  * ``bucket_cycles`` rounds the cycle count up, keeping 4 significant
    bits (<= 12.5% padded cycles) — cycles past the schedule are dead by
    construction: every STORE is gated by the control module's
    iteration-validity window, so final memory is untouched;
  * ``bucket_rf`` (multi-architecture stacking only) rounds the
    register-file width up so fabrics differing only in RF provisioning
    share one executable — padded registers are dead lanes (write ports
    KIND_NONE, reads clipped to the config's real RF).

All paddings preserve the bit-exactness contract pinned by
``tests/test_batched_verify.py`` and ``tests/test_multiarch_sim.py``.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Callable, Dict


@dataclass(frozen=True)
class SimSignature:
    """Everything static that determines a batched-simulator executable.

    ``multi=True`` marks the multi-architecture variant of the body, where
    configuration planes carry a leading batch axis (one config per memory
    row) so one executable scores many candidate fabrics sharing this
    shape bucket; its state-vector layout depends on the live-in register
    count, so ``LI`` joins the key there (the single-config body infers LI
    from the traced live-in stack and keeps the historical key).
    """
    II: int
    P: int
    RF: int
    bits: int
    n_iters: int
    n_cycles: int
    batch: int
    LI: int = 0
    multi: bool = False


def bucket_batch(batch: int) -> int:
    """Round a batch size up to the next power of two (>= 1)."""
    if batch <= 1:
        return 1
    return 1 << (batch - 1).bit_length()


def bucket_cycles(n_cycles: int) -> int:
    """Round a cycle count up to its 4-significant-bit bucket boundary.

    Keeps at most 8 buckets per octave, so the padding overhead is bounded
    by 12.5% of simulated cycles while distinct ``n_cycles`` values (and
    therefore traces) stay capped.
    """
    if n_cycles <= 8:
        return max(1, n_cycles)
    quantum = 1 << (n_cycles.bit_length() - 4)
    return -(-n_cycles // quantum) * quantum


def bucket_rows(rows: int) -> int:
    """Batch-row bucket of the *multi-architecture* stacked body: same
    4-significant-bit rounding as ``bucket_cycles`` (<= 12.5% padded
    rows), instead of ``bucket_batch``'s power of two (up to 100%).
    Stacked batches are sums of per-config seed batches — pow-of-two
    rounding of e.g. 40 rows to 64 wastes more simulated rows than the
    launch it shares, and on a compute-bound backend padded rows are
    pure loss.  Single-config batches keep pow-of-two: they are seed
    counts, small and already round."""
    return bucket_cycles(rows)


def bucket_rf(rf: int) -> int:
    """Register-file width bucket of the *multi-architecture* stacked
    body: every RF provisioning up to 16 pads to 16 registers (wider ones
    round up to the next power of two), so fabrics that differ only in
    routing-register provisioning — the axis a DSE search explores
    hardest — share one executable.  Padded registers are dead lanes
    (never written: their write ports are KIND_NONE; never read: gather
    indices clip to the config's own RF), so stacking stays bit-exact.
    The single-config path keeps exact RF — padding there would buy
    nothing and cost state width."""
    if rf <= 16:
        return 16
    return 1 << (rf - 1).bit_length()


class _Entry:
    __slots__ = ("fn", "hits")

    def __init__(self, fn: Callable):
        self.fn = fn
        self.hits = 0


_lock = threading.Lock()
_entries: Dict[SimSignature, _Entry] = {}
_misses = 0


def get(sig: SimSignature, build: Callable[[], Callable]) -> Callable:
    """Return the cached executable for ``sig``, building it on first use.

    ``build`` must return a callable closed over ``sig``'s static values;
    it is invoked at most once per signature per process.
    """
    global _misses
    with _lock:
        entry = _entries.get(sig)
        if entry is None:
            entry = _Entry(build())
            _entries[sig] = entry
            _misses += 1
        else:
            entry.hits += 1
        return entry.fn


def stats() -> Dict[str, int]:
    """Executable-cache counters: ``entries`` live signatures, ``hits``
    calls served by an existing executable, ``misses`` builds."""
    with _lock:
        return {"entries": len(_entries),
                "hits": sum(e.hits for e in _entries.values()),
                "misses": _misses}


def clear() -> None:
    """Drop every cached executable (tests / memory pressure)."""
    global _misses
    with _lock:
        _entries.clear()
        _misses = 0
