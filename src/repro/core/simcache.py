"""Process-wide shape-bucketed cache of compiled simulator executables.

The batched verification engine (``simulator.simulate_batch``) compiles one
XLA executable per *shape signature* — ``(II, P, RF, bits, n_iters,
n_cycles, batch)`` — not per call.  Verifying the six Table-I kernels plus
the four DSL kernels across N seeds therefore triggers a handful of traces
instead of one per ``verify`` call, and repeated verification sweeps (CI,
architecture exploration) reuse the executables for the lifetime of the
process, across every ``Toolchain`` and ``CompiledKernel`` instance.

Two bucketing knobs cap retraces from near-miss shapes:

  * ``bucket_batch`` rounds the batch (seed count) up to the next power of
    two — padded rows are simulated and discarded by the caller;
  * ``bucket_cycles`` rounds the cycle count up, keeping 4 significant
    bits (<= 12.5%% padded cycles) — cycles past the schedule are dead by
    construction: every STORE is gated by the control module's
    iteration-validity window, so final memory is untouched.

Both paddings preserve the bit-exactness contract pinned by
``tests/test_batched_verify.py``.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Callable, Dict


@dataclass(frozen=True)
class SimSignature:
    """Everything static that determines a batched-simulator executable."""
    II: int
    P: int
    RF: int
    bits: int
    n_iters: int
    n_cycles: int
    batch: int


def bucket_batch(batch: int) -> int:
    """Round a batch size up to the next power of two (>= 1)."""
    if batch <= 1:
        return 1
    return 1 << (batch - 1).bit_length()


def bucket_cycles(n_cycles: int) -> int:
    """Round a cycle count up to its 4-significant-bit bucket boundary.

    Keeps at most 8 buckets per octave, so the padding overhead is bounded
    by 12.5%% of simulated cycles while distinct ``n_cycles`` values (and
    therefore traces) stay capped.
    """
    if n_cycles <= 8:
        return max(1, n_cycles)
    quantum = 1 << (n_cycles.bit_length() - 4)
    return -(-n_cycles // quantum) * quantum


class _Entry:
    __slots__ = ("fn", "hits")

    def __init__(self, fn: Callable):
        self.fn = fn
        self.hits = 0


_lock = threading.Lock()
_entries: Dict[SimSignature, _Entry] = {}
_misses = 0


def get(sig: SimSignature, build: Callable[[], Callable]) -> Callable:
    """Return the cached executable for ``sig``, building it on first use.

    ``build`` must return a callable closed over ``sig``'s static values;
    it is invoked at most once per signature per process.
    """
    global _misses
    with _lock:
        entry = _entries.get(sig)
        if entry is None:
            entry = _Entry(build())
            _entries[sig] = entry
            _misses += 1
        else:
            entry.hits += 1
        return entry.fn


def stats() -> Dict[str, int]:
    """Executable-cache counters: ``entries`` live signatures, ``hits``
    calls served by an existing executable, ``misses`` builds."""
    with _lock:
        return {"entries": len(_entries),
                "hits": sum(e.hits for e in _entries.values()),
                "misses": _misses}


def clear() -> None:
    """Drop every cached executable (tests / memory pressure)."""
    global _misses
    with _lock:
        _entries.clear()
        _misses = 0
