"""Cycle-accurate functional CGRA simulator in JAX (paper Fig. 3 piece 8).

Morpher simulates the generated Verilog with Verilator; here the same
contract is met by a jit-compiled `lax.scan` over cycles that executes the
configuration bitstreams exactly as the RTL control memories would:

  * every cycle, every PE reads its slot-(t mod II) configuration,
  * operand muxes select from {4 inbound crossbar wires, register file,
    own FU output register, immediate, live-in register},
  * the FU executes (16-bit two's-complement datapath), LOADs have a
    2-cycle latency through a pipeline register, STOREs commit at end of
    cycle gated by the control module's iteration-validity window
    (prologue/epilogue predication),
  * crossbar output registers and RF writes update from the same
    start-of-cycle snapshot (fully synchronous design).

All PEs are vectorized; the cycle loop is a `lax.scan`; invocations (the
host-driven outer loops) are a second `lax.scan` threading the memory
image.  This is the component that makes verification fast enough to run
in CI for every mapped kernel.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config_gen import (KIND_FUOUT, KIND_IMM, KIND_IN_E, KIND_IN_N,
                         KIND_IN_S, KIND_IN_W, KIND_LIREG, KIND_NONE,
                         KIND_REG, OPC, OPC_LOAD, OPC_NONE, OPC_PASS,
                         OPC_STORE, SimConfig)
from .dfg import Op

# xo-port index a reader consults on its neighbour: OPP of (N,E,S,W)
_OPP_IDX = np.array([2, 3, 0, 1], dtype=np.int32)


def _wrap(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    half = 1 << (bits - 1)
    full = 1 << bits
    return ((x + half) & (full - 1)) - half


def _alu(opc: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray,
         bits: int) -> jnp.ndarray:
    sh = b & (bits - 1)
    res = jnp.zeros_like(a)
    res = jnp.where(opc == OPC_PASS, a, res)
    res = jnp.where(opc == OPC[Op.ADD], a + b, res)
    res = jnp.where(opc == OPC[Op.SUB], a - b, res)
    res = jnp.where(opc == OPC[Op.MUL], a * b, res)
    res = jnp.where(opc == OPC[Op.SHL], a << sh, res)
    res = jnp.where(opc == OPC[Op.SHR], a >> sh, res)
    res = jnp.where(opc == OPC[Op.AND], a & b, res)
    res = jnp.where(opc == OPC[Op.OR], a | b, res)
    res = jnp.where(opc == OPC[Op.XOR], a ^ b, res)
    res = jnp.where(opc == OPC[Op.CMPGE], (a >= b).astype(a.dtype), res)
    res = jnp.where(opc == OPC[Op.CMPEQ], (a == b).astype(a.dtype), res)
    res = jnp.where(opc == OPC[Op.CMPLT], (a < b).astype(a.dtype), res)
    res = jnp.where(opc == OPC[Op.SELECT], jnp.where(a != 0, b, c), res)
    return _wrap(res, bits)


def _as_jnp(cfg: SimConfig) -> Dict[str, jnp.ndarray]:
    return {k: jnp.asarray(getattr(cfg, k)) for k in (
        "op", "imm", "src_kind", "src_idx", "force_before", "force_val",
        "xo_kind", "xo_idx", "rf_kind", "rf_idx", "mem_off", "mem_words",
        "valid_start", "nbr_idx")}


@functools.partial(jax.jit, static_argnames=("II", "P", "RF", "bits",
                                             "n_iters", "n_cycles",
                                             "scratch"))
def _run_invocations(c: Dict[str, jnp.ndarray], mem0: jnp.ndarray,
                     li_stack: jnp.ndarray, *, II: int, P: int, RF: int,
                     bits: int, n_iters: int, n_cycles: int,
                     scratch: int) -> jnp.ndarray:
    opp = jnp.asarray(_OPP_IDX)
    pe_ar = jnp.arange(P)

    def one_invocation(mem: jnp.ndarray, li: jnp.ndarray) -> Tuple[jnp.ndarray, int]:
        regs0 = jnp.zeros((P, RF), dtype=jnp.int32)
        xo0 = jnp.zeros((P, 4), dtype=jnp.int32)
        fu0 = jnp.zeros((P,), dtype=jnp.int32)
        ldp0 = jnp.zeros((P,), dtype=jnp.int32)
        fl0 = jnp.zeros((P,), dtype=bool)

        def cycle(carry, t):
            regs, xo, fu, ldp, fl, mem = carry
            slot = t % II
            opc = c["op"][slot]
            # inbound wires: what my neighbour's opposite-facing port holds
            inp = xo[c["nbr_idx"], opp[None, :]]          # [P,4]

            def resolve(kind, idx):
                v = jnp.zeros((P,), dtype=jnp.int32)
                v = jnp.where(kind == KIND_IN_N, inp[:, 0], v)
                v = jnp.where(kind == KIND_IN_E, inp[:, 1], v)
                v = jnp.where(kind == KIND_IN_S, inp[:, 2], v)
                v = jnp.where(kind == KIND_IN_W, inp[:, 3], v)
                v = jnp.where(kind == KIND_REG,
                              regs[pe_ar, jnp.clip(idx, 0, RF - 1)], v)
                v = jnp.where(kind == KIND_FUOUT, fu, v)
                v = jnp.where(kind == KIND_IMM, c["imm"][slot], v)
                v = jnp.where(kind == KIND_LIREG,
                              li[pe_ar, jnp.clip(idx, 0, li.shape[1] - 1)], v)
                return v

            def operand(port):
                v = resolve(c["src_kind"][slot, :, port],
                            c["src_idx"][slot, :, port])
                fb = c["force_before"][slot, :, port]
                return jnp.where(t < fb, c["force_val"][slot, :, port], v)

            a, b, p3 = operand(0), operand(1), operand(2)
            res = _alu(opc, a, b, p3, bits)

            # memory
            gaddr = c["mem_off"][slot] + jnp.clip(a, 0,
                                                  c["mem_words"][slot] - 1)
            loaded = jnp.take(mem, gaddr)
            is_load = opc == OPC_LOAD
            is_store = opc == OPC_STORE
            vstart = c["valid_start"][slot]
            gate = is_store & (t >= vstart) & (t < vstart + n_iters * II)
            st_addr = jnp.where(gate, gaddr, scratch)
            mem = mem.at[st_addr].set(jnp.where(gate, b, mem[scratch]))

            fu_next = jnp.where(fl, ldp,
                                jnp.where((opc != OPC_NONE) & ~is_load
                                          & ~is_store, res, fu))
            ldp_next = jnp.where(is_load, loaded, ldp)
            fl_next = is_load

            def write_bank(vals, kinds, idxs, old):
                # vals written from the same start-of-cycle snapshot
                new = resolve(kinds, idxs)
                return jnp.where(kinds != KIND_NONE, new, old)

            regs_next = jnp.stack(
                [write_bank(None, c["rf_kind"][slot, :, r],
                            c["rf_idx"][slot, :, r], regs[:, r])
                 for r in range(RF)], axis=1)
            xo_next = jnp.stack(
                [write_bank(None, c["xo_kind"][slot, :, d],
                            c["xo_idx"][slot, :, d], xo[:, d])
                 for d in range(4)], axis=1)

            return (regs_next, xo_next, fu_next, ldp_next, fl_next, mem), 0

        carry = (regs0, xo0, fu0, ldp0, fl0, mem)
        carry, _ = jax.lax.scan(cycle, carry, jnp.arange(n_cycles))
        return carry[-1], 0

    mem, _ = jax.lax.scan(one_invocation, mem0, li_stack)
    return mem


def simulate(cfg: SimConfig, banks: Dict[str, np.ndarray],
             invocations, n_iters: int,
             liveins_builder=None) -> Dict[str, np.ndarray]:
    """Run the mapped kernel for every invocation and return final banks.

    banks: {"bank<i>": int array} initial memory images.
    invocations: list of {livein name: value} dicts (host outer loops).
    """
    n_banks = len(cfg.bank_offsets)
    mem = np.zeros(cfg.total_words, dtype=np.int32)
    for i in range(n_banks):
        img = banks[f"bank{i}"]
        mem[cfg.bank_offsets[i]:cfg.bank_offsets[i] + len(img)] = img

    li_stack = np.stack([cfg.livein_array(inv) for inv in invocations])
    out = _run_invocations(
        _as_jnp(cfg), jnp.asarray(mem), jnp.asarray(li_stack),
        II=cfg.II, P=cfg.P, RF=cfg.RF, bits=cfg.bits,
        n_iters=n_iters, n_cycles=cfg.n_cycles(n_iters),
        scratch=cfg.total_words - 1)
    out = np.asarray(out)

    result = {}
    for i in range(n_banks):
        w = len(banks[f"bank{i}"])
        result[f"bank{i}"] = out[cfg.bank_offsets[i]:cfg.bank_offsets[i] + w]
    return result
