"""Cycle-accurate functional CGRA simulator in JAX (paper Fig. 3 piece 8).

Morpher simulates the generated Verilog with Verilator; here the same
contract is met by a jit-compiled `lax.scan` over cycles that executes the
configuration bitstreams exactly as the RTL control memories would:

  * every cycle, every PE reads its slot-(t mod II) configuration,
  * operand muxes select from {4 inbound crossbar wires, register file,
    own FU output register, immediate, live-in register},
  * the FU executes (16-bit two's-complement datapath), LOADs have a
    2-cycle latency through a pipeline register, STOREs commit at end of
    cycle gated by the control module's iteration-validity window
    (prologue/epilogue predication),
  * crossbar output registers and RF writes update from the same
    start-of-cycle snapshot (fully synchronous design).

All PEs are vectorized; the cycle loop is a `lax.scan`; invocations (the
host-driven outer loops) are a second `lax.scan` threading the memory
image.  This is the component that makes verification fast enough to run
in CI for every mapped kernel.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config_gen import (KIND_FUOUT, KIND_IMM, KIND_IN_E, KIND_IN_N,
                         KIND_IN_S, KIND_IN_W, KIND_LIREG, KIND_NONE,
                         KIND_REG, OPC, OPC_LOAD, OPC_NONE, OPC_PASS,
                         OPC_STORE, SimConfig)
from .dfg import Op

# xo-port index a reader consults on its neighbour: OPP of (N,E,S,W)
_OPP_IDX = np.array([2, 3, 0, 1], dtype=np.int32)



def _wrap(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    half = 1 << (bits - 1)
    full = 1 << bits
    return ((x + half) & (full - 1)) - half


def _alu(opc: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray,
         bits: int) -> jnp.ndarray:
    sh = b & (bits - 1)
    res = jnp.zeros_like(a)
    res = jnp.where(opc == OPC_PASS, a, res)
    res = jnp.where(opc == OPC[Op.ADD], a + b, res)
    res = jnp.where(opc == OPC[Op.SUB], a - b, res)
    res = jnp.where(opc == OPC[Op.MUL], a * b, res)
    res = jnp.where(opc == OPC[Op.SHL], a << sh, res)
    res = jnp.where(opc == OPC[Op.SHR], a >> sh, res)
    res = jnp.where(opc == OPC[Op.AND], a & b, res)
    res = jnp.where(opc == OPC[Op.OR], a | b, res)
    res = jnp.where(opc == OPC[Op.XOR], a ^ b, res)
    res = jnp.where(opc == OPC[Op.CMPGE], (a >= b).astype(a.dtype), res)
    res = jnp.where(opc == OPC[Op.CMPEQ], (a == b).astype(a.dtype), res)
    res = jnp.where(opc == OPC[Op.CMPLT], (a < b).astype(a.dtype), res)
    res = jnp.where(opc == OPC[Op.SELECT], jnp.where(a != 0, b, c), res)
    return _wrap(res, bits)


def _as_jnp(cfg: SimConfig) -> Dict[str, jnp.ndarray]:
    return {k: jnp.asarray(getattr(cfg, k)) for k in (
        "op", "imm", "src_kind", "src_idx", "force_before", "force_val",
        "xo_kind", "xo_idx", "rf_kind", "rf_idx", "mem_off", "mem_words",
        "valid_start", "nbr_idx")}


# configuration planes indexed by the II slot; pre-tiled to cycle streams
# before the scan so the traced body does no `[t % II]` dynamic gathers
_SLOT_PLANES = ("op", "imm", "src_kind", "src_idx", "force_before",
                "force_val", "xo_kind", "xo_idx", "rf_kind", "rf_idx",
                "mem_off", "mem_words", "valid_start")

# pre-tiling cap: beyond ~this many n_cycles*P elements per plane the tiled
# streams would dominate memory (tens of MB), so long simulations fall back
# to the per-cycle slot gather (identical numerics, O(II) config memory)
_TILE_CYCLE_LIMIT = 1 << 20


@functools.partial(jax.jit, static_argnames=("II", "P", "RF", "bits",
                                             "n_iters", "n_cycles",
                                             "scratch"))
def _run_invocations(c: Dict[str, jnp.ndarray], mem0: jnp.ndarray,
                     li_stack: jnp.ndarray, *, II: int, P: int, RF: int,
                     bits: int, n_iters: int, n_cycles: int,
                     scratch: int) -> jnp.ndarray:
    opp = jnp.asarray(_OPP_IDX)
    pe_ar = jnp.arange(P)

    # pre-tile the per-slot configuration into per-cycle streams: the scan
    # consumes them as xs, so XLA sees static slot schedules instead of a
    # dynamic `cfg[t % II]` gather inside every traced cycle (the gather
    # defeats scan-level constant propagation and costs a fused lookup per
    # cycle per plane).  One gather per plane here, outside the loop.
    # Tiling is O(n_cycles) memory, so very long simulations (bounded by
    # _TILE_CYCLE_LIMIT total cycle-plane elements) keep the II-sized
    # planes and gather per cycle instead.
    pretile = n_cycles * P <= _TILE_CYCLE_LIMIT
    t_arr = jnp.arange(n_cycles)
    if pretile:
        slots = jnp.arange(n_cycles) % II
        xs_cfg = {k: c[k][slots] for k in _SLOT_PLANES}
    else:
        xs_cfg = {}

    def one_invocation(mem: jnp.ndarray, li: jnp.ndarray) -> Tuple[jnp.ndarray, int]:
        regs0 = jnp.zeros((P, RF), dtype=jnp.int32)
        xo0 = jnp.zeros((P, 4), dtype=jnp.int32)
        fu0 = jnp.zeros((P,), dtype=jnp.int32)
        ldp0 = jnp.zeros((P,), dtype=jnp.int32)
        fl0 = jnp.zeros((P,), dtype=bool)

        def cycle(carry, xs):
            regs, xo, fu, ldp, fl, mem = carry
            t, ct = xs
            if not pretile:
                slot = t % II
                ct = {k: c[k][slot] for k in _SLOT_PLANES}
            opc = ct["op"]
            # inbound wires: what my neighbour's opposite-facing port holds
            inp = xo[c["nbr_idx"], opp[None, :]]          # [P,4]

            def resolve(kind, idx):
                # kind/idx: [P, K] — all K mux ports of a bank resolve in
                # one broadcasted select chain instead of one chain per port
                v = jnp.zeros(kind.shape, dtype=jnp.int32)
                v = jnp.where(kind == KIND_IN_N, inp[:, 0:1], v)
                v = jnp.where(kind == KIND_IN_E, inp[:, 1:2], v)
                v = jnp.where(kind == KIND_IN_S, inp[:, 2:3], v)
                v = jnp.where(kind == KIND_IN_W, inp[:, 3:4], v)
                v = jnp.where(kind == KIND_REG,
                              regs[pe_ar[:, None], jnp.clip(idx, 0, RF - 1)],
                              v)
                v = jnp.where(kind == KIND_FUOUT, fu[:, None], v)
                v = jnp.where(kind == KIND_IMM, ct["imm"][:, None], v)
                v = jnp.where(kind == KIND_LIREG,
                              li[pe_ar[:, None],
                                 jnp.clip(idx, 0, li.shape[1] - 1)], v)
                return v

            ops = resolve(ct["src_kind"], ct["src_idx"])       # [P,3]
            ops = jnp.where(t < ct["force_before"], ct["force_val"], ops)
            a, b, p3 = ops[:, 0], ops[:, 1], ops[:, 2]
            res = _alu(opc, a, b, p3, bits)

            # memory
            gaddr = ct["mem_off"] + jnp.clip(a, 0, ct["mem_words"] - 1)
            loaded = jnp.take(mem, gaddr)
            is_load = opc == OPC_LOAD
            is_store = opc == OPC_STORE
            vstart = ct["valid_start"]
            gate = is_store & (t >= vstart) & (t < vstart + n_iters * II)
            st_addr = jnp.where(gate, gaddr, scratch)
            mem = mem.at[st_addr].set(jnp.where(gate, b, mem[scratch]))

            fu_next = jnp.where(fl, ldp,
                                jnp.where((opc != OPC_NONE) & ~is_load
                                          & ~is_store, res, fu))
            ldp_next = jnp.where(is_load, loaded, ldp)
            fl_next = is_load

            # register-file and crossbar writes, each bank resolved as one
            # [P, K] select from the same start-of-cycle snapshot
            regs_next = jnp.where(ct["rf_kind"] != KIND_NONE,
                                  resolve(ct["rf_kind"], ct["rf_idx"]), regs)
            xo_next = jnp.where(ct["xo_kind"] != KIND_NONE,
                                resolve(ct["xo_kind"], ct["xo_idx"]), xo)

            return (regs_next, xo_next, fu_next, ldp_next, fl_next, mem), 0

        carry = (regs0, xo0, fu0, ldp0, fl0, mem)
        carry, _ = jax.lax.scan(cycle, carry, (t_arr, xs_cfg))
        return carry[-1], 0

    mem, _ = jax.lax.scan(one_invocation, mem0, li_stack)
    return mem


def simulate(cfg: SimConfig, banks: Dict[str, np.ndarray],
             invocations, n_iters: int,
             liveins_builder=None) -> Dict[str, np.ndarray]:
    """Run the mapped kernel for every invocation and return final banks.

    banks: {"bank<i>": int array} initial memory images.
    invocations: list of {livein name: value} dicts (host outer loops).
    """
    n_banks = len(cfg.bank_offsets)
    mem = np.zeros(cfg.total_words, dtype=np.int32)
    for i in range(n_banks):
        img = banks[f"bank{i}"]
        mem[cfg.bank_offsets[i]:cfg.bank_offsets[i] + len(img)] = img

    li_stack = np.stack([cfg.livein_array(inv) for inv in invocations])
    out = _run_invocations(
        _as_jnp(cfg), jnp.asarray(mem), jnp.asarray(li_stack),
        II=cfg.II, P=cfg.P, RF=cfg.RF, bits=cfg.bits,
        n_iters=n_iters, n_cycles=cfg.n_cycles(n_iters),
        scratch=cfg.total_words - 1)
    out = np.asarray(out)

    result = {}
    for i in range(n_banks):
        w = len(banks[f"bank{i}"])
        result[f"bank{i}"] = out[cfg.bank_offsets[i]:cfg.bank_offsets[i] + w]
    return result
