"""Cycle-accurate functional CGRA simulator in JAX (paper Fig. 3 piece 8).

Morpher simulates the generated Verilog with Verilator; here the same
contract is met by a jit-compiled `lax.scan` over cycles that executes the
configuration bitstreams exactly as the RTL control memories would:

  * every cycle, every PE reads its slot-(t mod II) configuration,
  * operand muxes select from {4 inbound crossbar wires, register file,
    own FU output register, immediate, live-in register},
  * the FU executes (16-bit two's-complement datapath), LOADs have a
    2-cycle latency through a pipeline register, STOREs commit at end of
    cycle gated by the control module's iteration-validity window
    (prologue/epilogue predication),
  * crossbar output registers and RF writes update from the same
    start-of-cycle snapshot (fully synchronous design).

All PEs are vectorized; the cycle loop is a `lax.scan`; invocations (the
host-driven outer loops) are a second `lax.scan` threading the memory
image.  This is the component that makes verification fast enough to run
in CI for every mapped kernel.

Both entry points run one shared traced body with a leading batch axis of
memory images (``simulate`` is the batch-of-one case):

  * ``simulate`` — one memory image (the historical per-seed path);
  * ``simulate_batch`` — many seeds / test vectors of the same compiled
    kernel in a single XLA launch, with the batched image buffer donated.
    Executables come from a process-wide shape-bucketed cache
    (``repro.core.simcache``), so a verification fleet across many kernels
    and seeds triggers a handful of traces, not one per call;
  * ``simulate_multi`` — many *configurations* sharing a shape bucket
    (``stack_signature``) in a single XLA launch: the config planes gain a
    leading batch-row axis and ride alongside the memory images, so one
    executable scores dozens of candidate fabrics of a design-space
    search.  Per (config, image) row the computation is op-for-op the
    single-config body, so results stay bit-identical.

The body is hand-batched rather than ``vmap``-ed, and shaped around what
profiles as expensive on small CGRA configurations:

  * the batch axis rides the PE dimension of every dense op, where it
    amortizes per-op dispatch nearly for free;
  * the memory image is a flat ``[batch*words]`` vector and stores scatter
    only the (few) lanes whose slot holds a STORE opcode — XLA scatters
    cost per *index*, so the historical all-P-lanes masked scatter paid
    ~90% of its cost writing the scratch word;
  * the operand / register-file / crossbar mux banks resolve in one
    concatenated select chain over all ports instead of three chains.

Configuration planes are dtype-narrowed (``config_gen.narrowed_planes``)
before entering the traced body: the pre-tiled per-cycle streams shrink
~4x, which is also what lets the tiling byte-cap admit longer simulations.
"""
from __future__ import annotations

import functools
from collections import OrderedDict
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import simcache
from .config_gen import (KIND_FUOUT, KIND_IMM, KIND_IN_E, KIND_IN_N,
                         KIND_IN_S, KIND_IN_W, KIND_LIREG, KIND_NONE,
                         KIND_REG, OPC, OPC_LOAD, OPC_NONE, OPC_PASS,
                         OPC_STORE, SimConfig, narrowed_planes)
from .dfg import Op

# xo-port index a reader consults on its neighbour: OPP of (N,E,S,W)
_OPP_IDX = np.array([2, 3, 0, 1], dtype=np.int32)



def _dp_dtype(bits: int):
    """Datapath carrier dtype: a `bits`-wide two's-complement machine is
    simulated natively in int16 when the widths coincide (integer overflow
    in XLA HLO is defined as mod-2^n wraparound, which *is* the datapath's
    wrap semantics, so the explicit `_wrap` becomes the identity and every
    value/state/memory buffer halves); other widths keep int32 carriers
    with explicit wrapping."""
    return jnp.int16 if bits == 16 else jnp.int32


def _wrap(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    if x.dtype == jnp.int16 and bits == 16:
        return x  # int16 overflow already wraps mod 2^16
    half = 1 << (bits - 1)
    full = 1 << bits
    return ((x + half) & (full - 1)) - half


def _alu(opc: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray,
         bits: int) -> jnp.ndarray:
    sh = b & (bits - 1)
    res = jnp.zeros_like(a)
    res = jnp.where(opc == OPC_PASS, a, res)
    res = jnp.where(opc == OPC[Op.ADD], a + b, res)
    res = jnp.where(opc == OPC[Op.SUB], a - b, res)
    res = jnp.where(opc == OPC[Op.MUL], a * b, res)
    res = jnp.where(opc == OPC[Op.SHL], a << sh, res)
    res = jnp.where(opc == OPC[Op.SHR], a >> sh, res)
    res = jnp.where(opc == OPC[Op.AND], a & b, res)
    res = jnp.where(opc == OPC[Op.OR], a | b, res)
    res = jnp.where(opc == OPC[Op.XOR], a ^ b, res)
    res = jnp.where(opc == OPC[Op.CMPGE], (a >= b).astype(a.dtype), res)
    res = jnp.where(opc == OPC[Op.CMPEQ], (a == b).astype(a.dtype), res)
    res = jnp.where(opc == OPC[Op.CMPLT], (a < b).astype(a.dtype), res)
    res = jnp.where(opc == OPC[Op.SELECT], jnp.where(a != 0, b, c), res)
    return _wrap(res, bits)


# configuration planes indexed by the II slot; pre-tiled to cycle streams
# before the scan so the traced body does no `[t % II]` dynamic gathers.
# ``port_idx`` maps every mux port (operands + RF writes + crossbar
# writes, [II,P,3+RF+4]) to its gather index into the flat start-of-cycle
# state vector — the whole mux fabric resolves as one gather instead of a
# per-kind select chain; ``rf_mask``/``xo_mask`` flag which write ports
# are configured; ``store_lanes`` lists the (padded, -1-terminated) PE
# indices whose slot holds a STORE, so the memory scatter touches only
# lanes that can commit.
_SLOT_PLANES = ("op", "imm", "port_idx", "rf_mask", "xo_mask",
                "force_before", "force_val", "mem_off", "mem_words",
                "valid_start", "store_lanes")

# pre-tiling cap in *bytes of tiled stream*: beyond this the tiled config
# would dominate memory, so long simulations fall back to the per-cycle
# slot gather (identical numerics, O(II) config memory).  The budget is
# sized from the actual per-cycle footprint — every plane's inner dims
# (e.g. kind_all is [P,3+RF+4]) times its (narrowed) item size — not the
# bare n_cycles*P estimate, which undercounted the streams several-fold.
_TILE_BYTES_LIMIT = 64 << 20


def _tile_bytes_per_cycle(c: Dict[str, jnp.ndarray], II: int) -> int:
    """Bytes of pre-tiled stream one simulated cycle costs: the sum over
    slot planes of (elements per slot) x (narrowed item size).  Dividing
    the total element count by II covers both plane layouts — ``[II,...]``
    single-config and ``[B,II,...]`` config-batched (where every batch
    row's slot is streamed, so the per-cycle cost scales with B)."""
    return sum(int(np.prod(c[k].shape)) // II * c[k].dtype.itemsize
               for k in _SLOT_PLANES)


def _state_layout(P: int, RF: int, LI: int):
    """Section offsets of the flat per-cycle state vector the mux fabric
    gathers from: [ xo (P*4) | regs (P*RF) | fu (P) | imm (P) |
    li (P*LI) | zero (1) ] — the trailing cell is a constant 0 every
    unconfigured (KIND_NONE) port reads."""
    xo_off = 0
    reg_off = xo_off + P * 4
    fu_off = reg_off + P * RF
    imm_off = fu_off + P
    li_off = imm_off + P
    zero_off = li_off + P * LI
    return xo_off, reg_off, fu_off, imm_off, li_off, zero_off


def _port_gather_idx(kind: np.ndarray, idx: np.ndarray, cfg: SimConfig,
                     LI: int, rf_pad: int) -> np.ndarray:
    """Host-side compilation of one mux bank ([II,P,K] kind/idx planes)
    into flat state-vector gather indices — the per-kind select chain of
    the mux fabric becomes pure data, so the traced body resolves every
    port of every bank with a single gather.

    ``rf_pad >= cfg.RF`` is the register-file width of the *executable*'s
    state layout (``simulate_multi`` pads the group to one RF bucket so
    differently-provisioned fabrics share a trace); reads still clip to
    the config's own RF, so padded rows are never addressed."""
    P, RF = cfg.P, cfg.RF
    xo_off, reg_off, fu_off, imm_off, li_off, zero_off = \
        _state_layout(P, rf_pad, LI)
    II, _, K = kind.shape
    pe = np.arange(P)[None, :, None]
    nbr = np.asarray(cfg.nbr_idx)                          # [P,4]
    out = np.full(kind.shape, zero_off, dtype=np.int64)    # KIND_NONE -> 0
    for d, kind_in in enumerate((KIND_IN_N, KIND_IN_E, KIND_IN_S,
                                 KIND_IN_W)):
        # inbound wire: neighbour's opposite-facing crossbar port
        sel = kind == kind_in
        val = nbr[:, d][None, :, None] * 4 + _OPP_IDX[d] + xo_off
        out = np.where(sel, np.broadcast_to(val, kind.shape), out)
    out = np.where(kind == KIND_REG,
                   reg_off + pe * rf_pad + np.clip(idx, 0, RF - 1), out)
    out = np.where(kind == KIND_FUOUT, fu_off + pe, out)
    out = np.where(kind == KIND_IMM, imm_off + pe, out)
    out = np.where(kind == KIND_LIREG,
                   li_off + pe * LI + np.clip(idx, 0, LI - 1), out)
    return out.astype(np.int16 if zero_off <= np.iinfo(np.int16).max
                      else np.int32)


def _host_planes(cfg: SimConfig,
                 rf_pad: int = 0) -> Dict[str, np.ndarray]:
    """Host-side compilation of a SimConfig into the simulator's slot
    planes (numpy), cached on the SimConfig (keyed by the RF width the
    executable will use; 0 / cfg.RF is the plain single-config layout).

    Starting from the dtype-narrowed planes, the three mux banks are
    compiled into one ``port_idx`` gather plane over the flat state
    vector, write masks replace the RF/crossbar kind tests, and the
    per-slot store-lane table is derived from the opcode plane (see
    ``_SLOT_PLANES``).  With ``rf_pad > cfg.RF`` the RF write-port bank
    pads to ``rf_pad`` ports with unconfigured (KIND_NONE, mask-off)
    lanes and the state layout stretches to match — the padded register
    rows are never written or read, which is what lets fabrics with
    different register-file provisioning stack into one executable
    bit-exactly.

    The cache means a SimConfig is frozen once simulated — and that is
    enforced: building the cache marks the numpy planes read-only, so a
    later in-place edit raises instead of silently diverging from the
    compiled copies.  Configs come out of ``generate_config``/
    ``from_json`` and are never mutated by the flow; anyone editing one by
    hand (tests injecting faults) must do so before the first run or
    delete ``_np_planes``/``_jnp_planes`` and restore
    ``.flags.writeable``.
    """
    R = rf_pad or cfg.RF
    assert R >= cfg.RF, "rf_pad must not shrink the register file"
    by_rf = getattr(cfg, "_np_planes", None)
    if by_rf is None:
        by_rf = cfg._np_planes = {}
    cached = by_rf.get(R)
    if cached is None:
        p = narrowed_planes(cfg)
        II, P, LI = cfg.II, cfg.P, max(1, cfg.LI)
        lanes = [np.nonzero(np.asarray(cfg.op)[s] == OPC_STORE)[0]
                 for s in range(II)]
        S = max(1, max((len(l) for l in lanes), default=0))
        store_lanes = np.full((II, S), -1, dtype=np.int8 if P <= 127
                              else np.int16)
        for s, l in enumerate(lanes):
            store_lanes[s, :len(l)] = l
        rf_kind = np.asarray(p["rf_kind"])
        rf_idx = np.asarray(p["rf_idx"])
        if R > cfg.RF:                   # pad write-port bank: dead lanes
            pad = ((0, 0), (0, 0), (0, R - cfg.RF))
            rf_kind = np.pad(rf_kind, pad, constant_values=KIND_NONE)
            rf_idx = np.pad(rf_idx, pad, constant_values=0)
        kind_all = np.concatenate(
            [p["src_kind"], rf_kind, p["xo_kind"]], axis=2)
        idx_all = np.concatenate(
            [p["src_idx"], rf_idx, p["xo_idx"]], axis=2)
        cached = {
            "op": np.asarray(p["op"]), "imm": np.asarray(p["imm"]),
            "port_idx": _port_gather_idx(kind_all, idx_all, cfg, LI, R),
            "rf_mask": rf_kind != KIND_NONE,
            "xo_mask": np.asarray(p["xo_kind"]) != KIND_NONE,
            "force_before": np.asarray(p["force_before"]),
            "force_val": np.asarray(p["force_val"]),
            "mem_off": np.asarray(p["mem_off"]),
            "mem_words": np.asarray(p["mem_words"]),
            "valid_start": np.asarray(p["valid_start"]),
            "store_lanes": store_lanes,
        }
        for k in SimConfig._ARRAY_DTYPES:
            arr = getattr(cfg, k)
            if isinstance(arr, np.ndarray):
                arr.flags.writeable = False
        by_rf[R] = cached
    return cached


def _as_jnp(cfg: SimConfig) -> Dict[str, jnp.ndarray]:
    """Device copies of ``_host_planes(cfg)``, cached on the SimConfig so
    repeated runs/verifies skip the host-side compilation and the
    transfer."""
    cached = getattr(cfg, "_jnp_planes", None)
    if cached is None:
        cached = {k: jnp.asarray(v) for k, v in _host_planes(cfg).items()}
        cfg._jnp_planes = cached
    return cached


def _sim_body(c: Dict[str, jnp.ndarray], mem0: jnp.ndarray,
              li_stack: jnp.ndarray, *, II: int, P: int, RF: int,
              bits: int, n_iters: int, n_cycles: int,
              cfg_batched: bool = False) -> jnp.ndarray:
    """A batch of memory images through all invocations in one launch.

    ``mem0``: [batch, words] initial images (batch=1 is the sequential
    path).  Per batch row the computation is op-for-op the classic
    single-image simulation, so results are bit-identical per element;
    batch and image size specialize from ``mem0``'s shape at trace time.
    Address and time-window sums happen in int32 (the narrowed config
    streams only carry the values).

    ``cfg_batched=True`` is the multi-architecture variant: every config
    plane carries a leading batch-row axis (``[B, II, ...]``, one config
    per memory image; ``li_stack`` becomes ``[n_inv, B, P, LI]``), so one
    launch simulates many *different* fabrics sharing the static shape
    tuple.  The branches below are trace-time only — with a broadcast
    config the batched trace degenerates to exactly the single-config
    graph per row, which is what keeps ``simulate_multi`` bit-identical
    to ``simulate_batch`` per element.
    """
    B, W = mem0.shape
    LI = li_stack.shape[-1]
    dt = _dp_dtype(bits)
    row_off = (jnp.arange(B) * W)[:, None]                # [B,1]
    scratch = row_off + (W - 1)                           # [B,1] per-row

    # pre-tile the per-slot configuration into per-cycle streams: the scan
    # consumes them as xs, so XLA sees static slot schedules instead of a
    # dynamic `cfg[t % II]` gather inside every traced cycle (the gather
    # defeats scan-level constant propagation and costs a fused lookup per
    # cycle per plane).  One gather per plane here, outside the loop.
    # Tiling is O(n_cycles) memory, so very long simulations (bounded by
    # _TILE_BYTES_LIMIT total tiled-stream bytes) keep the II-sized
    # planes and gather per cycle instead.
    pretile = n_cycles * _tile_bytes_per_cycle(c, II) <= _TILE_BYTES_LIMIT
    t_arr = jnp.arange(n_cycles)
    if pretile:
        slots = jnp.arange(n_cycles) % II
        if cfg_batched:
            # [B,II,...] -> [n_cycles,B,...]: scan consumes cycle-major
            xs_cfg = {k: jnp.moveaxis(c[k][:, slots], 0, 1)
                      for k in _SLOT_PLANES}
        else:
            xs_cfg = {k: c[k][slots] for k in _SLOT_PLANES}
    else:
        xs_cfg = {}

    def one_invocation(mem: jnp.ndarray, li: jnp.ndarray) -> Tuple[jnp.ndarray, int]:
        regs0 = jnp.zeros((B, P, RF), dtype=dt)
        xo0 = jnp.zeros((B, P, 4), dtype=dt)
        fu0 = jnp.zeros((B, P), dtype=dt)
        ldp0 = jnp.zeros((B, P), dtype=dt)
        fl0 = jnp.zeros((B, P), dtype=bool)
        if cfg_batched:
            li_flat = li.reshape(B, P * LI).astype(dt)
        else:
            li_flat = jnp.broadcast_to(li.reshape(-1).astype(dt),
                                       (B, P * LI))
        zero_cell = jnp.zeros((B, 1), dtype=dt)
        state_len = P * (4 + RF + 2 + LI) + 1
        state_row_off = (jnp.arange(B) * state_len)[:, None, None]  # [B,1,1]

        def cycle(carry, xs):
            regs, xo, fu, ldp, fl, mem = carry
            t, ct = xs
            if not pretile:
                slot = t % II
                ct = {k: (c[k][:, slot] if cfg_batched else c[k][slot])
                      for k in _SLOT_PLANES}
            opc = ct["op"]                                # [B,P] | [P]

            # the whole mux fabric (operand + RF-write + crossbar-write
            # ports) resolves as one flat 1D gather from the start-of-
            # cycle state snapshot (layout: _state_layout; indices
            # precompiled per slot by _port_gather_idx, offset per batch
            # row here — flat scalar gathers are what XLA CPU does fast)
            imm = ct["imm"].astype(dt)
            if not cfg_batched:
                imm = jnp.broadcast_to(imm[None], (B, P))
            state = jnp.concatenate(
                [xo.reshape(B, -1), regs.reshape(B, -1), fu,
                 imm, li_flat, zero_cell], axis=1)        # [B,SL]
            pidx = state_row_off + ct["port_idx"].astype(jnp.int32)
            v = jnp.take(state.reshape(-1), pidx)         # [B,P,3+RF+4]

            ops = v[:, :, :3]                             # [B,P,3]
            ops = jnp.where(t < ct["force_before"], ct["force_val"], ops)
            a, b, p3 = ops[:, :, 0], ops[:, :, 1], ops[:, :, 2]
            res = _alu(opc, a, b, p3, bits)

            # memory: flat global addresses = row offset + bank offset +
            # clipped bank-relative address; stores commit through only
            # the lanes whose slot holds a STORE (XLA scatters cost per
            # index), gated by the iteration-validity window — padded /
            # gated-off lanes write the scratch word's own value back
            mem_w = ct["mem_words"].astype(jnp.int32)
            gaddr = row_off + ct["mem_off"].astype(jnp.int32) + \
                jnp.clip(a, 0, mem_w - 1)                 # [B,P]
            loaded = jnp.take(mem, gaddr)
            is_load = opc == OPC_LOAD
            is_store = opc == OPC_STORE
            vstart = ct["valid_start"].astype(jnp.int32)
            window = is_store & (t >= vstart) & (t < vstart + n_iters * II)
            sl = ct["store_lanes"]                        # [B,S] | [S]
            if cfg_batched:
                slc = jnp.clip(sl, 0, P - 1).astype(jnp.int32)
                gate = (jnp.take_along_axis(window, slc, axis=1)
                        & (sl >= 0))                      # [B,S]
                st_src = jnp.take_along_axis(gaddr, slc, axis=1)
                st_val = jnp.take_along_axis(b, slc, axis=1)
            else:
                slc = jnp.clip(sl, 0, P - 1)
                gate = window[slc] & (sl >= 0)            # [S]
                st_src = gaddr[:, slc]
                st_val = b[:, slc]
            st_addr = jnp.where(gate, st_src, scratch)
            scr_val = jnp.take(mem, scratch)              # [B,1]
            mem = mem.at[st_addr].set(jnp.where(gate, st_val, scr_val))

            fu_next = jnp.where(fl, ldp,
                                jnp.where((opc != OPC_NONE) & ~is_load
                                          & ~is_store, res, fu))
            ldp_next = jnp.where(is_load, loaded, ldp)
            fl_next = jnp.broadcast_to(is_load, (B, P))

            # register-file and crossbar writes from the resolved ports
            regs_next = jnp.where(ct["rf_mask"], v[:, :, 3:3 + RF], regs)
            xo_next = jnp.where(ct["xo_mask"], v[:, :, 3 + RF:], xo)

            return (regs_next, xo_next, fu_next, ldp_next, fl_next, mem), 0

        carry = (regs0, xo0, fu0, ldp0, fl0, mem)
        carry, _ = jax.lax.scan(cycle, carry, (t_arr, xs_cfg))
        return carry[-1], 0

    mem, _ = jax.lax.scan(one_invocation, mem0.reshape(B * W), li_stack)
    return mem.reshape(B, W)


_run_invocations = functools.partial(
    jax.jit, static_argnames=("II", "P", "RF", "bits", "n_iters",
                              "n_cycles", "cfg_batched"))(_sim_body)


def _build_batched(sig: simcache.SimSignature):
    """Compile-on-demand builder for one batched-simulator signature,
    jitted with the batched image buffer donated so per-seed images are
    updated in place.  Buffer donation is a device-memory optimization XLA
    only implements off-CPU, so it is skipped on the CPU backend (where it
    would just warn)."""
    body = functools.partial(_sim_body, II=sig.II, P=sig.P, RF=sig.RF,
                             bits=sig.bits, n_iters=sig.n_iters,
                             n_cycles=sig.n_cycles, cfg_batched=sig.multi)
    donate = (1,) if jax.default_backend() != "cpu" else ()
    return jax.jit(body, donate_argnums=donate)


def _banks_to_mem(cfg: SimConfig, banks: Dict[str, np.ndarray]) -> np.ndarray:
    mem = np.zeros(cfg.total_words,
                   dtype=np.int16 if cfg.bits == 16 else np.int32)
    for bid, off in cfg.bank_offsets.items():
        img = banks[f"bank{bid}"]
        mem[off:off + len(img)] = img
    return mem


def _mem_to_banks(cfg: SimConfig, mem: np.ndarray,
                  banks: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    return {f"bank{bid}": mem[off:off + len(banks[f"bank{bid}"])]
            for bid, off in cfg.bank_offsets.items()}


def simulate(cfg: SimConfig, banks: Dict[str, np.ndarray],
             invocations, n_iters: int,
             liveins_builder=None) -> Dict[str, np.ndarray]:
    """Run the mapped kernel for every invocation and return final banks.

    banks: {"bank<i>": int array} initial memory images.
    invocations: list of {livein name: value} dicts (host outer loops).
    """
    mem = _banks_to_mem(cfg, banks)
    if not len(invocations):
        # nothing to run: the final image is the initial image
        return _mem_to_banks(cfg, mem, banks)

    li_stack = np.stack([cfg.livein_array(inv) for inv in invocations])
    out = _run_invocations(
        _as_jnp(cfg), jnp.asarray(mem[None, :]), jnp.asarray(li_stack),
        II=cfg.II, P=cfg.P, RF=cfg.RF, bits=cfg.bits,
        n_iters=n_iters, n_cycles=cfg.n_cycles(n_iters))
    return _mem_to_banks(cfg, np.asarray(out)[0], banks)


def simulate_batch(cfg: SimConfig, banks_batch: List[Dict[str, np.ndarray]],
                   invocations, n_iters: int) -> List[Dict[str, np.ndarray]]:
    """Run the same mapped kernel over a batch of initial memory images.

    All images share one configuration and invocation schedule (the batch
    axis is seeds / test vectors, not kernels), so the whole batch is one
    batched XLA launch: per-element results are bit-identical to
    ``simulate`` on that element.  The executable comes from the process-
    wide shape-bucketed cache (``repro.core.simcache``): batch is rounded
    up to a power of two (padded images are simulated and dropped) and the
    cycle count to its bucket boundary (padded cycles are store-gated
    no-ops), so sweeps across many kernels and seed counts retrace XLA a
    handful of times instead of once per call.
    """
    B = len(banks_batch)
    if B == 0:
        return []
    mem = np.stack([_banks_to_mem(cfg, banks) for banks in banks_batch])
    if not len(invocations):
        return [_mem_to_banks(cfg, mem[i], banks_batch[i]) for i in range(B)]

    li_stack = np.stack([cfg.livein_array(inv) for inv in invocations])
    sig = simcache.SimSignature(
        II=cfg.II, P=cfg.P, RF=cfg.RF, bits=cfg.bits, n_iters=n_iters,
        n_cycles=simcache.bucket_cycles(cfg.n_cycles(n_iters)),
        batch=simcache.bucket_batch(B))
    if sig.batch > B:  # pad to the bucket; padded rows are masked out below
        mem = np.concatenate(
            [mem, np.repeat(mem[-1:], sig.batch - B, axis=0)])
    fn = simcache.get(sig, lambda: _build_batched(sig))
    out = np.asarray(fn(_as_jnp(cfg), jnp.asarray(mem),
                        jnp.asarray(li_stack)))
    return [_mem_to_banks(cfg, out[i], banks_batch[i]) for i in range(B)]


# ------------------------------------------------- multi-architecture batch
def stack_signature(cfg: SimConfig, n_iters: int,
                    n_invocations: int) -> Tuple[int, ...]:
    """The shape bucket a (config, schedule) pair simulates in.

    Configs agreeing on this tuple can be stacked into one multi-arch
    executable (``simulate_multi``): every element is a *static* shape
    input of the traced body — per-arch values (opcode planes, neighbour
    tables, bank offsets, live-in values) ride the batch axis as data.
    The cycle count enters bucketed, so near-miss schedule depths stack
    too (padded cycles are store-gated no-ops); the register-file width
    enters bucketed (``simcache.bucket_rf``), so fabrics differing only
    in RF provisioning stack too — each config's planes pad to the
    bucket with dead write ports, and its own reads never index past its
    real RF.
    """
    return (cfg.II, cfg.P, simcache.bucket_rf(cfg.RF), cfg.bits,
            max(1, cfg.LI), n_iters, n_invocations,
            simcache.bucket_cycles(cfg.n_cycles(n_iters)))


def _stack_planes(per: List[Dict[str, np.ndarray]],
                  reps: List[int]) -> Dict[str, np.ndarray]:
    """Stack per-config host planes into ``[B, II, ...]`` rows, repeating
    each config for its memory-image count.  Store-lane tables pad to the
    group-wide lane count with -1 (dead lanes); value planes promote to
    the group's common dtype — both value-preserving, so stacked rows
    decode exactly as their single-config originals."""
    S = max(p["store_lanes"].shape[1] for p in per)
    out: Dict[str, np.ndarray] = {}
    for k in per[0]:
        arrs = []
        for p, rep in zip(per, reps):
            a = p[k]
            if k == "store_lanes" and a.shape[1] < S:
                a = np.concatenate(
                    [a, np.full((a.shape[0], S - a.shape[1]), -1,
                                dtype=a.dtype)], axis=1)
            arrs.append(np.repeat(a[None], rep, axis=0))
        dtype = np.result_type(*(a.dtype for a in arrs))
        out[k] = np.concatenate([a.astype(dtype, copy=False)
                                 for a in arrs], axis=0)
    return out


# stacked-plane device cache: the multi-arch analogue of the per-config
# ``_jnp_planes`` memo.  A search cohort is re-simulated (warm executable)
# many times — rung after rung, benchmark repeats — and restacking +
# re-uploading ~10 config planes per call would otherwise dominate the
# launch it saves.  Keyed by config identities (the cached tuple holds
# strong refs, so an id can never be recycled while its key is live);
# bounded FIFO keeps one search's worth of groups.
_STACK_PLANES_CACHE: "OrderedDict[tuple, tuple]" = OrderedDict()
_STACK_PLANES_MAX = 32


def _stacked_jnp_planes(cfgs: Tuple[SimConfig, ...],
                        reps: Tuple[int, ...], pad: int,
                        rf_pad: int) -> Dict:
    key = (tuple(id(c) for c in cfgs), reps, pad, rf_pad)
    hit = _STACK_PLANES_CACHE.get(key)
    if hit is not None:
        _STACK_PLANES_CACHE.move_to_end(key)
        return hit[1]
    planes = _stack_planes([_host_planes(c, rf_pad) for c in cfgs],
                           list(reps))
    if pad:  # pad to the batch bucket by repeating the last config row
        planes = {k: np.concatenate([v, np.repeat(v[-1:], pad, axis=0)])
                  for k, v in planes.items()}
    jp = {k: jnp.asarray(v) for k, v in planes.items()}
    _STACK_PLANES_CACHE[key] = (cfgs, jp)
    while len(_STACK_PLANES_CACHE) > _STACK_PLANES_MAX:
        _STACK_PLANES_CACHE.popitem(last=False)
    return jp


def simulate_multi(items: Sequence[Tuple[SimConfig,
                                         List[Dict[str, np.ndarray]],
                                         List[Dict[str, int]]]],
                   n_iters: int) -> List[List[Dict[str, np.ndarray]]]:
    """Simulate many *configurations* in one XLA launch.

    ``items``: a list of ``(cfg, banks_batch, invocations)`` triples — all
    sharing one :func:`stack_signature` — e.g. the same kernel compiled
    onto many candidate fabrics of a design-space search, each with its
    own seed batch.  Config planes are stacked along the batch axis next
    to the memory images, so the whole group is a single executable
    launch; per (config, image) element the result is bit-identical to
    ``simulate_batch`` on that config alone (pinned by
    ``tests/test_multiarch_sim.py``).

    Memory rows pad to the group's widest image (each config addresses
    only its own ``total_words``; the shared scratch word sits at the
    padded row end), the batch rounds up to its power-of-two bucket, and
    every config's register file pads to the group's RF bucket
    (``simcache.bucket_rf``) with dead write ports, so signatures — and
    executables — are shared with other groups of the same shapes and
    across RF provisioning variants.  Returns one list of final-banks
    dicts per item, in item order.
    """
    items = [(cfg, list(bb), list(inv)) for cfg, bb, inv in items]
    out: List[List[Dict[str, np.ndarray]]] = [[] for _ in items]
    live = [i for i, (_, bb, _inv) in enumerate(items) if bb]
    if not live:
        return out
    sigs = sorted({stack_signature(items[i][0], n_iters, len(items[i][2]))
                   for i in live})
    if len(sigs) != 1:
        raise ValueError(
            f"simulate_multi: items span {len(sigs)} shape buckets "
            f"{sigs}; stack only configs sharing one stack_signature")
    II, P, RF, bits, LI, _, n_inv, n_cycles = sigs[0]
    if n_inv == 0:
        # nothing to run: final images are the initial images
        for i in live:
            cfg, bb, _ = items[i]
            out[i] = [_mem_to_banks(cfg, _banks_to_mem(cfg, b), b)
                      for b in bb]
        return out
    if len(live) == 1:
        # a group of one is the plain batched path (shares its executable
        # with every non-stacked caller)
        i = live[0]
        cfg, bb, inv = items[i]
        out[i] = simulate_batch(cfg, bb, inv, n_iters)
        return out

    reps = [len(items[i][1]) for i in live]
    B = sum(reps)
    W = max(items[i][0].total_words for i in live)
    mem = np.zeros((B, W), dtype=np.int16 if bits == 16 else np.int32)
    row = 0
    for i in live:
        cfg, bb, _ = items[i]
        for b in bb:
            mem[row, :cfg.total_words] = _banks_to_mem(cfg, b)
            row += 1
    li = np.concatenate(
        [np.repeat(np.stack([items[i][0].livein_array(inv)
                             for inv in items[i][2]])[:, None],
                   rep, axis=1)
         for i, rep in zip(live, reps)], axis=1)       # [n_inv,B,P,LI]
    sig = simcache.SimSignature(
        II=II, P=P, RF=RF, bits=bits, n_iters=n_iters, n_cycles=n_cycles,
        batch=simcache.bucket_rows(B), LI=LI, multi=True)
    pad = sig.batch - B
    if pad:  # pad to the bucket by repeating the last row everywhere
        mem = np.concatenate([mem, np.repeat(mem[-1:], pad, axis=0)])
        li = np.concatenate([li, np.repeat(li[:, -1:], pad, axis=1)],
                            axis=1)
    planes = _stacked_jnp_planes(tuple(items[i][0] for i in live),
                                 tuple(reps), pad, RF)
    fn = simcache.get(sig, lambda: _build_batched(sig))
    res = np.asarray(fn(planes, jnp.asarray(mem), jnp.asarray(li)))
    row = 0
    for i in live:
        cfg, bb, _ = items[i]
        out[i] = []
        for b in bb:
            out[i].append(_mem_to_banks(cfg, res[row], b))
            row += 1
    return out
