"""Unified compile API: the paper's integrated flow as one staged object.

Morpher's core claim (paper Fig. 3) is that ADL, DFG generation, mapping,
configuration generation, simulation and verification form *one* pipeline.
This module is that pipeline's front door:

    tc = Toolchain(options=MapperOptions())        # or default_toolchain()
    ck = tc.compile(spec)                          # KernelSpec -> artifact
    ck.run(init_banks)                             # cycle-accurate simulate
    ck.verify()                                    # paper IV-C flow
    text = ck.to_json()                            # serializable artifact
    ck2 = CompiledKernel.from_json(text)           # ... reload anywhere
    ck2.verify()                                   # still bit-exact

``CompiledKernel`` bundles everything the downstream stages need — the DFG,
data layout, the :class:`Mapping`, and the generated :class:`SimConfig` —
and is fully JSON-serializable (CGRA4ML-style artifact-oriented HW/SW
handoff).  A deserialized artifact carries no Python closures, so its
``verify`` falls back to the DFG's sequential reference execution as the
oracle; both paths are bit-exact comparisons of final memory images.

Compiles are memoized through a content-addressed on-disk cache keyed by a
stable SHA-256 of (DFG canonical form, arch ADL JSON, mapper options, data
layout, invocation schedule).  Re-mapping the same tile — which the edge-
deployment analyzer does for every GEMM site of every model — is a cache
hit across processes and sessions.  *Negative* results are memoized too:
the mapper is deterministic, so a MapError for a given content address is
as reproducible as a mapping, and a design-space sweep re-run must not
re-pay the II escalation of every infeasible (arch, kernel) point — a
``<key>.err.json`` marker short-circuits it.  Cache location:
``$MORPHER_CACHE_DIR`` (default ``~/.cache/morpher-toolchain``; set it to
the empty string, or pass ``cache_dir=""``, to disable the on-disk cache).
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
import threading
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from .adl import CGRAArch
from .config_gen import ConfigConflict, SimConfig, generate_config
from .dfg import DFG
from .kernels_lib import KernelSpec
from .layout import DataLayout
from .mapper import MapError, Mapping, MapperOptions, map_kernel_opts

# v2: SimConfig.bank_offsets became an id-keyed mapping (banks are
# identified by MemBank.id, not list position) — v1 artifacts are
# incompatible and recompile on load
# v3: SimConfig.to_json is canonical (sorted keys, compact separators) —
# the instruction-stream exporter's byte-determinism contract rests on
# it; v2 artifacts parse fine but recompile so cached bytes are canonical
ARTIFACT_VERSION = 3
CACHE_ENV = "MORPHER_CACHE_DIR"


def default_cache_dir() -> str:
    """Resolve the on-disk artifact cache directory.

    ``$MORPHER_CACHE_DIR`` overrides; an empty value disables caching.
    """
    env = os.environ.get(CACHE_ENV)
    if env is not None:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache",
                        "morpher-toolchain")


def spec_cache_key(spec: KernelSpec, options: MapperOptions) -> str:
    """Content address of a compile: everything that determines the
    artifact, nothing that doesn't (golden-model closures are derived from
    the same structural inputs and deliberately excluded; the DFG enters
    in canonical form, so cosmetic node names — which differ between the
    hand-built builders and the ``repro.frontend`` tracer — cannot change
    the address)."""
    ident = {
        "v": ARTIFACT_VERSION,
        "dfg": spec.dfg.canonical_dict(),
        "arch": json.loads(spec.arch.to_json()),
        "options": options.to_json_dict(),
        "layout": spec.layout.to_json_dict(),
        "mapped_iters": spec.mapped_iters,
        "invocations": spec.invocations,
        "meta": spec.meta,
        "name": spec.name,
    }
    blob = json.dumps(ident, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()


def _compile_worker(payload: str) -> str:
    """Process-pool worker: map + generate config from the JSON form of the
    compile inputs (specs carry unpicklable closures; their structural parts
    round-trip losslessly).  Pure Python/numpy — no JAX in the child.

    An infeasible mapping is a *result*, not a crash: MapError comes back
    as an error marker so one unmappable (arch, kernel) pair — routine in
    a design-space sweep — cannot kill the whole fan-out."""
    d = json.loads(payload)
    arch = CGRAArch.from_json(json.dumps(d["arch"]))
    dfg = DFG.from_json_dict(d["dfg"])
    layout = DataLayout.from_json_dict(d["layout"], arch)
    opt = MapperOptions.from_json_dict(d["options"])
    try:
        mapping = map_kernel_opts(dfg, arch, layout, opt)
        cfg = generate_config(mapping, layout)
    except (MapError, ConfigConflict) as e:
        return json.dumps({"map_error": _compile_error_str(e)})
    return json.dumps({"mapping": mapping.to_json_dict(),
                       "cfg": json.loads(cfg.to_json())})


def _compile_error_str(e: Exception) -> str:
    """One canonical error string per compile failure mode.  A
    ConfigConflict (the mapper accepted a schedule the crossbar fabric
    cannot realize — possible on heavily heterogeneous variants) is an
    infeasibility *result* exactly like MapError: same message in the
    fleet worker and the sequential path, so the memoized failure is
    bit-identical either way."""
    if isinstance(e, ConfigConflict):
        return f"configuration conflict: {e}"
    return str(e)


# --------------------------------------------------------------------------
@dataclass
class CompiledKernel:
    """The serializable product of one compile: spec metadata + mapping +
    configuration + layout, with run/verify attached."""
    name: str
    arch: CGRAArch
    dfg: DFG
    layout: DataLayout
    mapping: Mapping
    cfg: SimConfig
    mapped_iters: int
    invocations: List[Dict[str, int]]
    meta: Dict[str, int]
    options: MapperOptions
    cache_key: str
    # transient: the builder spec (golden model + bank init closures); not
    # serialized, absent on artifacts reloaded from JSON.
    spec: Optional[KernelSpec] = None
    from_cache: bool = False

    # ------------------------------------------------------------ metadata
    @property
    def II(self) -> int:
        return self.mapping.II

    @property
    def mii(self) -> int:
        return self.mapping.mii

    @property
    def utilization(self) -> float:
        return self.mapping.utilization

    @property
    def depth(self) -> int:
        return self.mapping.depth

    def schedule_cycles(self) -> int:
        """Cycles per invocation (fill + steady state + drain)."""
        return self.mapping.schedule_len(self.mapped_iters)

    def liveout_banks(self) -> List[str]:
        """The bank arrays any STORE node writes — the only memory the
        simulation can change, hence the only words verification compares."""
        from .dfg import Op
        return sorted({n.array for n in self.dfg.nodes.values()
                       if n.op == Op.STORE})

    # ------------------------------------------------------------ execution
    def run(self, init_banks: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        """Cycle-accurately simulate all invocations; returns final banks."""
        from .simulator import simulate
        return simulate(self.cfg, init_banks, self.invocations,
                        self.mapped_iters)

    def run_batch(self, init_banks_batch: List[Dict[str, np.ndarray]]
                  ) -> List[Dict[str, np.ndarray]]:
        """Simulate a batch of initial images (seeds / test vectors) in one
        vmapped launch; element i is bit-identical to ``run`` on it."""
        from .simulator import simulate_batch
        return simulate_batch(self.cfg, init_banks_batch, self.invocations,
                              self.mapped_iters)

    def random_banks(self, seed: int = 0) -> Dict[str, np.ndarray]:
        """Deterministic random bank images over the target's banks — the
        self-contained test-data generator for deserialized artifacts."""
        rng = np.random.default_rng(seed)
        return {f"bank{bid}": rng.integers(-8, 8, size=w).astype(np.int64)
                for bid, w in self.layout.bank_image_size().items()}

    def verify(self, seed: int = 0, check_dfg: bool = True
               ) -> "CompiledKernel":
        """Paper IV-C functional verification; raises AssertionError on any
        final-memory mismatch, returns self on success.

        With the builder spec attached (fresh compiles), the oracle is the
        kernel's golden numpy model on spec-generated test data.  Without it
        (artifacts reloaded from JSON), the oracle is sequential DFG
        reference execution on deterministic random bank images — the same
        bit-exact contract, self-contained in the artifact.
        """
        from .verify import check_enabled
        if check_enabled():
            # opt-in static gate (MORPHER_CHECK=1): a clean artifact must
            # be diagnostic-free before any simulation runs
            from ..check import assert_clean
            assert_clean(self)
        if self.spec is not None:
            from .verify import check_dfg_semantics, generate_test_data
            data = generate_test_data(self.spec, seed)
            if check_dfg:
                check_dfg_semantics(self.spec, data)
            init, expected = data.init_banks, data.expected_banks
        else:
            from .verify import reference_banks
            init = self.random_banks(seed)
            banks = reference_banks(self.dfg, init, self.invocations,
                                    self.mapped_iters,
                                    self.arch.datapath_bits)
            expected = {k: np.asarray(v) for k, v in banks.items()}
        final = self.run(init)
        for bank, exp in expected.items():
            got = np.asarray(final[bank])
            exp = np.asarray(exp)
            if not np.array_equal(got, exp):
                bad = np.nonzero(got != exp)[0][:8]
                raise AssertionError(
                    f"{self.name} (II={self.II}): simulation mismatch in "
                    f"{bank} at words {bad.tolist()}: got {got[bad]}, "
                    f"want {exp[bad]}")
        from .verify import xval_enabled
        if xval_enabled():
            # opt-in second oracle (MORPHER_XVAL=1): the exported
            # instruction stream through the standalone interpreter must
            # also match the simulator bit-for-bit
            from ..isa.xval import cross_validate
            cross_validate(self, seeds=(seed,))
        return self

    def verify_batch(self, seeds: Sequence[int] = (0,),
                     check_dfg: bool = True) -> "CompiledKernel":
        """Paper IV-C verification over many seeds in one batched pass.

        All test vectors are generated up front, the DFG oracle runs once
        vectorized over the seed axis, and the cycle-accurate simulation is
        a single vmapped XLA launch through the process-wide executable
        cache — with results bit-identical to per-seed ``verify`` (pinned
        by the golden-equivalence tests).  Live-out banks (the ones STORE
        nodes target) are compared word-for-word against the oracle;
        every other bank is pinned to its initial image, so a miscompiled
        store straying into an input-only bank still fails.  Raises
        AssertionError naming the first offending (seed, bank, words);
        returns self on success.
        """
        seeds = list(seeds)
        if not seeds:
            return self
        from .verify import check_enabled
        if check_enabled():
            from ..check import assert_clean
            assert_clean(self)
        init_batch, expected = _batch_oracle(self, seeds, check_dfg)
        finals = self.run_batch(init_batch)
        _check_batch(self, seeds, init_batch, expected, finals)
        from .verify import xval_enabled
        if xval_enabled():
            from ..isa.xval import cross_validate
            cross_validate(self, seeds=seeds)
        return self

    # --------------------------------------------------------- serialization
    def to_json(self) -> str:
        return json.dumps({
            "version": ARTIFACT_VERSION,
            "name": self.name,
            "cache_key": self.cache_key,
            "mapped_iters": self.mapped_iters,
            "invocations": self.invocations,
            "meta": self.meta,
            "arch": json.loads(self.arch.to_json()),
            "dfg": self.dfg.to_json_dict(),
            "layout": self.layout.to_json_dict(),
            "options": self.options.to_json_dict(),
            "mapping": self.mapping.to_json_dict(),
            "cfg": json.loads(self.cfg.to_json()),
        })

    @staticmethod
    def from_json(s: str) -> "CompiledKernel":
        d = json.loads(s)
        if d.get("version") != ARTIFACT_VERSION:
            raise ValueError(f"artifact version {d.get('version')} != "
                             f"{ARTIFACT_VERSION}")
        arch = CGRAArch.from_json(json.dumps(d["arch"]))
        dfg = DFG.from_json_dict(d["dfg"])
        return CompiledKernel(
            name=d["name"], arch=arch, dfg=dfg,
            layout=DataLayout.from_json_dict(d["layout"], arch),
            mapping=Mapping.from_json_dict(d["mapping"], dfg, arch),
            cfg=SimConfig.from_json(json.dumps(d["cfg"])),
            mapped_iters=d["mapped_iters"],
            invocations=d["invocations"], meta=d["meta"],
            options=MapperOptions.from_json_dict(d["options"]),
            cache_key=d["cache_key"])


# --------------------------------------------------------------------------
def _batch_oracle(ck: CompiledKernel, seeds: Sequence[int],
                  check_dfg: bool):
    """Test vectors + expected final banks for one kernel over a seed
    batch — the ``verify_batch`` oracle, shared verbatim by the stacked
    multi-architecture path so both report identical results.  With the
    builder spec attached the oracle is the golden numpy model on
    spec-generated data; reloaded artifacts fall back to sequential DFG
    reference execution on deterministic random bank images."""
    if ck.spec is not None:
        from .verify import (check_dfg_semantics_batch,
                             generate_test_data_batch)
        data = generate_test_data_batch(ck.spec, seeds)
        if check_dfg:
            check_dfg_semantics_batch(ck.spec, data)
        init_batch = [data.init_row(i) for i in range(len(seeds))]
        expected = data.expected_banks
    else:
        from .verify import reference_banks_batch
        init_batch = [ck.random_banks(s) for s in seeds]
        expected = reference_banks_batch(
            ck.dfg,
            {k: np.stack([ib[k] for ib in init_batch])
             for k in init_batch[0]},
            ck.invocations, ck.mapped_iters,
            ck.arch.datapath_bits)
    return init_batch, expected


def _check_batch(ck: CompiledKernel, seeds: Sequence[int],
                 init_batch, expected, finals) -> None:
    """Word-for-word comparison of simulated final banks against the
    oracle: live-out banks match ``expected``, every other bank comes back
    untouched.  Raises AssertionError naming the first offending
    (seed, bank, words)."""
    live = set(ck.liveout_banks())
    for i, (seed, final) in enumerate(zip(seeds, finals)):
        for bank in sorted(final):
            got = np.asarray(final[bank])
            # non-liveout banks have no oracle data to compare; they
            # must simply come back untouched
            exp = np.asarray(expected[bank][i] if bank in live
                             else init_batch[i][bank])
            if not np.array_equal(got, exp):
                bad = np.nonzero(got != exp)[0][:8]
                raise AssertionError(
                    f"{ck.name} (II={ck.II}, seed={seed}): batched "
                    f"simulation mismatch in {bank} at words "
                    f"{bad.tolist()}: got {got[bad]}, want {exp[bad]}")


def verify_stacked(kernels: Sequence[CompiledKernel],
                   seeds: Sequence[int] = (0,),
                   check_dfg: bool = True) -> List[CompiledKernel]:
    """Verify many compiled kernels over one seed batch, stacking every
    group of configs that shares a shape bucket
    (:func:`~repro.core.simulator.stack_signature`) into a single
    multi-architecture XLA launch (:func:`simulate_multi`).

    The oracles, the comparison and the error messages are exactly
    ``verify_batch``'s — only the launch count changes, which is what
    makes this the throughput path of design-space search evaluation
    (``BENCH_dse_search``'s evaluated-points-per-second headline).
    Raises AssertionError on the first mismatch; returns the kernels in
    input order.
    """
    from .simulator import simulate_multi, stack_signature
    kernels = list(kernels)
    seeds = list(seeds)
    if not seeds or not kernels:
        return kernels
    from .verify import check_enabled
    if check_enabled():
        from ..check import assert_clean
        for ck in kernels:
            assert_clean(ck)
    groups: Dict[tuple, List[int]] = {}
    for idx, ck in enumerate(kernels):
        sig = stack_signature(ck.cfg, ck.mapped_iters,
                              len(ck.invocations))
        groups.setdefault(sig, []).append(idx)
    for sig in sorted(groups):
        idxs = groups[sig]
        prep = [(kernels[i],) + _batch_oracle(kernels[i], seeds, check_dfg)
                for i in idxs]
        finals = simulate_multi(
            [(ck.cfg, init_batch, ck.invocations)
             for ck, init_batch, _exp in prep],
            n_iters=kernels[idxs[0]].mapped_iters)
        for (ck, init_batch, expected), f in zip(prep, finals):
            _check_batch(ck, seeds, init_batch, expected, f)
    from .verify import xval_enabled
    if xval_enabled():
        from ..isa.xval import cross_validate
        for ck in kernels:
            cross_validate(ck, seeds=seeds)
    return kernels


# --------------------------------------------------------------------------
class Toolchain:
    """The staged compile pipeline with artifact caching.

    arch:      default target for helpers; ``compile`` always maps a spec
               onto the architecture the spec was built against.
    options:   MapperOptions shared by every compile from this toolchain.
    cache_dir: on-disk artifact cache; None -> $MORPHER_CACHE_DIR or
               ~/.cache/morpher-toolchain, "" -> disk cache disabled.
    """

    def __init__(self, arch: Optional[CGRAArch] = None,
                 options: Optional[MapperOptions] = None,
                 cache_dir: Optional[str] = None):
        self.arch = arch
        self.options = options or MapperOptions()
        self.cache_dir = (default_cache_dir() if cache_dir is None
                          else cache_dir)
        self._memo: Dict[str, CompiledKernel] = {}
        self._memo_err: Dict[str, str] = {}
        self._lock = threading.Lock()
        # recovery ledger of the most recent compile_many fan-out (a
        # dist.fleet.FleetReport), None before the first one / after a
        # FleetError degradation — sweeps surface it in their logs
        self.last_fleet_report = None

    # ----------------------------------------------------------- cache I/O
    def _cache_path(self, key: str) -> Optional[str]:
        if not self.cache_dir:
            return None
        return os.path.join(self.cache_dir, f"{key}.json")

    def _error_path(self, key: str) -> Optional[str]:
        if not self.cache_dir:
            return None
        return os.path.join(self.cache_dir, f"{key}.err.json")

    def _cache_load(self, key: str) -> Optional[CompiledKernel]:
        path = self._cache_path(key)
        if path is None or not os.path.exists(path):
            return None
        try:
            with open(path, "r", encoding="utf-8") as f:
                ck = CompiledKernel.from_json(f.read())
        except (OSError, ValueError, KeyError, TypeError,
                json.JSONDecodeError):
            return None  # corrupt/stale artifact: fall through to recompile
        ck.from_cache = True
        return ck

    def _cache_store(self, key: str, ck: CompiledKernel) -> None:
        path = self._cache_path(key)
        if path is None:
            return
        tmp = None
        try:
            os.makedirs(self.cache_dir, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=self.cache_dir, suffix=".tmp")
            with os.fdopen(fd, "w", encoding="utf-8") as f:
                f.write(ck.to_json())
            os.replace(tmp, path)  # atomic: concurrent compilers race safely
            tmp = None
        except OSError:
            pass  # cache is an optimization; never fail the compile
        finally:
            if tmp is not None:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass

    def _cache_load_error(self, key: str) -> Optional[str]:
        """A memoized MapError message for this content address, if any
        (the mapper is deterministic: same inputs, same failure)."""
        with self._lock:
            if key in self._memo_err:
                return self._memo_err[key]
        path = self._error_path(key)
        if path is None or not os.path.exists(path):
            return None
        try:
            with open(path, "r", encoding="utf-8") as f:
                d = json.load(f)
            if d.get("version") != ARTIFACT_VERSION:
                return None
            err = str(d["map_error"])
        except (OSError, ValueError, KeyError, TypeError,
                json.JSONDecodeError):
            return None
        with self._lock:
            self._memo_err[key] = err
        return err

    def _cache_store_error(self, key: str, msg: str,
                           opt: MapperOptions) -> None:
        if opt.time_budget_s is not None:
            # a budget-limited failure is wall-clock-dependent, not a
            # property of the content address: a retry on an idle machine
            # may map fine, so it must never become a sticky verdict
            return
        with self._lock:
            self._memo_err[key] = msg
        path = self._error_path(key)
        if path is None:
            return
        tmp = None
        try:
            os.makedirs(self.cache_dir, exist_ok=True)
            fd, tmp = tempfile.mkstemp(dir=self.cache_dir, suffix=".tmp")
            with os.fdopen(fd, "w", encoding="utf-8") as f:
                f.write(json.dumps({"version": ARTIFACT_VERSION,
                                    "map_error": msg}))
            os.replace(tmp, path)
            tmp = None
        except OSError:
            pass  # cache is an optimization; never fail the compile
        finally:
            if tmp is not None:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass

    def cached_map_error(self, spec,
                         options: Optional[MapperOptions] = None
                         ) -> Optional[str]:
        """The memoized MapError message for this compile, if one is on
        record — how a sweep reports *why* a point was infeasible (op
        support, bank reachability, II escalation) instead of a generic
        "unmappable"."""
        spec = self._bind(spec)
        return self._cache_load_error(
            spec_cache_key(spec, options or self.options))

    def clear_cache(self) -> None:
        self._memo.clear()
        self._memo_err.clear()
        if self.cache_dir and os.path.isdir(self.cache_dir):
            for fn in os.listdir(self.cache_dir):
                if fn.endswith((".json", ".tmp")):
                    try:
                        os.unlink(os.path.join(self.cache_dir, fn))
                    except OSError:
                        pass

    # ------------------------------------------------------------- compile
    def _lookup(self, key: str, spec: KernelSpec
                ) -> Optional[CompiledKernel]:
        with self._lock:
            hit = self._memo.get(key)
        if hit is not None:
            return hit
        hit = self._cache_load(key)
        if hit is not None:
            hit.spec = spec
            with self._lock:
                self._memo[key] = hit
        return hit

    def load_artifact(self, cache_key: str) -> Optional[CompiledKernel]:
        """Resolve a compiled artifact by its content address: in-process
        memo first, then the on-disk cache.  Returns None when the key is
        unknown — how serve plans serialized with kernel *refs* instead of
        embedded artifacts (``ServePlan.to_json(embed_kernels=False)``)
        re-resolve their kernels on load."""
        with self._lock:
            hit = self._memo.get(cache_key)
        if hit is not None:
            return hit
        return self._cache_load(cache_key)

    def _finish(self, spec: KernelSpec, opt: MapperOptions, key: str,
                mapping: Mapping, cfg: SimConfig,
                use_cache: bool) -> CompiledKernel:
        ck = CompiledKernel(
            name=spec.name, arch=spec.arch, dfg=spec.dfg, layout=spec.layout,
            mapping=mapping, cfg=cfg, mapped_iters=spec.mapped_iters,
            invocations=spec.invocations, meta=dict(spec.meta),
            options=opt, cache_key=key, spec=spec)
        if use_cache:
            self._cache_store(key, ck)
            with self._lock:
                self._memo[key] = ck
        return ck

    def _bind(self, spec) -> KernelSpec:
        """Accept traced front-end kernels: an arch-deferred DSL program
        (anything exposing ``bind(arch)``, e.g.
        ``repro.frontend.KernelProgram``) is traced against this
        toolchain's architecture here."""
        if not isinstance(spec, KernelSpec) and hasattr(spec, "bind"):
            return spec.bind(self.arch)
        return spec

    def compile(self, spec: KernelSpec,
                options: Optional[MapperOptions] = None,
                use_cache: bool = True) -> CompiledKernel:
        """KernelSpec (or frontend KernelProgram) -> CompiledKernel
        (map + generate configuration).

        Memoized in-process and through the content-addressed disk cache;
        a hit returns without re-running placement/routing.
        """
        spec = self._bind(spec)
        opt = options or self.options
        key = spec_cache_key(spec, opt)
        if use_cache:
            hit = self._lookup(key, spec)
            if hit is not None:
                return hit
            err = self._cache_load_error(key)
            if err is not None:
                # err already carries the kernel name (mapper formatting)
                raise MapError(f"{err} [cached result]")
        try:
            mapping = map_kernel_opts(spec.dfg, spec.arch, spec.layout, opt)
            cfg = generate_config(mapping, spec.layout)
        except (MapError, ConfigConflict) as e:
            if use_cache:
                self._cache_store_error(key, _compile_error_str(e), opt)
            raise MapError(_compile_error_str(e)) from e
        return self._finish(spec, opt, key, mapping, cfg, use_cache)

    def compile_many(self, specs: Iterable[KernelSpec],
                     options: Optional[MapperOptions] = None,
                     jobs: Optional[int] = None,
                     use_cache: bool = True,
                     allow_unmapped: bool = False,
                     fleet=None
                     ) -> List[Optional[CompiledKernel]]:
        """Fan independent kernel compiles out across worker processes.

        Cache hits resolve immediately; misses (deduplicated by content
        address) run concurrently.  The mapper is pure Python and therefore
        GIL-bound, so the fan-out uses processes, bridging each compile
        through its JSON form (specs carry unpicklable closures; their
        structural parts round-trip losslessly).  Falls back to sequential
        in-process compiles if no process pool is available.

        The fan-out runs through the supervised fleet runner
        (:func:`repro.dist.fleet.run_fleet`): every compile unit gets a
        deadline (``MORPHER_TASK_TIMEOUT_S``), bounded deterministic
        retry, and transparent recovery from killed workers — a lost
        worker re-queues its units on a rebuilt pool instead of crashing
        the sweep.  Content-addressing makes units idempotent, so
        recovery is exact.  Pass a ``fleet``
        :class:`~repro.dist.fleet.FleetConfig` to shard units across
        worker groups (elastic membership, work stealing) or to inject
        faults; the last run's recovery ledger is on
        ``self.last_fleet_report``.

        Specs may target heterogeneous architectures — each compile carries
        its own arch — which is how design-space sweeps fan one kernel
        suite across many CGRA variants.  With ``allow_unmapped=True`` an
        infeasible (arch, kernel) pair yields ``None`` at its index instead
        of raising MapError, so one impossible variant cannot abort a
        sweep; the default remains raise-on-failure.  Failures are
        memoized like successes (deterministic mapper, deterministic
        failure), so a sweep re-run does not re-pay the II escalation of
        its infeasible points.
        """
        specs = [self._bind(s) for s in specs]
        opt = options or self.options
        self.last_fleet_report = None   # set again iff a fan-out runs
        keys = [spec_cache_key(s, opt) for s in specs]
        results: List[Optional[CompiledKernel]] = [None] * len(specs)
        todo: Dict[str, List[int]] = {}      # cache_key -> spec indices

        def unmapped(idxs: List[int], err: str) -> None:
            if not allow_unmapped:
                # err already carries the kernel name (mapper formatting)
                raise MapError(err)

        for i, (spec, key) in enumerate(zip(specs, keys)):
            hit = self._lookup(key, spec) if use_cache else None
            if hit is not None:
                results[i] = hit
                continue
            err = self._cache_load_error(key) if use_cache else None
            if err is not None:
                unmapped([i], f"{err} [cached result]")
                continue    # allow_unmapped: stays None
            todo.setdefault(key, []).append(i)

        def finish(key: str, idxs: List[int], mapping: Mapping,
                   cfg: SimConfig) -> None:
            ck = self._finish(specs[idxs[0]], opt, key, mapping, cfg,
                              use_cache)
            for i in idxs:
                results[i] = ck

        if jobs is None:
            jobs = min(len(todo), os.cpu_count() or 1) or 1
        if fleet is not None:
            # an explicit fleet config is a request to shard: even a
            # 1-CPU host runs the supervised fan-out so fault injection
            # and the recovery paths behave identically everywhere
            jobs = max(jobs, fleet.groups)
        order = list(todo.items())
        if len(order) > 1 and jobs > 1:
            payloads = [json.dumps({
                "dfg": specs[idxs[0]].dfg.to_json_dict(),
                "arch": json.loads(specs[idxs[0]].arch.to_json()),
                "layout": specs[idxs[0]].layout.to_json_dict(),
                "options": opt.to_json_dict(),
            }) for _key, idxs in order]
            # the supervised fleet runner sits on the shared pool (which
            # handles start-method selection, REPL-driver detection and
            # nested-worker suppression) and adds deadlines, retry and
            # killed-worker recovery; results=None means no fan-out is
            # available here — go sequential.  A unit failing past its
            # retry budget (FleetError) degrades the same way: the
            # sequential path is bit-identical by contract.
            from ..dist.fleet import FleetConfig, FleetError, run_fleet
            fcfg = fleet if fleet is not None else FleetConfig()
            if fcfg.max_inflight is None:
                import dataclasses
                fcfg = dataclasses.replace(fcfg, max_inflight=jobs)
            try:
                report = run_fleet(_compile_worker, payloads, fcfg,
                                   inline_fallback=False)
                outs = report.results
            except FleetError:
                report, outs = None, None
            self.last_fleet_report = report
            if outs is not None:
                for (key, idxs), out in zip(order, outs):
                    d = json.loads(out)
                    if "map_error" in d:
                        if use_cache:
                            self._cache_store_error(key, d["map_error"],
                                                    opt)
                        unmapped(idxs, d["map_error"])
                        continue
                    spec = specs[idxs[0]]
                    finish(key, idxs,
                           Mapping.from_json_dict(d["mapping"], spec.dfg,
                                                  spec.arch),
                           SimConfig.from_json(json.dumps(d["cfg"])))
                order = []
        for key, idxs in order:              # sequential path / fallback
            spec = specs[idxs[0]]
            try:
                mapping = map_kernel_opts(spec.dfg, spec.arch, spec.layout,
                                          opt)
                cfg = generate_config(mapping, spec.layout)
            except (MapError, ConfigConflict) as e:
                if use_cache:
                    self._cache_store_error(key, _compile_error_str(e), opt)
                unmapped(idxs, _compile_error_str(e))
                continue
            finish(key, idxs, mapping, cfg)
        return results

    # --------------------------------------------- instruction-stream export
    def export_streams(self, kernel, out_dir: str,
                       options: Optional[MapperOptions] = None
                       ) -> Dict[str, str]:
        """Lower a kernel to the per-PE instruction-stream artifact family
        (``repro.isa``): ``instructions.csv`` + ``kernel.asm`` +
        ``stream_manifest.json`` written under ``out_dir``.

        ``kernel`` may be a :class:`CompiledKernel`, a spec, or an
        arch-deferred frontend program (compiled here first; compiles are
        cache hits after the first).  The artifacts are byte-deterministic
        — two cold exports of the same kernel are ``cmp``-identical —
        which is what makes them a deployment format rather than a debug
        dump.  Returns filename -> written path.
        """
        ck = (kernel if isinstance(kernel, CompiledKernel)
              else self.compile(kernel, options))
        from ..isa.encode import export_streams
        return export_streams(ck, out_dir)

    def cross_validate(self, kernel, seeds: Sequence[int] = (0,),
                       options: Optional[MapperOptions] = None
                       ) -> CompiledKernel:
        """Run the exporter -> standalone-interpreter loop and assert the
        final memory image is bit-identical to ``simulate()`` for every
        seed — the flow's independent second oracle (the interpreter
        shares no code with the JAX simulator).  Raises AssertionError on
        the first diverging (seed, bank, word); returns the compiled
        kernel."""
        ck = (kernel if isinstance(kernel, CompiledKernel)
              else self.compile(kernel, options))
        from ..isa.xval import cross_validate
        cross_validate(ck, seeds=seeds)
        return ck

    def check(self, kernel, options: Optional[MapperOptions] = None):
        """Static legality audit (``repro.check``): run the mapping, config
        and instruction-stream checkers over one kernel without simulating
        it.  ``kernel`` may be a :class:`CompiledKernel`, a spec, or an
        arch-deferred frontend program (compiled here first).  Returns the
        list of :class:`~repro.check.Diagnostic` records — empty for a
        clean artifact (the ``MORPHER_CHECK=1`` contract)."""
        ck = (kernel if isinstance(kernel, CompiledKernel)
              else self.compile(kernel, options))
        from ..check import check_kernel
        return check_kernel(ck)

    def verify_many(self, kernels: Iterable, seeds: Sequence[int] = (0,),
                    check_dfg: bool = True,
                    jobs: Optional[int] = None,
                    fleet=None,
                    stacked: bool = False) -> List[CompiledKernel]:
        """Batch-verify many kernels over many seeds — the verification-
        fleet entry point.

        ``kernels`` may mix :class:`CompiledKernel` artifacts, specs and
        arch-deferred frontend programs; anything uncompiled goes through
        ``compile_many`` first — that process fan-out is the fleet-
        supervised stage (pass a ``fleet``
        :class:`~repro.dist.fleet.FleetConfig` to shard it across worker
        groups / inject faults; a lost worker re-queues its compile units
        instead of crashing the fleet).  Each kernel then verifies every
        seed in one ``verify_batch`` pass *in this process*: simulation
        rides the process-wide shape-bucketed XLA executable cache and
        the spec's golden-model oracle, both of which a child process
        would have to rebuild — and the bit-exactness contract pins this
        path, so it must not silently swap oracles under distribution.
        Raises AssertionError on the first mismatch; returns the compiled
        kernels in input order.

        ``stacked=True`` routes the simulations through
        :func:`verify_stacked`: kernels sharing a shape bucket batch
        their *config planes* into one multi-architecture launch — same
        oracles, same word-for-word comparison, fewer launches.
        """
        items = list(kernels)
        compiled: List[Optional[CompiledKernel]] = [
            k if isinstance(k, CompiledKernel) else None for k in items]
        todo = [k for k, ck in zip(items, compiled) if ck is None]
        if todo:
            done = iter(self.compile_many(todo, jobs=jobs, fleet=fleet))
            compiled = [ck if ck is not None else next(done)
                        for ck in compiled]
        if stacked:
            verify_stacked(compiled, seeds, check_dfg=check_dfg)
        else:
            for ck in compiled:
                ck.verify_batch(seeds, check_dfg=check_dfg)
        return compiled


_default: Optional[Toolchain] = None
_default_lock = threading.Lock()


def default_toolchain() -> Toolchain:
    """Process-wide shared Toolchain with default MapperOptions and the
    standard cache location — the one-liner entry into the whole flow."""
    global _default
    with _default_lock:
        if _default is None:
            _default = Toolchain()
        return _default
