"""Functional verification flow (paper section IV-C).

Morpher instruments the application to record live-in variables (arrays,
outer-loop iteration variables) and live-out arrays by running it on a
general-purpose processor, then checks the post-simulation memory content
against the expected results.  The same three-step contract here:

  1. *test-data generation*: initialize bank images, record the live-in
     values of every host invocation, and compute expected live-outs with
     the kernel's golden (numpy) model;
  2. additionally cross-check the DFG itself by sequential dataflow
     execution (`DFG.reference_execute`) — this separates "the DFG is the
     right program" from "the mapping executes the DFG correctly";
  3. simulate the mapped configuration cycle-by-cycle and compare the
     final memory images word-for-word.

The canonical entry point is ``Toolchain.compile(spec).verify(seed)``
(`repro.core.toolchain`); this module provides the test-data generator and
the DFG-semantics cross-check it uses, plus the deprecated
``verify_mapping`` shim.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from .config_gen import SimConfig, generate_config
from .kernels_lib import KernelSpec
from .mapper import Mapping


@dataclass
class TestData:
    init_banks: Dict[str, np.ndarray]
    expected_banks: Dict[str, np.ndarray]


def generate_test_data(spec: KernelSpec, seed: int = 0) -> TestData:
    rng = np.random.default_rng(seed)
    init = spec.init_banks(rng)
    expected = spec.golden(init)
    return TestData(init_banks=init, expected_banks=expected)


def reference_banks(dfg, init_banks, invocations, mapped_iters: int,
                    bits: int) -> Dict[str, list]:
    """Fold sequential DFG reference execution over all invocations — the
    closure-free oracle shared by the DFG cross-check and deserialized-
    artifact verification."""
    banks = {k: [int(x) for x in v] for k, v in init_banks.items()}
    for inv in invocations:
        banks = dfg.reference_execute(mapped_iters, banks, inv, bits=bits)
    return banks


def check_dfg_semantics(spec: KernelSpec, data: TestData) -> None:
    """Step 2: sequential DFG execution must match the golden model."""
    banks = reference_banks(spec.dfg, data.init_banks, spec.invocations,
                            spec.mapped_iters, spec.arch.datapath_bits)
    for name, exp in data.expected_banks.items():
        got = np.asarray(banks[name])
        if not np.array_equal(got, exp):
            bad = np.nonzero(got != np.asarray(exp))[0][:8]
            raise AssertionError(
                f"{spec.name}: DFG reference mismatch in {name} at words "
                f"{bad.tolist()}: got {got[bad]}, want {np.asarray(exp)[bad]}")


def verify_mapping(spec: KernelSpec, mapping: Optional[Mapping] = None,
                   cfg: Optional[SimConfig] = None, seed: int = 0,
                   check_dfg: bool = True) -> Mapping:
    """Deprecated shim — use ``Toolchain.compile(spec).verify(seed)``.

    Returns the (possibly freshly computed) mapping; raises AssertionError
    on any mismatch, exactly as before.
    """
    warnings.warn(
        "verify_mapping(spec, ...) is deprecated; use "
        "repro.core.toolchain.Toolchain.compile(spec).verify(seed)",
        DeprecationWarning, stacklevel=2)
    from .mapper import MapperOptions
    from .toolchain import CompiledKernel, Toolchain
    # legacy semantics exactly: a fresh map with the old map_kernel default
    # (ii_max=64) and no artifact-cache involvement
    legacy = MapperOptions(ii_max=64)
    if mapping is None:
        ck = Toolchain(options=legacy, cache_dir="").compile(spec)
    else:
        ck = CompiledKernel(
            name=spec.name, arch=spec.arch, dfg=spec.dfg, layout=spec.layout,
            mapping=mapping, cfg=cfg or generate_config(mapping, spec.layout),
            mapped_iters=spec.mapped_iters, invocations=spec.invocations,
            meta=dict(spec.meta), options=legacy, cache_key="", spec=spec)
    ck.verify(seed=seed, check_dfg=check_dfg)
    return ck.mapping
