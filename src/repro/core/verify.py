"""Functional verification flow (paper section IV-C).

Morpher instruments the application to record live-in variables (arrays,
outer-loop iteration variables) and live-out arrays by running it on a
general-purpose processor, then checks the post-simulation memory content
against the expected results.  The same three-step contract here:

  1. *test-data generation*: initialize bank images, record the live-in
     values of every host invocation, and compute expected live-outs with
     the kernel's golden (numpy) model;
  2. additionally cross-check the DFG itself by sequential dataflow
     execution (`DFG.reference_execute`) — this separates "the DFG is the
     right program" from "the mapping executes the DFG correctly";
  3. simulate the mapped configuration cycle-by-cycle and compare the
     final memory images word-for-word.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from .config_gen import SimConfig, generate_config
from .kernels_lib import KernelSpec
from .mapper import Mapping, map_kernel
from .simulator import simulate


@dataclass
class TestData:
    init_banks: Dict[str, np.ndarray]
    expected_banks: Dict[str, np.ndarray]


def generate_test_data(spec: KernelSpec, seed: int = 0) -> TestData:
    rng = np.random.default_rng(seed)
    init = spec.init_banks(rng)
    expected = spec.golden(init)
    return TestData(init_banks=init, expected_banks=expected)


def check_dfg_semantics(spec: KernelSpec, data: TestData) -> None:
    """Step 2: sequential DFG execution must match the golden model."""
    banks = {k: [int(x) for x in v] for k, v in data.init_banks.items()}
    for inv in spec.invocations:
        banks = spec.dfg.reference_execute(spec.mapped_iters, banks, inv,
                                           bits=spec.arch.datapath_bits)
    for name, exp in data.expected_banks.items():
        got = np.asarray(banks[name])
        if not np.array_equal(got, exp):
            bad = np.nonzero(got != np.asarray(exp))[0][:8]
            raise AssertionError(
                f"{spec.name}: DFG reference mismatch in {name} at words "
                f"{bad.tolist()}: got {got[bad]}, want {np.asarray(exp)[bad]}")


def verify_mapping(spec: KernelSpec, mapping: Optional[Mapping] = None,
                   cfg: Optional[SimConfig] = None, seed: int = 0,
                   check_dfg: bool = True) -> Mapping:
    """Full paper-IV-C flow.  Returns the (possibly freshly computed)
    mapping; raises AssertionError on any mismatch."""
    data = generate_test_data(spec, seed)
    if check_dfg:
        check_dfg_semantics(spec, data)
    if mapping is None:
        mapping = map_kernel(spec.dfg, spec.arch, spec.layout)
    if cfg is None:
        cfg = generate_config(mapping, spec.layout)
    final = simulate(cfg, data.init_banks, spec.invocations,
                     spec.mapped_iters)
    for name, exp in data.expected_banks.items():
        got = final[name]
        if not np.array_equal(got, np.asarray(exp)):
            bad = np.nonzero(got != np.asarray(exp))[0][:8]
            raise AssertionError(
                f"{spec.name} (II={mapping.II}): simulation mismatch in "
                f"{name} at words {bad.tolist()}: got {got[bad]}, "
                f"want {np.asarray(exp)[bad]}")
    return mapping
