"""Functional verification flow (paper section IV-C).

Morpher instruments the application to record live-in variables (arrays,
outer-loop iteration variables) and live-out arrays by running it on a
general-purpose processor, then checks the post-simulation memory content
against the expected results.  The same three-step contract here:

  1. *test-data generation*: initialize bank images, record the live-in
     values of every host invocation, and compute expected live-outs with
     the kernel's golden (numpy) model;
  2. additionally cross-check the DFG itself by sequential dataflow
     execution (`DFG.reference_execute`) — this separates "the DFG is the
     right program" from "the mapping executes the DFG correctly";
  3. simulate the mapped configuration cycle-by-cycle and compare the
     final memory images word-for-word.

The canonical entry point is ``Toolchain.compile(spec).verify(seed)``
(`repro.core.toolchain`); this module provides the test-data generator and
the DFG-semantics cross-check it uses, plus the deprecated
``verify_mapping`` shim.
"""
from __future__ import annotations

import os
import warnings
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from .config_gen import SimConfig, generate_config
from .kernels_lib import KernelSpec
from .mapper import Mapping


def xval_enabled() -> bool:
    """Opt-in second oracle: ``MORPHER_XVAL=1`` routes every verify through
    the exported instruction stream + standalone interpreter
    (``repro.isa.xval``) in addition to the simulator comparison, so a
    verify pass additionally certifies the deployment artifact."""
    return os.environ.get("MORPHER_XVAL", "") == "1"


def check_enabled() -> bool:
    """Opt-in static gate: ``MORPHER_CHECK=1`` runs the ``repro.check``
    static legality checker at the top of every verify (and as a DSE
    pre-screen).  Clean compiled artifacts must be diagnostic-free — the
    PR-10 contract — so under this gate a verify additionally certifies
    the artifact's structural/temporal legality without extra simulation."""
    return os.environ.get("MORPHER_CHECK", "") == "1"


@dataclass
class TestData:
    init_banks: Dict[str, np.ndarray]
    expected_banks: Dict[str, np.ndarray]


@dataclass
class TestDataBatch:
    """All test vectors of one batched verification up front: bank images
    stacked along a leading seed axis, one row per seed."""
    seeds: List[int]
    init_banks: Dict[str, np.ndarray]       # [batch, words]
    expected_banks: Dict[str, np.ndarray]   # [batch, words]

    def init_row(self, i: int) -> Dict[str, np.ndarray]:
        return {k: v[i] for k, v in self.init_banks.items()}


def generate_test_data(spec: KernelSpec, seed: int = 0) -> TestData:
    rng = np.random.default_rng(seed)
    init = spec.init_banks(rng)
    expected = spec.golden(init)
    return TestData(init_banks=init, expected_banks=expected)


def generate_test_data_batch(spec: KernelSpec,
                             seeds: Sequence[int]) -> TestDataBatch:
    """Test vectors for every seed, stacked for the batched engine.

    Each row is drawn from that seed's own rng stream — bit-identical to
    ``generate_test_data(spec, seed)`` — so batched and sequential verify
    see the very same images; the numpy golden models are cheap, it is the
    DFG oracle and the simulator that are batch-vectorized downstream.
    """
    if not len(seeds):
        return TestDataBatch(seeds=[], init_banks={}, expected_banks={})
    datas = [generate_test_data(spec, s) for s in seeds]
    names = list(datas[0].init_banks)
    return TestDataBatch(
        seeds=list(seeds),
        init_banks={k: np.stack([np.asarray(d.init_banks[k])
                                 for d in datas]) for k in names},
        expected_banks={k: np.stack([np.asarray(d.expected_banks[k])
                                     for d in datas]) for k in names})


def reference_banks(dfg, init_banks, invocations, mapped_iters: int,
                    bits: int) -> Dict[str, list]:
    """Fold sequential DFG reference execution over all invocations — the
    closure-free oracle shared by the DFG cross-check and deserialized-
    artifact verification."""
    banks = {k: [int(x) for x in v] for k, v in init_banks.items()}
    for inv in invocations:
        banks = dfg.reference_execute(mapped_iters, banks, inv, bits=bits)
    return banks


def reference_banks_batch(dfg, init_banks, invocations, mapped_iters: int,
                          bits: int) -> Dict[str, np.ndarray]:
    """``reference_banks`` vectorized over the leading seed axis of
    ``init_banks`` ([batch, words] per bank) — one oracle pass for the
    whole batch and invocation sweep instead of one per (seed,
    invocation), so the oracle does not become the bottleneck of batched
    verification.  The heavy lifting runs on the JAX-lowered DFG executor
    (``repro.core.refexec``); ``DFG.reference_execute_batch`` is its
    bit-identical numpy reference (pinned by tests) and the fallback
    wherever JAX is unavailable."""
    try:
        from .refexec import reference_execute_jax
    except ImportError:
        return dfg.reference_execute_batch(
            mapped_iters, {k: np.asarray(v, dtype=np.int64)
                           for k, v in init_banks.items()},
            invocations, bits=bits)
    return reference_execute_jax(dfg, mapped_iters, init_banks,
                                 invocations, bits=bits)


def check_dfg_semantics(spec: KernelSpec, data: TestData) -> None:
    """Step 2: sequential DFG execution must match the golden model."""
    banks = reference_banks(spec.dfg, data.init_banks, spec.invocations,
                            spec.mapped_iters, spec.arch.datapath_bits)
    for name, exp in data.expected_banks.items():
        got = np.asarray(banks[name])
        if not np.array_equal(got, exp):
            bad = np.nonzero(got != np.asarray(exp))[0][:8]
            raise AssertionError(
                f"{spec.name}: DFG reference mismatch in {name} at words "
                f"{bad.tolist()}: got {got[bad]}, want {np.asarray(exp)[bad]}")


def check_dfg_semantics_batch(spec: KernelSpec, data: TestDataBatch) -> None:
    """Step 2 over a whole seed batch in one vectorized oracle pass."""
    banks = reference_banks_batch(spec.dfg, data.init_banks,
                                  spec.invocations, spec.mapped_iters,
                                  spec.arch.datapath_bits)
    for name, exp in data.expected_banks.items():
        got = np.asarray(banks[name])
        exp = np.asarray(exp)
        if not np.array_equal(got, exp):
            row = int(np.nonzero(got != exp)[0][0])
            bad = np.nonzero(got[row] != exp[row])[0][:8]
            raise AssertionError(
                f"{spec.name}: DFG reference mismatch for seed "
                f"{data.seeds[row]} in {name} at words {bad.tolist()}: "
                f"got {got[row][bad]}, want {exp[row][bad]}")


def verify_mapping(spec: KernelSpec, mapping: Optional[Mapping] = None,
                   cfg: Optional[SimConfig] = None, seed: int = 0,
                   check_dfg: bool = True) -> Mapping:
    """Deprecated shim — use ``Toolchain.compile(spec).verify(seed)``.

    Returns the (possibly freshly computed) mapping; raises AssertionError
    on any mismatch, exactly as before.
    """
    warnings.warn(
        "verify_mapping(spec, ...) is deprecated; use "
        "repro.core.toolchain.Toolchain.compile(spec).verify(seed)",
        DeprecationWarning, stacklevel=2)
    from .mapper import MapperOptions
    from .toolchain import CompiledKernel, Toolchain
    # legacy semantics exactly: a fresh map with the old map_kernel default
    # (ii_max=64) and no artifact-cache involvement
    legacy = MapperOptions(ii_max=64)
    if mapping is None:
        ck = Toolchain(options=legacy, cache_dir="").compile(spec)
    else:
        ck = CompiledKernel(
            name=spec.name, arch=spec.arch, dfg=spec.dfg, layout=spec.layout,
            mapping=mapping, cfg=cfg or generate_config(mapping, spec.layout),
            mapped_iters=spec.mapped_iters, invocations=spec.invocations,
            meta=dict(spec.meta), options=legacy, cache_key="", spec=spec)
    ck.verify(seed=seed, check_dfg=check_dfg)
    return ck.mapping
