"""Tokenized data pipeline: deterministic per-host sharding + background
prefetch.

Sources: a memory-mapped flat token file (one giant uint16/uint32 stream,
the standard packed-LM format) or a synthetic deterministic stream (CI /
benchmarks).  Every host reads only its own slice — deterministic
host-indexed sharding means a straggling or restarted host re-derives its
stream from (step, host_id) alone: no shuffle barrier, no data-server
state, which is the straggler-mitigation property the trainer relies on.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass
class DataConfig:
    seq_len: int
    global_batch: int
    vocab: int
    n_hosts: int = 1
    host_id: int = 0
    token_file: Optional[str] = None
    dtype: str = "int32"
    seed: int = 1234


class TokenSource:
    """Deterministic, restartable token stream for one host."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        assert cfg.global_batch % cfg.n_hosts == 0
        self.local_batch = cfg.global_batch // cfg.n_hosts
        self.tokens = None
        if cfg.token_file:
            self.tokens = np.memmap(cfg.token_file, dtype=np.uint16,
                                    mode="r")

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        T = cfg.seq_len
        out = np.empty((self.local_batch, T + 1), dtype=np.int32)
        for i in range(self.local_batch):
            row = step * cfg.global_batch + cfg.host_id * self.local_batch + i
            if self.tokens is not None:
                n = len(self.tokens) - (T + 1)
                off = (row * 977) % max(1, n)
                out[i] = np.asarray(self.tokens[off:off + T + 1],
                                    dtype=np.int32)
            else:
                rng = np.random.default_rng(cfg.seed + row)
                out[i] = rng.integers(0, cfg.vocab, size=T + 1,
                                      dtype=np.int32)
        return {"inputs": out[:, :-1], "labels": out[:, 1:]}


class Prefetcher:
    """Background-thread prefetch queue (keeps the accelerator fed)."""

    def __init__(self, source: TokenSource, start_step: int = 0,
                 depth: int = 2):
        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
