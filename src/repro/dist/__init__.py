"""Sharded, elastic, fault-tolerant fleets (ROADMAP item 2).

Four modules, one robustness contract — a fleet-scale operation with
injected worker loss emits byte-identical artifacts to an undisturbed
single-process run:

  * ``elastic``  — heartbeat liveness, surviving-mesh planning, resume
    planning (the API ``tests/test_substrate.py`` pins);
  * ``sharding`` — shape -> PartitionSpec rules the launch specs import;
  * ``fleet``    — supervised work-queue runner over the shared process
    pool: deadlines, deterministic retry/backoff, pool rebuilds,
    heartbeat eviction with work stealing, sequential degradation;
  * ``faults``   — deterministic fault injection (seeded worker kills,
    stragglers, muted heartbeats, checkpoint corruption) so the failure
    paths are first-class tested code.

``sharding`` resolves lazily (PEP 562): it imports JAX, and fleet
*worker processes* import this package — they must stay cheap.
"""
from __future__ import annotations

import importlib

from . import elastic, faults, fleet  # noqa: F401  (light, JAX-free)


def __getattr__(name: str):
    if name == "sharding":
        return importlib.import_module(".sharding", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | {"sharding"})
