"""Elastic membership primitives for sweep and verification fleets.

Fleet-scale operations — DSE sweeps sharded across worker groups,
multi-seed verification fleets, multi-host training — survive member
loss through three small, deterministic mechanisms:

  * :class:`HeartbeatMonitor` — liveness tracking with an injectable
    clock.  Members ``beat`` on progress; anything silent for longer
    than ``timeout_s`` is reported by ``dead_hosts`` and can be evicted,
    with its outstanding work re-queued ("stolen") by the survivors.
  * :func:`best_mesh_shape` — after losing hosts, the largest (data,
    model) mesh the surviving device count supports: keep the requested
    model-parallel degree when it still divides, otherwise shrink it
    through its divisors (model-parallel groups must be whole).
  * :func:`resume_plan` — which checkpoint step to restart from given
    what survived on disk.

Everything here is pure bookkeeping: no sockets, no threads, no JAX —
the fleet runner (:mod:`repro.dist.fleet`) and the training launcher
both drive it with whatever clock and transport they own.
"""
from __future__ import annotations

import time
from typing import Dict, Hashable, List, Optional, Sequence, Tuple


class HeartbeatMonitor:
    """Tracks the last heartbeat per member against a staleness timeout.

    All methods accept ``now`` so callers (and tests) can inject a
    clock; when omitted, ``time.monotonic()`` is used.  Members are any
    hashable id — host ranks, fleet worker-group indices.
    """

    def __init__(self, timeout_s: float = 30.0):
        if timeout_s <= 0:
            raise ValueError(f"timeout_s must be positive, got {timeout_s}")
        self.timeout_s = float(timeout_s)
        self._last: Dict[Hashable, float] = {}
        self._evicted: set = set()

    def _now(self, now: Optional[float]) -> float:
        return time.monotonic() if now is None else now

    def beat(self, member: Hashable, now: Optional[float] = None) -> None:
        """Record a liveness signal from ``member``."""
        self._last[member] = self._now(now)

    def members(self) -> List[Hashable]:
        return sorted(self._last)

    def alive(self, member: Hashable, now: Optional[float] = None) -> bool:
        last = self._last.get(member)
        return (last is not None and member not in self._evicted
                and self._now(now) - last <= self.timeout_s)

    def all_alive(self, n: int, now: Optional[float] = None) -> bool:
        """True when members ``0..n-1`` have all beaten within the
        timeout (the launcher's "is the whole fleet up" check)."""
        now = self._now(now)
        return all(self.alive(m, now) for m in range(n))

    def dead_hosts(self, now: Optional[float] = None) -> List[Hashable]:
        """Members whose last beat is older than the timeout, sorted.
        Already-evicted members are not re-reported."""
        now = self._now(now)
        return sorted(m for m, last in self._last.items()
                      if m not in self._evicted
                      and now - last > self.timeout_s)

    def evict(self, member: Hashable) -> None:
        """Mark ``member`` as evicted: it stops appearing in
        ``dead_hosts`` and stays dead until it beats again."""
        self._evicted.add(member)

    def evicted(self) -> List[Hashable]:
        return sorted(self._evicted)

    def readmit(self, member: Hashable, now: Optional[float] = None) -> None:
        """An evicted member rejoined (elastic scale-up)."""
        self._evicted.discard(member)
        self.beat(member, now)


def best_mesh_shape(n_devices: int, model_parallel: int
                    ) -> Tuple[int, int]:
    """The (data, model) mesh for ``n_devices`` surviving devices.

    Keeps the requested model-parallel degree when it divides the device
    count; otherwise shrinks MP through its divisors (an MP group must be
    whole — a fractional group cannot hold a sharded layer).  Always
    succeeds: MP=1 divides anything.

    >>> best_mesh_shape(512, 16)
    (32, 16)
    >>> best_mesh_shape(500, 16)   # lost 12 hosts: shrink MP to 4
    (125, 4)
    """
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    if model_parallel < 1:
        raise ValueError(
            f"model_parallel must be >= 1, got {model_parallel}")
    for mp in range(model_parallel, 0, -1):
        if model_parallel % mp == 0 and n_devices % mp == 0:
            return (n_devices // mp, mp)
    return (n_devices, 1)  # unreachable: mp=1 always matches


def resume_plan(available_steps: Sequence[int],
                requested_step: Optional[int] = None) -> Optional[int]:
    """Which checkpoint step to restart from.

    The newest step not past ``requested_step`` (a partially-written or
    known-bad newer step must not be restored), or the newest overall
    when no step is requested.  None when nothing survived — the caller
    starts from scratch.
    """
    steps = sorted(available_steps)
    if requested_step is not None:
        steps = [s for s in steps if s <= requested_step]
    return steps[-1] if steps else None
