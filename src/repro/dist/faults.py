"""Deterministic fault injection for fleet runs.

The robustness contract of ``repro.dist`` is that a sweep with injected
worker loss emits byte-identical artifacts to an undisturbed run — which
is only testable if the failure paths are first-class, reproducible
code.  A :class:`FaultPlan` scripts the failures:

  * ``kill_units``   — the worker executing that unit calls ``os._exit``
    (an OS-killed worker: the parent sees ``BrokenProcessPool``);
  * ``delay_units``  — the worker sleeps past the task deadline (a
    straggler: the parent times the unit out and re-queues it);
  * ``mute_groups``  — completions from that worker group never beat the
    heartbeat monitor (a silent host: the group is evicted and its
    queued units stolen by the survivors).

Kills and delays fire **exactly once** per unit, coordinated across
worker processes through ``O_EXCL`` marker files in ``state_dir`` — the
retried attempt runs clean, so an injected fault perturbs scheduling but
never the result.  Mutes are unconditional for the whole run (a dead
host stays dead).  Plans serialize to JSON so the parent can ship them
to workers inside each work-unit payload.

Fault injection only simulates *worker* failures: the fleet's inline
(sequential-fallback) path never consults the plan — killing the parent
would be testing the OS, not the runner.

``corrupt_file`` rounds out the harness: deterministic byte corruption
for checkpoint-recovery tests.
"""
from __future__ import annotations

import json
import os
import random
import tempfile
import time
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple

KILL_EXIT_CODE = 86


@dataclass(frozen=True)
class FaultPlan:
    """A scripted, seeded set of failures for one fleet run."""
    kill_units: Tuple[int, ...] = ()
    delay_units: Tuple[Tuple[int, float], ...] = ()   # (unit, sleep_s)
    mute_groups: Tuple[int, ...] = ()
    state_dir: str = ""          # fire-once marker dir; "" = not armed

    # ------------------------------------------------------------- build
    @staticmethod
    def seeded(seed: int = 0, units: int = 8, kills: int = 1,
               delays: int = 1, delay_s: float = 30.0,
               mutes: int = 0, groups: int = 2) -> "FaultPlan":
        """A deterministic plan: ``kills`` + ``delays`` distinct units
        drawn from ``range(units)`` by a seeded RNG (armed and ready)."""
        rng = random.Random(seed)
        picks = rng.sample(range(max(units, kills + delays)),
                           kills + delays)
        muted = tuple(sorted(rng.sample(range(groups), mutes))) \
            if mutes else ()
        return FaultPlan(
            kill_units=tuple(sorted(picks[:kills])),
            delay_units=tuple((u, float(delay_s))
                              for u in sorted(picks[kills:])),
            mute_groups=muted).armed()

    def armed(self) -> "FaultPlan":
        """Plan with a fire-once marker directory attached (idempotent)."""
        if self.state_dir:
            return self
        return replace(self,
                       state_dir=tempfile.mkdtemp(prefix="morpher-faults-"))

    # -------------------------------------------------------------- wire
    def to_json_dict(self) -> Dict:
        return {"kill_units": list(self.kill_units),
                "delay_units": [[u, s] for u, s in self.delay_units],
                "mute_groups": list(self.mute_groups),
                "state_dir": self.state_dir}

    @staticmethod
    def from_json_dict(d: Dict) -> "FaultPlan":
        return FaultPlan(
            kill_units=tuple(d.get("kill_units", ())),
            delay_units=tuple((int(u), float(s))
                              for u, s in d.get("delay_units", ())),
            mute_groups=tuple(d.get("mute_groups", ())),
            state_dir=d.get("state_dir", ""))

    def to_json(self) -> str:
        return json.dumps(self.to_json_dict(), sort_keys=True)

    @staticmethod
    def from_json(s: str) -> "FaultPlan":
        return FaultPlan.from_json_dict(json.loads(s))

    # -------------------------------------------------------------- fire
    def _fire_once(self, tag: str) -> bool:
        """True exactly once per tag across every process sharing
        ``state_dir`` (O_EXCL marker); an unarmed plan never fires."""
        if not self.state_dir:
            return False
        try:
            os.makedirs(self.state_dir, exist_ok=True)
            fd = os.open(os.path.join(self.state_dir, tag),
                         os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            return False
        except OSError:
            return False
        os.close(fd)
        return True

    def fire_unit(self, unit: int) -> None:
        """Worker-side hook: inject this unit's scripted fault, if any
        and not already fired.  A kill does not return."""
        if unit in self.kill_units and self._fire_once(f"kill-{unit}"):
            os._exit(KILL_EXIT_CODE)
        for u, sleep_s in self.delay_units:
            if u == unit and self._fire_once(f"delay-{unit}"):
                time.sleep(sleep_s)

    def muted(self, group: int) -> bool:
        """Parent-side hook: is this worker group's heartbeat suppressed?"""
        return group in self.mute_groups


def corrupt_file(path: str, seed: int = 0, n_bytes: int = 8) -> None:
    """Deterministically flip ``n_bytes`` of ``path`` in place — the
    corrupted-checkpoint leg of the fault harness."""
    with open(path, "rb") as f:
        data = bytearray(f.read())
    if not data:
        data = bytearray(b"\x00")
    rng = random.Random(seed)
    for _ in range(n_bytes):
        data[rng.randrange(len(data))] ^= 0xFF
    with open(path, "wb") as f:
        f.write(bytes(data))


# ----------------------------------------------------------- test doubles
# Module-level so they pickle by reference into pool workers; kept free of
# heavy imports (workers importing this module must stay cheap).
def double(payload):
    """Well-behaved work function for fleet/pool tests."""
    return payload * 2


def kill_worker(payload):  # pragma: no cover - exits the process
    """Work function that kills its worker process (pool-recovery tests)."""
    os._exit(KILL_EXIT_CODE)
