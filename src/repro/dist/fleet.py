"""Supervised, elastic work-queue execution over the shared process pool.

``run_fleet(fn, payloads)`` is the fleet-scale counterpart of
``core.pool.process_map``: same contract (``[fn(p) for p in payloads]``,
order preserved), but each work unit is *supervised* —

  * per-task deadline (``MORPHER_TASK_TIMEOUT_S``): a straggler is timed
    out, recorded, and re-queued; its late result is harvested if it
    lands before the retry does;
  * bounded retry with a deterministic exponential backoff schedule
    (``MORPHER_FLEET_RETRIES``; see :func:`backoff_schedule`);
  * killed workers: ``BrokenProcessPool`` triggers a pool rebuild and
    re-queues every in-flight unit (not charged against their retry
    budget — the infrastructure died, not the unit);
  * worker groups with heartbeat-based elastic membership: units shard
    across ``groups`` logical groups, each with its own in-flight
    window; a group silent past the heartbeat timeout is evicted and
    its queued units are stolen by the survivors, exactly once;
  * graceful degradation: no pool (nested worker, REPL main, sandbox,
    or rebuild budget exhausted) -> sequential inline execution.

Work units MUST be idempotent (the toolchain's content-addressed cache
already makes compiles so): recovery re-executes units, and only
idempotence makes recovery exact — the robustness contract is that a
run with injected worker loss returns results identical to an
undisturbed sequential run.  Fault injection (:mod:`repro.dist.faults`)
rides inside each unit's payload, so the failure paths above are
first-class tested code.
"""
from __future__ import annotations

import os
import time
from collections import deque
from concurrent.futures import FIRST_COMPLETED
from concurrent.futures import wait as _futures_wait
from concurrent.futures.process import BrokenProcessPool
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..core import pool
from .elastic import HeartbeatMonitor
from .faults import FaultPlan

TIMEOUT_ENV = "MORPHER_TASK_TIMEOUT_S"
RETRIES_ENV = "MORPHER_FLEET_RETRIES"
DEFAULT_TIMEOUT_S = 300.0
DEFAULT_RETRIES = 2


class FleetError(RuntimeError):
    """A work unit failed beyond its retry budget.  Callers with a
    bit-identical sequential fallback (the toolchain) catch this and
    degrade; others propagate it."""


def backoff_schedule(retries: int, base_s: float = 0.05,
                     cap_s: float = 1.0) -> Tuple[float, ...]:
    """The deterministic re-queue delays: ``base * 2**attempt`` capped.
    A pure function of its arguments, so two runs retry on the same
    schedule — no jitter, by design (determinism beats thundering-herd
    avoidance at this scale)."""
    return tuple(min(cap_s, base_s * (2 ** k)) for k in range(retries))


@dataclass
class FleetConfig:
    """Knobs for one fleet run.  ``timeout_s``/``retries`` default to the
    ``MORPHER_TASK_TIMEOUT_S``/``MORPHER_FLEET_RETRIES`` env vars."""
    groups: int = 1                 # worker groups to shard units across
    timeout_s: Optional[float] = None      # per-task deadline
    retries: Optional[int] = None          # retry budget per unit
    backoff_base_s: float = 0.05
    backoff_cap_s: float = 1.0
    heartbeat_timeout_s: Optional[float] = None   # default: 2 * timeout_s
    poll_s: float = 0.02            # supervisor wakeup period
    max_inflight: Optional[int] = None     # default: pool width
    faults: Optional[FaultPlan] = None

    def resolved_timeout_s(self) -> float:
        if self.timeout_s is not None:
            return float(self.timeout_s)
        return float(os.environ.get(TIMEOUT_ENV, DEFAULT_TIMEOUT_S))

    def resolved_retries(self) -> int:
        if self.retries is not None:
            return int(self.retries)
        return int(os.environ.get(RETRIES_ENV, DEFAULT_RETRIES))

    def resolved_heartbeat_s(self, timeout_s: float) -> float:
        if self.heartbeat_timeout_s is not None:
            return float(self.heartbeat_timeout_s)
        return 2.0 * timeout_s


@dataclass
class FleetReport:
    """Results plus the recovery ledger of one run.  ``results`` is in
    payload order; the ledger (timings-dependent) is observability data
    and deliberately kept out of every byte-deterministic artifact."""
    results: Optional[List] = None
    sequential: bool = False        # ran on the inline fallback path
    retries: int = 0
    timeouts: List[Dict] = field(default_factory=list)  # {unit, attempt}
    pool_rebuilds: int = 0
    evicted_groups: List[int] = field(default_factory=list)
    stolen_units: List[int] = field(default_factory=list)

    def quiet(self) -> bool:
        """True when the run saw no faults, timeouts or degradation."""
        return not (self.retries or self.timeouts or self.pool_rebuilds
                    or self.evicted_groups or self.stolen_units
                    or self.sequential)

    def events_json_dict(self) -> Dict:
        return {"retries": self.retries,
                "timeouts": list(self.timeouts),
                "pool_rebuilds": self.pool_rebuilds,
                "evicted_groups": list(self.evicted_groups),
                "stolen_units": list(self.stolen_units),
                "sequential": self.sequential}


def _run_unit(blob):
    """Pool-worker entry: fire any scripted fault for this unit, then run
    the real work function."""
    unit, plan_dict, fn, payload = blob
    if plan_dict is not None:
        FaultPlan.from_json_dict(plan_dict).fire_unit(unit)
    return fn(payload)


def run_fleet(fn: Callable, payloads: Sequence,
              config: Optional[FleetConfig] = None, *,
              inline_fallback: bool = True,
              log: Optional[Callable[[str], None]] = None) -> FleetReport:
    """``[fn(p) for p in payloads]`` across supervised worker groups.

    ``fn`` must be a picklable module-level function over picklable
    payloads, and idempotent (units may re-execute during recovery).
    With ``inline_fallback=False``, an unavailable pool returns
    ``results=None`` instead of computing inline — for callers that own
    a cheaper sequential path (``Toolchain.compile_many``).

    Raises :class:`FleetError` when a unit keeps failing (exception or
    deadline) past its retry budget.  Worker loss, stragglers and
    evictions are recovered transparently and recorded in the report.
    """
    cfg = config or FleetConfig()
    say = log or (lambda s: None)
    rep = FleetReport()
    n = len(payloads)
    if n == 0:
        rep.results = []
        return rep
    ex = pool.shared_pool() if n >= 2 else None
    if ex is None:
        rep.sequential = True
        if inline_fallback:
            rep.results = [fn(p) for p in payloads]
        return rep

    faults = cfg.faults.armed() if cfg.faults is not None else None
    plan_dict = faults.to_json_dict() if faults is not None else None
    timeout_s = cfg.resolved_timeout_s()
    retries = cfg.resolved_retries()
    backoff = backoff_schedule(retries, cfg.backoff_base_s,
                               cfg.backoff_cap_s)
    groups = max(1, min(cfg.groups, n))
    hb = HeartbeatMonitor(timeout_s=cfg.resolved_heartbeat_s(timeout_s))
    workers = getattr(ex, "_max_workers", None) or (os.cpu_count() or 2)
    total_cap = max(1, cfg.max_inflight if cfg.max_inflight else workers)
    per_group = max(1, total_cap // groups)

    group_of = [i % groups for i in range(n)]
    queue = deque((i, 0, 0.0) for i in range(n))  # (unit, attempt, ready_at)
    results: List = [None] * n
    done = [False] * n
    n_done = 0
    inflight: Dict = {}     # future -> (unit, attempt, deadline, group)
    orphans: Dict = {}      # timed-out future -> unit (late results count)
    stolen: set = set()     # units re-queued by eviction (exactly once)
    evicted: set = set()
    rebuilds_left = retries + 1
    start = time.monotonic()
    for g in range(groups):
        hb.beat(g, now=start)

    def requeue(unit: int, attempt: int, charge: bool, why: str = "") -> None:
        # charge=True: the failure is attributable to the unit (raised /
        # deadline) and spends its retry budget; charge=False: the
        # infrastructure died under it (pool rebuild) — retried free.
        if charge:
            if attempt >= retries:
                raise FleetError(f"unit {unit} failed after "
                                 f"{attempt + 1} attempt(s): {why}")
            rep.retries += 1
            delay = backoff[attempt] if attempt < len(backoff) else 0.0
            queue.append((unit, attempt + 1, time.monotonic() + delay))
        else:
            queue.append((unit, attempt, 0.0))

    def drain_inline() -> FleetReport:
        # pool gone for good: finish the remaining units in-process (no
        # fault injection inline — the plan scripts *worker* failures)
        rep.sequential = True
        say(f"# fleet: pool unavailable, draining "
            f"{n - n_done} unit(s) sequentially")
        for i in range(n):
            if not done[i]:
                results[i] = fn(payloads[i])
                done[i] = True
        rep.results = results
        return rep

    try:
        while n_done < n:
            now = time.monotonic()
            # ------------------------------------------------- submission
            cap = {g: per_group for g in range(groups)}
            for (_u, _a, _dl, g) in inflight.values():
                cap[g] = cap.get(g, per_group) - 1
            broken = False
            skipped: List[Tuple[int, int, float]] = []
            for _ in range(len(queue)):
                if len(inflight) >= total_cap:
                    break
                unit, attempt, ready_at = queue.popleft()
                if done[unit]:
                    continue
                g = group_of[unit]
                if g in evicted:      # retries of an evicted group's
                    g = min(x for x in range(groups)   # units run on the
                            if x not in evicted)       # survivors
                    group_of[unit] = g
                if ready_at > now or cap.get(g, 0) <= 0:
                    skipped.append((unit, attempt, ready_at))
                    continue
                try:
                    fut = ex.submit(_run_unit,
                                    (unit, plan_dict, fn, payloads[unit]))
                except (BrokenProcessPool, RuntimeError):
                    skipped.append((unit, attempt, ready_at))
                    broken = True
                    break
                cap[g] -= 1
                inflight[fut] = (unit, attempt, now + timeout_s, g)
            queue.extendleft(reversed(skipped))

            # ------------------------------------------------ completions
            if not broken:
                watch = list(inflight) + list(orphans)
                if not watch:
                    if not queue:
                        break
                    wake = min(r for (_u, _a, r) in queue)
                    time.sleep(max(0.001, min(cfg.poll_s,
                                              wake - time.monotonic())))
                    continue
                done_futs, _ = _futures_wait(watch, timeout=cfg.poll_s,
                                             return_when=FIRST_COMPLETED)
            else:
                done_futs = {f for f in list(inflight) + list(orphans)
                             if f.done()}
            now = time.monotonic()
            for fut in done_futs:
                if fut in orphans:
                    unit = orphans.pop(fut)
                    try:
                        val = fut.result()
                    except BaseException:
                        continue      # its timeout already re-queued it
                    if not done[unit]:   # straggler's late result counts
                        results[unit] = val
                        done[unit] = True
                        n_done += 1
                    continue
                if fut not in inflight:
                    continue
                unit, attempt, _deadline, g = inflight.pop(fut)
                try:
                    val = fut.result()
                except BrokenProcessPool:
                    broken = True
                    requeue(unit, attempt, charge=False)
                except Exception as e:
                    requeue(unit, attempt, charge=True,
                            why=f"{type(e).__name__}: {e}")
                else:
                    if not done[unit]:
                        results[unit] = val
                        done[unit] = True
                        n_done += 1
                    if faults is None or not faults.muted(g):
                        hb.beat(g, now=now)

            # ----------------------------------------------- pool rebuild
            if broken:
                rep.pool_rebuilds += 1
                say(f"# fleet: worker pool broke "
                    f"(rebuild {rep.pool_rebuilds}); re-queueing "
                    f"{len(inflight)} in-flight unit(s)")
                pool.reset_pool(kill=True)
                for _fut, (unit, attempt, _dl, _g) in inflight.items():
                    requeue(unit, attempt, charge=False)
                inflight.clear()
                orphans.clear()   # their processes died with the pool
                if rebuilds_left <= 0:
                    return drain_inline()
                rebuilds_left -= 1
                ex = pool.shared_pool()
                if ex is None:
                    return drain_inline()
                continue

            # -------------------------------------------------- deadlines
            for fut in list(inflight):
                unit, attempt, deadline, g = inflight[fut]
                if now >= deadline and not fut.done():
                    del inflight[fut]
                    fut.cancel()           # running tasks won't cancel:
                    orphans[fut] = unit    # orphaned, result harvested
                    rep.timeouts.append({"unit": unit, "attempt": attempt})
                    say(f"# fleet: unit {unit} missed its {timeout_s:g}s "
                        f"deadline (attempt {attempt + 1}); re-queueing")
                    requeue(unit, attempt, charge=True,
                            why=f"deadline {timeout_s:g}s expired")

            # ------------------------------- heartbeats / work stealing
            if groups > 1:
                alive = [g for g in range(groups) if g not in evicted]
                for g in hb.dead_hosts(now=now):
                    if g not in alive or len(alive) <= 1:
                        continue   # never evict the last group standing
                    outstanding = (
                        any(group_of[u] == g for u, _a, _r in queue)
                        or any(m[3] == g for m in inflight.values()))
                    if not outstanding:
                        hb.beat(g, now=now)   # idle, not dead
                        continue
                    hb.evict(g)
                    alive.remove(g)
                    evicted.add(g)
                    rep.evicted_groups.append(g)
                    for unit, _a, _r in queue:   # steal its queued units
                        if (group_of[unit] == g and unit not in stolen
                                and not done[unit]):
                            stolen.add(unit)
                            group_of[unit] = alive[
                                len(rep.stolen_units) % len(alive)]
                            rep.stolen_units.append(unit)
                    say(f"# fleet: evicted silent group {g}; stole "
                        f"{len(rep.stolen_units)} queued unit(s)")
    except FleetError:
        if orphans:
            pool.reset_pool(kill=True)
        raise
    if orphans:
        # stragglers still executing would stall interpreter exit and
        # waste workers; kill the pool — the next fan-out rebuilds it
        say(f"# fleet: discarding {len(orphans)} orphaned straggler(s)")
        pool.reset_pool(kill=True)
    rep.results = results
    return rep
