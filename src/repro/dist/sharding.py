"""Sharding rules: shape -> PartitionSpec for params, batches and caches.

One policy, applied everywhere (``launch/specs.py`` builds every
dry-run input through these):

  * model parallelism ("model" axis) goes to the feature-like dimension
    — the last axis of a weight matrix, the expert axis of a MoE stack,
    the kv-heads axis of a cache (falling back to the sequence axis for
    MQA, where kv-heads is indivisible);
  * data parallelism ("data", composed with "pod" on multi-pod meshes)
    goes to the leading batch-like dimension;
  * an axis is only sharded when the mesh axis size divides it exactly —
    anything indivisible is replicated, never padded.  Rules degrade to
    full replication (all-None specs) rather than failing, so a config
    that fits one mesh never crashes the planner on another.

The functions accept any object with ``devices`` (ndarray) and
``axis_names`` — a real ``jax.sharding.Mesh`` or a test double.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

AxisEntry = Union[None, str, Tuple[str, ...]]


def _axis_sizes(mesh: Any) -> dict:
    return dict(zip(tuple(mesh.axis_names), mesh.devices.shape))


def _data_axis(mesh: Any, dim: int) -> AxisEntry:
    """The largest data-parallel axis (combo) that divides ``dim``:
    ("pod", "data") on multi-pod meshes, then "data", then "pod"."""
    sizes = _axis_sizes(mesh)
    candidates = []
    if "pod" in sizes and "data" in sizes:
        candidates.append((("pod", "data"), sizes["pod"] * sizes["data"]))
    if "data" in sizes:
        candidates.append(("data", sizes["data"]))
    if "pod" in sizes:
        candidates.append(("pod", sizes["pod"]))
    for axis, n in candidates:
        if n > 1 and dim % n == 0:
            return axis
    return None


def param_spec(shape: Sequence[int], mesh: Any, stacked: bool = False,
               expert: bool = False) -> P:
    """PartitionSpec for one parameter tensor.

    stacked: leading axis is a scanned layer stack — never sharded (every
             device owns every layer's shard of its slice).
    expert:  leading (post-stack) axis enumerates MoE experts — expert
             parallelism maps it onto the "model" axis.
    """
    shape = tuple(shape)
    sizes = _axis_sizes(mesh)
    mp = sizes.get("model", 1)
    spec: list = [None] * len(shape)
    dims = list(range(len(shape)))
    if stacked and dims:
        dims = dims[1:]

    model_used = False
    if expert and dims:
        d = dims[0]
        if mp > 1 and shape[d] % mp == 0:
            spec[d] = "model"
            model_used = True
        dims = dims[1:]
    if not model_used and dims and mp > 1 and shape[dims[-1]] % mp == 0:
        spec[dims[-1]] = "model"
        model_used = True
        dims = dims[:-1]

    for d in dims:  # FSDP-style: first remaining dim the dp size divides
        axis = _data_axis(mesh, shape[d])
        if axis is not None:
            spec[d] = axis
            break
    return P(*spec)


def batch_spec(shape: Sequence[int], mesh: Any) -> P:
    """PartitionSpec for an activation/batch tensor: leading dim across
    the data-parallel axes when divisible, everything else replicated."""
    shape = tuple(shape)
    spec: list = [None] * len(shape)
    if shape:
        spec[0] = _data_axis(mesh, shape[0])
    return P(*spec)


def cache_spec(shape: Sequence[int], mesh: Any) -> P:
    """PartitionSpec for a KV/state cache laid out ``(..., batch, heads,
    seq, head_dim)`` (a leading stacked-layers axis is fine).

    Heads shard on "model"; with indivisible heads (MQA/GQA down to
    kv=1) the sequence axis takes "model" instead — a cache too big for
    one device must still spread.  The batch axis shards on data.
    """
    shape = tuple(shape)
    r = len(shape)
    spec: list = [None] * r
    sizes = _axis_sizes(mesh)
    mp = sizes.get("model", 1)
    if mp > 1:
        for d in (r - 3, r - 2):  # heads first, then sequence
            if 0 <= d and shape[d] % mp == 0:
                spec[d] = "model"
                break
    b = r - 4 if r >= 4 else 0
    if 0 <= b < r and spec[b] is None:
        spec[b] = _data_axis(mesh, shape[b])
    return P(*spec)


def _path_str(path) -> str:
    parts = []
    for k in path:
        parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return "/".join(parts).lower()


def params_shardings(tree: Any, mesh: Any) -> Any:
    """Pytree of PartitionSpecs for a parameter pytree (leaves are arrays
    or ShapeDtypeStructs).  Stacked-layer and expert axes are recognized
    from the leaf's key path (scan stacks live under layers/blocks/stack
    keys; expert tensors under experts/moe keys) plus rank."""
    def spec_for(path, leaf) -> P:
        name = _path_str(path)
        shape = tuple(leaf.shape)
        expert = ("expert" in name or "moe" in name) and len(shape) >= 2
        stacked = (len(shape) >= 3 and not expert
                   and any(t in name for t in ("layers", "blocks", "stack")))
        return param_spec(shape, mesh, stacked=stacked, expert=expert)
    return jax.tree_util.tree_map_with_path(spec_for, tree)


def tree_shardings(tree: Any, mesh: Any,
                   specs: Optional[Any] = None) -> Any:
    """Pytree of NamedShardings for ``tree`` on ``mesh`` — ``specs``
    overrides the per-leaf PartitionSpecs (defaults to
    :func:`params_shardings`)."""
    if specs is None:
        specs = params_shardings(tree, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
