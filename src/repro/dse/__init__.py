"""Architecture design-space exploration (the paper's "vast design space
of CGRAs" claim as a first-class subsystem).

The DSE loop mirrors the agile-hardware workflow of the open-CGRA
ecosystem papers: enumerate parameterized :class:`~repro.core.CGRAArch`
variants (``space``), fan the full kernel library across them on the
shared worker pool with content-addressed compile memoization and
resumable checkpointing (``explore``), then score each variant against an
area proxy and report the Pareto frontier (``pareto``).

    from repro.dse import get_space, run_sweep, frontier

    results = run_sweep(get_space("small"))
    best = frontier(results)

Exhaustive sweeps stop paying off past a few hundred points; ``search``
adds seeded multi-objective search (NSGA-II / successive halving) over
the widened ``wide_space`` universe, scored through the batched
cross-architecture evaluator (``evaluate_points``):

    from repro.dse import get_space, run_search, SearchConfig

    res = run_search(get_space("wide"), SearchConfig(algo="nsga2"))

CLI entry points: ``examples/dse_sweep.py --space small`` (sweep) and
``examples/dse_sweep.py --space wide --search nsga2`` (search).
"""
from .space import (ArchPoint, HET_KINDS, SPACE_NAMES, axis_domains,
                    crossover, full_space, get_space, mutate, small_space,
                    tiny_space, wide_space)
from .explore import (KernelOutcome, VariantResult, evaluate_points,
                      kernel_suite, run_sweep, SUITE_KERNELS)
from .search import SEARCH_ALGOS, SearchConfig, SearchResult, run_search
from .pareto import (area_units, frontier, frontier_table, sweep_bench_rows,
                     write_artifacts)

__all__ = [
    "ArchPoint", "HET_KINDS", "SPACE_NAMES", "axis_domains", "crossover",
    "full_space", "get_space", "mutate", "small_space", "tiny_space",
    "wide_space", "KernelOutcome", "VariantResult", "evaluate_points",
    "kernel_suite", "run_sweep", "SUITE_KERNELS", "SEARCH_ALGOS",
    "SearchConfig", "SearchResult", "run_search", "area_units", "frontier",
    "frontier_table", "sweep_bench_rows", "write_artifacts",
]
