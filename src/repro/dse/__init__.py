"""Architecture design-space exploration (the paper's "vast design space
of CGRAs" claim as a first-class subsystem).

The DSE loop mirrors the agile-hardware workflow of the open-CGRA
ecosystem papers: enumerate parameterized :class:`~repro.core.CGRAArch`
variants (``space``), fan the full kernel library across them on the
shared worker pool with content-addressed compile memoization and
resumable checkpointing (``explore``), then score each variant against an
area proxy and report the Pareto frontier (``pareto``).

    from repro.dse import get_space, run_sweep, frontier

    results = run_sweep(get_space("small"))
    best = frontier(results)

CLI entry point: ``examples/dse_sweep.py --space small``.
"""
from .space import (ArchPoint, SPACE_NAMES, get_space, full_space,
                    small_space, tiny_space)
from .explore import (KernelOutcome, VariantResult, kernel_suite, run_sweep,
                      SUITE_KERNELS)
from .pareto import (area_units, frontier, frontier_table, sweep_bench_rows,
                     write_artifacts)

__all__ = [
    "ArchPoint", "SPACE_NAMES", "get_space", "full_space", "small_space",
    "tiny_space", "KernelOutcome", "VariantResult", "kernel_suite",
    "run_sweep", "SUITE_KERNELS", "area_units", "frontier", "frontier_table",
    "sweep_bench_rows", "write_artifacts",
]
