"""Design-space sweep driver: fan the kernel library across architecture
variants with memoized compiles, batched verification and resumable
checkpointing.

For every :class:`~repro.dse.space.ArchPoint` the driver builds the
ten-kernel suite (the six Table-I kernels at verification dims plus the
four DSL-only kernels) against that variant, compiles the whole suite
through ``Toolchain.compile_many`` (process fan-out; per-(arch, kernel)
results are content-addressed cache hits on re-runs), verifies each
mapped kernel with the batched IV-C engine, and scores it with
``costmodel.kernel_cost``.  Each mapping spans the variant's whole
fabric, so it is scored as one configured instance (``clusters=1``);
the variant's logical cluster count is reported as metadata only.

Infeasible points are results, not errors: a kernel that cannot be laid
out (bank overflow), mapped (MapError within ``ii_max``) or verified is
recorded with its status, and the variant simply drops out of the Pareto
candidate set.

Checkpointing: pass ``checkpoint=<path>`` and every finished variant is
flushed to JSON (atomic tmp+rename); an interrupted sweep resumes by
skipping variants already on disk.  The checkpoint records a fingerprint
of (mapper options, seeds, suite) and ignores stale files whose
fingerprint differs.
"""
from __future__ import annotations

import json
import os
import tempfile
import time
import warnings
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

from ..core.adl import CGRAArch
from ..core.costmodel import WORD_BYTES, kernel_cost
from ..core.kernels_lib import KernelSpec, table1_kernels
from ..core.mapper import MapperOptions
from ..core.toolchain import Toolchain
from ..frontend.library import dsl_kernels
from .pareto import area_units
from .space import ArchPoint

# the ten-kernel library every variant is scored on, in report order
SUITE_KERNELS = ("GEMM", "GEMM-U", "GEMM-U-C", "CONV", "CONV-U-C-1",
                 "CONV-U-C-2", "dwconv", "avgpool2x2", "gemm-bias-relu",
                 "requant-int8")

CHECKPOINT_SCHEMA = 1


def kernel_suite(arch: CGRAArch) -> Dict[str, KernelSpec]:
    """The full kernel library bound to ``arch`` (Table-I verification
    dims + DSL kernels), keyed in ``SUITE_KERNELS`` order."""
    suite = {**table1_kernels(small=True, arch=arch), **dsl_kernels(arch)}
    return {k: suite[k] for k in SUITE_KERNELS}


# --------------------------------------------------------------- results
@dataclass
class KernelOutcome:
    """One (variant, kernel) cell of the sweep."""
    kernel: str
    status: str                   # ok | layout_error | map_error | verify_error
    II: int = 0
    mii: int = 0
    utilization: float = 0.0
    cycles_per_inv: int = 0
    invocations: int = 0
    compute_ms: float = 0.0
    total_ms: float = 0.0
    from_cache: bool = False
    cache_key: str = ""
    error: str = ""

    def to_json_dict(self) -> Dict:
        # from_cache is a property of the *run*, not the result — keeping
        # it out of the artifact is what makes cold and warm sweeps
        # byte-identical
        return {k: v for k, v in self.__dict__.items() if k != "from_cache"}

    @staticmethod
    def from_json_dict(d: Dict) -> "KernelOutcome":
        return KernelOutcome(**d)


@dataclass
class VariantResult:
    """One architecture variant: per-kernel outcomes + aggregate score."""
    name: str
    point: ArchPoint
    n_pes: int
    clusters: int
    area: int
    kernels: Dict[str, KernelOutcome] = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        """Every suite kernel compiled AND verified on this variant."""
        return (len(self.kernels) == len(SUITE_KERNELS)
                and all(o.status == "ok" for o in self.kernels.values()))

    @property
    def mapped(self) -> int:
        return sum(1 for o in self.kernels.values() if o.status == "ok")

    @property
    def total_ms(self) -> float:
        """Suite latency: modeled total over all (verified) kernels."""
        return sum(o.total_ms for o in self.kernels.values()
                   if o.status == "ok")

    @property
    def mean_utilization(self) -> float:
        utils = [o.utilization for o in self.kernels.values()
                 if o.status == "ok"]
        return sum(utils) / len(utils) if utils else 0.0

    @property
    def max_ii(self) -> int:
        return max((o.II for o in self.kernels.values()
                    if o.status == "ok"), default=0)

    def to_json_dict(self) -> Dict:
        return {"name": self.name, "point": self.point.to_json_dict(),
                "n_pes": self.n_pes, "clusters": self.clusters,
                "area": self.area,
                "kernels": {k: o.to_json_dict()
                            for k, o in self.kernels.items()}}

    @staticmethod
    def from_json_dict(d: Dict) -> "VariantResult":
        return VariantResult(
            name=d["name"], point=ArchPoint.from_json_dict(d["point"]),
            n_pes=d["n_pes"], clusters=d["clusters"], area=d["area"],
            kernels={k: KernelOutcome.from_json_dict(o)
                     for k, o in d["kernels"].items()})


# ------------------------------------------------------------ checkpoint
def _fingerprint(options: MapperOptions, seeds: Sequence[int],
                 verify: bool,
                 suite: Optional[Sequence[str]] = None) -> Dict:
    # verify is part of the identity: resuming a --no-verify checkpoint
    # must not let unsimulated mappings pass as "fully verified".  The
    # fingerprint deliberately carries only what determines a point's
    # *evaluation* — never search hyper-parameters — so sweep and search
    # ledgers interoperate and a short search run is a valid resume
    # prefix of a longer one.
    return {"schema": CHECKPOINT_SCHEMA,
            "options": options.to_json_dict(),
            "seeds": list(seeds),
            "verify": bool(verify),
            "suite": list(SUITE_KERNELS if suite is None else suite)}


# paths already warned about this process (one warning per path per
# failure mode, not one per variant — a sweep stores after every variant)
_warned_store_paths: set = set()
_warned_corrupt_paths: set = set()


def _load_checkpoint(path: Optional[str], fp: Dict
                     ) -> Dict[str, VariantResult]:
    if not path or not os.path.exists(path):
        return {}
    try:
        with open(path, "r", encoding="utf-8") as f:
            d = json.load(f)
        if d.get("fingerprint") != fp:
            return {}  # different sweep configuration: start fresh
        return {name: VariantResult.from_json_dict(v)
                for name, v in d["variants"].items()}
    except (OSError, ValueError, KeyError, TypeError) as e:
        # corrupt checkpoint: recompute (cache soaks the cost) — but say
        # so, or an operator never learns their resume point was lost
        if path not in _warned_corrupt_paths:
            _warned_corrupt_paths.add(path)
            warnings.warn(
                f"DSE checkpoint {path!r} is unreadable "
                f"({type(e).__name__}: {e}); ignoring it and recomputing "
                f"(warm cache soaks the cost)", RuntimeWarning,
                stacklevel=3)
        return {}


def _store_checkpoint(path: Optional[str], fp: Dict,
                      done: Dict[str, VariantResult],
                      events: Optional[List[Dict]] = None) -> None:
    if not path:
        return
    blob_dict = {"fingerprint": fp,
                 "variants": {name: v.to_json_dict()
                              for name, v in sorted(done.items())}}
    if events:
        # fleet recovery ledger (timeouts, retries, evictions) — recorded
        # so an operator can audit a disturbed sweep; loaders ignore it,
        # and it never enters the byte-deterministic report artifacts
        blob_dict["events"] = events
    blob = json.dumps(blob_dict, sort_keys=True, indent=1)
    out_dir = os.path.dirname(os.path.abspath(path))
    try:
        os.makedirs(out_dir, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=out_dir, suffix=".tmp")
        with os.fdopen(fd, "w", encoding="utf-8") as f:
            f.write(blob)
        os.replace(tmp, path)  # atomic: a killed sweep never corrupts it
    except OSError as e:
        # checkpointing is an optimization only — the sweep continues —
        # but a silently dead checkpoint costs hours on the next
        # interruption, so warn once per path
        if path not in _warned_store_paths:
            _warned_store_paths.add(path)
            warnings.warn(
                f"DSE checkpoint write to {path!r} failed "
                f"({type(e).__name__}: {e}); sweep progress is NOT being "
                f"saved and an interrupted sweep will restart from the "
                f"compile cache only", RuntimeWarning, stacklevel=3)


# ------------------------------------------------------------------ sweep
def _kernel_outcome(kname: str, spec, ck, status: str,
                    err: str) -> KernelOutcome:
    """The scored outcome of one mapped (variant, kernel) cell — shared
    by the per-variant and the batched evaluators so both emit identical
    results."""
    cost = kernel_cost(
        spec, ck.mapping,
        array_bytes_moved=sum(p.words for p in
                              spec.layout.placements.values())
        * WORD_BYTES)
    return KernelOutcome(
        kernel=kname, status=status, II=ck.II, mii=ck.mii,
        utilization=round(ck.utilization, 6),
        cycles_per_inv=cost.cycles_per_inv,
        invocations=cost.invocations,
        compute_ms=round(cost.compute_ms, 6),
        total_ms=round(cost.total_ms, 6),
        from_cache=ck.from_cache, cache_key=ck.cache_key, error=err)


def _static_check_wanted(static_check: Optional[bool]) -> bool:
    """DSE pre-screen opt-in: explicit argument wins, otherwise follow the
    MORPHER_CHECK=1 environment gate (so a checked CI run statically
    pre-screens every evaluated point at no configuration cost)."""
    if static_check is not None:
        return static_check
    from ..core.verify import check_enabled
    return check_enabled()


def _prescreen(ck) -> str:
    """Static legality pre-screen for one mapped point; returns the empty
    string when clean, else a summary of the first few diagnostics."""
    from ..check import check_kernel, errors
    found = errors(check_kernel(ck))
    if not found:
        return ""
    head = "; ".join(str(d) for d in found[:3])
    more = "" if len(found) <= 3 else f" (+{len(found) - 3} more)"
    return f"{len(found)} static diagnostic(s): {head}{more}"


def _score_variant(point: ArchPoint, arch: CGRAArch, tc: Toolchain,
                   seeds: Sequence[int], jobs: Optional[int],
                   verify: bool, fleet=None,
                   static_check: Optional[bool] = None) -> VariantResult:
    # clusters is descriptive metadata here, NOT a cost divisor: the
    # mapper schedules each kernel across the variant's whole fabric
    # (one configured instance), so modeling extra data-parallel copies
    # on top would double-count the same PEs.  kernel_cost's clusters
    # division is for per-cluster mappings scaled to a multi-cluster
    # deployment (the Table-I convention).
    n_clusters = max(1, len(arch.clusters))
    result = VariantResult(name=point.name, point=point, n_pes=arch.n_pes,
                           clusters=n_clusters, area=area_units(arch))

    try:
        suite = kernel_suite(arch)
    except ValueError as e:
        # a kernel's arrays do not fit this variant's banks: the whole
        # suite is un-layoutable here (the builders share the bank scheme)
        result.kernels = {k: KernelOutcome(kernel=k, status="layout_error",
                                           error=str(e))
                          for k in SUITE_KERNELS}
        return result

    names = list(SUITE_KERNELS)
    do_check = _static_check_wanted(static_check)
    cks = tc.compile_many([suite[k] for k in names], jobs=jobs,
                          allow_unmapped=True, fleet=fleet)
    for kname, ck in zip(names, cks):
        if ck is None:
            reason = (tc.cached_map_error(suite[kname])
                      or f"unmappable within ii_max={tc.options.ii_max}")
            result.kernels[kname] = KernelOutcome(
                kernel=kname, status="map_error", error=reason)
            continue
        status, err = "ok", ""
        if do_check:
            bad = _prescreen(ck)
            if bad:
                status, err = "check_error", bad
        if verify and status == "ok":
            try:
                ck.verify_batch(seeds)
            except AssertionError as e:
                status, err = "verify_error", str(e)
        result.kernels[kname] = _kernel_outcome(kname, suite[kname], ck,
                                                status, err)
    return result


def evaluate_points(points: Sequence[ArchPoint], *,
                    toolchain: Optional[Toolchain] = None,
                    seeds: Sequence[int] = (0,),
                    jobs: Optional[int] = None,
                    verify: bool = True,
                    check_dfg: bool = True,
                    suite_names: Optional[Sequence[str]] = None,
                    fleet=None,
                    static_check: Optional[bool] = None
                    ) -> List[VariantResult]:
    """Score a whole population of variants in one batched pass — the
    search driver's evaluator and the throughput path the
    ``dse_search`` benchmark measures.

    Produces :class:`VariantResult`\\ s identical to ``run_sweep``'s
    per-point scoring (same mapper, oracles, cost model, rounding — the
    results interleave freely in one checkpoint ledger); only the
    batching changes:

      * ONE ``compile_many`` fan-out across every (variant, kernel) unit
        of the population (instead of one per variant), and
      * stacked multi-architecture verification
        (:func:`repro.core.toolchain.verify_stacked`): every group of
        mapped kernels sharing a shape bucket is a single XLA launch,
        so one launch scores dozens of candidate fabrics.

    ``suite_names`` restricts evaluation to a subset of
    ``SUITE_KERNELS`` — the successive-halving driver's partial-fidelity
    rungs.  A verify mismatch inside a stacked group (contract-breaking,
    so effectively never) falls back to per-kernel ``verify_batch`` to
    attribute the failure to its kernel.
    """
    from ..core.toolchain import verify_stacked
    suite_names = list(suite_names or SUITE_KERNELS)
    tc = toolchain or Toolchain(options=MapperOptions(ii_max=20))
    results: List[VariantResult] = []
    units: List[tuple] = []               # (variant index, kernel, spec)
    for point in points:
        try:
            arch = point.build()
        except ValueError as e:
            vr = VariantResult(name=point.name, point=point, n_pes=0,
                               clusters=0, area=0)
            vr.kernels = {k: KernelOutcome(kernel=k, status="layout_error",
                                           error=str(e))
                          for k in suite_names}
            results.append(vr)
            continue
        vr = VariantResult(name=point.name, point=point, n_pes=arch.n_pes,
                           clusters=max(1, len(arch.clusters)),
                           area=area_units(arch))
        results.append(vr)
        try:
            suite = kernel_suite(arch)
        except ValueError as e:
            vr.kernels = {k: KernelOutcome(kernel=k, status="layout_error",
                                           error=str(e))
                          for k in suite_names}
            continue
        for k in suite_names:
            units.append((len(results) - 1, k, suite[k]))

    cks = tc.compile_many([spec for _, _, spec in units], jobs=jobs,
                          allow_unmapped=True, fleet=fleet)
    mapped: List[tuple] = []              # (variant index, kernel, spec, ck)
    for (vi, kname, spec), ck in zip(units, cks):
        if ck is None:
            reason = (tc.cached_map_error(spec)
                      or f"unmappable within ii_max={tc.options.ii_max}")
            results[vi].kernels[kname] = KernelOutcome(
                kernel=kname, status="map_error", error=reason)
        else:
            mapped.append((vi, kname, spec, ck))

    if _static_check_wanted(static_check) and mapped:
        # statically pre-screen every mapped point: flagged artifacts are
        # scored as check_error and never reach the (much more expensive)
        # stacked simulation — clean artifacts are unaffected, so frontier
        # bytes are unchanged when nothing fires
        screened: List[tuple] = []
        for vi, kname, spec, ck in mapped:
            bad = _prescreen(ck)
            if bad:
                results[vi].kernels[kname] = _kernel_outcome(
                    kname, spec, ck, "check_error", bad)
            else:
                screened.append((vi, kname, spec, ck))
        mapped = screened

    statuses: Dict[tuple, tuple] = {}
    if verify and mapped and len(seeds):
        try:
            verify_stacked([ck for *_, ck in mapped], seeds,
                           check_dfg=check_dfg)
            statuses = {(vi, k): ("ok", "") for vi, k, _, _ in mapped}
        except AssertionError:
            for vi, kname, _spec, ck in mapped:
                try:
                    ck.verify_batch(seeds, check_dfg=check_dfg)
                    statuses[(vi, kname)] = ("ok", "")
                except AssertionError as e:
                    statuses[(vi, kname)] = ("verify_error", str(e))
    else:
        statuses = {(vi, k): ("ok", "") for vi, k, _, _ in mapped}
    for vi, kname, spec, ck in mapped:
        status, err = statuses[(vi, kname)]
        results[vi].kernels[kname] = _kernel_outcome(kname, spec, ck,
                                                     status, err)
    for vr in results:  # report order: suite order, as _score_variant emits
        vr.kernels = {k: vr.kernels[k] for k in suite_names
                      if k in vr.kernels}
    return results


def run_sweep(points: Sequence[ArchPoint], *,
              seeds: Sequence[int] = (0,),
              options: Optional[MapperOptions] = None,
              toolchain: Optional[Toolchain] = None,
              checkpoint: Optional[str] = None,
              jobs: Optional[int] = None,
              verify: bool = True,
              workers: Optional[int] = None,
              faults=None,
              fleet=None,
              static_check: Optional[bool] = None,
              log: Optional[Callable[[str], None]] = None
              ) -> List[VariantResult]:
    """Sweep the kernel library across ``points``; returns one
    :class:`VariantResult` per point, in input order.

    Deterministic by construction: mapper search is seeded and
    wall-clock-free (the default options carry no time budget), scores
    come from the analytic cost model, and re-runs hit the toolchain's
    content-addressed cache — so two runs of the same sweep produce
    byte-identical reports, the second one warm.

    ``workers=N`` shards each variant's compile units across N
    supervised worker groups (:mod:`repro.dist.fleet`): per-task
    deadlines, deterministic retry, killed-worker pool rebuilds,
    heartbeat eviction with work stealing.  ``faults`` injects a
    :class:`~repro.dist.faults.FaultPlan` into those workers; because
    units are idempotent (content-addressed cache) and every finished
    variant checkpoints, a sweep with injected worker loss emits
    byte-identical artifacts to an undisturbed run — that is the
    robustness contract, pinned by tests and the dist-smoke CI job.
    ``fleet`` passes a full :class:`~repro.dist.fleet.FleetConfig`
    instead (overrides ``workers``/``faults``).  Fleet recovery events
    are logged and recorded in the checkpoint's ``events`` section —
    timed-out and retried units are visible, never silently dropped.

    ``options`` configures the sweep's own Toolchain; when a ``toolchain``
    is passed its options govern (they feed every compile and the
    checkpoint fingerprint), so passing a *different* ``options`` too is
    a contradiction and raises.
    """
    if toolchain is not None and options is not None \
            and options != toolchain.options:
        raise ValueError("run_sweep: options conflicts with "
                         "toolchain.options; pass one or the other")
    if verify and not len(seeds):
        raise ValueError("run_sweep: verify=True needs at least one seed "
                         "(verify_batch over zero seeds checks nothing); "
                         "pass verify=False to skip verification "
                         "explicitly")
    options = options or MapperOptions(ii_max=20)
    tc = toolchain or Toolchain(options=options)
    say = log or (lambda s: None)

    if fleet is None and (workers is not None or faults is not None):
        from ..dist.fleet import FleetConfig
        fleet = FleetConfig(groups=workers or 2, faults=faults)

    fp = _fingerprint(tc.options, seeds, verify)
    done = _load_checkpoint(checkpoint, fp)
    if done:
        say(f"# checkpoint: {len(done)} variant(s) already swept")

    events: List[Dict] = []
    results: List[VariantResult] = []
    for i, point in enumerate(points):
        if point.name in done:
            results.append(done[point.name])
            continue
        t0 = time.time()
        try:
            arch = point.build()
        except ValueError as e:
            vr = VariantResult(name=point.name, point=point, n_pes=0,
                               clusters=0, area=0)
            vr.kernels = {k: KernelOutcome(kernel=k, status="layout_error",
                                           error=str(e))
                          for k in SUITE_KERNELS}
            done[point.name] = vr
            results.append(vr)
            _store_checkpoint(checkpoint, fp, done, events)
            say(f"[{i + 1}/{len(points)}] {point.name}: invalid ({e})")
            continue
        vr = _score_variant(point, arch, tc, seeds, jobs, verify,
                            fleet=fleet, static_check=static_check)
        done[point.name] = vr
        results.append(vr)
        report = tc.last_fleet_report
        if report is not None and not report.quiet():
            # a disturbed fan-out: keep the recovery ledger with the
            # checkpoint (timed-out units are recorded, not dropped)
            events.append({"variant": point.name,
                           **report.events_json_dict()})
            say(f"# fleet[{point.name}]: "
                f"{len(report.timeouts)} timeout(s), "
                f"{report.retries} retrie(s), "
                f"{report.pool_rebuilds} pool rebuild(s), "
                f"evicted={report.evicted_groups}, "
                f"stolen={report.stolen_units}")
        _store_checkpoint(checkpoint, fp, done, events)
        say(f"[{i + 1}/{len(points)}] {point.name}: "
            f"{vr.mapped}/{len(SUITE_KERNELS)} kernels ok, "
            f"area={vr.area}, latency={vr.total_ms:.3f}ms "
            f"({time.time() - t0:.1f}s)")
    return results
