"""Pareto scoring and reporting for design-space sweeps.

Each fully-verified variant is a point in (suite latency, area) space:
latency is the cost model's total over the ten-kernel library (each
mapping spans the variant's whole fabric and is scored as one configured
instance; transfer rides the shared host link), area is a deterministic
proxy in integer "area units":

    area = n_pes * (PE_AREA + (regfile + livein regs) * REG_AREA)
         + total_bank_kb * BANK_AREA_PER_KB

The constants are relative weights (a PE datapath ~ a few registers, a
kilobyte of SRAM ~ a couple of PEs), not silicon numbers — the frontier
shape, not absolute mm^2, is what the sweep reports.  The frontier is
the set of non-dominated variants (no other variant is at most as slow
AND at most as small), ordered by ascending latency; ties are broken by
name so the report is byte-deterministic.

``write_artifacts`` emits two files: ``dse_frontier.json`` (the full
deterministic report) and ``BENCH_dse_sweep.json`` (one row per variant
in the ``benchmarks.run`` schema, ``us`` = modeled suite latency — also
deterministic, so the regression comparator gates the cost model and
mapper quality, not wall clock).
"""
from __future__ import annotations

import json
import os
import subprocess
from typing import Dict, List, Optional, Sequence, TYPE_CHECKING

from ..core.adl import CGRAArch

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .explore import VariantResult

PE_AREA = 4          # FU + crossbar + control, in area units
REG_AREA = 1         # one routing/live-in register
BANK_AREA_PER_KB = 8  # 1 kB of banked SRAM + bus port

BENCH_SCHEMA = 1


def area_units(arch: CGRAArch) -> int:
    """Deterministic integer area proxy for a CGRA variant."""
    per_pe = PE_AREA + REG_AREA * (arch.regfile_size + arch.livein_regs)
    bank_kb = sum(b.size_bytes for b in arch.banks) // 1024
    return arch.n_pes * per_pe + bank_kb * BANK_AREA_PER_KB


def frontier(results: Sequence["VariantResult"]) -> List["VariantResult"]:
    """The Pareto-optimal subset of the fully-verified variants,
    minimizing (suite latency, area); ascending latency order."""
    ok = [r for r in results if r.ok]
    ok.sort(key=lambda r: (r.total_ms, r.area, r.name))
    front: List["VariantResult"] = []
    best_area: Optional[int] = None
    for r in ok:
        if best_area is None or r.area < best_area:
            front.append(r)
            best_area = r.area
    return front


def frontier_table(results: Sequence["VariantResult"]) -> str:
    """Human-readable sweep report: every variant, frontier marked."""
    front = {r.name for r in frontier(results)}
    lines = [f"{'variant':<28} {'PEs':>4} {'area':>6} {'ok':>5} "
             f"{'maxII':>5} {'util':>7} {'latency_ms':>11}  pareto"]
    lines.append("-" * len(lines[0]))
    for r in sorted(results, key=lambda r: (r.total_ms if r.ok else 1e18,
                                            r.area, r.name)):
        ok = f"{r.mapped}/{len(r.kernels)}"
        lat = f"{r.total_ms:11.3f}" if r.ok else f"{'—':>11}"
        lines.append(f"{r.name:<28} {r.n_pes:>4} {r.area:>6} {ok:>5} "
                     f"{r.max_ii:>5} {r.mean_utilization * 100:6.1f}% "
                     f"{lat}  {'*' if r.name in front else ''}")
    return "\n".join(lines)


def _git_sha() -> Optional[str]:
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            text=True, stderr=subprocess.DEVNULL).strip()
    except (OSError, subprocess.CalledProcessError):
        return None


def sweep_bench_rows(results: Sequence["VariantResult"]) -> List[Dict]:
    """Benchmark rows (``benchmarks.run`` schema) for the sweep: one row
    per fully-verified variant, ``us`` = modeled suite latency
    (deterministic, so the regression gate tracks mapper/cost-model
    quality).  Partially-mapped variants are reported only in
    ``dse_frontier.json`` — a ``None`` duration has no place in a gated
    benchmark row."""
    front = {r.name for r in frontier(results)}
    rows: List[Dict] = []
    for r in results:
        if not r.ok:
            continue
        rows.append({"name": r.name,
                     "us": round(r.total_ms * 1e3, 1),
                     "derived": {"area": r.area, "pes": r.n_pes,
                                 "mapped": r.mapped,
                                 "kernels": len(r.kernels),
                                 "max_ii": r.max_ii,
                                 "util": round(r.mean_utilization, 4),
                                 "pareto": int(r.name in front)}})
    return rows


def write_artifacts(results: Sequence["VariantResult"], out_dir: str,
                    space: str = "custom",
                    seeds: Sequence[int] = (0,),
                    verified: bool = True,
                    bench_name: str = "dse_sweep",
                    extra: Optional[Dict] = None) -> Dict[str, str]:
    """Write ``dse_frontier.json`` + ``BENCH_<bench_name>.json`` under
    ``out_dir``; returns {artifact name: path}.  Both files are
    byte-deterministic for a given sweep configuration and commit.
    ``verified=False`` (a ``--no-verify`` sweep) is stamped into both
    artifacts so score-only output can never masquerade as a verified
    baseline.  ``extra`` (e.g. the search trajectory from
    :func:`repro.dse.search.run_search`) merges into the frontier report;
    the defaults keep sweep artifacts byte-identical to earlier
    releases."""
    os.makedirs(out_dir, exist_ok=True)
    front = frontier(results)
    report = {
        "schema": BENCH_SCHEMA,
        "space": space,
        "seeds": list(seeds),
        "verified": bool(verified),
        "suite_kernels": sorted({k for r in results for k in r.kernels}),
        "variants": [r.to_json_dict() for r in results],
        "frontier": [r.name for r in front],
    }
    if extra:
        report.update(extra)
    paths = {}
    p = os.path.join(out_dir, "dse_frontier.json")
    with open(p, "w", encoding="utf-8") as f:
        json.dump(report, f, sort_keys=True, indent=1)
        f.write("\n")
    paths["dse_frontier.json"] = p

    fname = f"BENCH_{bench_name}.json"
    p = os.path.join(out_dir, fname)
    with open(p, "w", encoding="utf-8") as f:
        json.dump({"bench": bench_name, "schema": BENCH_SCHEMA,
                   "git_sha": _git_sha(), "verified": bool(verified),
                   "rows": sweep_bench_rows(results)}, f, indent=1)
        f.write("\n")
    paths[fname] = p
    return paths
