"""Seeded multi-objective design-space search (ROADMAP item 3).

Exhaustive enumeration caps out at ``full_space()``; this driver explores
the widened universe (``wide_space()`` and beyond) with two classic
budgeted strategies, both **byte-deterministic**:

  nsga2    an NSGA-II-style evolutionary loop: non-dominated sorting with
           crowding distance over the (suite latency, area) objectives,
           binary-tournament parent selection, uniform knob crossover and
           seeded mutation over the universe's ``axis_domains``.
  halving  successive halving: start from ``population * eta**(rungs-1)``
           sampled candidates, evaluate each rung on a growing prefix of
           the kernel suite (cheap partial-fidelity scoring, no verify),
           keep the best ``1/eta`` per rung, and evaluate the survivors
           at full fidelity on the last rung.

Determinism contract (the search extension of the DSE contract): the RNG
is ``random.Random(config.seed)`` consumed in a fixed trajectory, scores
are the analytic cost model, and there are **no wall-clock budgets** — so
cold, warm, resumed and fleet-faulted runs emit byte-identical
``dse_frontier.json`` artifacts (pinned by ``tests/test_search.py`` and
the CI ``search-smoke`` job).  Resume works by *replaying* the whole
trajectory: every point evaluation is memoized in the
:mod:`repro.dse.explore` checkpoint ledger (fingerprint =
(options, seeds, verify, suite) — deliberately free of search
hyper-parameters), so the replay costs ledger lookups, a short run's
checkpoint is a valid prefix of a longer one, and sweep and search
ledgers interoperate.  Partial-fidelity (halving rung) evaluations are
stored under ``<name>@<k>nv`` keys that no :class:`ArchPoint` name can
collide with; ``run_sweep`` simply ignores them.

Evaluation is the batched path (:func:`repro.dse.explore.evaluate_points`):
one ``compile_many`` fan-out per round across every (variant, kernel)
unit, then stacked multi-architecture verification — one XLA launch per
shape bucket scores the whole cohort (``BENCH_dse_search``'s
evaluated-points-per-second headline).
"""
from __future__ import annotations

import math
import random
from dataclasses import asdict, dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..core.mapper import MapperOptions
from ..core.toolchain import Toolchain
from .explore import (SUITE_KERNELS, VariantResult, _fingerprint,
                      _load_checkpoint, _store_checkpoint, evaluate_points)
from .space import ArchPoint, axis_domains, crossover, mutate

SEARCH_ALGOS = ("nsga2", "halving")


@dataclass(frozen=True)
class SearchConfig:
    """Hyper-parameters of one search run.  None of these enter the
    checkpoint fingerprint — evaluations are pure functions of
    (point, options, seeds, verify, suite) — so ledgers are shared
    across budgets and algorithms."""
    algo: str = "nsga2"
    seed: int = 0
    generations: int = 4          # nsga2: rounds; halving: rungs
    population: int = 12          # nsga2: per generation; halving: finalists
    mutation: float = 0.25        # per-knob mutation probability
    crossover: float = 0.9        # probability a child crosses two parents
    eta: int = 2                  # halving keep-fraction denominator

    def to_json_dict(self) -> Dict:
        return asdict(self)


@dataclass
class SearchResult:
    """Everything a search run produced, in trajectory order."""
    evaluated: List[VariantResult]      # full-fidelity evals, first-eval order
    population: List[str]               # final population / survivors (names)
    history: List[Dict] = field(default_factory=list)
    n_requested: int = 0                # point-evals requested (incl. repeats)
    n_partial: int = 0                  # partial-fidelity evals (halving rungs)


# ------------------------------------------------------------- objectives
def _objectives(vr: VariantResult,
                n_kernels: int) -> Optional[Tuple[float, int]]:
    """The (suite latency, area) minimization objectives — or None when
    the variant failed any evaluated kernel (infeasible points rank
    behind every feasible front)."""
    if (len(vr.kernels) == n_kernels
            and all(o.status == "ok" for o in vr.kernels.values())):
        return (round(vr.total_ms, 6), vr.area)
    return None


def _dominates(a: Tuple[float, int], b: Tuple[float, int]) -> bool:
    return a[0] <= b[0] and a[1] <= b[1] and a != b


def _fronts(items: Sequence[Tuple[str, Optional[Tuple[float, int]]]]
            ) -> List[List[str]]:
    """Non-dominated sorting: feasible fronts first (each sorted by name),
    then one trailing front of every infeasible point."""
    feas = {n: o for n, o in items if o is not None}
    fronts: List[List[str]] = []
    remaining = dict(feas)
    while remaining:
        front = sorted(
            n for n, o in remaining.items()
            if not any(_dominates(o2, o) for n2, o2 in remaining.items()
                       if n2 != n))
        fronts.append(front)
        for n in front:
            del remaining[n]
    infeas = sorted(n for n, o in items if o is None)
    if infeas:
        fronts.append(infeas)
    return fronts


def _crowding(front: Sequence[str],
              objs: Dict[str, Tuple[float, int]]) -> Dict[str, float]:
    """NSGA-II crowding distance within one feasible front (boundary
    points are infinitely crowded-distant, i.e. always kept)."""
    if len(front) <= 2:
        return {n: math.inf for n in front}
    d = {n: 0.0 for n in front}
    for k in range(2):
        s = sorted(front, key=lambda n: (objs[n][k], n))
        d[s[0]] = d[s[-1]] = math.inf
        span = float(objs[s[-1]][k] - objs[s[0]][k])
        if span <= 0:
            continue
        for i in range(1, len(s) - 1):
            if d[s[i]] != math.inf:
                d[s[i]] += (objs[s[i + 1]][k] - objs[s[i - 1]][k]) / span
    return d


def _rank(points: Sequence[ArchPoint],
          results: Dict[str, VariantResult], n_kernels: int
          ) -> Tuple[Dict[str, int], Dict[str, float]]:
    """(front index, crowding distance) per point name — the NSGA-II
    fitness ordering (lower front wins; within a front, higher crowding
    wins; ties break by name)."""
    items = [(p.name, _objectives(results[p.name], n_kernels))
             for p in points]
    objs = {n: o for n, o in items if o is not None}
    rank: Dict[str, int] = {}
    crowd: Dict[str, float] = {}
    for fi, front in enumerate(_fronts(items)):
        cd = (_crowding(front, objs) if front[0] in objs
              else {n: 0.0 for n in front})
        for n in front:
            rank[n] = fi
            crowd[n] = cd[n]
    return rank, crowd


def _select(points: Sequence[ArchPoint],
            results: Dict[str, VariantResult], n_kernels: int,
            n: int) -> List[ArchPoint]:
    """Environmental selection: fill by front; the cut front orders by
    descending crowding distance, ties by name.  Deterministic."""
    by_name = {p.name: p for p in points}
    items = [(p.name, _objectives(results[p.name], n_kernels))
             for p in points]
    objs = {nm: o for nm, o in items if o is not None}
    chosen: List[str] = []
    for front in _fronts(items):
        if len(chosen) + len(front) <= n:
            chosen.extend(front)
        else:
            cd = (_crowding(front, objs) if front[0] in objs
                  else {nm: 0.0 for nm in front})
            rest = sorted(front, key=lambda nm: (-cd[nm], nm))
            chosen.extend(rest[:n - len(chosen)])
            break
    return [by_name[nm] for nm in chosen]


def _tournament(rng: random.Random, names: Sequence[str],
                rank: Dict[str, int], crowd: Dict[str, float]) -> str:
    """Binary tournament on (front, -crowding, name)."""
    a = names[rng.randrange(len(names))]
    b = names[rng.randrange(len(names))]
    ka = (rank[a], -crowd[a], a)
    kb = (rank[b], -crowd[b], b)
    return a if ka <= kb else b


def _sample(rng: random.Random, universe: Sequence[ArchPoint],
            n: int) -> List[ArchPoint]:
    """Seeded sample of n distinct points from the universe."""
    n = min(n, len(universe))
    return [universe[i] for i in rng.sample(range(len(universe)), n)]


# ------------------------------------------------------------------ driver
def run_search(points: Sequence[ArchPoint],
               config: Optional[SearchConfig] = None, *,
               seeds: Sequence[int] = (0,),
               options: Optional[MapperOptions] = None,
               toolchain: Optional[Toolchain] = None,
               checkpoint: Optional[str] = None,
               jobs: Optional[int] = None,
               verify: bool = True,
               workers: Optional[int] = None,
               faults=None,
               fleet=None,
               suite: Optional[Sequence[str]] = None,
               log: Optional[Callable[[str], None]] = None
               ) -> SearchResult:
    """Run a seeded multi-objective search over the candidate universe
    ``points``.

    The universe defines the gene pool (``axis_domains``): crossover and
    mutation may visit knob combinations absent from the input list —
    that widening is the point.  Checkpointing, fleet fan-out, and the
    ``options``/``toolchain``/``verify`` semantics match
    :func:`repro.dse.explore.run_sweep` (the ledger is shared); see the
    module docstring for the determinism/resume contract.

    ``suite`` restricts scoring to a subset of ``SUITE_KERNELS`` (tests
    and quick scans); it enters the checkpoint fingerprint.  Returns a
    :class:`SearchResult` whose ``evaluated`` list (full-fidelity
    evaluations, first-evaluation order) feeds
    :func:`repro.dse.pareto.write_artifacts` unchanged.
    """
    config = config or SearchConfig()
    if config.algo not in SEARCH_ALGOS:
        raise ValueError(f"unknown search algo {config.algo!r} "
                         f"(choose from {SEARCH_ALGOS})")
    if config.population < 2:
        raise ValueError("run_search: population must be >= 2")
    if config.generations < 1:
        raise ValueError("run_search: generations must be >= 1")
    if config.algo == "halving" and config.eta < 2:
        raise ValueError("run_search: halving needs eta >= 2")
    if toolchain is not None and options is not None \
            and options != toolchain.options:
        raise ValueError("run_search: options conflicts with "
                         "toolchain.options; pass one or the other")
    if verify and not len(seeds):
        raise ValueError("run_search: verify=True needs at least one seed; "
                         "pass verify=False to skip verification explicitly")
    universe = list(points)
    if not universe:
        raise ValueError("run_search: empty candidate universe")
    options = options or MapperOptions(ii_max=20)
    tc = toolchain or Toolchain(options=options)
    say = log or (lambda s: None)
    if fleet is None and (workers is not None or faults is not None):
        from ..dist.fleet import FleetConfig
        fleet = FleetConfig(groups=workers or 2, faults=faults)

    suite_names = list(suite if suite is not None else SUITE_KERNELS)
    unknown = [k for k in suite_names if k not in SUITE_KERNELS]
    if unknown or not suite_names:
        raise ValueError(f"run_search: unknown suite kernel(s) {unknown} "
                         f"(choose from {list(SUITE_KERNELS)})")
    n_full = len(suite_names)
    fp = _fingerprint(tc.options, seeds, verify, suite=suite_names)
    ledger = _load_checkpoint(checkpoint, fp)
    if ledger:
        say(f"# checkpoint: {len(ledger)} evaluation(s) on ledger")

    domains = axis_domains(universe)
    rng = random.Random(config.seed)
    events: List[Dict] = []
    history: List[Dict] = []
    order: List[str] = []          # full-fidelity names, first-eval order
    seen_full: set = set()
    n_requested = 0
    n_partial = 0

    def evaluate(pts: Sequence[ArchPoint], n_kernels: int,
                 vflag: bool) -> List[VariantResult]:
        """Resolve one fidelity level for each point: ledger hits replay
        for free, the rest go through ONE batched evaluate_points call.
        Results are independent of the hit/miss split — that is the
        resume contract."""
        nonlocal n_requested, n_partial
        # full fidelity = whole suite AND the run's verify policy; a
        # whole-suite-but-unverified rung (tiny suites clamp there) is
        # still partial and must not publish under the plain name key
        full = n_kernels == n_full and vflag == verify

        def key(p: ArchPoint) -> str:
            return p.name if full else f"{p.name}@{n_kernels}nv"

        n_requested += len(pts)
        if not full:
            n_partial += len(pts)
        uniq: List[ArchPoint] = []
        seen = set()
        for p in pts:
            if key(p) not in seen:
                seen.add(key(p))
                uniq.append(p)
        todo = [p for p in uniq if key(p) not in ledger]
        if todo:
            res = evaluate_points(todo, toolchain=tc, seeds=seeds,
                                  jobs=jobs, verify=vflag,
                                  suite_names=suite_names[:n_kernels],
                                  fleet=fleet)
            for p, vr in zip(todo, res):
                ledger[key(p)] = vr
            report = tc.last_fleet_report
            if report is not None and not report.quiet():
                events.append({"round": len(history),
                               **report.events_json_dict()})
                say(f"# fleet[round {len(history)}]: "
                    f"{len(report.timeouts)} timeout(s), "
                    f"{report.retries} retrie(s), "
                    f"{report.pool_rebuilds} pool rebuild(s)")
            _store_checkpoint(checkpoint, fp, ledger, events)
        if full:
            for p in uniq:
                if p.name not in seen_full:
                    seen_full.add(p.name)
                    order.append(p.name)
        return [ledger[key(p)] for p in pts]

    if config.algo == "nsga2":
        pop = _sample(rng, universe, config.population)
        res = evaluate(pop, n_full, verify)
        by_name = {p.name: r for p, r in zip(pop, res)}
        feas = sum(1 for p in pop
                   if _objectives(by_name[p.name], n_full) is not None)
        history.append({"round": 0, "evaluated": [p.name for p in pop],
                        "population": [p.name for p in pop],
                        "feasible": feas})
        say(f"[gen 1/{config.generations}] evaluated {len(pop)} "
            f"point(s), {feas} feasible")
        for gen in range(1, config.generations):
            rank, crowd = _rank(pop, by_name, n_full)
            names = [p.name for p in pop]
            by_point = {p.name: p for p in pop}
            offspring: List[ArchPoint] = []
            taken = set(names)
            guard = 0
            while (len(offspring) < config.population
                   and guard < config.population * 20):
                guard += 1
                pa = by_point[_tournament(rng, names, rank, crowd)]
                pb = by_point[_tournament(rng, names, rank, crowd)]
                child = (crossover(rng, pa, pb)
                         if rng.random() < config.crossover else pa)
                child = mutate(rng, child, domains, config.mutation)
                if child.name in taken:
                    continue
                taken.add(child.name)
                offspring.append(child)
            res_off = evaluate(offspring, n_full, verify)
            for p, r in zip(offspring, res_off):
                by_name[p.name] = r
            pool = pop + offspring
            pop = _select(pool, by_name, n_full, config.population)
            feas = sum(1 for p in pop
                       if _objectives(by_name[p.name], n_full) is not None)
            history.append({"round": gen,
                            "evaluated": [p.name for p in offspring],
                            "population": [p.name for p in pop],
                            "feasible": feas})
            say(f"[gen {gen + 1}/{config.generations}] "
                f"{len(offspring)} offspring, population {len(pop)}, "
                f"{feas} feasible")
    else:  # successive halving
        rungs = config.generations
        cands = _sample(rng, universe,
                        config.population * config.eta ** (rungs - 1))
        for r in range(rungs):
            last = r == rungs - 1
            if last:
                n_k, vflag = n_full, verify
            else:
                n_k = max(1, min(n_full - 1,
                                 -(-n_full * (r + 1) // rungs)))
                vflag = False
            res = evaluate(cands, n_k, vflag)
            by_name = {p.name: vr for p, vr in zip(cands, res)}
            feas = sum(1 for p in cands
                       if _objectives(by_name[p.name], n_k) is not None)
            say(f"[rung {r + 1}/{rungs}] {len(cands)} candidate(s) at "
                f"{n_k}/{n_full} kernels"
                f"{' + verify' if vflag and verify else ''}, "
                f"{feas} feasible")
            if last:
                pop = _select(cands, by_name, n_k, config.population)
            else:
                keep = max(config.population, -(-len(cands) // config.eta))
                pop = _select(cands, by_name, n_k, keep)
            history.append({"round": r, "fidelity": n_k,
                            "evaluated": [p.name for p in cands],
                            "population": [p.name for p in pop],
                            "feasible": feas})
            cands = pop

    return SearchResult(
        evaluated=[ledger[name] for name in order],
        population=[p.name for p in pop],
        history=history, n_requested=n_requested, n_partial=n_partial)
