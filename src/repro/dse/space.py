"""Parameterized CGRA architecture generator for design-space sweeps.

An :class:`ArchPoint` is one coordinate of the ADL design space the paper
calls architecture-adaptivity: grid size, torus vs mesh interconnect,
routing register-file size, memory bank count/size/placement, and
heterogeneous per-PE op sets.  ``ArchPoint.build()`` materializes the
coordinate as a validated :class:`~repro.core.adl.CGRAArch` with a
deterministic name, so a sweep is reproducible from its space name alone
and every (variant, kernel) compile is a stable content-addressed cache
key.

Bank placement follows the paper's target family: data memories sit on
the left/right boundary columns behind shared buses (one access port per
bank per cycle).  ``banks_per_col=2`` splits each boundary column into a
top-half and bottom-half bank — more aggregate ports, same capacity
knob.  Bank ids are assigned so that id 0 is always a left-column bank
and id 1 a right-column bank, matching the kernel library's layout hints
(accumulator/weight arrays vs streamed inputs on opposite buses).

Heterogeneity (``het``):
  none     homogeneous FUs (every PE has the full op set)
  alulite  interior PEs keep only the arithmetic core (add/sub/mul/
           shl/shr + const/livein); compare/select/bitwise logic — the
           induction-chain machinery of coalesced kernels — is restricted
           to the boundary columns, modeling cheap ALU-lite interior
           tiles.  (Memory ops are always boundary-only: LOAD/STORE must
           reach a bank bus regardless of the op set.)
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Dict, List

from ..core.adl import CGRAArch, MemBank
from ..core.dfg import Op

# the arithmetic core every PE keeps under "alulite" heterogeneity
LITE_OPS = frozenset(o.value for o in (Op.ADD, Op.SUB, Op.MUL, Op.SHL,
                                       Op.SHR, Op.CONST, Op.LIVEIN))

HET_KINDS = ("none", "alulite")


@dataclass(frozen=True)
class ArchPoint:
    """One coordinate of the CGRA design space (see module docstring)."""
    rows: int
    cols: int
    torus: bool = False
    regfile_size: int = 8
    bank_kb: int = 8
    banks_per_col: int = 1
    het: str = "none"

    @property
    def name(self) -> str:
        """Deterministic variant name — the checkpoint / report / cache
        identity of this point."""
        topo = "torus" if self.torus else "mesh"
        n_banks = 2 * self.banks_per_col
        s = (f"dse-{self.rows}x{self.cols}-{topo}-rf{self.regfile_size}"
             f"-b{n_banks}x{self.bank_kb}k")
        if self.het != "none":
            s += f"-{self.het}"
        return s

    def to_json_dict(self) -> Dict:
        return asdict(self)

    @staticmethod
    def from_json_dict(d: Dict) -> "ArchPoint":
        return ArchPoint(**d)

    def build(self) -> CGRAArch:
        """Materialize (and validate) the CGRAArch for this point."""
        rows, cols = self.rows, self.cols
        if cols < 2:
            raise ValueError(f"{self.name}: need >= 2 columns for "
                             f"left/right boundary memory buses")
        if self.banks_per_col not in (1, 2):
            raise ValueError(f"{self.name}: banks_per_col must be 1 or 2")
        if self.banks_per_col == 2 and rows < 2:
            raise ValueError(f"{self.name}: banks_per_col=2 needs >= 2 rows")
        if self.het not in HET_KINDS:
            raise ValueError(f"{self.name}: unknown het kind {self.het!r} "
                             f"(choose from {HET_KINDS})")

        left = [r * cols + 0 for r in range(rows)]
        right = [r * cols + (cols - 1) for r in range(rows)]
        size = self.bank_kb * 1024
        banks: List[MemBank] = []
        if self.banks_per_col == 1:
            banks = [MemBank(0, size, tuple(left)),
                     MemBank(1, size, tuple(right))]
        else:
            half = rows // 2
            banks = [MemBank(0, size, tuple(left[:half])),
                     MemBank(1, size, tuple(right[:half])),
                     MemBank(2, size, tuple(left[half:])),
                     MemBank(3, size, tuple(right[half:]))]

        # logical clustering: tile 4x4 clusters when the grid allows more
        # than one (the paper's 8x8 = 4 clusters), else one cluster
        if rows % 4 == 0 and cols % 4 == 0 and rows * cols > 16:
            clusters = [[(cr * 4 + r) * cols + (cc * 4 + c)
                         for r in range(4) for c in range(4)]
                        for cr in range(rows // 4) for cc in range(cols // 4)]
        else:
            clusters = [list(range(rows * cols))]

        per_pe_ops: Dict[int, frozenset] = {}
        if self.het == "alulite":
            boundary = set(left) | set(right)
            per_pe_ops = {p: LITE_OPS for p in range(rows * cols)
                          if p not in boundary}

        arch = CGRAArch(name=self.name, rows=rows, cols=cols,
                        datapath_bits=16, regfile_size=self.regfile_size,
                        banks=banks, torus=self.torus,
                        per_pe_ops=per_pe_ops, clusters=clusters)
        arch.validate()
        return arch


# ------------------------------------------------------------------ spaces
def tiny_space() -> List[ArchPoint]:
    """Four variants for CI smoke — a strict subset of ``small`` so the
    smoke BENCH rows stay comparable against the committed small-sweep
    baseline."""
    return [
        ArchPoint(4, 4),
        ArchPoint(4, 4, torus=True),
        ArchPoint(4, 4, regfile_size=4),
        ArchPoint(4, 4, banks_per_col=2, bank_kb=4),
    ]


def small_space() -> List[ArchPoint]:
    """The default sweep: 20 variants spanning every knob, centered on
    grids the whole kernel library maps onto comfortably (the 4x4
    cluster family, 4x8, 8x8), plus aggressive stretch points — 2x2 and
    2x4 grids, ALU-lite interiors, small register files — where some
    kernels legitimately fail to map within ``ii_max`` (the sweep driver
    records those as per-kernel statuses and drops the variant from the
    Pareto candidate set)."""
    pts = list(tiny_space())
    pts += [
        ArchPoint(4, 4, regfile_size=16),
        ArchPoint(4, 4, torus=True, regfile_size=4),
        ArchPoint(4, 4, torus=True, regfile_size=16),
        ArchPoint(4, 4, torus=True, banks_per_col=2, bank_kb=4),
        ArchPoint(4, 4, banks_per_col=2),
        ArchPoint(4, 4, torus=True, banks_per_col=2),
        ArchPoint(4, 4, regfile_size=16, banks_per_col=2, bank_kb=4),
        ArchPoint(4, 4, torus=True, regfile_size=16, banks_per_col=2,
                  bank_kb=4),
        ArchPoint(4, 8),
        ArchPoint(4, 8, torus=True),
        ArchPoint(8, 8),
        ArchPoint(8, 8, torus=True),
        # stretch points: minimal grids and heterogeneous interiors
        ArchPoint(2, 2),
        ArchPoint(2, 4),
        ArchPoint(4, 4, het="alulite"),
        ArchPoint(4, 4, torus=True, het="alulite"),
    ]
    return pts


def full_space() -> List[ArchPoint]:
    """The exhaustive grid: every knob combination over 2x2..8x8 grids.
    Deterministic enumeration order; infeasible/unmappable points are
    sweep results ("unmapped"), not errors."""
    pts: List[ArchPoint] = []
    for rows, cols in ((2, 2), (2, 4), (4, 4), (4, 8), (6, 6), (8, 8)):
        for torus in (False, True):
            for rf in (4, 8, 16):
                for bank_kb, bpc in ((8, 1), (4, 2), (8, 2)):
                    for het in ("none", "alulite"):
                        if het == "alulite" and cols <= 2:
                            continue  # no interior PEs to restrict
                        pts.append(ArchPoint(rows, cols, torus=torus,
                                             regfile_size=rf,
                                             bank_kb=bank_kb,
                                             banks_per_col=bpc, het=het))
    return pts


SPACE_NAMES = ("tiny", "small", "full")


def get_space(name: str) -> List[ArchPoint]:
    try:
        return {"tiny": tiny_space, "small": small_space,
                "full": full_space}[name]()
    except KeyError:
        raise ValueError(f"unknown space {name!r} (choose from "
                         f"{SPACE_NAMES})") from None
