"""Parameterized CGRA architecture generator for design-space sweeps.

An :class:`ArchPoint` is one coordinate of the ADL design space the paper
calls architecture-adaptivity: grid size, torus vs mesh interconnect,
routing register-file size, memory bank count/size/placement, and
heterogeneous per-PE op sets.  ``ArchPoint.build()`` materializes the
coordinate as a validated :class:`~repro.core.adl.CGRAArch` with a
deterministic name, so a sweep is reproducible from its space name alone
and every (variant, kernel) compile is a stable content-addressed cache
key.

Bank placement follows the paper's target family: data memories sit on
the left/right boundary columns behind shared buses (one access port per
bank per cycle).  ``banks_per_col=2`` splits each boundary column into a
top-half and bottom-half bank — more aggregate ports, same capacity
knob.  Bank ids are assigned so that id 0 is always a left-column bank
and id 1 a right-column bank, matching the kernel library's layout hints
(accumulator/weight arrays vs streamed inputs on opposite buses).

Heterogeneity (``het``) — the compute-provisioning axis (how much FU
capability each tile carries; the register-file size is the routing-
provisioning axis — together the compute-vs-communication trade of
"Aligned Compute and Communication Provisioning for CGRAs",
arXiv 2412.08137):
  none     homogeneous FUs (every PE has the full op set)
  alulite  interior PEs keep only the arithmetic core (add/sub/mul/
           shl/shr + const/livein); compare/select/bitwise logic — the
           induction-chain machinery of coalesced kernels — is restricted
           to the boundary columns, modeling cheap ALU-lite interior
           tiles.  (Memory ops are always boundary-only: LOAD/STORE must
           reach a bank bus regardless of the op set.)
  mulring  interior PEs drop the multiplier (everything else stays):
           multiplies ride a ring of full-FU boundary tiles, modeling
           the area-dominant multiplier being provisioned only where
           operands stream in.
  checker  checkerboard interiors: alternating interior PEs are ALU-lite
           (by ``(row + col)`` parity), the rest keep the full set —
           half-way compute provisioning between ``none`` and
           ``alulite``.

The search operators at the bottom (``axis_domains`` / ``mutate`` /
``crossover`` / ``point_valid``) treat the knobs as genes over the
domains a candidate universe spans — the seeded evolutionary driver in
:mod:`repro.dse.search` is built on them.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Dict, List, Sequence, Tuple

from ..core.adl import CGRAArch, MemBank
from ..core.dfg import ALU_OPS, MEM_OPS, Op

# the arithmetic core every PE keeps under "alulite" heterogeneity
LITE_OPS = frozenset(o.value for o in (Op.ADD, Op.SUB, Op.MUL, Op.SHL,
                                       Op.SHR, Op.CONST, Op.LIVEIN))
# the homogeneous full FU op set (CGRAArch's default)
FULL_OPS = frozenset(o.value for o in (ALU_OPS | MEM_OPS
                                       | {Op.CONST, Op.LIVEIN}))
# "mulring": interior tiles keep everything but the multiplier
NOMUL_OPS = FULL_OPS - frozenset((Op.MUL.value,))

HET_KINDS = ("none", "alulite", "mulring", "checker")


@dataclass(frozen=True)
class ArchPoint:
    """One coordinate of the CGRA design space (see module docstring)."""
    rows: int
    cols: int
    torus: bool = False
    regfile_size: int = 8
    bank_kb: int = 8
    banks_per_col: int = 1
    het: str = "none"

    @property
    def name(self) -> str:
        """Deterministic variant name — the checkpoint / report / cache
        identity of this point."""
        topo = "torus" if self.torus else "mesh"
        n_banks = 2 * self.banks_per_col
        s = (f"dse-{self.rows}x{self.cols}-{topo}-rf{self.regfile_size}"
             f"-b{n_banks}x{self.bank_kb}k")
        if self.het != "none":
            s += f"-{self.het}"
        return s

    def to_json_dict(self) -> Dict:
        return asdict(self)

    @staticmethod
    def from_json_dict(d: Dict) -> "ArchPoint":
        return ArchPoint(**d)

    def build(self) -> CGRAArch:
        """Materialize (and validate) the CGRAArch for this point."""
        rows, cols = self.rows, self.cols
        if cols < 2:
            raise ValueError(f"{self.name}: need >= 2 columns for "
                             f"left/right boundary memory buses")
        if self.banks_per_col not in (1, 2):
            raise ValueError(f"{self.name}: banks_per_col must be 1 or 2")
        if self.banks_per_col == 2 and rows < 2:
            raise ValueError(f"{self.name}: banks_per_col=2 needs >= 2 rows")
        if self.het not in HET_KINDS:
            raise ValueError(f"{self.name}: unknown het kind {self.het!r} "
                             f"(choose from {HET_KINDS})")

        left = [r * cols + 0 for r in range(rows)]
        right = [r * cols + (cols - 1) for r in range(rows)]
        size = self.bank_kb * 1024
        banks: List[MemBank] = []
        if self.banks_per_col == 1:
            banks = [MemBank(0, size, tuple(left)),
                     MemBank(1, size, tuple(right))]
        else:
            half = rows // 2
            banks = [MemBank(0, size, tuple(left[:half])),
                     MemBank(1, size, tuple(right[:half])),
                     MemBank(2, size, tuple(left[half:])),
                     MemBank(3, size, tuple(right[half:]))]

        # logical clustering: tile 4x4 clusters when the grid allows more
        # than one (the paper's 8x8 = 4 clusters), else one cluster
        if rows % 4 == 0 and cols % 4 == 0 and rows * cols > 16:
            clusters = [[(cr * 4 + r) * cols + (cc * 4 + c)
                         for r in range(4) for c in range(4)]
                        for cr in range(rows // 4) for cc in range(cols // 4)]
        else:
            clusters = [list(range(rows * cols))]

        per_pe_ops: Dict[int, frozenset] = {}
        if self.het != "none":
            boundary = set(left) | set(right)
            interior = [p for p in range(rows * cols) if p not in boundary]
            if self.het == "alulite":
                per_pe_ops = {p: LITE_OPS for p in interior}
            elif self.het == "mulring":
                per_pe_ops = {p: NOMUL_OPS for p in interior}
            else:  # checker
                per_pe_ops = {p: LITE_OPS for p in interior
                              if (p // cols + p % cols) % 2 == 1}

        arch = CGRAArch(name=self.name, rows=rows, cols=cols,
                        datapath_bits=16, regfile_size=self.regfile_size,
                        banks=banks, torus=self.torus,
                        per_pe_ops=per_pe_ops, clusters=clusters)
        arch.validate()
        return arch


# ------------------------------------------------------------------ spaces
def tiny_space() -> List[ArchPoint]:
    """Four variants for CI smoke — a strict subset of ``small`` so the
    smoke BENCH rows stay comparable against the committed small-sweep
    baseline."""
    return [
        ArchPoint(4, 4),
        ArchPoint(4, 4, torus=True),
        ArchPoint(4, 4, regfile_size=4),
        ArchPoint(4, 4, banks_per_col=2, bank_kb=4),
    ]


def small_space() -> List[ArchPoint]:
    """The default sweep: 20 variants spanning every knob, centered on
    grids the whole kernel library maps onto comfortably (the 4x4
    cluster family, 4x8, 8x8), plus aggressive stretch points — 2x2 and
    2x4 grids, ALU-lite interiors, small register files — where some
    kernels legitimately fail to map within ``ii_max`` (the sweep driver
    records those as per-kernel statuses and drops the variant from the
    Pareto candidate set)."""
    pts = list(tiny_space())
    pts += [
        ArchPoint(4, 4, regfile_size=16),
        ArchPoint(4, 4, torus=True, regfile_size=4),
        ArchPoint(4, 4, torus=True, regfile_size=16),
        ArchPoint(4, 4, torus=True, banks_per_col=2, bank_kb=4),
        ArchPoint(4, 4, banks_per_col=2),
        ArchPoint(4, 4, torus=True, banks_per_col=2),
        ArchPoint(4, 4, regfile_size=16, banks_per_col=2, bank_kb=4),
        ArchPoint(4, 4, torus=True, regfile_size=16, banks_per_col=2,
                  bank_kb=4),
        ArchPoint(4, 8),
        ArchPoint(4, 8, torus=True),
        ArchPoint(8, 8),
        ArchPoint(8, 8, torus=True),
        # stretch points: minimal grids and heterogeneous interiors
        ArchPoint(2, 2),
        ArchPoint(2, 4),
        ArchPoint(4, 4, het="alulite"),
        ArchPoint(4, 4, torus=True, het="alulite"),
    ]
    return pts


def full_space() -> List[ArchPoint]:
    """The exhaustive grid: every knob combination over 2x2..8x8 grids.
    Deterministic enumeration order; infeasible/unmappable points are
    sweep results ("unmapped"), not errors."""
    pts: List[ArchPoint] = []
    for rows, cols in ((2, 2), (2, 4), (4, 4), (4, 8), (6, 6), (8, 8)):
        for torus in (False, True):
            for rf in (4, 8, 16):
                for bank_kb, bpc in ((8, 1), (4, 2), (8, 2)):
                    for het in ("none", "alulite"):
                        if het == "alulite" and cols <= 2:
                            continue  # no interior PEs to restrict
                        pts.append(ArchPoint(rows, cols, torus=torus,
                                             regfile_size=rf,
                                             bank_kb=bank_kb,
                                             banks_per_col=bpc, het=het))
    return pts


def wide_space() -> List[ArchPoint]:
    """The widened search universe (~500 points): the ``full`` grid plus
    a big-single-bank provisioning option (16 kB x 1 per column) and the
    two heterogeneity kinds beyond ALU-lite (``mulring``, ``checker``).
    Deliberately too large to sweep exhaustively in CI — the seeded
    search driver (:mod:`repro.dse.search`) is how it gets explored.
    ``full_space()`` is a strict subset (same validity rule, superset
    axes), so exhaustive baselines stay comparable."""
    pts: List[ArchPoint] = []
    for rows, cols in ((2, 2), (2, 4), (4, 4), (4, 8), (6, 6), (8, 8)):
        for torus in (False, True):
            for rf in (4, 8, 16):
                for bank_kb, bpc in ((8, 1), (4, 2), (8, 2), (16, 1)):
                    for het in HET_KINDS:
                        p = ArchPoint(rows, cols, torus=torus,
                                      regfile_size=rf, bank_kb=bank_kb,
                                      banks_per_col=bpc, het=het)
                        if point_valid(p):
                            pts.append(p)
    return pts


SPACE_NAMES = ("tiny", "small", "full", "wide")


def get_space(name: str) -> List[ArchPoint]:
    try:
        return {"tiny": tiny_space, "small": small_space,
                "full": full_space, "wide": wide_space}[name]()
    except KeyError:
        raise ValueError(f"unknown space {name!r} (choose from "
                         f"{SPACE_NAMES})") from None


# -------------------------------------------------------- search operators
# the knob axes a point decomposes into (grid and bank move as pairs: a
# row count without its column count — or a bank size without its port
# count — is not a meaningful half-gene)
AXES = ("grid", "torus", "regfile_size", "bank", "het")


def genes(p: ArchPoint) -> Dict[str, object]:
    """Decompose a point into its knob genes, keyed by ``AXES``."""
    return {"grid": (p.rows, p.cols), "torus": p.torus,
            "regfile_size": p.regfile_size,
            "bank": (p.bank_kb, p.banks_per_col), "het": p.het}


def from_genes(g: Dict[str, object]) -> ArchPoint:
    """Reassemble an :class:`ArchPoint` from a gene dict."""
    rows, cols = g["grid"]
    bank_kb, bpc = g["bank"]
    return ArchPoint(rows, cols, torus=bool(g["torus"]),
                     regfile_size=int(g["regfile_size"]),
                     bank_kb=int(bank_kb), banks_per_col=int(bpc),
                     het=str(g["het"]))


def point_valid(p: ArchPoint) -> bool:
    """Structural validity of a point — the same rules ``build()``
    enforces, plus "heterogeneity needs interior PEs" (``cols > 2``),
    which ``full_space``/``wide_space`` enumeration also applies.  Search
    operators cross and mutate genes freely and discard what fails
    here."""
    if p.cols < 2 or p.rows < 1 or p.banks_per_col not in (1, 2):
        return False
    if p.banks_per_col == 2 and p.rows < 2:
        return False
    if p.het not in HET_KINDS:
        return False
    if p.het != "none" and p.cols <= 2:
        return False
    return True


def axis_domains(points: Sequence[ArchPoint]) -> Dict[str, List]:
    """Per-axis value domains spanned by a candidate universe, in
    deterministic order — the gene pool the search operators draw from.
    Crossing domain values can produce combinations absent from the
    input list; that widening is intentional (``point_valid`` is the only
    fence)."""
    pts = list(points)
    return {
        "grid": sorted({(p.rows, p.cols) for p in pts}),
        "torus": sorted({p.torus for p in pts}),
        "regfile_size": sorted({p.regfile_size for p in pts}),
        "bank": sorted({(p.bank_kb, p.banks_per_col) for p in pts}),
        "het": sorted({p.het for p in pts}, key=HET_KINDS.index),
    }


def mutate(rng, p: ArchPoint, domains: Dict[str, List],
           rate: float = 0.25) -> ArchPoint:
    """Seeded point mutation: each knob independently resamples from its
    domain with probability ``rate`` (at least one knob always moves);
    invalid gene combinations redraw (bounded), falling back to the
    parent.  Deterministic for a given ``rng`` state."""
    for _ in range(8):
        g = genes(p)
        moved = False
        for axis in AXES:
            dom = domains.get(axis, [])
            if len(dom) > 1 and rng.random() < rate:
                g[axis] = dom[rng.randrange(len(dom))]
                moved = True
        if not moved:
            movable = [ax for ax in AXES if len(domains.get(ax, [])) > 1]
            if not movable:
                return p
            ax = movable[rng.randrange(len(movable))]
            dom = domains[ax]
            g[ax] = dom[rng.randrange(len(dom))]
        q = from_genes(g)
        if q != p and point_valid(q):
            return q
    return p


def crossover(rng, a: ArchPoint, b: ArchPoint) -> ArchPoint:
    """Seeded uniform crossover: each knob comes from either parent with
    equal probability; an invalid child falls back to parent ``a``.
    Deterministic for a given ``rng`` state."""
    ga, gb = genes(a), genes(b)
    g = {axis: (ga[axis] if rng.random() < 0.5 else gb[axis])
         for axis in AXES}
    q = from_genes(g)
    return q if point_valid(q) else a
