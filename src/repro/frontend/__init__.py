"""repro.frontend — the traced, Pallas-style kernel DSL.

The compile entry point's authoring layer (paper Fig. 3 piece 3/4): kernel
bodies are restricted Python over a :class:`KernelContext`, lowered by the
tracer to the DFG + data-layout + invocation-schedule triple that
``Toolchain.compile`` consumes:

    from repro.frontend import KernelContext, trace
    from repro.core import Toolchain

    ctx = KernelContext("triple", layout)
    X, Y = ctx.arrays("X", "Y")
    n = ctx.counter(stop=N - 1)
    Y[n] = X[n] * 3
    dfg = ctx.build()

Higher-level pieces:

  * :mod:`repro.frontend.tracer` — the tracer (``TracedValue``,
    ``ArrayRef``, counter/coalesce primitives, ``unroll``).
  * :mod:`repro.frontend.library` — DSL-only kernels beyond Table I
    (depthwise conv, average pooling, bias+ReLU GEMM epilogue, int8
    requantize) plus :class:`KernelProgram`, the arch-deferred form
    ``Toolchain.compile`` accepts directly.

Attributes resolve lazily (PEP 562, same idiom as ``repro.core``) so that
``repro.core.kernels_lib`` can import the tracer without dragging the
kernel library (which itself imports ``kernels_lib``) into the cycle.
"""
from __future__ import annotations

import importlib

_EXPORTS = {
    "KernelContext": ".tracer",
    "TracedValue": ".tracer",
    "ArrayRef": ".tracer",
    "TraceError": ".tracer",
    "trace": ".tracer",
    "unroll": ".tracer",
    "KernelProgram": ".library",
    "build_dwconv": ".library",
    "build_avgpool2x2": ".library",
    "build_gemm_bias_relu": ".library",
    "build_requant_int8": ".library",
    "dsl_kernels": ".library",
    "DSL_PROGRAMS": ".library",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    try:
        modname = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    return getattr(importlib.import_module(modname, __name__), name)


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
