"""DSL-only kernel library: workloads beyond the paper's Table I.

These kernels exist only as traced front-end programs — there is no
hand-built ``DFGBuilder`` counterpart, which is the point: each is a
handful of lines over a :class:`KernelContext` where the equivalent manual
node wiring would be another ~60-line builder.

  dwconv          depthwise 3x3 conv, C channels (MobileNet-style stage)
  avgpool2x2      2x2 average pooling (stride 2, power-of-two divide)
  gemm-bias-relu  fused bias + ReLU GEMM epilogue (output tile post-pass)
  requant-int8    int8 requantization (multiplier/shift + saturation),
                  the CGRA-side model of ``repro.kernels.qgemm_int8``'s
                  output stage — its golden is the same ``requantize_ref``

:class:`KernelProgram` wraps a builder so kernels can be handed to
``Toolchain.compile`` before an architecture is chosen (the toolchain
binds its own default target).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import numpy as np

from ..core.adl import CGRAArch, cluster_4x4
from ..core.kernels_lib import KernelSpec, _bank_arrays, _wrap16
from ..core.layout import ArrayDecl, DataLayout, assign_layout
from .tracer import KernelContext, unroll


@dataclass(frozen=True)
class KernelProgram:
    """An arch-deferred DSL kernel: ``bind(arch)`` traces it into the
    :class:`KernelSpec` that ``Toolchain.compile`` consumes (and
    ``Toolchain.compile`` accepts a ``KernelProgram`` directly, binding
    its own default architecture)."""
    name: str
    build: Callable[[Optional[CGRAArch]], KernelSpec]

    def bind(self, arch: Optional[CGRAArch] = None) -> KernelSpec:
        return self.build(arch)


def _placed(layout: DataLayout, *names: str):
    return tuple(layout.placements[n] for n in names)


# ======================================================================
# Depthwise 3x3 convolution: O[c,i,j] += I[c,i+k1,j+k2] * W[c,k1,k2]
# ======================================================================
def build_dwconv(C: int = 2, OH: int = 5, OW: int = 5, K: int = 3,
                 arch: Optional[CGRAArch] = None) -> KernelSpec:
    """Depthwise conv: per-channel KxK filters, fully unrolled MACs, the
    innermost spatial j loop mapped, (c, i) live-ins per invocation."""
    arch = arch or cluster_4x4()
    IH, IW = OH + K - 1, OW + K - 1
    layout = assign_layout(arch, [
        ArrayDecl("O", C * OH * OW, bank_pref=0),
        ArrayDecl("W", C * K * K, bank_pref=0),
        ArrayDecl("I", C * IH * IW, bank_pref=1),
    ])

    ctx = KernelContext("dwconv", layout)
    W, I, O = ctx.arrays("W", "I", "O")
    c, i = ctx.livein("c"), ctx.livein("i")
    j = ctx.counter(stop=OW - 1, name="j")

    ibase = c * (IH * IW)                 # channel planes
    wbase = c * (K * K)
    oa = O.addr(c * (OH * OW) + i * OW + j)
    oval = O.at(oa, name="oval")
    prods = []
    for k1 in unroll(K):
        row = ibase + (i + k1) * IW
        for k2 in unroll(K):
            prods.append(I[row + (j + k2)] * W[wbase + k1 * K + k2])
    st = O.store_at(oa, oval + ctx.treesum(prods), name="ost")
    ctx.loop_carried(st, oval)
    dfg = ctx.build()

    pw, pi, po = _placed(layout, "W", "I", "O")

    def init(rng: np.random.Generator) -> Dict[str, np.ndarray]:
        banks = _bank_arrays(layout)
        banks[pi.bank_array][pi.base:pi.base + pi.words] = \
            rng.integers(-8, 8, size=C * IH * IW)
        banks[pw.bank_array][pw.base:pw.base + pw.words] = \
            rng.integers(-4, 4, size=C * K * K)
        return banks

    def golden(banks: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        out = {k: v.copy() for k, v in banks.items()}
        Iv = banks[pi.bank_array][pi.base:pi.base + pi.words].reshape(C, IH, IW)
        Wv = banks[pw.bank_array][pw.base:pw.base + pw.words].reshape(C, K, K)
        Ov = banks[po.bank_array][po.base:po.base + po.words] \
            .reshape(C, OH, OW).astype(np.int64)
        for k1 in range(K):
            for k2 in range(K):
                Ov = Ov + Iv[:, k1:k1 + OH, k2:k2 + OW] * Wv[:, k1:k1 + 1,
                                                             k2:k2 + 1]
        out[po.bank_array][po.base:po.base + po.words] = \
            _wrap16(Ov).reshape(-1)
        return out

    return KernelSpec(
        name=dfg.name, dfg=dfg, arch=arch, layout=layout,
        mapped_iters=OW,
        invocations=[{"c": cc, "i": ii} for cc in range(C)
                     for ii in range(OH)],
        golden=golden, init_banks=init,
        meta=dict(C=C, OH=OH, OW=OW, K=K, liveins_per_inv=2))


# ======================================================================
# 2x2 average pooling (stride 2): O[i,j] = mean of the 2x2 input window
# ======================================================================
def build_avgpool2x2(OH: int = 6, OW: int = 6,
                     arch: Optional[CGRAArch] = None) -> KernelSpec:
    """Average pooling with the power-of-two divide as an arithmetic
    shift — a pure streaming kernel (no accumulator recurrence)."""
    arch = arch or cluster_4x4()
    IH, IW = 2 * OH, 2 * OW
    layout = assign_layout(arch, [
        ArrayDecl("O", OH * OW, bank_pref=0),
        ArrayDecl("I", IH * IW, bank_pref=1),
    ])

    ctx = KernelContext("avgpool2x2", layout)
    I, O = ctx.arrays("I", "O")
    i = ctx.livein("i")
    j = ctx.counter(stop=OW - 1, name="j")

    r0 = (i + i) * IW                      # top row of the window
    j2 = j + j
    s = (I[r0 + j2] + I[r0 + (j2 + 1)]
         + I[(r0 + IW) + j2] + I[(r0 + IW) + (j2 + 1)])
    O[i * OW + j] = s >> 2
    dfg = ctx.build()

    pi, po = _placed(layout, "I", "O")

    def init(rng: np.random.Generator) -> Dict[str, np.ndarray]:
        banks = _bank_arrays(layout)
        banks[pi.bank_array][pi.base:pi.base + pi.words] = \
            rng.integers(0, 64, size=IH * IW)
        return banks

    def golden(banks: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        out = {k: v.copy() for k, v in banks.items()}
        Iv = banks[pi.bank_array][pi.base:pi.base + pi.words].reshape(IH, IW)
        Ov = (Iv[0::2, 0::2] + Iv[0::2, 1::2]
              + Iv[1::2, 0::2] + Iv[1::2, 1::2]) >> 2
        out[po.bank_array][po.base:po.base + po.words] = \
            _wrap16(Ov).reshape(-1)
        return out

    return KernelSpec(
        name=dfg.name, dfg=dfg, arch=arch, layout=layout,
        mapped_iters=OW,
        invocations=[{"i": ii} for ii in range(OH)],
        golden=golden, init_banks=init,
        meta=dict(OH=OH, OW=OW, liveins_per_inv=1))


# ======================================================================
# Fused bias + ReLU GEMM epilogue: O[i,j] = relu(ACC[i,j] + B[j])
# ======================================================================
def build_gemm_bias_relu(TI: int = 6, TJ: int = 6,
                         arch: Optional[CGRAArch] = None) -> KernelSpec:
    """The GEMM output-tile epilogue fused on the fabric: per-column bias
    add plus ReLU saturation over the accumulator tile."""
    arch = arch or cluster_4x4()
    layout = assign_layout(arch, [
        ArrayDecl("ACC", TI * TJ, bank_pref=0),
        ArrayDecl("O", TI * TJ, bank_pref=0),
        ArrayDecl("B", TJ, bank_pref=1),
    ])

    ctx = KernelContext("gemm-bias-relu", layout)
    ACC, B, O = ctx.arrays("ACC", "B", "O")
    i = ctx.livein("i")
    j = ctx.counter(stop=TJ - 1, name="j")

    row = i * TJ + j
    O[row] = ctx.relu(ACC[row] + B[j])
    dfg = ctx.build()

    pa, pb, po = _placed(layout, "ACC", "B", "O")

    def init(rng: np.random.Generator) -> Dict[str, np.ndarray]:
        banks = _bank_arrays(layout)
        banks[pa.bank_array][pa.base:pa.base + pa.words] = \
            rng.integers(-512, 512, size=TI * TJ)
        banks[pb.bank_array][pb.base:pb.base + pb.words] = \
            rng.integers(-64, 64, size=TJ)
        return banks

    def golden(banks: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        out = {k: v.copy() for k, v in banks.items()}
        A = banks[pa.bank_array][pa.base:pa.base + pa.words].reshape(TI, TJ)
        Bv = banks[pb.bank_array][pb.base:pb.base + pb.words]
        Ov = np.maximum(_wrap16(A + Bv[None, :]), 0)
        out[po.bank_array][po.base:po.base + po.words] = Ov.reshape(-1)
        return out

    return KernelSpec(
        name=dfg.name, dfg=dfg, arch=arch, layout=layout,
        mapped_iters=TJ,
        invocations=[{"i": ii} for ii in range(TI)],
        golden=golden, init_banks=init,
        meta=dict(TI=TI, TJ=TJ, liveins_per_inv=1))


# ======================================================================
# int8 requantization: R[n] = clamp((X[n] * mult) >> shift, -127, 127)
# ======================================================================
def build_requant_int8(N: int = 48, mult: int = 3, shift: int = 5,
                       arch: Optional[CGRAArch] = None) -> KernelSpec:
    """The output stage of ``repro.kernels.qgemm_int8`` on the fabric:
    fixed-point multiplier/shift requantization with int8 saturation.

    The golden model *is* ``repro.kernels.qgemm_int8.ref.requantize_ref``
    — the CGRA kernel and the Pallas datapath share one oracle, so the
    two implementations of the edge-inference int8 path are pinned to the
    same rounding and saturation semantics.
    """
    arch = arch or cluster_4x4()
    assert 0 < mult < 16 and 0 <= shift < 15
    layout = assign_layout(arch, [
        ArrayDecl("R", N, bank_pref=0),
        ArrayDecl("X", N, bank_pref=1),
    ])

    ctx = KernelContext("requant-int8", layout)
    X, R = ctx.arrays("X", "R")
    n = ctx.counter(stop=N - 1, name="n")
    R[n] = ctx.clamp((X[n] * mult) >> shift, -127, 127)
    dfg = ctx.build()

    px, pr = _placed(layout, "X", "R")

    def init(rng: np.random.Generator) -> Dict[str, np.ndarray]:
        banks = _bank_arrays(layout)
        # int16-safe accumulator range: |x * mult| < 2**15
        banks[px.bank_array][px.base:px.base + px.words] = \
            rng.integers(-2048, 2048, size=N)
        return banks

    def golden(banks: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        from ..kernels.qgemm_int8.ref import requantize_ref  # lazy: numpy path
        out = {k: v.copy() for k, v in banks.items()}
        x = banks[px.bank_array][px.base:px.base + px.words]
        out[pr.bank_array][pr.base:pr.base + pr.words] = \
            requantize_ref(x.astype(np.int64), mult, shift)
        return out

    return KernelSpec(
        name=dfg.name, dfg=dfg, arch=arch, layout=layout,
        mapped_iters=N, invocations=[{}],
        golden=golden, init_banks=init,
        meta=dict(N=N, mult=mult, shift=shift, liveins_per_inv=0))


# ----------------------------------------------------------------- registry
DSL_PROGRAMS: List[KernelProgram] = [
    KernelProgram("dwconv", lambda arch=None: build_dwconv(arch=arch)),
    KernelProgram("avgpool2x2",
                  lambda arch=None: build_avgpool2x2(arch=arch)),
    KernelProgram("gemm-bias-relu",
                  lambda arch=None: build_gemm_bias_relu(arch=arch)),
    KernelProgram("requant-int8",
                  lambda arch=None: build_requant_int8(arch=arch)),
]


def dsl_kernels(arch: Optional[CGRAArch] = None) -> Dict[str, KernelSpec]:
    """The four DSL-only kernels, traced against ``arch`` (default:
    the paper's 4x4 cluster)."""
    return {p.name: p.bind(arch) for p in DSL_PROGRAMS}
