"""Trace-based kernel DSL: the Pallas-style front end over the DFG IR.

Users write the body of the *mapped* loop level as restricted Python over a
:class:`KernelContext` — loads/stores through :class:`ArrayRef` handles,
arithmetic on :class:`TracedValue` operands, induction variables through
``ctx.counter`` / ``ctx.wrapping_counter`` / ``ctx.gated_counter`` — and the
tracer lowers it to the existing :class:`~repro.core.dfg.DFG`:

    def body(ctx):
        X, Y = ctx.arrays("X", "Y")
        n = ctx.counter(stop=N - 1, name="n")
        Y[n] = X[n] * 3

    dfg = trace(body, name="triple", layout=layout)

Tracing rules (what "restricted Python" means):

  * Plain Python ints stay compile-time: ``k1 * K + k2`` over ints emits no
    nodes; an int only materializes as a CONST node when it meets a traced
    value (constants and live-ins are CSE-cached, like the LLVM pass).
  * ``tv + 0`` / ``tv - 0`` fold away — so base offsets of bank-resident
    arrays and zero unroll offsets cost nothing, exactly as a hand-built
    DFG would elide them.
  * Python ``for`` loops over ``range`` are compile-time unrolling; the
    :func:`unroll` helper is the declarative spelling of the same thing.
  * Loop-carried scalar state is declared through the counter primitives
    (which patch the self-referential ``dist=1`` operands), and carried
    memory recurrences through ``ctx.loop_carried(store, load)``.

The tracer emits nodes in Python evaluation order, so a DSL kernel written
in the shape of its loop body produces the *same canonical DFG* as the
hand-built ``DFGBuilder`` wiring it replaces (``DFG.canonical_dict`` — node
names are cosmetic and excluded).  That is the front-end contract the
legacy Table-I kernels are pinned to in ``tests/test_frontend.py``.
"""
from __future__ import annotations

from typing import Callable, Iterable, List, Optional, Sequence, Union

from ..core.dfg import DFG, DFGBuilder, Node, Op, Operand
from ..core.layout import DataLayout


class TraceError(TypeError):
    """A DSL kernel stepped outside the restricted-Python subset."""


IntOrTraced = Union[int, "TracedValue"]


class TracedValue:
    """A scalar SSA value inside a traced kernel body.

    Wraps one DFG node id; arithmetic operators emit new nodes on the
    owning context.  Comparisons return traced 0/1 values (CMPGE/CMPEQ/
    CMPLT), not Python bools — use them only as SELECT conditions.
    """
    __slots__ = ("ctx", "id")

    def __init__(self, ctx: "KernelContext", nid: int):
        self.ctx = ctx
        self.id = nid

    def __repr__(self) -> str:
        n = self.ctx._b.dfg.nodes[self.id]
        return f"<traced {n.op.value}#{self.id}>"

    def __bool__(self) -> bool:
        raise TraceError(
            "traced values have no compile-time truth value; use select() "
            "for data-dependent choices (Python `if` over traced values "
            "would un-trace the branch)")

    def __hash__(self):
        return hash((id(self.ctx), self.id))

    # ---------------------------------------------------------- arithmetic
    def __add__(self, o: IntOrTraced) -> "TracedValue":
        if isinstance(o, int) and o == 0:
            return self
        return self.ctx._node(Op.ADD, (self, o))

    def __radd__(self, o: int) -> "TracedValue":
        if o == 0:
            return self
        return self.ctx._node(Op.ADD, (o, self))

    def __sub__(self, o: IntOrTraced) -> "TracedValue":
        if isinstance(o, int) and o == 0:
            return self
        return self.ctx._node(Op.SUB, (self, o))

    def __rsub__(self, o: int) -> "TracedValue":
        return self.ctx._node(Op.SUB, (o, self))

    def __mul__(self, o: IntOrTraced) -> "TracedValue":
        if isinstance(o, int) and o == 1:
            return self
        return self.ctx._node(Op.MUL, (self, o))

    def __rmul__(self, o: int) -> "TracedValue":
        if o == 1:
            return self
        return self.ctx._node(Op.MUL, (o, self))

    def __lshift__(self, o: IntOrTraced) -> "TracedValue":
        return self.ctx._node(Op.SHL, (self, o))

    def __rshift__(self, o: IntOrTraced) -> "TracedValue":
        return self.ctx._node(Op.SHR, (self, o))

    def __and__(self, o: IntOrTraced) -> "TracedValue":
        return self.ctx._node(Op.AND, (self, o))

    def __or__(self, o: IntOrTraced) -> "TracedValue":
        return self.ctx._node(Op.OR, (self, o))

    def __xor__(self, o: IntOrTraced) -> "TracedValue":
        return self.ctx._node(Op.XOR, (self, o))

    # -------------------------------------------------------- comparisons
    def __ge__(self, o: IntOrTraced) -> "TracedValue":
        return self.ctx._node(Op.CMPGE, (self, o))

    def __lt__(self, o: IntOrTraced) -> "TracedValue":
        return self.ctx._node(Op.CMPLT, (self, o))

    def __eq__(self, o: IntOrTraced) -> "TracedValue":  # type: ignore[override]
        return self.ctx._node(Op.CMPEQ, (self, o))

    def __ne__(self, o):  # pragma: no cover - guard
        raise TraceError("!= is not a CGRA op; use (a == b) ^ 1")


class ArrayRef:
    """Bank-resident array handle: Pallas-``Ref``-style load/store sugar.

    ``arr[idx]`` loads, ``arr[idx] = val`` stores; ``idx`` is a flat index
    into the array (int or traced) and the data layout's base offset is
    folded into the address exactly once.  For hand-scheduled address reuse
    (unrolled bodies), ``arr.addr(idx)`` returns the based address value
    and ``arr.at`` / ``arr.store_at`` operate on raw addresses.
    """
    __slots__ = ("ctx", "name", "_placement")

    def __init__(self, ctx: "KernelContext", name: str):
        self.ctx = ctx
        if ctx.layout is None or name not in ctx.layout.placements:
            raise TraceError(f"array {name!r} is not in the kernel's data "
                             f"layout")
        self.name = name
        self._placement = ctx.layout.placements[name]

    @property
    def bank_array(self) -> str:
        return self._placement.bank_array

    @property
    def words(self) -> int:
        return self._placement.words

    def addr(self, index: IntOrTraced) -> TracedValue:
        """Based bank-local address of ``index`` (base folded in once)."""
        base = self._placement.base
        if isinstance(index, int):
            return self.ctx.const(base + index)
        if not isinstance(index, TracedValue):
            raise TraceError(f"array index must be int or traced value, "
                             f"got {type(index).__name__}")
        return index + base if base else index

    def at(self, addr: IntOrTraced, name: str = "") -> TracedValue:
        """LOAD at a raw (already based) address."""
        return self.ctx._node(Op.LOAD, (addr,), array=self.bank_array,
                              name=name)

    def store_at(self, addr: IntOrTraced, val: IntOrTraced,
                 name: str = "") -> TracedValue:
        """STORE at a raw (already based) address; returns the store node
        (feed it to ``ctx.loop_carried`` for carried recurrences)."""
        return self.ctx._node(Op.STORE, (addr, val), array=self.bank_array,
                              name=name)

    def __getitem__(self, index: IntOrTraced) -> TracedValue:
        return self.at(self.addr(index))

    def __setitem__(self, index: IntOrTraced, val: IntOrTraced) -> None:
        self.store_at(self.addr(index), val)


class KernelContext:
    """The tracing context handed to a DSL kernel body.

    Wraps a :class:`DFGBuilder`; every primitive emits IR nodes in call
    order.  ``layout`` (a :class:`DataLayout`) gives ``ctx.array`` handles
    their bank placement.
    """

    def __init__(self, name: str, layout: Optional[DataLayout] = None):
        self._b = DFGBuilder(name)
        self.layout = layout

    # ------------------------------------------------------------- plumbing
    def _coerce(self, v: IntOrTraced) -> int:
        """Value -> node id, materializing ints as cached CONSTs."""
        if isinstance(v, TracedValue):
            if v.ctx is not self:
                raise TraceError("traced value belongs to another kernel "
                                 "context")
            return v.id
        if isinstance(v, int) and not isinstance(v, bool):
            return self._b.const(v)
        raise TraceError(f"expected int or traced value, got "
                         f"{type(v).__name__} ({v!r})")

    def _node(self, op: Op, operands: Sequence[IntOrTraced] = (),
              **kw) -> TracedValue:
        # inlined DFGBuilder._add: one Operand construction per edge (this
        # is the tracer's per-node hot path)
        # operands coerce FIRST (an int may materialize a fresh CONST node),
        # then the op itself takes the next id — the emission order every
        # hand-built listing uses
        ops = tuple([Operand(self._coerce(o)) for o in operands])
        b = self._b
        nid = b._next
        b._next = nid + 1
        b.dfg.nodes[nid] = Node(nid, op, ops, **kw)
        return TracedValue(self, nid)

    def emit(self, op: Op, *operands: IntOrTraced,
             name: str = "") -> TracedValue:
        """Emit one ALU node (the escape hatch under the operator sugar)."""
        return self._node(op, operands, name=name)

    # ------------------------------------------------------------ leaves
    def const(self, v: int, name: str = "") -> TracedValue:
        """Compile-time immediate (CSE-cached CONST node)."""
        if not isinstance(v, int) or isinstance(v, bool):
            raise TraceError(f"const expects an int, got {type(v).__name__}")
        return TracedValue(self, self._b.const(v, name=name))

    def livein(self, name: str) -> TracedValue:
        """Host-preloaded outer-loop iteration variable (cached)."""
        return TracedValue(self, self._b.livein(name))

    def array(self, name: str) -> ArrayRef:
        return ArrayRef(self, name)

    def arrays(self, *names: str) -> List[ArrayRef]:
        return [ArrayRef(self, n) for n in names]

    # --------------------------------------------------- loop-carried state
    def counter(self, step: IntOrTraced = 1, *, init: Optional[int] = None,
                stop: Optional[IntOrTraced] = None,
                name: str = "") -> TracedValue:
        """Mapped-loop induction variable: ``k += step`` each iteration.

        ``init`` is the carried register's preload (default ``-step`` so
        iteration 0 observes 0; explicit for traced steps).  ``stop``
        additionally emits the loop's exit guard ``k >= stop`` (the branch
        the compiler's DFG pass would keep for the trip count).
        """
        if init is None:
            if not isinstance(step, int):
                raise TraceError("counter(init=...) is required when the "
                                 "step is a traced value")
            init = -step
        stepv = self._coerce(step)
        k = self._b.add(Operand(0, 0), stepv, name=name)
        self._b.dfg.nodes[k].operands = (Operand(k, dist=1, init=init),
                                         Operand(stepv))
        kv = TracedValue(self, k)
        if stop is not None:
            self.emit(Op.CMPGE, kv, stop, name="exit")
        return kv

    def wrapping_counter(self, step: IntOrTraced, stop: IntOrTraced, *,
                         init: int = 0, advance: Optional[TracedValue] = None,
                         name: str = ""):
        """One level of a coalesced loop nest: a counter that wraps to 0 at
        ``stop``.  Returns ``(value, wrapped)`` where ``wrapped`` is the
        0/1 carry into the next-outer level.

        Innermost levels advance every iteration (``advance=None``); outer
        levels advance only when the inner carry fires (``advance=carry``).
        """
        stepv = self._coerce(step)
        nid = self._b.add(Operand(0, 0), stepv, name=f"{name}new")
        new = TracedValue(self, nid)
        wrap = self.emit(Op.CMPGE, new, stop, name=f"{name}wrap")
        if advance is None:
            val = self.select(wrap, self.const(0), new, name=name)
        else:
            sel = self.select(wrap, self.const(0), new, name=f"{name}sel")
            vid = self._b.select(advance.id, sel.id, Operand(0, 0), name=name)
            self._b.dfg.nodes[vid].operands = (
                Operand(advance.id), Operand(sel.id),
                Operand(vid, dist=1, init=init))
            val = TracedValue(self, vid)
        self._b.dfg.nodes[nid].operands = (Operand(val.id, dist=1, init=init),
                                           Operand(stepv))
        return val, wrap

    def gated_counter(self, step: IntOrTraced, advance: TracedValue, *,
                      init: int = 0, name: str = "") -> TracedValue:
        """Outermost coalesced level: counts ``+step`` only on the cycles
        where ``advance`` is 1 (no wrap of its own)."""
        stepv = self._coerce(step)
        nid = self._b.add(Operand(0, 0), stepv, name=f"{name}new")
        vid = self._b.select(advance.id, nid, Operand(0, 0), name=name)
        self._b.dfg.nodes[nid].operands = (Operand(vid, dist=1, init=init),
                                           Operand(stepv))
        self._b.dfg.nodes[vid].operands = (
            Operand(advance.id), Operand(nid),
            Operand(vid, dist=1, init=init))
        return TracedValue(self, vid)

    def coalesce(self, *levels, name_prefix: str = ""):
        """Coalesce a loop nest into one mapped loop (Listing 4/5 idiom).

        ``levels`` are ``(trip, step)`` (or bare ``trip``) pairs ordered
        outermost-first; returns the induction values in the same order.
        The innermost level wraps every iteration; each outer level
        advances on the inner carry, the outermost never wraps.
        """
        lv = [(l, 1) if isinstance(l, int) else tuple(l) for l in levels]
        if len(lv) < 2:
            raise TraceError("coalesce needs at least two loop levels")
        # materialize consts up front in the canonical Listing-4 order:
        # inner step, inner stop, outer wrapping stops (inner->outer),
        # then 0 and 1
        self._coerce(lv[-1][1])
        self._coerce(lv[-1][0])
        for trip, _step in reversed(lv[1:-1]):
            self._coerce(trip)
        self.const(0)
        self.const(1)
        vals: List[TracedValue] = []
        carry: Optional[TracedValue] = None
        for depth, (trip, step) in enumerate(reversed(lv[1:])):
            v, wrap = self.wrapping_counter(
                step, trip, init=-step if depth == 0 else 0, advance=carry)
            carry = wrap if carry is None else self.emit(Op.AND, carry, wrap,
                                                         name="carry")
            vals.append(v)
        vals.append(self.gated_counter(lv[0][1], carry))
        return tuple(reversed(vals))

    def loop_carried(self, store: TracedValue, load: TracedValue,
                     dist: int = 1) -> None:
        """Declare the carried memory recurrence store -> next-iter load
        (the output-stationary accumulator ordering edge)."""
        self._b.mem_dep(store.id, load.id, dist=dist)

    # ------------------------------------------------------------ helpers
    def select(self, cond: TracedValue, a: IntOrTraced, b: IntOrTraced,
               name: str = "") -> TracedValue:
        """``a if cond else b`` as a predicated SELECT node."""
        return self._node(Op.SELECT, (cond, a, b), name=name)

    def treesum(self, values: Iterable[IntOrTraced]) -> TracedValue:
        """Balanced pairwise reduction of unrolled partial products."""
        vals = [v if isinstance(v, TracedValue) else self.const(v)
                for v in values]
        if not vals:
            raise TraceError("treesum of no values")
        while len(vals) > 1:
            nxt = [self.emit(Op.ADD, vals[t], vals[t + 1])
                   for t in range(0, len(vals) - 1, 2)]
            if len(vals) % 2:
                nxt.append(vals[-1])
            vals = nxt
        return vals[0]

    def accumulate(self, arr: ArrayRef, addr: IntOrTraced,
                   val: IntOrTraced, name: str = "o") -> TracedValue:
        """Read-modify-write ``arr[addr] += val`` with the loop-carried
        store->load ordering edge (output-stationary accumulator)."""
        old = arr.at(addr, name=f"{name}val")
        acc = self.emit(Op.ADD, old, val, name="acc")
        st = arr.store_at(addr, acc, name=f"{name}st")
        self.loop_carried(st, old)
        return st

    def relu(self, v: TracedValue) -> TracedValue:
        """max(v, 0) via CMPGE + SELECT (the fused-epilogue idiom)."""
        ge = self.emit(Op.CMPGE, v, self.const(0))
        return self.select(ge, v, self.const(0), name="relu")

    def clamp(self, v: TracedValue, lo: int, hi: int) -> TracedValue:
        """Saturate v into [lo, hi] (requantization epilogues)."""
        chi, clo = self.const(hi), self.const(lo)
        over = self.emit(Op.CMPGE, v, chi)
        v = self.select(over, chi, v)
        under = self.emit(Op.CMPLT, v, clo)
        return self.select(under, clo, v, name="clamp")

    # -------------------------------------------------------------- finish
    def build(self) -> DFG:
        return self._b.build()


def unroll(n: int) -> range:
    """Compile-time unroll marker: iterate the traced body ``n`` times.

    Python loops over the result are fully unrolled into the DFG — this is
    the declarative spelling of ``range(n)`` inside a kernel body.
    """
    if not isinstance(n, int) or n < 1:
        raise TraceError(f"unroll expects a positive int, got {n!r}")
    return range(n)


def trace(body: Callable[[KernelContext], None], *, name: str,
          layout: Optional[DataLayout] = None) -> DFG:
    """Run ``body`` under a fresh tracing context and return the lowered,
    validated DFG."""
    ctx = KernelContext(name, layout)
    body(ctx)
    return ctx.build()
