"""Instruction-stream backend: per-PE stream export + standalone
interpreter + bit-exact cross-validation (ROADMAP hardware-facing leg).

    from repro.isa import export_streams, load_stream, interpret

    ck = Toolchain().compile(spec)
    export_streams(ck, "out/gemm")        # instructions.csv / kernel.asm /
                                          # stream_manifest.json
    stream = load_stream("out/gemm")
    final = interpret(stream, init_banks, ck.invocations, ck.mapped_iters)

    from repro.isa import cross_validate
    cross_validate(ck, seeds=(0, 1))      # interpreter ≡ simulate(), bitwise

The exported artifacts are byte-deterministic (the repo's standing
contract: two cold exports of the same kernel ``cmp`` equal), and the
interpreter shares no code with the JAX simulator — it is the flow's
independent second oracle (``MORPHER_XVAL=1`` enables it inside verify).
"""
from .encode import (ASM_NAME, CSV_NAME, MANIFEST_NAME, STREAM_FORMAT,
                     encode_kernel, export_streams, to_asm, to_csv,
                     to_manifest_json)
from .interp import (InstructionStream, StreamError, interpret, load_stream,
                     parse_stream)
from .xval import cross_validate, cross_validate_dir, stream_for

__all__ = [
    "ASM_NAME", "CSV_NAME", "MANIFEST_NAME", "STREAM_FORMAT",
    "InstructionStream", "StreamError",
    "cross_validate", "cross_validate_dir", "encode_kernel",
    "export_streams", "interpret", "load_stream", "parse_stream",
    "stream_for", "to_asm", "to_csv", "to_manifest_json",
]
