"""CLI: export per-PE instruction streams for the kernel library.

    python -m repro.isa --out streams                 # ten kernels, small
    python -m repro.isa --out streams --xval --seeds 0,1
    python -m repro.isa --out streams --kernels GEMM,CONV

Each kernel lands in ``<out>/<kernel>/`` as ``instructions.csv`` /
``kernel.asm`` / ``stream_manifest.json``.  The artifacts are
byte-deterministic: exporting twice and ``cmp``-ing is the CI
``isa-smoke`` determinism check.  ``--xval`` re-parses the on-disk
artifacts through the standalone interpreter and asserts bit-identity
with ``simulate()`` for every seed.
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import List, Optional


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.isa",
        description="export per-PE instruction streams "
                    "(+ optional cross-validation)")
    ap.add_argument("--out", required=True,
                    help="output directory (one subdirectory per kernel)")
    ap.add_argument("--kernels", default=None,
                    help="comma-separated subset (default: the full "
                         "ten-kernel library)")
    ap.add_argument("--table1", action="store_true",
                    help="restrict to the six Table-I kernels")
    ap.add_argument("--xval", action="store_true",
                    help="cross-validate the exported streams against "
                         "simulate() bit-for-bit")
    ap.add_argument("--seeds", default="0",
                    help="comma-separated verification seeds for --xval")
    args = ap.parse_args(argv)

    from repro.core.kernels_lib import table1_kernels
    from repro.core.toolchain import Toolchain
    from repro.frontend.library import dsl_kernels
    from repro.isa.encode import export_streams
    from repro.isa.xval import cross_validate_dir

    suite = dict(table1_kernels(small=True))
    if not args.table1:
        suite.update(dsl_kernels())
    if args.kernels:
        names = args.kernels.split(",")
        unknown = [n for n in names if n not in suite]
        if unknown:
            ap.error(f"unknown kernels {unknown}; have {sorted(suite)}")
        suite = {n: suite[n] for n in names}

    tc = Toolchain()
    seeds = [int(s) for s in args.seeds.split(",")]
    cks = tc.compile_many(list(suite.values()))
    import os
    for name, ck in zip(suite, cks):
        out_dir = os.path.join(args.out, name)
        t0 = time.time()
        paths = export_streams(ck, out_dir)
        msg = (f"{name:<14} II={ck.II:<3d} -> {out_dir} "
               f"({(time.time() - t0) * 1e3:.1f} ms)")
        if args.xval:
            t0 = time.time()
            n = cross_validate_dir(ck, out_dir, seeds=seeds)
            msg += (f"  xval OK ({n} seed(s), "
                    f"{(time.time() - t0) * 1e3:.0f} ms)")
        print(msg)
        assert sorted(paths) == sorted(
            ("instructions.csv", "kernel.asm", "stream_manifest.json"))
    return 0


if __name__ == "__main__":
    sys.exit(main())
