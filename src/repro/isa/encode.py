"""Per-PE instruction-stream exporter: SimConfig -> deployment artifacts.

The Morpher ecosystem's RTL flows consume per-PE control streams (the
ESL-CGRA ``instructions.csv`` / assembly artifact family), not an
in-process numpy struct.  This module lowers a :class:`SimConfig` to that
shape: one record per (II slot, PE) carrying the FU opcode mnemonic, the
three operand mux selects, the four crossbar and RF writeback selects, the
immediate, the operand force window (loop-carried prologue preloads), the
memory bank binding and the store-validity start — everything a control
memory needs, nothing the simulator privately caches.

Three files per kernel, all byte-deterministic (fixed column order, fixed
integer formatting, ``\\n`` line endings, trailing newline):

  ``instructions.csv``       canonical machine-readable stream (sorted
                             columns, rows sorted by (slot, pe))
  ``kernel.asm``             human-readable disassembly of the same stream
  ``stream_manifest.json``   self-describing envelope: II/P/RF/LI/bits,
                             depth, bank offsets, live-in register
                             assignments, the neighbour table, the CSV
                             column list, ARTIFACT_VERSION

Opcode and mux-select spellings come from the bidirectional mnemonic
tables in ``core.config_gen`` (``MNEMONIC`` / ``KIND_MNEMONIC``) — the
single source of truth shared with the simulator and the standalone
interpreter (``repro.isa.interp``), so the three can never drift.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Tuple

import numpy as np

from ..core.config_gen import (INDEXED_KINDS, KIND_MNEMONIC, KIND_NONE,
                               MNEMONIC, OPC_NONE, OPC_STORE, SimConfig)

# version of the stream *format* itself (column set, mnemonic spellings,
# manifest schema) — distinct from the toolchain ARTIFACT_VERSION, which
# tracks the CompiledKernel artifact family
STREAM_FORMAT = 1

CSV_NAME = "instructions.csv"
ASM_NAME = "kernel.asm"
MANIFEST_NAME = "stream_manifest.json"

# direction order of the xo_* columns and the manifest neighbour table
DIRS = ("n", "e", "s", "w")


def _artifact_version() -> int:
    from ..core.toolchain import ARTIFACT_VERSION
    return ARTIFACT_VERSION


def _sel(kind: int, idx: int) -> str:
    """One mux select as its CSV spelling: bare mnemonic, or mnemonic+index
    for the register-file / live-in-register kinds ("reg3", "li0")."""
    m = KIND_MNEMONIC[int(kind)]
    return f"{m}{int(idx)}" if kind in INDEXED_KINDS else m


def columns(cfg: SimConfig) -> List[str]:
    """The canonical CSV column list for this configuration: the fixed
    scalar columns plus one writeback column per RF register, sorted
    lexicographically (the byte-determinism contract's column order)."""
    cols = ["slot", "pe", "opcode", "imm",
            "mem_off", "mem_words", "tstart"]
    for o in range(3):
        cols += [f"op{o}", f"op{o}_fb", f"op{o}_fv"]
    cols += [f"xo_{d}" for d in DIRS]
    cols += [f"rf{r}" for r in range(cfg.RF)]
    return sorted(cols)


def encode_rows(cfg: SimConfig) -> Tuple[List[str], List[Dict[str, str]]]:
    """Lower every (slot, pe) configuration cell to its CSV record.

    Returns (header, rows); rows are sorted by (slot, pe) and every value
    is already a string in its canonical spelling.
    """
    header = columns(cfg)
    op = np.asarray(cfg.op)
    rows: List[Dict[str, str]] = []
    for slot in range(cfg.II):
        for pe in range(cfg.P):
            rec = {
                "slot": str(slot), "pe": str(pe),
                "opcode": MNEMONIC[int(op[slot, pe])],
                "imm": str(int(cfg.imm[slot, pe])),
                "mem_off": str(int(cfg.mem_off[slot, pe])),
                "mem_words": str(int(cfg.mem_words[slot, pe])),
                "tstart": str(int(cfg.valid_start[slot, pe])),
            }
            for o in range(3):
                rec[f"op{o}"] = _sel(cfg.src_kind[slot, pe, o],
                                     cfg.src_idx[slot, pe, o])
                rec[f"op{o}_fb"] = str(int(cfg.force_before[slot, pe, o]))
                rec[f"op{o}_fv"] = str(int(cfg.force_val[slot, pe, o]))
            for d, dn in enumerate(DIRS):
                rec[f"xo_{dn}"] = _sel(cfg.xo_kind[slot, pe, d],
                                       cfg.xo_idx[slot, pe, d])
            for r in range(cfg.RF):
                rec[f"rf{r}"] = _sel(cfg.rf_kind[slot, pe, r],
                                     cfg.rf_idx[slot, pe, r])
            rows.append(rec)
    return header, rows


def to_csv(cfg: SimConfig) -> str:
    """The canonical ``instructions.csv`` text (byte-deterministic)."""
    header, rows = encode_rows(cfg)
    lines = [",".join(header)]
    lines += [",".join(rec[c] for c in header) for rec in rows]
    return "\n".join(lines) + "\n"


def manifest_dict(cfg: SimConfig, name: str) -> dict:
    """The self-describing stream envelope: everything the standalone
    interpreter needs beyond the CSV itself."""
    neighbors = [[int(cfg.nbr_idx[p, d]) if bool(cfg.nbr_ok[p, d]) else None
                  for d in range(4)] for p in range(cfg.P)]
    return {
        "artifact_version": _artifact_version(),
        "stream_format": STREAM_FORMAT,
        "kernel": name,
        "II": cfg.II, "P": cfg.P, "RF": cfg.RF, "LI": cfg.LI,
        "bits": cfg.bits, "depth": cfg.depth,
        "total_words": cfg.total_words,
        "bank_offsets": {str(bid): off
                         for bid, off in cfg.bank_offsets.items()},
        "liveins": {n: list(pe_idx)
                    for n, pe_idx in cfg.lireg_assign.items()},
        "dirs": list(DIRS),
        "neighbors": neighbors,
        "columns": columns(cfg),
    }


def to_manifest_json(cfg: SimConfig, name: str) -> str:
    return json.dumps(manifest_dict(cfg, name), sort_keys=True,
                      separators=(",", ":")) + "\n"


def _asm_cell(cfg: SimConfig, slot: int, pe: int) -> str:
    """One PE's instruction at one slot, disassembled; '' when idle."""
    opc = int(cfg.op[slot, pe])
    parts: List[str] = []
    ops = []
    for o in range(3):
        k, i = int(cfg.src_kind[slot, pe, o]), int(cfg.src_idx[slot, pe, o])
        if k == KIND_NONE:
            continue
        s = f"op{o}={_sel(k, i)}"
        if KIND_MNEMONIC[k] == "imm":
            s += f"({int(cfg.imm[slot, pe])})"
        fb = int(cfg.force_before[slot, pe, o])
        if fb > 0:
            s += f"{{t<{fb}:{int(cfg.force_val[slot, pe, o])}}}"
        ops.append(s)
    if opc != OPC_NONE or ops:
        line = f"{MNEMONIC[opc]:<7s}" + " ".join(ops)
        if int(cfg.mem_words[slot, pe]) > 1:
            line += (f" @mem(off={int(cfg.mem_off[slot, pe])},"
                     f"words={int(cfg.mem_words[slot, pe])})")
        if opc == OPC_STORE:
            line += f" valid>={int(cfg.valid_start[slot, pe])}"
        parts.append(line)
    wb = []
    for d, dn in enumerate(DIRS):
        k = int(cfg.xo_kind[slot, pe, d])
        if k != KIND_NONE:
            wb.append(f"xo_{dn}<-{_sel(k, int(cfg.xo_idx[slot, pe, d]))}")
    for r in range(cfg.RF):
        k = int(cfg.rf_kind[slot, pe, r])
        if k != KIND_NONE:
            wb.append(f"rf{r}<-{_sel(k, int(cfg.rf_idx[slot, pe, r]))}")
    if wb:
        parts.append("; " + " ".join(wb))
    return " ".join(parts)


def to_asm(cfg: SimConfig, name: str) -> str:
    """Readable disassembly of the stream (idle PEs omitted per slot)."""
    out = [f"; {name}: per-PE instruction streams",
           f"; II={cfg.II} P={cfg.P} RF={cfg.RF} LI={cfg.LI} "
           f"bits={cfg.bits} depth={cfg.depth} "
           f"total_words={cfg.total_words}",
           f"; artifact_version={_artifact_version()} "
           f"stream_format={STREAM_FORMAT}"]
    for n, (pe, idx) in sorted(cfg.lireg_assign.items()):
        out.append(f"; livein {n} -> pe{pe} li{idx}")
    for slot in range(cfg.II):
        out.append(f"slot {slot}:")
        for pe in range(cfg.P):
            cell = _asm_cell(cfg, slot, pe)
            if cell:
                out.append(f"  pe{pe:<3d} {cell}")
    return "\n".join(out) + "\n"


def encode_kernel(ck) -> Dict[str, str]:
    """All three stream artifacts of a :class:`CompiledKernel` as text,
    keyed by their canonical filenames."""
    return {CSV_NAME: to_csv(ck.cfg),
            ASM_NAME: to_asm(ck.cfg, ck.name),
            MANIFEST_NAME: to_manifest_json(ck.cfg, ck.name)}


def export_streams(ck, out_dir: str) -> Dict[str, str]:
    """Write the stream artifact family for one compiled kernel.

    Creates ``out_dir`` and writes ``instructions.csv``, ``kernel.asm``
    and ``stream_manifest.json`` (newline-exact, so ``cmp`` across two
    cold exports is the determinism check).  Returns filename -> path.
    """
    os.makedirs(out_dir, exist_ok=True)
    paths: Dict[str, str] = {}
    for fn, text in encode_kernel(ck).items():
        path = os.path.join(out_dir, fn)
        with open(path, "w", encoding="utf-8", newline="\n") as f:
            f.write(text)
        paths[fn] = path
    return paths
