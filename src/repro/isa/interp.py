"""Standalone interpreter for exported per-PE instruction streams.

This is the reproduction's *second, independent* executable semantics: a
small pure-Python/numpy machine that parses the ``instructions.csv`` /
``stream_manifest.json`` artifact family (``repro.isa.encode``) and
executes it cycle-by-cycle over a word-addressed memory image.  It shares
**no code** with the JAX simulator (``core/simulator.py``): instructions
are decoded from their CSV mnemonics, not from ``SimConfig`` arrays, and
every machine rule below is written from the architecture contract —

  * each cycle, every PE runs its slot-(t mod II) instruction;
  * all reads (operand muxes, RF/crossbar writeback selects, loads) see
    the *start-of-cycle* state snapshot; all writes (FU output register,
    load pipeline register, RF, crossbar output registers, memory stores)
    commit together at end of cycle (fully synchronous design);
  * operand selects draw from {4 inbound crossbar wires (the neighbour's
    opposite-facing output port), register file, own FU output, the
    slot's immediate, live-in registers}; an operand with an active force
    window reads its preload value while ``t < force_before``;
  * the datapath is ``bits``-wide two's complement; LOAD has a 2-cycle
    latency through the load pipeline register; STORE commits end of
    cycle, gated by the iteration-validity window
    ``tstart <= t < tstart + n_iters * II``; load/store addresses clip
    into the bound bank;
  * invocations reset all registers but thread the memory image.

Cross-validation (``repro.isa.xval``) pins this interpreter bit-identical
to ``simulate()`` on the whole kernel library, which is what makes the
exported stream a trustworthy deployment artifact *and* gives the verify
fleet an oracle that cannot share a bug with the simulator's XLA path.
"""
from __future__ import annotations

import json
import os
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .encode import ASM_NAME, CSV_NAME, MANIFEST_NAME, STREAM_FORMAT


class StreamError(ValueError):
    """The stream artifact is malformed or internally inconsistent."""


# the four inbound-wire mnemonics, in the manifest's direction order, and
# the opposite-facing port a reader consults on its neighbour
_IN_DIRS = ("in_n", "in_e", "in_s", "in_w")
_OPP = (2, 3, 0, 1)

_SEL_RE = re.compile(r"^([a-z_]+?)(\d*)$")


def _parse_sel(text: str) -> Tuple[str, int]:
    """'reg3' -> ('reg', 3); 'in_n' -> ('in_n', 0); 'none' -> ('none', 0)."""
    m = _SEL_RE.match(text)
    if not m:
        raise StreamError(f"unparseable mux select {text!r} "
                          f"(rule STR-SEL-RANGE)")
    kind, idx = m.group(1), m.group(2)
    return kind, int(idx) if idx else 0


@dataclass
class Insn:
    """One decoded (slot, pe) record with at least one effect."""
    pe: int
    opcode: str                                  # mnemonic ('nop' possible)
    imm: int
    ops: List[Tuple[str, int]]                   # 3 operand selects
    force: List[Tuple[int, int]]                 # (force_before, force_val)
    xo: List[Tuple[int, str, int]] = field(default_factory=list)
    rf: List[Tuple[int, str, int]] = field(default_factory=list)
    mem_off: int = 0
    mem_words: int = 1
    tstart: int = 0


@dataclass
class InstructionStream:
    """A parsed stream: the manifest header plus per-slot decoded insns."""
    kernel: str
    II: int
    P: int
    RF: int
    LI: int
    bits: int
    depth: int
    total_words: int
    bank_offsets: Dict[int, int]
    liveins: Dict[str, Tuple[int, int]]
    neighbors: List[List[Optional[int]]]         # [P][4], None = no wire
    slots: List[List[Insn]]                      # [II] active insns, pe asc

    def n_cycles(self, n_iters: int) -> int:
        return (n_iters - 1) * self.II + self.depth


def parse_stream(csv_text: str, manifest: dict) -> InstructionStream:
    """Decode the CSV against its manifest into an executable stream."""
    if manifest.get("stream_format") != STREAM_FORMAT:
        raise StreamError(f"stream_format {manifest.get('stream_format')} "
                          f"!= {STREAM_FORMAT} (rule STR-PARSE)")
    II, P, RF = manifest["II"], manifest["P"], manifest["RF"]
    LI = max(1, manifest["LI"])
    lines = csv_text.split("\n")
    if lines and lines[-1] == "":
        lines.pop()                              # trailing newline
    header = lines[0].split(",")
    if header != manifest["columns"]:
        raise StreamError("CSV header does not match manifest columns "
                          "(rule STR-PARSE)")
    col = {c: i for i, c in enumerate(header)}
    if len(lines) - 1 != II * P:
        raise StreamError(f"expected {II * P} records, got {len(lines) - 1} "
                          f"(rule STR-PARSE)")

    slots: List[List[Insn]] = [[] for _ in range(II)]
    seen = set()
    for ln in lines[1:]:
        v = ln.split(",")
        if len(v) != len(header):
            raise StreamError(f"short record: {ln!r} (rule STR-PARSE)")
        slot, pe = int(v[col["slot"]]), int(v[col["pe"]])
        if not (0 <= slot < II and 0 <= pe < P):
            raise StreamError(f"slot{slot}/pe{pe}: record out of range "
                              f"(rule STR-PARSE)")
        if (slot, pe) in seen:
            raise StreamError(f"slot{slot}/pe{pe}: duplicate record "
                              f"(rule STR-PARSE)")
        seen.add((slot, pe))
        ops = [_parse_sel(v[col[f"op{o}"]]) for o in range(3)]
        force = [(int(v[col[f"op{o}_fb"]]), int(v[col[f"op{o}_fv"]]))
                 for o in range(3)]
        xo = []
        for d, dn in enumerate(manifest["dirs"]):
            k, i = _parse_sel(v[col[f"xo_{dn.lower()}"]])
            if k != "none":
                xo.append((d, k, i))
        rf = []
        for r in range(RF):
            k, i = _parse_sel(v[col[f"rf{r}"]])
            if k != "none":
                rf.append((r, k, i))
        ins = Insn(pe=pe, opcode=v[col["opcode"]],
                   imm=int(v[col["imm"]]), ops=ops, force=force,
                   xo=xo, rf=rf,
                   mem_off=int(v[col["mem_off"]]),
                   mem_words=int(v[col["mem_words"]]),
                   tstart=int(v[col["tstart"]]))
        if ins.opcode != "nop" or xo or rf:
            slots[slot].append(ins)
    for sl in slots:
        sl.sort(key=lambda i: i.pe)              # commit order = pe asc
    return InstructionStream(
        kernel=manifest["kernel"], II=II, P=P, RF=RF, LI=LI,
        bits=manifest["bits"], depth=manifest["depth"],
        total_words=manifest["total_words"],
        bank_offsets={int(k): v
                      for k, v in manifest["bank_offsets"].items()},
        liveins={n: (pe, idx)
                 for n, (pe, idx) in manifest["liveins"].items()},
        neighbors=manifest["neighbors"], slots=slots)


def load_stream(stream_dir: str) -> InstructionStream:
    """Parse an exported stream directory (``instructions.csv`` +
    ``stream_manifest.json``; the ``.asm`` is documentation, not input)."""
    with open(os.path.join(stream_dir, MANIFEST_NAME), encoding="utf-8") as f:
        manifest = json.load(f)
    with open(os.path.join(stream_dir, CSV_NAME), encoding="utf-8") as f:
        csv_text = f.read()
    return parse_stream(csv_text, manifest)


def _wrap(x: int, bits: int) -> int:
    m = 1 << bits
    x &= m - 1
    return x - m if x >= (m >> 1) else x


def _alu(opcode: str, a: int, b: int, c: int, bits: int) -> int:
    if opcode == "pass":
        r = a
    elif opcode == "add":
        r = a + b
    elif opcode == "sub":
        r = a - b
    elif opcode == "mul":
        r = a * b
    elif opcode == "shl":
        r = a << (b & (bits - 1))
    elif opcode == "shr":
        r = a >> (b & (bits - 1))
    elif opcode == "and":
        r = a & b
    elif opcode == "or":
        r = a | b
    elif opcode == "xor":
        r = a ^ b
    elif opcode == "cmpge":
        r = 1 if a >= b else 0
    elif opcode == "cmpeq":
        r = 1 if a == b else 0
    elif opcode == "cmplt":
        r = 1 if a < b else 0
    elif opcode == "select":
        r = b if a != 0 else c
    else:
        raise StreamError(f"unknown opcode mnemonic {opcode!r} "
                          f"(rule STR-OPC)")
    return _wrap(r, bits)


class _Machine:
    """Register state of one invocation (memory lives outside: it threads
    across invocations)."""

    def __init__(self, s: InstructionStream):
        self.regs = [[0] * s.RF for _ in range(s.P)]
        self.xo = [[0, 0, 0, 0] for _ in range(s.P)]
        self.fu = [0] * s.P
        self.ldp = [0] * s.P
        self.fl: set = set()                     # PEs that loaded last cycle


def _resolve(s: InstructionStream, m: _Machine, pe: int, imm: int,
             kind: str, idx: int) -> int:
    """One mux select against the start-of-cycle snapshot."""
    if kind == "none":
        return 0
    if kind == "fu":
        return m.fu[pe]
    if kind == "imm":
        return imm
    if kind == "reg":
        return m.regs[pe][idx]
    if kind == "li":
        return m.li[pe][idx]
    try:
        d = _IN_DIRS.index(kind)
    except ValueError:
        raise StreamError(f"pe{pe}: unknown mux select {kind!r} "
                          f"(rule STR-SEL-RANGE)") from None
    nbr = s.neighbors[pe][d]
    if nbr is None:
        raise StreamError(f"pe{pe} reads {kind} but has no neighbour there "
                          f"(rule STR-SEL-RANGE)")
    return m.xo[nbr][_OPP[d]]


def interpret(s: InstructionStream, banks: Dict[str, np.ndarray],
              invocations: Sequence[Dict[str, int]],
              n_iters: int) -> Dict[str, np.ndarray]:
    """Execute every invocation over the initial bank images; returns the
    final banks (same keying as the simulator: ``bank<id>`` -> array).
    """
    dtype = np.int16 if s.bits == 16 else np.int32
    mem = np.zeros(s.total_words, dtype=dtype)
    for bid, off in s.bank_offsets.items():
        img = np.asarray(banks[f"bank{bid}"])
        mem[off:off + len(img)] = img.astype(dtype)  # datapath-width wrap

    n_cycles = s.n_cycles(n_iters)
    window = n_iters * s.II
    for inv in invocations:
        m = _Machine(s)
        m.li = [[0] * s.LI for _ in range(s.P)]
        for name, (pe, idx) in s.liveins.items():
            m.li[pe][idx] = _wrap(int(inv.get(name, 0)), s.bits)
        for t in range(n_cycles):
            insns = s.slots[t % s.II]
            res_up: Dict[int, int] = {}
            ld_up: Dict[int, int] = {}
            st_commits: List[Tuple[int, int]] = []
            rf_writes: List[Tuple[int, int, int]] = []
            xo_writes: List[Tuple[int, int, int]] = []
            for ins in insns:
                pe = ins.pe
                vals = [_resolve(s, m, pe, ins.imm, k, i)
                        for k, i in ins.ops]
                for o, (fb, fv) in enumerate(ins.force):
                    if t < fb:
                        vals[o] = fv
                a, b, c = vals
                if ins.opcode == "load":
                    addr = ins.mem_off + min(max(a, 0), ins.mem_words - 1)
                    ld_up[pe] = int(mem[addr])
                elif ins.opcode == "store":
                    if ins.tstart <= t < ins.tstart + window:
                        addr = ins.mem_off + min(max(a, 0),
                                                 ins.mem_words - 1)
                        st_commits.append((addr, b))
                elif ins.opcode != "nop":
                    res_up[pe] = _alu(ins.opcode, a, b, c, s.bits)
                for d, k, i in ins.xo:
                    xo_writes.append((pe, d, _resolve(s, m, pe, ins.imm,
                                                      k, i)))
                for r, k, i in ins.rf:
                    rf_writes.append((pe, r, _resolve(s, m, pe, ins.imm,
                                                      k, i)))
            # end-of-cycle commit: FU pipeline first (a completing load
            # wins the FU output register over this slot's ALU result)
            for pe in m.fl:
                m.fu[pe] = m.ldp[pe]
            for pe, v in res_up.items():
                if pe not in m.fl:
                    m.fu[pe] = v
            m.fl = set(ld_up)
            for pe, v in ld_up.items():
                m.ldp[pe] = v
            for addr, v in st_commits:
                mem[addr] = v
            for pe, r, v in rf_writes:
                m.regs[pe][r] = v
            for pe, d, v in xo_writes:
                m.xo[pe][d] = v
    return {f"bank{bid}": mem[off:off + len(np.asarray(banks[f"bank{bid}"]))]
            .copy()
            for bid, off in s.bank_offsets.items()}
