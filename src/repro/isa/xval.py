"""Cross-validation: exported streams vs the cycle-accurate simulator.

The harness closes the loop export -> parse -> interpret and asserts the
standalone interpreter's final memory image is **bit-identical** to
``simulate()`` on the same initial banks — for every requested seed.  Both
executables claim to implement the same machine; agreeing word-for-word
across the kernel library means (a) the exported artifact really carries
the full configuration (nothing simulator-private leaked into behaviour)
and (b) each implementation is an independent oracle for the other.

Entry points:

  ``cross_validate(ck, seeds)``             in-memory round trip
  ``cross_validate_dir(ck, stream_dir)``    against on-disk artifacts
  ``Toolchain.cross_validate(kernel, ...)`` the toolchain-level wrapper
  ``MORPHER_XVAL=1``                        opt-in second oracle inside
                                            the verify flow (see
                                            ``core.verify.xval_enabled``)
"""
from __future__ import annotations

import json
from typing import Dict, Optional, Sequence

import numpy as np

from .encode import CSV_NAME, MANIFEST_NAME, encode_kernel
from .interp import InstructionStream, interpret, load_stream, parse_stream


def _init_banks(ck, seed: int) -> Dict[str, np.ndarray]:
    """Test images for one seed: the spec's own generator when the builder
    spec is attached (fresh compiles — realistic data distributions), the
    artifact's deterministic random banks otherwise."""
    if ck.spec is not None:
        rng = np.random.default_rng(seed)
        return ck.spec.init_banks(rng)
    return ck.random_banks(seed)


def stream_for(ck) -> InstructionStream:
    """Export in memory and parse back — the decoded form every
    cross-validation executes."""
    artifacts = encode_kernel(ck)
    return parse_stream(artifacts[CSV_NAME],
                        json.loads(artifacts[MANIFEST_NAME]))


def _compare(ck, seed: int, sim: Dict[str, np.ndarray],
             got: Dict[str, np.ndarray]) -> None:
    if sorted(sim) != sorted(got):
        raise AssertionError(
            f"{ck.name}: interpreter banks {sorted(got)} != simulator "
            f"banks {sorted(sim)}")
    for bank in sorted(sim):
        s, g = np.asarray(sim[bank]), np.asarray(got[bank])
        if not np.array_equal(s, g):
            bad = np.nonzero(s != g)[0][:8]
            raise AssertionError(
                f"{ck.name} (II={ck.II}, seed={seed}): instruction-stream "
                f"interpreter diverges from simulate() in {bank} at words "
                f"{bad.tolist()}: interpreter {g[bad]}, simulator {s[bad]}")


def cross_validate(ck, seeds: Sequence[int] = (0,),
                   stream: Optional[InstructionStream] = None) -> int:
    """Assert interpreter ≡ simulator on ``ck`` for every seed; returns
    the number of seeds checked.  Raises AssertionError naming the first
    diverging (seed, bank, words)."""
    if stream is None:
        stream = stream_for(ck)
    for seed in seeds:
        init = _init_banks(ck, seed)
        sim = ck.run(init)
        got = interpret(stream, init, ck.invocations, ck.mapped_iters)
        _compare(ck, seed, sim, got)
    return len(list(seeds))


def cross_validate_dir(ck, stream_dir: str,
                       seeds: Sequence[int] = (0,)) -> int:
    """Same check, but parsing the artifacts back off disk — the form the
    CI smoke job uses after an ``export_streams``."""
    return cross_validate(ck, seeds, stream=load_stream(stream_dir))
