"""Shared helpers for the Pallas TPU kernels.

All kernels target TPU (pl.pallas_call + explicit BlockSpec VMEM tiling,
MXU-aligned block shapes) and are *validated* on CPU with interpret=True
against their pure-jnp oracles in ref.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU memory spaces / compiler params (name moved across jax versions)
    from jax.experimental.pallas import tpu as pltpu
    VMEM = pltpu.VMEM
    CompilerParams = getattr(pltpu, "CompilerParams",
                             getattr(pltpu, "TPUCompilerParams", None))
except Exception:  # pragma: no cover - pallas tpu backend unavailable
    pltpu = None
    VMEM = None
    CompilerParams = None

# TPU v5e hardware alignment
MXU = 128        # systolic array dim; matmul tiles should be multiples
SUBLANE = 8      # fp32 sublane packing
LANE = 128


def compiler_params(dimension_semantics):
    if CompilerParams is None:
        return None
    try:
        return CompilerParams(dimension_semantics=dimension_semantics)
    except TypeError:  # pragma: no cover
        return None


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def pad_to(x: jnp.ndarray, axis: int, multiple: int):
    """Zero-pad ``axis`` up to a multiple; returns (padded, original_size)."""
    size = x.shape[axis]
    target = cdiv(size, multiple) * multiple
    if target == size:
        return x, size
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - size)
    return jnp.pad(x, pad), size
