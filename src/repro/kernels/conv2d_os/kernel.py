"""Output-stationary direct convolution (the paper's Listing-2/5 dataflow).

CGRA -> TPU adaptation: the paper keeps one output-channel tile resident in
the cluster banks and fully unrolls the KxK taps (CONV-U-C); here each grid
step keeps a (OH*OW, bco) fp32 accumulator in VMEM and unrolls the KxK taps
as static slices feeding MXU matmuls (implicit GEMM over Cin).  The spatial
image of an edge-AI conv (e.g. 64x64) fits VMEM whole, exactly like the
paper's 8 kB banks hold the 64x64 int16 tile.

Grid: (N, Cout/bco) — both "arbitrary"; input block is the full image of
one batch element, weights stream one output-channel tile per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import VMEM, compiler_params


def _conv_kernel(x_ref, w_ref, o_ref, acc_ref, *, KH, KW, OH, OW):
    acc_ref[...] = jnp.zeros_like(acc_ref)
    x = x_ref[0]                      # (H, W, Cin)
    Cin = x.shape[-1]
    for kh in range(KH):
        for kw in range(KW):
            patch = x[kh:kh + OH, kw:kw + OW, :].reshape(OH * OW, Cin)
            tap = w_ref[kh, kw]       # (Cin, bco)
            acc_ref[...] += jnp.dot(patch, tap,
                                    preferred_element_type=jnp.float32)
    o_ref[...] = acc_ref[...].reshape(1, OH, OW, -1).astype(o_ref.dtype)


def conv2d_os_pallas(x: jnp.ndarray, w: jnp.ndarray, *, bco: int = 128,
                     out_dtype=None, interpret: bool = False) -> jnp.ndarray:
    N, H, W, Cin = x.shape
    KH, KW, Cin2, Cout = w.shape
    assert Cin == Cin2 and Cout % bco == 0
    OH, OW = H - KH + 1, W - KW + 1
    out_dtype = out_dtype or x.dtype
    scratch = [VMEM((OH * OW, bco), jnp.float32)] if VMEM is not None else [
        jax.ShapeDtypeStruct((OH * OW, bco), jnp.float32)]

    return pl.pallas_call(
        functools.partial(_conv_kernel, KH=KH, KW=KW, OH=OH, OW=OW),
        grid=(N, Cout // bco),
        in_specs=[
            pl.BlockSpec((1, H, W, Cin), lambda n, c: (n, 0, 0, 0)),
            pl.BlockSpec((KH, KW, Cin, bco), lambda n, c: (0, 0, 0, c)),
        ],
        out_specs=pl.BlockSpec((1, OH, OW, bco), lambda n, c: (n, 0, 0, c)),
        out_shape=jax.ShapeDtypeStruct((N, OH, OW, Cout), out_dtype),
        scratch_shapes=scratch,
        compiler_params=compiler_params(("arbitrary", "arbitrary")),
        interpret=interpret,
    )(x, w)
