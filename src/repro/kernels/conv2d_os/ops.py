"""Jitted wrapper for conv2d_os: pads Cout to the channel-block multiple."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..common import pad_to
from .kernel import conv2d_os_pallas
from .ref import conv2d_ref


@functools.partial(jax.jit, static_argnames=("bco", "out_dtype", "interpret",
                                             "use_kernel"))
def conv2d_os(x: jnp.ndarray, w: jnp.ndarray, *, bco: int = 128,
              out_dtype=None, interpret: bool = False,
              use_kernel: bool = True) -> jnp.ndarray:
    out_dtype = out_dtype or x.dtype
    if not use_kernel:
        return conv2d_ref(x, w, out_dtype)
    Cout = w.shape[-1]
    bco_ = min(bco, Cout) if Cout % min(bco, Cout) == 0 else bco
    w_p, C0 = pad_to(w, 3, bco_)
    out = conv2d_os_pallas(x, w_p, bco=bco_, out_dtype=out_dtype,
                           interpret=interpret)
    return out[..., :Cout]
