"""Pure-jnp oracle for the output-stationary direct convolution."""
from __future__ import annotations

import jax.numpy as jnp


def conv2d_ref(x: jnp.ndarray, w: jnp.ndarray,
               out_dtype=None) -> jnp.ndarray:
    """Valid NHWC direct conv.  x: (N,H,W,Cin), w: (KH,KW,Cin,Cout)."""
    out_dtype = out_dtype or x.dtype
    N, H, W, Cin = x.shape
    KH, KW, _, Cout = w.shape
    OH, OW = H - KH + 1, W - KW + 1
    acc = jnp.zeros((N, OH, OW, Cout), jnp.float32)
    for kh in range(KH):
        for kw in range(KW):
            patch = x[:, kh:kh + OH, kw:kw + OW, :].astype(jnp.float32)
            acc = acc + jnp.einsum("nhwc,co->nhwo", patch,
                                   w[kh, kw].astype(jnp.float32))
    return acc.astype(out_dtype)
