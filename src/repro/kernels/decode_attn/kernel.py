"""Flash-decode attention kernel: one new token against a long KV cache.

Output-stationary insight applied to attention: the (G, D) output tile for
one kv-head's query group stays resident in VMEM with running max/denom
(online softmax) while KV blocks stream through — KV is read exactly once
from HBM, which is the roofline-optimal schedule for decode (memory-bound).

Grid: (B, Hkv, S/bs) — the S axis is "arbitrary" (sequential) so the
softmax state carries across KV blocks in scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import VMEM, compiler_params

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, bs, s_steps, scale):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)            # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)         # (bs, D)
    v = v_ref[0, 0].astype(jnp.float32)         # (bs, D)
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    length = len_ref[0]
    pos = s * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    logits = jnp.where(pos < length, logits, NEG_INF)      # (G, bs)

    m_prev = m_ref[...]                         # (G, 1)
    m_cur = jnp.max(logits, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(logits - m_new)                 # (G, bs)
    alpha = jnp.exp(m_prev - m_new)             # (G, 1)
    l_new = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(s == s_steps - 1)
    def _done():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def decode_attn_pallas(q, k, v, lengths, *, bs: int = 512, scale=None,
                       interpret: bool = False):
    """q: (B, Hkv, G, D); k/v: (B, Hkv, S, D); lengths: (B,) int32."""
    B, Hkv, G, D = q.shape
    _, _, S, _ = k.shape
    assert S % bs == 0
    s_steps = S // bs
    scale = float(scale if scale is not None else 1.0 / (D ** 0.5))
    mk = VMEM if VMEM is not None else (
        lambda shp, dt: jax.ShapeDtypeStruct(shp, dt))
    return pl.pallas_call(
        functools.partial(_decode_kernel, bs=bs, s_steps=s_steps,
                          scale=scale),
        grid=(B, Hkv, s_steps),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, s: (b,)),
            pl.BlockSpec((1, G, D), lambda b, h, s: (b * Hkv + h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, bs, D), lambda b, h, s: (b, h, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda b, h, s: (b * Hkv + h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, G, D), q.dtype),
        scratch_shapes=[mk((G, 1), jnp.float32),
                        mk((G, 1), jnp.float32),
                        mk((G, D), jnp.float32)],
        compiler_params=compiler_params(
            ("arbitrary", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(lengths, q.reshape(B * Hkv, G, D), k, v).reshape(B, Hkv, G, D)
