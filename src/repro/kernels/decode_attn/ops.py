"""Jitted wrapper for flash-decode attention."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..common import cdiv
from .kernel import decode_attn_pallas
from .ref import decode_attn_ref


@functools.partial(jax.jit, static_argnames=("bs", "interpret", "use_kernel"))
def decode_attn(q, k, v, lengths, *, bs: int = 512,
                interpret: bool = False, use_kernel: bool = True):
    """q: (B, H, D); k/v: (B, Hkv, S, D); lengths: (B,).  GQA decode."""
    if not use_kernel:
        return decode_attn_ref(q, k, v, lengths)
    B, H, D = q.shape
    _, Hkv, S, _ = k.shape
    G = H // Hkv
    bs_ = min(bs, S)
    if S % bs_ != 0:
        pad = cdiv(S, bs_) * bs_ - S
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    out = decode_attn_pallas(q.reshape(B, Hkv, G, D), k, v,
                             lengths.astype(jnp.int32), bs=bs_,
                             interpret=interpret)
    return out.reshape(B, H, D)
