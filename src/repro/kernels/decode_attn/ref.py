"""Pure-jnp oracle for single-token GQA decode attention."""
from __future__ import annotations

import jax.numpy as jnp


def decode_attn_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    lengths: jnp.ndarray, scale=None) -> jnp.ndarray:
    """q: (B, H, D); k/v: (B, Hkv, S, D); lengths: (B,) valid cache length.
    Returns (B, H, D).  H must be a multiple of Hkv (GQA)."""
    B, H, D = q.shape
    _, Hkv, S, _ = k.shape
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    qf = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bhgd,bhsd->bhgs", qf, kf) * scale
    mask = jnp.arange(S)[None, :] < lengths[:, None]       # (B, S)
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    probs = jnp.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    out = jnp.einsum("bhgs,bhsd->bhgd", probs, vf)
    return out.reshape(B, H, D).astype(q.dtype)
