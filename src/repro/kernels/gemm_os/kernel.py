"""Output-stationary tiled GEMM — the paper's Listing-1 dataflow on TPU.

CGRA -> TPU adaptation (DESIGN.md section 3):
  * the paper sizes an output tile to the cluster's on-chip banks and keeps
    O resident while W/I stream through; here the (bm, bn) fp32 accumulator
    lives in VMEM scratch and A/B tiles stream HBM->VMEM per K step;
  * the paper's *loop unrolling* raising PE utilization maps to unrolling
    the K micro-loop over MXU-aligned (128x128) blocks;
  * the paper's *loop coalescing* (Listing 4) — one flat loop instead of a
    nest, slashing invocation overhead — maps to grid flattening: a single
    linearized grid dimension with div/mod index reconstruction, enabling
    revolving-buffer reuse and removing per-dimension grid bookkeeping.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import VMEM, cdiv, compiler_params


def _apply_act(acc, activation):
    if activation == "relu":
        return jnp.maximum(acc, 0.0)
    if activation == "gelu":
        return 0.5 * acc * (1.0 + jnp.tanh(
            0.7978845608028654 * (acc + 0.044715 * acc ** 3)))
    if activation == "silu":
        return acc * (1.0 / (1.0 + jnp.exp(-acc)))
    assert activation is None
    return acc


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps, activation,
                 k_axis):
    k = pl.program_id(k_axis)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _done():
        o_ref[...] = _apply_act(acc_ref[...], activation).astype(o_ref.dtype)


def _gemm_bias_kernel(a_ref, b_ref, bias_ref, o_ref, acc_ref, *, k_steps,
                      activation, k_axis):
    k = pl.program_id(k_axis)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _done():
        acc = acc_ref[...] + bias_ref[...].astype(jnp.float32)
        o_ref[...] = _apply_act(acc, activation).astype(o_ref.dtype)


def gemm_os_pallas(a: jnp.ndarray, b: jnp.ndarray,
                   bias: Optional[jnp.ndarray] = None, *,
                   bm: int = 128, bn: int = 128, bk: int = 128,
                   activation: Optional[str] = None,
                   coalesce_grid: bool = False,
                   out_dtype=None,
                   interpret: bool = False) -> jnp.ndarray:
    """C[M,N] = act(A[M,K] @ B[K,N] + bias).  Shapes must be multiples of
    the block sizes (ops.py pads)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    gm, gn, gk = M // bm, N // bn, K // bk
    out_dtype = out_dtype or a.dtype
    out_shape = jax.ShapeDtypeStruct((M, N), out_dtype)
    scratch = [VMEM((bm, bn), jnp.float32)] if VMEM is not None else [
        jax.ShapeDtypeStruct((bm, bn), jnp.float32)]

    if coalesce_grid:
        # Listing-4 analogue: one flat loop over output tiles; K innermost.
        grid = (gm * gn, gk)
        k_axis = 1

        def a_idx(t, k):
            return (t // gn, k)

        def b_idx(t, k):
            return (k, t % gn)

        def o_idx(t, k):
            return (t // gn, t % gn)

        def bias_idx(t, k):
            return (0, t % gn)

        semantics = ("arbitrary", "arbitrary")
    else:
        grid = (gm, gn, gk)
        k_axis = 2

        def a_idx(i, j, k):
            return (i, k)

        def b_idx(i, j, k):
            return (k, j)

        def o_idx(i, j, k):
            return (i, j)

        def bias_idx(i, j, k):
            return (0, j)

        semantics = ("parallel", "arbitrary", "arbitrary")

    in_specs = [pl.BlockSpec((bm, bk), a_idx),
                pl.BlockSpec((bk, bn), b_idx)]
    args = [a, b]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bn), bias_idx))
        args.append(bias.reshape(1, N))
        kern = functools.partial(_gemm_bias_kernel, k_steps=gk,
                                 activation=activation, k_axis=k_axis)
    else:
        kern = functools.partial(_gemm_kernel, k_steps=gk,
                                 activation=activation, k_axis=k_axis)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), o_idx),
        out_shape=out_shape,
        scratch_shapes=scratch,
        compiler_params=compiler_params(semantics),
        interpret=interpret,
    )(*args)
