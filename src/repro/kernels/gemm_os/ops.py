"""Jitted public wrapper for the output-stationary GEMM kernel: handles
padding to block multiples, dtype plumbing, and the interpret switch."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..common import cdiv, pad_to
from .kernel import gemm_os_pallas
from .ref import gemm_ref


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "activation",
                                             "coalesce_grid", "out_dtype",
                                             "interpret", "use_kernel"))
def gemm_os(a: jnp.ndarray, b: jnp.ndarray,
            bias: Optional[jnp.ndarray] = None, *,
            bm: int = 128, bn: int = 128, bk: int = 128,
            activation: Optional[str] = None,
            coalesce_grid: bool = False,
            out_dtype=None, interpret: bool = False,
            use_kernel: bool = True) -> jnp.ndarray:
    """act(A @ B + bias) with arbitrary M/N/K (zero-padded to blocks)."""
    out_dtype = out_dtype or a.dtype
    if not use_kernel:
        return gemm_ref(a, b, bias, activation, out_dtype)
    M, K = a.shape
    _, N = b.shape
    bm_ = min(bm, max(8, M))
    a_p, M0 = pad_to(a, 0, bm_)
    a_p, K0 = pad_to(a_p, 1, bk)
    b_p, _ = pad_to(b, 0, bk)
    b_p, N0 = pad_to(b_p, 1, bn)
    bias_p = None
    if bias is not None:
        bias_p, _ = pad_to(bias, 0, bn)
    out = gemm_os_pallas(a_p, b_p, bias_p, bm=bm_, bn=bn, bk=bk,
                         activation=activation, coalesce_grid=coalesce_grid,
                         out_dtype=out_dtype, interpret=interpret)
    return out[:M, :N]
