"""Pure-jnp oracle for the output-stationary GEMM kernel."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def gemm_ref(a: jnp.ndarray, b: jnp.ndarray,
             bias: Optional[jnp.ndarray] = None,
             activation: Optional[str] = None,
             out_dtype=None) -> jnp.ndarray:
    out_dtype = out_dtype or a.dtype
    acc = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    if bias is not None:
        acc = acc + bias.astype(jnp.float32)[None, :]
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    elif activation == "gelu":
        acc = 0.5 * acc * (1.0 + jnp.tanh(
            0.7978845608028654 * (acc + 0.044715 * acc ** 3)))
    elif activation == "silu":
        acc = acc * (1.0 / (1.0 + jnp.exp(-acc)))
    elif activation is not None:
        raise ValueError(activation)
    return acc.astype(out_dtype)
