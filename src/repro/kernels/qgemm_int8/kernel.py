"""Quantized int8 x int8 -> int32 GEMM with per-row/col scales.

The paper's target CGRA has a 16-bit integer datapath ("in line with a
16-bit data path"); the edge-inference analogue on TPU is int8 MXU matmul
with int32 accumulation and fp32 rescale — the serving-path quantized
deployment kernel.  Same output-stationary structure as gemm_os: int32
accumulator resident in VMEM, A/B int8 tiles streamed per K step, scales
applied once on the final K step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import VMEM, compiler_params


def _qgemm_kernel(a_ref, b_ref, sa_ref, sb_ref, o_ref, acc_ref, *, k_steps):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...].astype(jnp.int32),
                            b_ref[...].astype(jnp.int32),
                            preferred_element_type=jnp.int32)

    @pl.when(k == k_steps - 1)
    def _done():
        sa = sa_ref[...].astype(jnp.float32)     # (bm, 1)
        sb = sb_ref[...].astype(jnp.float32)     # (1, bn)
        o_ref[...] = (acc_ref[...].astype(jnp.float32) * sa * sb
                      ).astype(o_ref.dtype)


def qgemm_int8_pallas(a, b, a_scale, b_scale, *, bm: int = 128,
                      bn: int = 128, bk: int = 256, out_dtype=jnp.float32,
                      interpret: bool = False):
    M, K = a.shape
    _, N = b.shape
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    gm, gn, gk = M // bm, N // bn, K // bk
    scratch = [VMEM((bm, bn), jnp.int32)] if VMEM is not None else [
        jax.ShapeDtypeStruct((bm, bn), jnp.int32)]
    return pl.pallas_call(
        functools.partial(_qgemm_kernel, k_steps=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=scratch,
        compiler_params=compiler_params(
            ("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(a, b, a_scale.reshape(M, 1), b_scale.reshape(1, N))
