"""Jitted wrapper for the int8 quantized GEMM."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..common import pad_to
from .kernel import qgemm_int8_pallas
from .ref import qgemm_ref


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "out_dtype",
                                             "interpret", "use_kernel"))
def qgemm_int8(a, b, a_scale, b_scale, *, bm: int = 128, bn: int = 128,
               bk: int = 256, out_dtype=jnp.float32,
               interpret: bool = False, use_kernel: bool = True):
    if not use_kernel:
        return qgemm_ref(a, b, a_scale, b_scale, out_dtype)
    M, K = a.shape
    _, N = b.shape
    bm_ = min(bm, max(8, M))
    bk_ = min(bk, K) if K % min(bk, K) == 0 else bk
    a_p, _ = pad_to(a, 0, bm_)
    a_p, _ = pad_to(a_p, 1, bk_)
    b_p, _ = pad_to(b, 0, bk_)
    b_p, _ = pad_to(b_p, 1, bn)
    sa_p, _ = pad_to(a_scale, 0, bm_)
    sb_p, _ = pad_to(b_scale, 0, bn)
    out = qgemm_int8_pallas(a_p, b_p, sa_p, sb_p, bm=bm_, bn=bn, bk=bk_,
                            out_dtype=out_dtype, interpret=interpret)
    return out[:M, :N]
