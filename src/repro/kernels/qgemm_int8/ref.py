"""Pure-jnp oracle for the int8 quantized GEMM (edge-inference datapath)."""
from __future__ import annotations

import jax.numpy as jnp


def qgemm_ref(a: jnp.ndarray, b: jnp.ndarray, a_scale: jnp.ndarray,
              b_scale: jnp.ndarray, out_dtype=jnp.float32) -> jnp.ndarray:
    """C = (a_scale[:,None] * b_scale[None,:]) * (int8 A @ int8 B).

    a: (M,K) int8, b: (K,N) int8, a_scale: (M,) f32 per-row,
    b_scale: (N,) f32 per-column."""
    acc = jnp.dot(a.astype(jnp.int32), b.astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    out = acc.astype(jnp.float32) * a_scale[:, None] * b_scale[None, :]
    return out.astype(out_dtype)


def requantize_ref(acc, mult: int, shift: int, qmin: int = -127,
                   qmax: int = 127):
    """Fixed-point requantization: ``clamp((acc * mult) >> shift)``.

    Operator-only on purpose so it runs identically on numpy *and* jax
    integer arrays: the CGRA-side ``requant-int8`` DSL kernel
    (``repro.frontend.library``) uses this same function as its golden
    model, pinning the fabric datapath and the Pallas int8 path to one
    rounding/saturation semantics."""
    v = (acc * mult) >> shift
    return v.clip(qmin, qmax)


def quantize_rowwise(x: jnp.ndarray):
    """Symmetric per-row int8 quantization: returns (q, scale)."""
    amax = jnp.max(jnp.abs(x), axis=1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale
