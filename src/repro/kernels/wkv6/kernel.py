"""RWKV6 WKV recurrence kernel (data-dependent decay), chunked over time.

TPU adaptation: the (D x D) per-head state is the "output-stationary"
resident in VMEM scratch across the sequential time-chunk grid axis;
r/k/v/w chunks stream HBM->VMEM once.  Within a chunk the recurrence is
stepped sequentially (the mathematically-exact form; a matmul-rich chunked
reformulation exists but divides by cumulative decays and is numerically
unsafe for long chunks — documented trade-off, see DESIGN.md).

Grid: (B*H, T/ct), both axes "arbitrary" (state carries across chunks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..common import VMEM, compiler_params


def _make_kernel(ct: int, s_steps: int):
    def kern(u_ref, s0_ref, r_ref, k_ref, v_ref, w_ref, o_ref, sout_ref,
             state):
        s = pl.program_id(1)

        @pl.when(s == 0)
        def _init():
            state[...] = s0_ref[0].astype(jnp.float32)

        u = u_ref[0].astype(jnp.float32)        # (D,)

        def body(i, S):
            idx = (0, pl.dslice(i, 1), slice(None))
            rt = pl.load(r_ref, idx)[0].astype(jnp.float32)
            kt = pl.load(k_ref, idx)[0].astype(jnp.float32)
            vt = pl.load(v_ref, idx)[0].astype(jnp.float32)
            wt = pl.load(w_ref, idx)[0].astype(jnp.float32)
            kv = kt[:, None] * vt[None, :]
            out = jnp.dot(rt[None, :], S + u[:, None] * kv,
                          preferred_element_type=jnp.float32)
            pl.store(o_ref, idx, out[None].astype(o_ref.dtype)[0])
            return wt[:, None] * S + kv

        S = jax.lax.fori_loop(0, ct, body, state[...])
        state[...] = S
        sout_ref[0] = S.astype(sout_ref.dtype)

    return kern


def wkv6_pallas(r, k, v, w, u, state0, *, ct: int = 64,
                interpret: bool = False):
    """r/k/v/w: (BH, T, D); u: (H, D); state0: (BH, D, D); BH = B*H.
    Returns (out (BH,T,D), state (BH,D,D))."""
    BH, T, D = r.shape
    H = u.shape[0]
    assert T % ct == 0
    s_steps = T // ct
    mk = VMEM if VMEM is not None else (
        lambda shp, dt: jax.ShapeDtypeStruct(shp, dt))
    kern = _make_kernel(ct, s_steps)
    out, sout = pl.pallas_call(
        kern,
        grid=(BH, s_steps),
        in_specs=[
            pl.BlockSpec((1, D), lambda bh, s: (bh % H, 0)),
            pl.BlockSpec((1, D, D), lambda bh, s: (bh, 0, 0)),
            pl.BlockSpec((1, ct, D), lambda bh, s: (bh, s, 0)),
            pl.BlockSpec((1, ct, D), lambda bh, s: (bh, s, 0)),
            pl.BlockSpec((1, ct, D), lambda bh, s: (bh, s, 0)),
            pl.BlockSpec((1, ct, D), lambda bh, s: (bh, s, 0)),
        ],
        out_specs=(pl.BlockSpec((1, ct, D), lambda bh, s: (bh, s, 0)),
                   pl.BlockSpec((1, D, D), lambda bh, s: (bh, 0, 0))),
        out_shape=(jax.ShapeDtypeStruct((BH, T, D), r.dtype),
                   jax.ShapeDtypeStruct((BH, D, D), jnp.float32)),
        scratch_shapes=[mk((D, D), jnp.float32)],
        compiler_params=compiler_params(("arbitrary", "arbitrary")),
        interpret=interpret,
    )(u, state0, r, k, v, w)
    return out, sout
