"""Jitted wrapper for the WKV6 kernel: (B,T,H,D) layout plumbing."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import wkv6_pallas
from .ref import wkv6_ref


@functools.partial(jax.jit, static_argnames=("ct", "interpret", "use_kernel"))
def wkv6(r, k, v, w, u, state0=None, *, ct: int = 64,
         interpret: bool = False, use_kernel: bool = True):
    """r/k/v/w: (B, T, H, D); u: (H, D).  Returns (out, state)."""
    B, T, H, D = r.shape
    if state0 is None:
        state0 = jnp.zeros((B, H, D, D), jnp.float32)
    if not use_kernel:
        return wkv6_ref(r, k, v, w, u, state0)
    ct_ = ct
    while T % ct_ != 0:
        ct_ //= 2
    ct_ = max(1, ct_)

    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, T, D)

    out, s = wkv6_pallas(to_bh(r), to_bh(k), to_bh(v), to_bh(w), u,
                         state0.reshape(B * H, D, D), ct=ct_,
                         interpret=interpret)
    out = out.reshape(B, H, T, D).transpose(0, 2, 1, 3)
    return out, s.reshape(B, H, D, D)
