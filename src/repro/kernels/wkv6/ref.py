"""Pure-jnp oracle for the RWKV6 (Finch) WKV recurrence with
data-dependent decay.

Per head (state S in R^{D x D}):
    o_t = r_t @ (S_{t-1} + diag(u) (k_t^T v_t))
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
with w_t = exp(-exp(w_log_t)) data-dependent decay in (0,1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u, state0=None):
    """r/k/v/w: (B, T, H, D) fp32; u: (H, D).  Returns (out, final_state)
    with out: (B, T, H, D), state: (B, H, D, D)."""
    B, T, H, D = r.shape
    if state0 is None:
        state0 = jnp.zeros((B, H, D, D), jnp.float32)

    def step(S, xs):
        rt, kt, vt, wt = xs                       # each (B, H, D)
        kv = kt[..., :, None] * vt[..., None, :]  # (B, H, D, D)
        out = jnp.einsum("bhd,bhde->bhe", rt, S + u[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, out

    xs = tuple(jnp.moveaxis(x.astype(jnp.float32), 1, 0) for x in (r, k, v, w))
    S, outs = jax.lax.scan(step, state0.astype(jnp.float32), xs)
    return jnp.moveaxis(outs, 0, 1).astype(r.dtype), S
