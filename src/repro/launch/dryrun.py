import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any other import: jax locks the device count on first init.

"""Multi-pod dry-run: prove the distribution config is coherent without
hardware.

For every (architecture x input-shape) cell and each production mesh
(single-pod 16x16, multi-pod 2x16x16), lower + compile the appropriate
step function against ShapeDtypeStruct inputs and record:
  * memory_analysis()  (bytes per device -> does it fit HBM),
  * cost_analysis()    (FLOPs / bytes for the roofline),
  * collective-bytes parsed from the compiled HLO.

Usage:
    python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""
import argparse
import functools
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.registry import ARCH_IDS, SHAPES, get_config, runnable
from ..models.zoo import build_model
from ..roofline.hlo import collective_bytes, cost_terms
from ..train import optimizer as optim
from ..train.step import make_train_step
from .mesh import make_production_mesh
from .specs import (abstract_cache, abstract_params, abstract_train_state,
                    decode_input_specs, token_or_embed_spec,
                    train_batch_specs)


def lower_cell(arch: str, shape: str, mesh, *, num_layers: Optional[int]
               = None, microbatches: int = 1, extra: Optional[Dict] = None):
    """Lower (not yet compile) one (arch, shape) cell on `mesh`.
    num_layers overrides cfg.n_layers (used by the roofline two-point fit).
    Returns (lowered, meta)."""
    import dataclasses
    cfg = get_config(arch)
    if num_layers is not None:
        # keep first_k_dense consistent when shrinking
        cfg = dataclasses.replace(
            cfg, n_layers=num_layers,
            first_k_dense=min(cfg.first_k_dense, max(0, num_layers - 1)),
            attn_every=min(cfg.attn_every, num_layers) if cfg.attn_every
            else 0)
    if extra and extra.get("scan_unroll"):
        cfg = dataclasses.replace(cfg, scan_unroll=True)
    if extra and extra.get("overrides"):
        cfg = dataclasses.replace(cfg, **extra["overrides"])
    cell = SHAPES[shape]
    ok, why = runnable(cfg, cell)
    if not ok:
        raise SkipCell(why)
    model = build_model(cfg)

    with mesh:
        if cell.kind == "train":
            opt_cfg = optim.OptConfig()
            logits_spec = None
            if extra and extra.get("shard_logits"):
                from jax.sharding import NamedSharding, PartitionSpec as P
                dp = tuple(n for n in ("pod", "data")
                           if n in mesh.axis_names)
                logits_spec = NamedSharding(
                    mesh, P(dp if len(dp) > 1 else dp[0], None, "model"))
            mb = (extra or {}).get("microbatches", microbatches)
            step = make_train_step(model, opt_cfg, num_microbatches=mb,
                                   logits_spec=logits_spec)
            state = abstract_train_state(model, mesh)
            batch = train_batch_specs(cfg, cell, mesh)
            lowered = jax.jit(step).lower(state, batch)
        elif cell.kind == "prefill":
            B, T = cell.global_batch, cell.seq_len
            inputs = token_or_embed_spec(cfg, B, T, mesh)
            lens = jax.ShapeDtypeStruct((B,), jnp.int32)
            params, _ = abstract_params(model, mesh)
            lowered = jax.jit(model.prefill).lower(params, inputs, lens)
        else:  # decode
            B, S = cell.global_batch, cell.seq_len
            params, _ = abstract_params(model, mesh)
            caches = abstract_cache(model, B, S, mesh)
            toks, pos, lens = decode_input_specs(cfg, cell, mesh)
            lowered = jax.jit(model.decode).lower(params, caches, toks,
                                                  pos, lens)
    return lowered, {"arch": arch, "shape": shape, "kind": cell.kind,
                     "n_layers": cfg.n_layers if num_layers is None
                     else num_layers}


class SkipCell(Exception):
    pass


def run_cell(arch: str, shape: str, mesh, mesh_name: str,
             compile_: bool = True) -> Dict[str, Any]:
    t0 = time.time()
    rec: Dict[str, Any] = {"arch": arch, "shape": shape, "mesh": mesh_name}
    try:
        lowered, meta = lower_cell(arch, shape, mesh)
    except SkipCell as e:
        rec.update(status="skip", reason=str(e))
        return rec
    rec["lower_s"] = round(time.time() - t0, 1)
    if not compile_:
        rec["status"] = "lowered"
        return rec
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 1)
    mem = compiled.memory_analysis()
    rec["bytes_per_device"] = {
        "argument": getattr(mem, "argument_size_in_bytes", None),
        "output": getattr(mem, "output_size_in_bytes", None),
        "temp": getattr(mem, "temp_size_in_bytes", None),
        "peak": (getattr(mem, "argument_size_in_bytes", 0) or 0)
        + (getattr(mem, "temp_size_in_bytes", 0) or 0),
    }
    rec["cost"] = cost_terms(compiled)
    rec["collective_bytes"] = collective_bytes(compiled.as_text())
    rec["status"] = "ok"
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    meshes = []
    if args.both_meshes:
        meshes = [("pod16x16", make_production_mesh(multi_pod=False)),
                  ("pods2x16x16", make_production_mesh(multi_pod=True))]
    else:
        mp = args.multi_pod
        meshes = [("pods2x16x16" if mp else "pod16x16",
                   make_production_mesh(multi_pod=mp))]

    cells = []
    if args.all:
        cells = [(a, s) for a in ARCH_IDS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    results = []
    fails = 0
    for mesh_name, mesh in meshes:
        for arch, shape in cells:
            try:
                rec = run_cell(arch, shape, mesh, mesh_name)
            except Exception as e:  # noqa: BLE001 — report and continue
                rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "status": "FAIL",
                       "error": f"{type(e).__name__}: {e}"}
                traceback.print_exc()
                fails += 1
            results.append(rec)
            print(json.dumps(rec), flush=True)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_skip = sum(1 for r in results if r["status"] == "skip")
    print(f"# dry-run: {n_ok} ok, {n_skip} skip, {fails} FAIL "
          f"of {len(results)}", flush=True)
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
