"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing never touches jax
device state; the dry-run sets XLA_FLAGS before the first jax call.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2 pods x 256 = 512 chips (pod, data, model) — the "pod"
    axis composes with "data" for cross-pod FSDP/DP (DCN-hierarchical)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1-device mesh with production axis names (CI smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
