"""ShapeDtypeStruct stand-ins for every model input: shardable, weak-type
correct, zero device allocation.  The dry-run lowers against these.

For [audio]/[vlm] archs the modality frontend is a stub: input_specs
provides precomputed frame/patch embeddings (B, T, d_model)."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.registry import ShapeCell
from ..dist.sharding import (batch_spec, cache_spec, params_shardings,
                             tree_shardings)
from ..models.common import ModelConfig
from ..models.zoo import Model, build_model
from ..train import optimizer as optim
from ..train.step import TrainState, init_train_state


def _sds(shape, dtype, mesh, spec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def token_or_embed_spec(cfg: ModelConfig, B: int, T: int, mesh: Mesh):
    if cfg.input_mode == "tokens":
        return _sds((B, T), jnp.int32, mesh, batch_spec((B, T), mesh))
    shape = (B, T, cfg.d_model)
    return _sds(shape, cfg.dtype, mesh, batch_spec(shape, mesh))


def train_batch_specs(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh):
    B, T = cell.global_batch, cell.seq_len
    return {
        "inputs": token_or_embed_spec(cfg, B, T, mesh),
        "labels": _sds((B, T), jnp.int32, mesh, batch_spec((B, T), mesh)),
    }


def abstract_params(model: Model, mesh: Mesh):
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = params_shardings(shapes, mesh)
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                          sharding=NamedSharding(mesh, p)),
        shapes, specs), specs


def abstract_train_state(model: Model, mesh: Mesh):
    params, specs = abstract_params(model, mesh)
    f32 = lambda t: jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, jnp.float32,
                                       sharding=x.sharding), t)
    opt = optim.OptState(
        step=jax.ShapeDtypeStruct(
            (), jnp.int32, sharding=NamedSharding(mesh, P())),
        master=f32(params), m=f32(params), v=f32(params))
    return TrainState(params=params, opt=opt)


def abstract_cache(model: Model, B: int, S: int, mesh: Mesh):
    shapes = jax.eval_shape(functools.partial(model.init_cache, B, S))
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(
            x.shape, x.dtype,
            sharding=NamedSharding(mesh, cache_spec(tuple(x.shape), mesh))),
        shapes)


def decode_input_specs(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh):
    B = cell.global_batch
    if cfg.input_mode == "tokens":
        toks = _sds((B, 1), jnp.int32, mesh, batch_spec((B, 1), mesh))
    else:
        toks = _sds((B, 1, cfg.d_model), cfg.dtype, mesh,
                    batch_spec((B, 1, cfg.d_model), mesh))
    pos = _sds((B, 1), jnp.int32, mesh, batch_spec((B, 1), mesh))
    lens = _sds((B,), jnp.int32, mesh, batch_spec((B,), mesh))
    return toks, pos, lens
