"""Attention variants: GQA/MQA/MHA with RoPE, and DeepSeek-style MLA
(latent-compressed KV).  Pure functions over param pytrees.

Shapes: x (B, T, d); caches (B, Hkv, S, hd) (GQA) or latent (B, S, r+rope)
(MLA).  Decode paths take `positions`/`lengths` for cache bookkeeping.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, apply_rope, causal_mask, init_dense, \
    rope_angles

NEG = -1e30


# ------------------------------------------------------------------ GQA
def init_gqa(key, cfg: ModelConfig) -> Dict:
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": init_dense(ks[0], (d, H * hd), dtype=cfg.dtype),
        "wk": init_dense(ks[1], (d, Hkv * hd), dtype=cfg.dtype),
        "wv": init_dense(ks[2], (d, Hkv * hd), dtype=cfg.dtype),
        "wo": init_dense(ks[3], (H * hd, d), dtype=cfg.dtype),
    }


def _sdpa(q, k, v, mask):
    """q: (B,T,H,hd); k/v: (B,S,Hkv,hd); mask: (T,S) or (B,T,S)."""
    B, T, H, hd = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qf = q.reshape(B, T, Hkv, G, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bthgd,bshd->bhgts", qf, kf) / (hd ** 0.5)
    if mask.ndim == 2:
        mask = mask[None]
    logits = jnp.where(mask[:, None, None], logits, NEG)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgts,bshd->bthgd", p, vf)
    return out.reshape(B, T, H, hd).astype(q.dtype)


def gqa_forward(p: Dict, cfg: ModelConfig, x, positions,
                cache: Optional[Tuple] = None,
                lengths: Optional[jnp.ndarray] = None):
    """Training/prefill when cache is None (causal over x itself);
    decode when cache=(k_cache, v_cache) — x is the new token(s), cache is
    updated at `positions` and attended with `lengths` masking.
    Returns (out, new_cache)."""
    B, T, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("btd,dk->btk", x, p["wq"]).reshape(B, T, H, hd)
    k = jnp.einsum("btd,dk->btk", x, p["wk"]).reshape(B, T, Hkv, hd)
    v = jnp.einsum("btd,dk->btk", x, p["wv"]).reshape(B, T, Hkv, hd)
    cos, sin = rope_angles(positions, hd, cfg.rope_theta)   # (B,T,hd/2)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    if cache is None:
        mask = causal_mask(T, T)
        out = _sdpa(q, k, v, mask)
        new_cache = (k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3))
    else:
        kc, vc = cache                                   # (B, Hkv, S, hd)
        S = kc.shape[2]
        # scatter the new token(s) at `positions`
        onehot = jax.nn.one_hot(positions, S, dtype=kc.dtype)  # (B,T,S)
        kc = kc + jnp.einsum("bts,bthd->bhsd", onehot, k)
        vc = vc + jnp.einsum("bts,bthd->bhsd", onehot, v)
        span = jnp.arange(S)[None, :] < lengths[:, None]       # (B,S)
        # attend directly in the cache layout: no (B,S,H,hd) transposes —
        # the sequence axis stays sharded end-to-end and GSPMD lowers the
        # softmax/weighted-sum contractions to small all-reduces instead
        # of all-gathering the cache (the decode collective hillclimb).
        G = H // Hkv
        qf = q.reshape(B, T, Hkv, G, hd).astype(jnp.float32)
        logits = jnp.einsum("bthgd,bhsd->bhgts", qf,
                            kc.astype(jnp.float32)) / (hd ** 0.5)
        logits = jnp.where(span[:, None, None, None, :], logits, NEG)
        pattn = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgts,bhsd->bthgd", pattn,
                         vc.astype(jnp.float32)).astype(x.dtype)
        out = out.reshape(B, T, H, hd)
        new_cache = (kc, vc)
    out = out.reshape(B, T, H * hd)
    return jnp.einsum("btk,kd->btd", out, p["wo"]), new_cache


# ------------------------------------------------------------------ MLA
def init_mla(key, cfg: ModelConfig) -> Dict:
    d, H = cfg.d_model, cfg.n_heads
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": init_dense(ks[0], (d, rq), dtype=cfg.dtype),
        "wq_b": init_dense(ks[1], (rq, H * (dn + dr)), dtype=cfg.dtype),
        "wkv_a": init_dense(ks[2], (d, rkv + dr), dtype=cfg.dtype),
        "wkv_b": init_dense(ks[3], (rkv, H * (dn + dv)), dtype=cfg.dtype),
        "wo": init_dense(ks[4], (H * dv, d), dtype=cfg.dtype),
    }


def mla_forward(p: Dict, cfg: ModelConfig, x, positions,
                cache: Optional[jnp.ndarray] = None,
                lengths: Optional[jnp.ndarray] = None):
    """MLA with latent-KV caching: the cache stores (c_kv, k_rope) —
    (B, S, rkv + dr) — the memory win of DeepSeek-V3.  Returns
    (out, new_cache)."""
    B, T, d = x.shape
    H = cfg.n_heads
    rkv, dn, dr, dv = (cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim,
                       cfg.v_head_dim)
    q = jnp.einsum("btd,dr->btr", x, p["wq_a"])
    q = jnp.einsum("btr,rk->btk", q, p["wq_b"]).reshape(B, T, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    cos, sin = rope_angles(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)

    ckv = jnp.einsum("btd,dr->btr", x, p["wkv_a"])        # (B,T,rkv+dr)
    c_lat, k_rope = ckv[..., :rkv], ckv[..., rkv:]
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    lat = jnp.concatenate([c_lat, k_rope], axis=-1)       # (B,T,rkv+dr)

    if cache is None:
        full = lat
        S = T
        mask = causal_mask(T, S)[None]
    else:
        S = cache.shape[1]
        onehot = jax.nn.one_hot(positions, S, dtype=cache.dtype)
        full = cache + jnp.einsum("bts,btr->bsr", onehot, lat)
        mask = (jnp.arange(S)[None, :] < lengths[:, None])[:, None, :]
    c_all, kr_all = full[..., :rkv], full[..., rkv:]

    # up-project latents to per-head keys/values
    kv = jnp.einsum("bsr,rk->bsk", c_all,
                    p["wkv_b"]).reshape(B, S, H, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    qf = q_nope.astype(jnp.float32)
    logits = (jnp.einsum("bthd,bshd->bhts", qf, k_nope.astype(jnp.float32))
              + jnp.einsum("bthd,bsd->bhts", q_rope.astype(jnp.float32),
                           kr_all.astype(jnp.float32))) / ((dn + dr) ** 0.5)
    logits = jnp.where(mask[:, None] if mask.ndim == 3 else mask,
                       logits, NEG)
    pattn = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", pattn, v.astype(jnp.float32))
    out = out.reshape(B, T, H * dv).astype(x.dtype)
    return jnp.einsum("btk,kd->btd", out, p["wo"]), full
