"""Model configuration + shared layer primitives (pure-JAX, pytree params)."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    rope_theta: float = 500000.0
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    # input modality: "tokens" or "embeddings" (audio/vlm backbone stubs)
    input_mode: str = "tokens"
    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 1
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    first_k_dense: int = 0
    dense_d_ff: int = 0         # d_ff of the first_k_dense layers
    # MLA (DeepSeek-V3)
    moe_capacity_factor: float = 1.25   # 8+ = effectively no-drop
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mtp: bool = False           # multi-token-prediction auxiliary head
    # SSM / hybrid
    ssm_state: int = 0
    attn_every: int = 0         # Zamba2: shared attention block period
    conv_kernel: int = 4        # mamba2 depthwise conv width
    # numerics
    dtype: Any = jnp.bfloat16
    # roofline instrumentation: unroll the layer scan so cost_analysis sees
    # every layer (scan bodies are otherwise counted once)
    scan_unroll: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def decay_lora_rank(self) -> int:
        """RWKV6 data-dependent decay LoRA rank (the Finch heuristic);
        shared by the layer init and the GEMM-site enumeration."""
        return max(32, self.d_model // 32)

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k+ context (O(1)-state recurrence)?"""
        return self.family in ("ssm", "hybrid")

    @property
    def params_dense(self) -> int:
        """Approximate total parameter count (for 6*N*D roofline math)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":                      # rwkv6
            att = L * (4 * d * d + 2 * d)             # r,k,v,o (+decay lora)
            ffn = L * (2 * d * self.d_ff)
            return emb + att + ffn
        att_out = self.n_heads * self.hd * d
        if self.mla:
            qk = d * self.q_lora_rank + self.q_lora_rank * self.n_heads * (
                self.qk_nope_dim + self.qk_rope_dim)
            kv = d * (self.kv_lora_rank + self.qk_rope_dim) + \
                self.kv_lora_rank * self.n_heads * (
                    self.qk_nope_dim + self.v_head_dim)
            att = L * (qk + kv + self.n_heads * self.v_head_dim * d)
        else:
            att = L * (d * self.n_heads * self.hd
                       + 2 * d * self.n_kv_heads * self.hd + att_out)
        if self.moe:
            n_moe = L - self.first_k_dense
            ffn = (self.first_k_dense * 3 * d * self.dense_d_ff
                   + n_moe * (self.n_experts + self.n_shared_experts)
                   * 3 * d * self.moe_d_ff
                   + n_moe * d * self.n_experts)
        else:
            ffn = L * 3 * d * self.d_ff
        return emb + att + ffn

    @property
    def params_active(self) -> int:
        """Activated parameters per token (MoE-aware)."""
        if not self.moe:
            return self.params_dense
        full = self.params_dense
        n_moe = self.n_layers - self.first_k_dense
        all_experts = n_moe * self.n_experts * 3 * self.d_model * self.moe_d_ff
        act_experts = n_moe * (self.top_k + self.n_shared_experts) * \
            3 * self.d_model * self.moe_d_ff
        return full - all_experts + act_experts

    def reduced(self) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        return replace(
            self,
            n_layers=min(self.n_layers, 2 if not self.attn_every else 4),
            d_model=64, n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads)),
            head_dim=16, d_ff=128, vocab=256,
            q_lora_rank=32 if self.mla else 0,
            kv_lora_rank=32 if self.mla else 0,
            qk_nope_dim=16 if self.mla else 0,
            qk_rope_dim=8 if self.mla else 0,
            v_head_dim=16 if self.mla else 0,
            n_experts=min(self.n_experts, 4), top_k=min(self.top_k, 2),
            moe_d_ff=64 if self.moe else 0,
            dense_d_ff=128 if self.first_k_dense else 0,
            first_k_dense=min(self.first_k_dense, 1),
            ssm_state=16 if self.ssm_state else 0,
            attn_every=2 if self.attn_every else 0,
            dtype=jnp.float32,
        )


# ------------------------------------------------------------- primitives
def rms_norm(x, w, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) \
        * w.astype(x.dtype)


def rope_angles(positions, dim: int, theta: float):
    """positions: (...,) int32 -> (cos, sin): (..., dim/2) fp32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., T, H, D); cos/sin: (T, D/2) broadcast over heads."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c],
                           axis=-1).astype(x.dtype)


def swiglu(x, wi_gate, wi_up, wo):
    g = jnp.einsum("...d,df->...f", x, wi_gate)
    u = jnp.einsum("...d,df->...f", x, wi_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, wo)


def init_dense(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    scale = scale if scale is not None else (1.0 / (shape[0] ** 0.5))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def causal_mask(Tq: int, Tk: int, offset: int = 0):
    """mask[i, j] = True where key j may attend to query i (j <= i+offset)."""
    q = jnp.arange(Tq)[:, None] + offset
    k = jnp.arange(Tk)[None, :]
    return k <= q
