"""Mamba2 (SSD) block + the Zamba2 hybrid wiring (Mamba2 backbone with a
shared attention block applied periodically).

SSD recurrence per head h with scalar decay a_t:
    S_t = a_t * S_{t-1} + dt_t * (x_t outer B_t)     S: (head_p, d_state)
    y_t = S_t @ C_t + D * x_t
a_t = exp(-softplus(dt_raw + bias) * exp(A_log)) — input-dependent.

O(1) state per layer -> 500k decode runnable (hybrid family).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, init_dense, rms_norm


def mamba_dims(cfg: ModelConfig):
    d_inner = 2 * cfg.d_model
    nh = cfg.n_heads
    hp = d_inner // nh
    ds = cfg.ssm_state
    return d_inner, nh, hp, ds


def init_mamba2_block(key, cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    d_inner, nh, hp, ds = mamba_dims(cfg)
    K = cfg.conv_kernel
    conv_dim = d_inner + 2 * ds
    ks = jax.random.split(key, 6)
    return {
        "ln": jnp.ones((d,), jnp.float32),
        "in_proj": init_dense(ks[0], (d, 2 * d_inner + 2 * ds + nh),
                              dtype=cfg.dtype),
        "conv_w": init_dense(ks[1], (K, conv_dim), scale=0.5,
                             dtype=cfg.dtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "out_proj": init_dense(ks[2], (d_inner, d), dtype=cfg.dtype),
    }


def _causal_conv(x, w, b, prev):
    """x: (B,T,C) depthwise causal conv, kernel K.  prev: (B,K-1,C) left
    context (zeros at sequence start).  Returns (y, new_prev)."""
    K = w.shape[0]
    xp = jnp.concatenate([prev, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(K))
    new_prev = xp[:, -(K - 1):] if K > 1 else prev
    return y + b[None, None], new_prev


def mamba2_block(p: Dict, cfg: ModelConfig, x,
                 state: Optional[Tuple] = None):
    """x: (B,T,d); state=(conv_prev (B,K-1,C), ssm (B,nh,hp,ds)) or None.
    Returns (out, new_state)."""
    B, T, d = x.shape
    d_inner, nh, hp, ds = mamba_dims(cfg)
    K = cfg.conv_kernel
    conv_dim = d_inner + 2 * ds
    if state is None:
        conv_prev = jnp.zeros((B, K - 1, conv_dim), x.dtype)
        S0 = jnp.zeros((B, nh, hp, ds), jnp.float32)
    else:
        conv_prev, S0 = state

    xn = rms_norm(x, p["ln"], cfg.rms_eps)
    zxbcdt = jnp.einsum("btd,de->bte", xn, p["in_proj"])
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + conv_dim]
    dt_raw = zxbcdt[..., d_inner + conv_dim:]              # (B,T,nh)

    xbc, conv_prev = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_prev)
    xbc = jax.nn.silu(xbc.astype(jnp.float32))
    xs = xbc[..., :d_inner].reshape(B, T, nh, hp)
    Bm = xbc[..., d_inner:d_inner + ds]                    # (B,T,ds)
    Cm = xbc[..., d_inner + ds:]                           # (B,T,ds)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None])       # (B,T,nh)
    a = jnp.exp(-dt * jnp.exp(p["A_log"])[None, None])     # (B,T,nh)

    def step(S, inp):
        xt, Bt, Ct, at, dtt = inp    # (B,nh,hp) (B,ds) (B,ds) (B,nh) (B,nh)
        dBx = jnp.einsum("bnp,bs,bn->bnps", xt, Bt, dtt)
        S = at[..., None, None] * S + dBx
        y = jnp.einsum("bnps,bs->bnp", S, Ct)
        return S, y

    xs_t = jnp.moveaxis(xs, 1, 0)
    S, ys = jax.lax.scan(step, S0,
                         (xs_t, jnp.moveaxis(Bm, 1, 0),
                          jnp.moveaxis(Cm, 1, 0), jnp.moveaxis(a, 1, 0),
                          jnp.moveaxis(dt, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1)                             # (B,T,nh,hp)
    y = y + p["D"][None, None, :, None] * xs
    y = y.reshape(B, T, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bte,ed->btd", y.astype(x.dtype), p["out_proj"])
    return x + out, (conv_prev.astype(x.dtype), S)
