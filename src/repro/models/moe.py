"""Mixture-of-Experts FFN with top-k routing (llama4-style top-1 and
DeepSeek-V3-style 1-shared + top-8).

Dense one-hot dispatch einsums: GSPMD partitions the expert axis over the
"model" mesh axis (EP) and lowers the dispatch/combine contractions to
all-to-all / all-gather — the routing pattern the roofline's collective
term measures.  An auxiliary load-balance loss (Switch-style) is returned
for the trainer.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, init_dense


def init_moe(key, cfg: ModelConfig) -> Dict:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": init_dense(ks[0], (d, E), scale=0.02, dtype=jnp.float32),
        "wi_gate": init_dense(ks[1], (E, d, f), dtype=cfg.dtype),
        "wi_up": init_dense(ks[2], (E, d, f), dtype=cfg.dtype),
        "wo": init_dense(ks[3], (E, f, d), dtype=cfg.dtype),
    }
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        ks2 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wi_gate": init_dense(ks2[0], (d, fs), dtype=cfg.dtype),
            "wi_up": init_dense(ks2[1], (d, fs), dtype=cfg.dtype),
            "wo": init_dense(ks2[2], (fs, d), dtype=cfg.dtype),
        }
    return p


def moe_forward(p: Dict, cfg: ModelConfig, x,
                capacity_factor: float = None) -> Tuple[jnp.ndarray,
                                                        jnp.ndarray]:
    """x: (B, T, d) -> (out, aux_loss).

    Capacity-based scatter/gather dispatch: per-expert buffers of
    C = ceil(N*k/E * capacity_factor) token slots (Switch-style drop
    beyond capacity).  Peak activation is (E, C, d) — linear in tokens —
    instead of the (E, N, d) dense-dispatch blow-up; the N->E scatter is
    what GSPMD lowers to the EP all-to-all."""
    B, T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    N = B * T
    cf = (capacity_factor if capacity_factor is not None
          else cfg.moe_capacity_factor)
    C = max(1, min(N, int((N * k / E) * cf)))
    xf = x.reshape(N, d)
    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)              # (N,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # rank of each (token, choice) within its expert, k-major priority
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)      # (N,k,E)
    flat = onehot.transpose(1, 0, 2).reshape(k * N, E)    # k-major
    ranks = (jnp.cumsum(flat, axis=0) - flat)             # (kN,E)
    rank_of = (ranks * flat).sum(-1).reshape(k, N).T      # (N,k)
    keep = rank_of < C
    slot = jnp.where(keep, rank_of, C)                    # overflow -> C

    # scatter tokens into (E, C+1, d); slot C is the drop bucket
    exp_idx = idx.reshape(-1)                             # (N*k,)
    slot_idx = slot.reshape(-1)
    src = jnp.repeat(xf, k, axis=0)                       # (N*k, d)
    xe = jnp.zeros((E, C + 1, d), xf.dtype)
    xe = xe.at[exp_idx, slot_idx].add(src)
    xe = xe[:, :C]                                        # (E, C, d)

    g = jnp.einsum("ecd,edf->ecf", xe, p["wi_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, p["wi_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xf.dtype) * u
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"])           # (E, C, d)

    # gather back and combine with gates
    gathered = ye[exp_idx, jnp.minimum(slot_idx, C - 1)]  # (N*k, d)
    gathered = gathered * (keep.reshape(-1, 1).astype(xf.dtype))
    gates = gate_vals.reshape(-1, 1).astype(xf.dtype)
    out = (gathered * gates).reshape(N, k, d).sum(1)

    if "shared" in p:
        s = p["shared"]
        gs = jnp.einsum("nd,df->nf", xf, s["wi_gate"])
        us = jnp.einsum("nd,df->nf", xf, s["wi_up"])
        hs = jax.nn.silu(gs.astype(jnp.float32)).astype(xf.dtype) * us
        out = out + jnp.einsum("nf,fd->nd", hs, s["wo"])

    # Switch-style load-balance aux loss
    me = probs.mean(0)                                    # (E,)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)
    return out.reshape(B, T, d), aux
