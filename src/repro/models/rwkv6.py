"""RWKV6 "Finch" block: time-mix with data-dependent decay (the WKV6
recurrence) + channel-mix.  Attention-free; O(1) state per layer makes the
500k-token decode shape runnable (DESIGN.md section 5).

The recurrence math matches kernels/wkv6/ref.py exactly; training uses a
chunk-sequential lax.scan (vectorized over batch x heads), decode carries
(B, H, D, D) state.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, init_dense, rms_norm


def init_rwkv6_block(key, cfg: ModelConfig) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    H = cfg.n_heads
    D = d // H
    ks = jax.random.split(key, 10)
    lora = cfg.decay_lora_rank
    return {
        "ln1": jnp.ones((d,), jnp.float32),
        "ln2": jnp.ones((d,), jnp.float32),
        "mix_r": 0.5 * jnp.ones((d,), cfg.dtype),
        "mix_k": 0.5 * jnp.ones((d,), cfg.dtype),
        "mix_v": 0.5 * jnp.ones((d,), cfg.dtype),
        "mix_w": 0.5 * jnp.ones((d,), cfg.dtype),
        "wr": init_dense(ks[0], (d, d), dtype=cfg.dtype),
        "wk": init_dense(ks[1], (d, d), dtype=cfg.dtype),
        "wv": init_dense(ks[2], (d, d), dtype=cfg.dtype),
        "wo": init_dense(ks[3], (d, d), dtype=cfg.dtype),
        # data-dependent decay LoRA (the Finch contribution)
        "w_a": init_dense(ks[4], (d, lora), scale=0.02, dtype=cfg.dtype),
        "w_b": init_dense(ks[5], (lora, d), scale=0.02, dtype=cfg.dtype),
        "w_base": -6.0 * jnp.ones((d,), jnp.float32),
        "u": init_dense(ks[6], (H, D), scale=0.5),
        "ck": init_dense(ks[7], (d, f), dtype=cfg.dtype),
        "cv": init_dense(ks[8], (f, d), dtype=cfg.dtype),
        "mix_c": 0.5 * jnp.ones((d,), cfg.dtype),
    }


def _token_shift(x, prev):
    """prev: (B,1,d) last token of the previous segment (zeros at start)."""
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _wkv6_scan(r, k, v, w, u, state0):
    """r/k/v/w: (B,T,H,D); u: (H,D); state0: (B,H,D,D)."""
    def step(S, xs):
        rt, kt, vt, wt = xs
        kv = kt[..., :, None] * vt[..., None, :]
        out = jnp.einsum("bhd,bhde->bhe", rt, S + u[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, out

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0)
               for a in (r, k, v, w))
    S, outs = jax.lax.scan(step, state0.astype(jnp.float32), xs)
    return jnp.moveaxis(outs, 0, 1), S


def rwkv6_block(p: Dict, cfg: ModelConfig, x,
                state: Optional[Tuple] = None):
    """x: (B,T,d).  state = (last_token (B,1,d), wkv_state (B,H,D,D),
    last_token_cm (B,1,d)) for decode; None for training (zeros).
    Returns (out, new_state)."""
    B, T, d = x.shape
    H = cfg.n_heads
    D = d // H
    if state is None:
        last = jnp.zeros((B, 1, d), x.dtype)
        S0 = jnp.zeros((B, H, D, D), jnp.float32)
        last_cm = jnp.zeros((B, 1, d), x.dtype)
    else:
        last, S0, last_cm = state

    # ---- time mix (WKV6)
    xn = rms_norm(x, p["ln1"], cfg.rms_eps)
    prev = _token_shift(xn, last)

    def mix(m):
        return xn + (prev - xn) * m

    r = jnp.einsum("btd,de->bte", mix(p["mix_r"]), p["wr"])
    k = jnp.einsum("btd,de->bte", mix(p["mix_k"]), p["wk"])
    v = jnp.einsum("btd,de->bte", mix(p["mix_v"]), p["wv"])
    wl = jnp.einsum("btd,dr->btr", mix(p["mix_w"]), p["w_a"])
    wl = jnp.einsum("btr,rd->btd", jnp.tanh(wl.astype(jnp.float32)).astype(
        x.dtype), p["w_b"])
    decay = jnp.exp(-jnp.exp(p["w_base"][None, None]
                             + wl.astype(jnp.float32)))     # (B,T,d) in (0,1)

    def heads(a):
        return a.reshape(B, T, H, D)

    out, S = _wkv6_scan(heads(r), heads(k), heads(v),
                        heads(decay.astype(x.dtype)), p["u"], S0)
    out = out.reshape(B, T, d).astype(x.dtype)
    x = x + jnp.einsum("btd,de->bte", out, p["wo"])

    # ---- channel mix
    xn2 = rms_norm(x, p["ln2"], cfg.rms_eps)
    prev2 = _token_shift(xn2, last_cm)
    xc = xn2 + (prev2 - xn2) * p["mix_c"]
    h = jnp.einsum("btd,df->btf", xc, p["ck"])
    h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(x.dtype)
    x = x + jnp.einsum("btf,fd->btd", h, p["cv"])

    new_state = (xn[:, -1:], S, xn2[:, -1:])
    return x, new_state
