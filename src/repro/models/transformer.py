"""Generic decoder-only transformer with scanned layers.

Supports every assigned LM-family arch via ModelConfig switches:
  * GQA/MQA/MHA attention (llama3.2, granite, codeqwen, musicgen, llava)
  * MLA attention (deepseek-v3)
  * dense SwiGLU FFN or MoE FFN (llama4-maverick, deepseek-v3), with
    first_k_dense dense layers before the MoE stack
  * token or embedding inputs (audio/vlm backbone stubs)
  * optional MTP auxiliary head (deepseek-v3)

Layers are stacked (leading axis L) and executed with `lax.scan` so the
HLO stays one-layer-sized: compile time at 512 devices remains tractable
and the roofline analysis scales per-layer costs analytically (L=1 vs L=2
two-point fit, see roofline/analysis.py).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import gqa_forward, init_gqa, init_mla, mla_forward
from .common import ModelConfig, init_dense, rms_norm, swiglu
from .moe import init_moe, moe_forward


# ------------------------------------------------------------- one block
def init_block(key, cfg: ModelConfig, moe: bool) -> Dict:
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    p: Dict[str, Any] = {
        "ln1": jnp.ones((d,), jnp.float32),
        "ln2": jnp.ones((d,), jnp.float32),
        "attn": init_mla(ks[0], cfg) if cfg.mla else init_gqa(ks[0], cfg),
    }
    if moe:
        p["ffn"] = init_moe(ks[1], cfg)
    else:
        f = cfg.dense_d_ff if (cfg.moe and cfg.first_k_dense) else cfg.d_ff
        k1, k2, k3 = jax.random.split(ks[1], 3)
        p["ffn"] = {
            "wi_gate": init_dense(k1, (d, f), dtype=cfg.dtype),
            "wi_up": init_dense(k2, (d, f), dtype=cfg.dtype),
            "wo": init_dense(k3, (f, d), dtype=cfg.dtype),
        }
    return p


def block_forward(p: Dict, cfg: ModelConfig, x, positions, cache, lengths,
                  moe: bool):
    """Returns (x, new_cache, aux)."""
    h = rms_norm(x, p["ln1"], cfg.rms_eps)
    if cfg.mla:
        attn_out, new_cache = mla_forward(p["attn"], cfg, h, positions,
                                          cache, lengths)
    else:
        attn_out, new_cache = gqa_forward(p["attn"], cfg, h, positions,
                                          cache, lengths)
    x = x + attn_out
    h = rms_norm(x, p["ln2"], cfg.rms_eps)
    if moe:
        f, aux = moe_forward(p["ffn"], cfg, h)
    else:
        f = swiglu(h, p["ffn"]["wi_gate"], p["ffn"]["wi_up"],
                   p["ffn"]["wo"])
        aux = jnp.zeros((), jnp.float32)
    return x + f, new_cache, aux


# ------------------------------------------------------------- full model
def init_transformer(key, cfg: ModelConfig) -> Dict:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    n_moe = cfg.n_layers - cfg.first_k_dense if cfg.moe else 0
    n_dense = cfg.first_k_dense if cfg.moe else cfg.n_layers
    p: Dict[str, Any] = {
        "embed": init_dense(ks[0], (cfg.vocab, d), scale=0.02,
                            dtype=cfg.dtype),
        "ln_f": jnp.ones((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        p["head"] = init_dense(ks[1], (d, cfg.vocab), dtype=cfg.dtype)
    if n_dense:
        p["dense_layers"] = jax.vmap(
            lambda k: init_block(k, cfg, moe=False))(
                jax.random.split(ks[2], n_dense))
    if n_moe:
        p["moe_layers"] = jax.vmap(
            lambda k: init_block(k, cfg, moe=True))(
                jax.random.split(ks[3], n_moe))
    if cfg.mtp:
        kp, kb = jax.random.split(ks[4])
        p["mtp"] = {"proj": init_dense(kp, (2 * d, d), dtype=cfg.dtype),
                    "block": init_block(kb, cfg, moe=False)}
    return p


def _scan_layers(stacked: Dict, cfg: ModelConfig, x, positions, caches,
                 lengths, moe: bool, remat: bool, want_cache: bool):
    """Scan a stacked-layer group.  caches: stacked per-layer cache pytree
    (or None).  Returns (x, new_caches, aux_sum)."""

    def body(carry, layer):
        xx = carry
        params, cache = layer
        f = block_forward
        if remat:
            f = jax.checkpoint(block_forward, static_argnums=(1, 6),
                               policy=jax.checkpoint_policies.dots_saveable)
        xx, new_cache, aux = f(params, cfg, xx, positions, cache, lengths,
                               moe)
        if not want_cache:
            new_cache = None   # training: don't materialize stacked KV
        return xx, (new_cache, aux)

    x, (new_caches, auxs) = jax.lax.scan(body, x, (stacked, caches),
                                         unroll=True if cfg.scan_unroll
                                         else 1)
    return x, new_caches, jnp.sum(auxs)


def transformer_apply(params: Dict, cfg: ModelConfig, tokens_or_embeds,
                      positions, caches: Optional[Dict] = None,
                      lengths: Optional[jnp.ndarray] = None,
                      remat: bool = False, want_cache: bool = False):
    """Core forward.  caches=None: causal self-attention over the inputs
    (training: want_cache=False / prefill: want_cache=True); caches given:
    decode.  Returns (hidden, new_caches, aux)."""
    if cfg.input_mode == "tokens":
        x = params["embed"][tokens_or_embeds]
    else:
        x = tokens_or_embeds.astype(cfg.dtype)

    want_cache = want_cache or caches is not None
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, Any] = {}
    if "dense_layers" in params:
        c = caches.get("dense") if caches else None
        x, nc, aux = _scan_layers(params["dense_layers"], cfg, x, positions,
                                  c, lengths, moe=False, remat=remat,
                                  want_cache=want_cache)
        new_caches["dense"] = nc
        aux_total += aux
    if "moe_layers" in params:
        c = caches.get("moe") if caches else None
        x, nc, aux = _scan_layers(params["moe_layers"], cfg, x, positions,
                                  c, lengths, moe=True, remat=remat,
                                  want_cache=want_cache)
        new_caches["moe"] = nc
        aux_total += aux
    x = rms_norm(x, params["ln_f"], cfg.rms_eps)
    return x, new_caches, aux_total


def logits_from_hidden(params: Dict, cfg: ModelConfig, x):
    if cfg.tie_embeddings:
        return jnp.einsum("btd,vd->btv", x, params["embed"])
    return jnp.einsum("btd,dv->btv", x, params["head"])


def mtp_logits(params: Dict, cfg: ModelConfig, hidden, tokens):
    """DeepSeek MTP: predict token t+2 from [h_t ; emb(token_{t+1})]."""
    emb_next = params["embed"][tokens[:, 1:]]              # (B,T-1,d)
    h = jnp.concatenate([hidden[:, :-1], emb_next], axis=-1)
    h = jnp.einsum("btd,dk->btk", h.astype(cfg.dtype), params["mtp"]["proj"])
    B, Tm1, _ = h.shape
    pos = jnp.arange(Tm1)[None].repeat(B, 0)
    out, _cache, _aux = block_forward(params["mtp"]["block"], cfg,
                                      h, pos, None, None, moe=False)
    return logits_from_hidden(params, cfg, out)
