"""Unified model API over all assigned architecture families.

    model = build_model(cfg)
    params = model.init(rng)
    logits, aux = model.train_logits(params, tokens_or_embeds)
    logits, caches = model.prefill(params, inputs, lengths)
    logits, caches = model.decode(params, caches, inputs, positions, lengths)
    caches = model.init_cache(batch, max_len)

Families:
  dense/moe/audio/vlm -> transformer.py (GQA or MLA, dense or MoE FFN)
  ssm                 -> RWKV6 stack (rwkv6.py)
  hybrid              -> Zamba2: scanned Mamba2 layers with a *shared*
                         attention block applied every cfg.attn_every
                         layers (per-slot KV cache).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, init_dense, rms_norm
from .mamba2 import init_mamba2_block, mamba2_block, mamba_dims
from .rwkv6 import init_rwkv6_block, rwkv6_block
from .transformer import (block_forward, init_block, init_transformer,
                          logits_from_hidden, mtp_logits, transformer_apply)


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    train_logits: Callable          # (params, inputs) -> (logits, aux)
    prefill: Callable               # (params, inputs, lengths) -> (logits, caches)
    decode: Callable                # (params, caches, inputs, positions, lengths)
    init_cache: Callable            # (batch, max_len) -> caches
    mtp_logits: Optional[Callable] = None


# ---------------------------------------------------------- transformer
def _build_transformer(cfg: ModelConfig) -> Model:
    def init(rng):
        return init_transformer(rng, cfg)

    def train_logits(params, inputs, remat: bool = True):
        B = inputs.shape[0]
        T = inputs.shape[1]
        pos = jnp.arange(T)[None].repeat(B, 0)
        h, _caches, aux = transformer_apply(params, cfg, inputs, pos,
                                            remat=remat)
        return logits_from_hidden(params, cfg, h), aux

    def prefill(params, inputs, lengths):
        B, T = inputs.shape[0], inputs.shape[1]
        pos = jnp.arange(T)[None].repeat(B, 0)
        h, caches, _aux = transformer_apply(params, cfg, inputs, pos,
                                            want_cache=True)
        return logits_from_hidden(params, cfg, h[:, -1:]), caches

    def decode(params, caches, inputs, positions, lengths):
        h, caches, _aux = transformer_apply(params, cfg, inputs, positions,
                                            caches=caches, lengths=lengths)
        return logits_from_hidden(params, cfg, h), caches

    def init_cache(batch: int, max_len: int):
        caches: Dict[str, Any] = {}
        n_moe = cfg.n_layers - cfg.first_k_dense if cfg.moe else 0
        n_dense = cfg.first_k_dense if cfg.moe else cfg.n_layers
        def one(n):
            if cfg.mla:
                return jnp.zeros(
                    (n, batch, max_len, cfg.kv_lora_rank + cfg.qk_rope_dim),
                    cfg.dtype)
            return (jnp.zeros((n, batch, cfg.n_kv_heads, max_len, cfg.hd),
                              cfg.dtype),
                    jnp.zeros((n, batch, cfg.n_kv_heads, max_len, cfg.hd),
                              cfg.dtype))
        if n_dense:
            caches["dense"] = one(n_dense)
        if n_moe:
            caches["moe"] = one(n_moe)
        return caches

    mtp = None
    if cfg.mtp:
        def mtp(params, hidden, tokens):  # noqa: F811
            return mtp_logits(params, cfg, hidden, tokens)

    return Model(cfg, init, train_logits, prefill, decode, init_cache,
                 mtp_logits=mtp)


# ----------------------------------------------------------------- rwkv6
def _build_rwkv(cfg: ModelConfig) -> Model:
    H = cfg.n_heads
    D = cfg.d_model // H

    def init(rng):
        ks = jax.random.split(rng, 3)
        return {
            "embed": init_dense(ks[0], (cfg.vocab, cfg.d_model), scale=0.02,
                                dtype=cfg.dtype),
            "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
            "head": init_dense(ks[1], (cfg.d_model, cfg.vocab),
                               dtype=cfg.dtype),
            "layers": jax.vmap(lambda k: init_rwkv6_block(k, cfg))(
                jax.random.split(ks[2], cfg.n_layers)),
        }

    def _apply(params, x, states):
        def body(xx, layer):
            p, st = layer
            xx, new_st = rwkv6_block(p, cfg, xx, st)
            return xx, new_st
        x, new_states = jax.lax.scan(body, x, (params["layers"], states),
                             unroll=True if cfg.scan_unroll else 1)
        x = rms_norm(x, params["ln_f"], cfg.rms_eps)
        return x, new_states

    def _zero_state(batch: int):
        L = cfg.n_layers
        return (jnp.zeros((L, batch, 1, cfg.d_model), cfg.dtype),
                jnp.zeros((L, batch, H, D, D), jnp.float32),
                jnp.zeros((L, batch, 1, cfg.d_model), cfg.dtype))

    def train_logits(params, inputs, remat: bool = True):
        x = params["embed"][inputs]
        h, _ = _apply(params, x, _zero_state(inputs.shape[0]))
        return jnp.einsum("btd,dv->btv", h, params["head"]), \
            jnp.zeros((), jnp.float32)

    def prefill(params, inputs, lengths):
        x = params["embed"][inputs]
        h, states = _apply(params, x, _zero_state(inputs.shape[0]))
        return jnp.einsum("btd,dv->btv", h[:, -1:], params["head"]), states

    def decode(params, states, inputs, positions, lengths):
        x = params["embed"][inputs]
        h, states = _apply(params, x, states)
        return jnp.einsum("btd,dv->btv", h, params["head"]), states

    def init_cache(batch: int, max_len: int):
        return _zero_state(batch)   # O(1) state: max_len-independent

    return Model(cfg, init, train_logits, prefill, decode, init_cache)


# ---------------------------------------------------------------- zamba2
def _build_zamba(cfg: ModelConfig) -> Model:
    every = cfg.attn_every
    n_apps = cfg.n_layers // every

    def init(rng):
        ks = jax.random.split(rng, 4)
        return {
            "embed": init_dense(ks[0], (cfg.vocab, cfg.d_model), scale=0.02,
                                dtype=cfg.dtype),
            "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
            "head": init_dense(ks[1], (cfg.d_model, cfg.vocab),
                               dtype=cfg.dtype),
            "layers": jax.vmap(lambda k: init_mamba2_block(k, cfg))(
                jax.random.split(ks[2], cfg.n_layers)),
            # the Zamba2 signature: ONE shared transformer block
            "shared": init_block(ks[3], cfg, moe=False),
        }

    def _apply(params, x, m_states, a_caches, positions, lengths,
               mode: str):
        """m_states: stacked mamba states; a_caches: stacked (n_apps) KV for
        the shared block's applications.  mode: train | prefill | decode."""
        idxs = jnp.arange(cfg.n_layers)

        def body(carry, layer):
            xx, acaches = carry
            p, mst, i = layer
            xx, new_mst = mamba2_block(p, cfg, xx, mst)

            def with_attn(args):
                xx, acaches = args
                slot = i // every
                if mode == "train":
                    out, _c, _a = block_forward(params["shared"], cfg, xx,
                                                positions, None, None,
                                                moe=False)
                    return out, acaches
                if mode == "prefill":
                    # causal self-attention; capture the slot's KV cache
                    out, new_c, _a = block_forward(params["shared"], cfg,
                                                   xx, positions, None,
                                                   None, moe=False)
                else:  # decode: attend into the slot's cache
                    cache = jax.tree.map(lambda c: c[slot], acaches)
                    out, new_c, _a = block_forward(params["shared"], cfg,
                                                   xx, positions, cache,
                                                   lengths, moe=False)
                acaches = jax.tree.map(
                    lambda c, n: jax.lax.dynamic_update_index_in_dim(
                        c, n, slot, 0), acaches, new_c)
                return out, acaches

            apply_attn = (i + 1) % every == 0
            xx, acaches = jax.lax.cond(apply_attn, with_attn,
                                       lambda a: a, (xx, acaches))
            return (xx, acaches), new_mst

        (x, a_caches), new_m = jax.lax.scan(
            body, (x, a_caches), (params["layers"], m_states, idxs),
            unroll=True if cfg.scan_unroll else 1)
        x = rms_norm(x, params["ln_f"], cfg.rms_eps)
        return x, new_m, a_caches

    def _zero_mstate(batch: int):
        d_inner, nh, hp, ds = mamba_dims(cfg)
        K = cfg.conv_kernel
        conv_dim = d_inner + 2 * ds
        L = cfg.n_layers
        return (jnp.zeros((L, batch, K - 1, conv_dim), cfg.dtype),
                jnp.zeros((L, batch, nh, hp, ds), jnp.float32))

    def _zero_acache(batch: int, max_len: int):
        return (jnp.zeros((n_apps, batch, cfg.n_kv_heads, max_len, cfg.hd),
                          cfg.dtype),
                jnp.zeros((n_apps, batch, cfg.n_kv_heads, max_len, cfg.hd),
                          cfg.dtype))

    def train_logits(params, inputs, remat: bool = True):
        B, T = inputs.shape
        x = params["embed"][inputs]
        pos = jnp.arange(T)[None].repeat(B, 0)
        h, _m, _a = _apply(params, x, _zero_mstate(B), None, pos, None,
                           "train")
        return jnp.einsum("btd,dv->btv", h, params["head"]), \
            jnp.zeros((), jnp.float32)

    def prefill(params, inputs, lengths):
        B, T = inputs.shape
        x = params["embed"][inputs]
        pos = jnp.arange(T)[None].repeat(B, 0)
        acache = _zero_acache(B, T)
        h, m, a = _apply(params, x, _zero_mstate(B), acache, pos, lengths,
                         "prefill")
        return jnp.einsum("btd,dv->btv", h[:, -1:], params["head"]), (m, a)

    def decode(params, caches, inputs, positions, lengths):
        m_states, a_caches = caches
        x = params["embed"][inputs]
        h, m, a = _apply(params, x, m_states, a_caches, positions, lengths,
                         "decode")
        return jnp.einsum("btd,dv->btv", h, params["head"]), (m, a)

    def init_cache(batch: int, max_len: int):
        return (_zero_mstate(batch), _zero_acache(batch, max_len))

    return Model(cfg, init, train_logits, prefill, decode, init_cache)


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "ssm":
        return _build_rwkv(cfg)
    if cfg.family == "hybrid":
        return _build_zamba(cfg)
    return _build_transformer(cfg)
