"""Roofline analysis from compiled dry-run artifacts (no hardware).

Per (arch x shape) cell on the single-pod mesh, derive the three terms:

    compute    = HLO_FLOPs / (chips * 197e12 bf16 FLOP/s)
    memory     = HLO_bytes / (chips * 819e9 B/s HBM)
    collective = collective_bytes / (chips * 50e9 B/s ICI)

cost_analysis counts a lax.scan body once, so totals are reconstructed
with a two-point *unrolled* fit: compile the model at n_layers=1 and
n_layers=2 with the layer scan unrolled — the difference is exactly one
layer's cost under the production shardings; total = base + L * layer.
(Approximations: zamba2's shared-attention cadence and deepseek's 3 dense
layers are folded into the layer term — noted in EXPERIMENTS.md.)

MODEL_FLOPS = 6 * N_active * tokens (train) / 2 * N_active * tokens
(inference); the ratio MODEL_FLOPS / HLO_FLOPs exposes remat/redundancy.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional

import jax

from ..configs.registry import SHAPES, get_config, runnable
from .hlo import collective_bytes, cost_terms

# TPU v5e per chip
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = 256


def _cell_costs(arch: str, shape: str, mesh, n_layers: int,
                unroll: bool) -> Dict[str, float]:
    """Lower+compile at a reduced layer count; return per-device terms."""
    from ..launch.dryrun import lower_cell  # late import (XLA_FLAGS order)
    import repro.launch.dryrun as dr

    cfg = get_config(arch)
    extra: Dict[str, Any] = {"scan_unroll": unroll}
    if cfg.moe and cfg.first_k_dense:
        # the layer term must measure the *MoE* layer (58/61 of deepseek):
        # force an all-MoE stack for both fit points
        extra["overrides"] = {"first_k_dense": 0}
    lowered, _meta = lower_cell(arch, shape, mesh, num_layers=n_layers,
                                extra=extra)
    compiled = lowered.compile()
    c = cost_terms(compiled)
    coll = collective_bytes(compiled.as_text())
    return {"flops": c["flops"], "bytes": c["bytes_accessed"],
            "coll": float(coll["total"])}


def analyze_cell(arch: str, shape: str, mesh,
                 full_record: Optional[Dict] = None) -> Dict[str, Any]:
    """Roofline terms for one cell (single-pod).  full_record: the
    40-cell dry-run JSON record (for memory_analysis / sanity)."""
    cfg = get_config(arch)
    cell = SHAPES[shape]
    ok, why = runnable(cfg, cell)
    if not ok:
        return {"arch": arch, "shape": shape, "status": "skip",
                "reason": why}

    one = _cell_costs(arch, shape, mesh, 1, True)
    two = _cell_costs(arch, shape, mesh, 2, True)
    layer = {k: max(0.0, two[k] - one[k]) for k in one}
    base = {k: max(0.0, one[k] - layer[k]) for k in one}
    L = cfg.n_layers
    total = {k: base[k] + L * layer[k] for k in one}

    # roofline terms (seconds, per device — cost_analysis is per-module,
    # i.e. per-device in SPMD)
    t_compute = total["flops"] / PEAK_FLOPS
    t_memory = total["bytes"] / HBM_BW
    t_coll = total["coll"] / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)

    # useful-model flops
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode"
                                  else 1)
    mult = 6 if cell.kind == "train" else 2
    model_flops = mult * cfg.params_active * tokens / CHIPS  # per device
    hlo_flops = total["flops"]
    ratio = model_flops / hlo_flops if hlo_flops else float("nan")
    bound = max(terms.values())
    # fraction of roofline: useful work / (dominant-term time * peak)
    roofline_frac = (model_flops / PEAK_FLOPS) / bound if bound else 0.0

    return {
        "arch": arch, "shape": shape, "status": "ok",
        "kind": cell.kind, "n_layers": L,
        "per_device": total,
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops_per_device": model_flops,
        "model_vs_hlo_flops": round(ratio, 4),
        "roofline_fraction": round(roofline_frac, 4),
        "memory_analysis": (full_record or {}).get("bytes_per_device"),
    }


SUGGESTIONS = {
    "compute": "raise MXU occupancy: larger per-device batch/microbatch, "
               "fuse small ops, drop remat on cheap layers",
    "memory": "cut HBM traffic: bf16 cache/activations, fuse elementwise "
              "chains, output-stationary blocking (gemm_os), "
              "better remat policy",
    "collective": "reshard: move collectives off the critical path, "
                  "overlap via async collectives, reduce TP degree or "
                  "switch reduce-scatter/all-gather placement",
}
