import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Perf hillclimbing driver: evaluate named variants of a cell and print
before/after roofline-relevant metrics from the full compiled graph.

    python -m repro.roofline.hillclimb --arch llama3.2-1b --shape train_4k \
        --variants baseline mb8 logits mb8+logits
"""
import argparse
import json
import sys
import time

from ..launch.dryrun import lower_cell
from ..launch.mesh import make_production_mesh
from .hlo import collective_bytes, cost_terms

VARIANTS = {
    "baseline": {},
    # gradient-accumulation microbatching: peak activation / microbatches
    "mb4": {"microbatches": 4},
    "mb8": {"microbatches": 8},
    "mb16": {"microbatches": 16},
    # pin fp32 logits/CE to a vocab-sharded layout
    "logits": {"shard_logits": True},
    "mb8+logits": {"microbatches": 8, "shard_logits": True},
    "mb16+logits": {"microbatches": 16, "shard_logits": True},
    # MoE capacity factor (smaller buffers, more drops)
    "cap1.0": {"overrides": {}},
}


def eval_variant(arch, shape, mesh, extra):
    t0 = time.time()
    lowered, _ = lower_cell(arch, shape, mesh, extra=extra or None)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = cost_terms(compiled)
    coll = collective_bytes(compiled.as_text())
    return {
        "peak_gb": round(((getattr(mem, "argument_size_in_bytes", 0) or 0)
                          + (getattr(mem, "temp_size_in_bytes", 0) or 0))
                         / 1e9, 2),
        "temp_gb": round((getattr(mem, "temp_size_in_bytes", 0) or 0) / 1e9,
                         2),
        "gflops": round(cost["flops"] / 1e9, 1),
        "gbytes": round(cost["bytes_accessed"] / 1e9, 2),
        "coll_gb": round(coll["total"] / 1e9, 3),
        "compile_s": round(time.time() - t0, 1),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", nargs="+", default=["baseline"])
    args = ap.parse_args(argv)
    mesh = make_production_mesh(multi_pod=False)
    for name in args.variants:
        extra = VARIANTS[name]
        try:
            m = eval_variant(args.arch, args.shape, mesh, extra)
            print(json.dumps({"variant": name, **m}), flush=True)
        except Exception as e:  # noqa: BLE001
            print(json.dumps({"variant": name,
                              "error": f"{type(e).__name__}: {e}"}),
                  flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
