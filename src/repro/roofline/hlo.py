"""HLO introspection: cost_analysis terms + collective-byte accounting.

collective_bytes parses the compiled HLO text and sums operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op (cost_analysis does not report collectives).

NOTE scan bodies appear once in the HLO; the roofline two-point layer fit
(analysis.py) handles trip-count scaling.
"""
from __future__ import annotations

import re
from typing import Any, Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*"
    r"((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute"
    r"|all-gather-start|all-reduce-start|collective-permute-start)\b",
    re.MULTILINE)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Output-shape bytes summed per collective kind (per device)."""
    out: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        kind = kind.replace("-start", "")
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_str)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def cost_terms(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0] if ca else {}
    get = ca.get if hasattr(ca, "get") else lambda k, d=0: d
    return {
        "flops": float(get("flops", 0.0) or 0.0),
        "bytes_accessed": float(get("bytes accessed", 0.0) or 0.0),
        "transcendentals": float(get("transcendentals", 0.0) or 0.0),
    }
