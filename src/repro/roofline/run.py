import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ must precede any jax import (production mesh needs 512 host devices)

"""Roofline table driver: per (arch x shape) cell on the single-pod mesh,
compute the three roofline terms via the unrolled L=1/L=2 two-point fit
(see analysis.py) and merge with the dry-run memory records.

    python -m repro.roofline.run [--cells arch:shape ...] [--out roofline.json]
"""
import argparse
import json
import sys
import time
import traceback

from ..configs.registry import ARCH_IDS, SHAPES
from ..launch.mesh import make_production_mesh
from .analysis import SUGGESTIONS, analyze_cell


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", nargs="*", default=None,
                    help="arch:shape pairs; default = all 40")
    ap.add_argument("--dryrun-json", default="dryrun_singlepod.json")
    ap.add_argument("--out", default="roofline.json")
    args = ap.parse_args(argv)

    full = {}
    try:
        with open(args.dryrun_json) as f:
            for rec in json.load(f):
                full[(rec["arch"], rec["shape"])] = rec
    except FileNotFoundError:
        pass

    if args.cells:
        cells = [tuple(c.split(":")) for c in args.cells]
    else:
        cells = [(a, s) for a in ARCH_IDS for s in SHAPES]

    mesh = make_production_mesh(multi_pod=False)
    out = []
    for arch, shape in cells:
        t0 = time.time()
        try:
            rec = analyze_cell(arch, shape, mesh,
                               full.get((arch, shape)))
        except Exception as e:  # noqa: BLE001
            rec = {"arch": arch, "shape": shape, "status": "FAIL",
                   "error": f"{type(e).__name__}: {e}"}
            traceback.print_exc()
        rec["elapsed_s"] = round(time.time() - t0, 1)
        if rec.get("status") == "ok":
            rec["suggestion"] = SUGGESTIONS[rec["dominant"]]
        out.append(rec)
        print(json.dumps({k: v for k, v in rec.items()
                          if k != "suggestion"}), flush=True)

    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)

    # markdown summary
    ok = [r for r in out if r.get("status") == "ok"]
    print("\n| arch | shape | compute s | memory s | collective s | "
          "dominant | MODEL/HLO flops | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    for r in sorted(ok, key=lambda r: r["roofline_fraction"]):
        print(f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4g} | "
              f"{r['memory_s']:.4g} | {r['collective_s']:.4g} | "
              f"{r['dominant']} | {r['model_vs_hlo_flops']:.3f} | "
              f"{r['roofline_fraction']:.3f} |")
    return 0


if __name__ == "__main__":
    sys.exit(main())
