"""CGRA-backed model serving: engine + offload plans + traffic harness.

``plan``/``traffic`` import lazily — ``engine`` alone must stay importable
without pulling the whole toolchain."""
from .engine import Engine, Request

__all__ = ["Engine", "Request", "ServePlan", "build_serve_plan",
           "CGRAExecutionModel", "TrafficConfig", "FixedLatencyModel",
           "run_traffic"]


def __getattr__(name):
    if name in ("ServePlan", "PlanSite", "build_serve_plan",
                "CGRAExecutionModel"):
        from . import plan
        return getattr(plan, name)
    if name in ("TrafficConfig", "FixedLatencyModel", "run_traffic",
                "generate_requests", "report_json", "report_bench_rows"):
        from . import traffic
        return getattr(traffic, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
