"""Batched serving engine: continuous-batching style decode loop.

Slots hold independent requests; each engine step decodes one token for
every active slot (the decode_32k dry-run shape is exactly one engine
step at full batch).  Prefill admits new requests into free slots.

The engine optionally carries an *execution model* (e.g.
``repro.serve.plan.CGRAExecutionModel``): the real JAX forward pass still
produces the tokens, while the execution model advances ``clock_s`` — the
modeled wall clock of running every prefill/decode step on the plan's
CGRA fabric.  The traffic harness (``repro.serve.traffic``) schedules
Poisson arrivals against that clock.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.zoo import Model


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False
    truncated: bool = False


class Engine:
    def __init__(self, model: Model, params: Any, batch: int, max_len: int,
                 exec_model: Optional[Any] = None):
        self.model = model
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.exec_model = exec_model
        self.clock_s = 0.0           # modeled time (advances only if exec_model)
        self.caches = model.init_cache(batch, max_len)
        self.lengths = np.zeros((batch,), np.int32)
        self.last_tok = np.zeros((batch,), np.int32)
        self.slots: List[Optional[Request]] = [None] * batch
        self._decode = jax.jit(model.decode)

    # -------------------------------------------------------------- slots
    @property
    def n_active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    def has_free_slot(self) -> bool:
        return any(s is None for s in self.slots)

    def advance_clock(self, t: float) -> None:
        """Idle until modeled time ``t`` (never runs the clock backward)."""
        self.clock_s = max(self.clock_s, t)

    # ---------------------------------------------------------- admission
    def admit(self, req: Request, truncate: bool = False) -> bool:
        """Prefill ``req`` into a free slot.  Returns False when every
        slot is busy (the caller queues and retries — slots are recycled
        as requests finish).

        Prompts longer than the KV budget no longer overflow silently:
        a prompt needing ``>= max_len`` positions (one must stay free for
        decode) is truncated to its last ``max_len - 1`` tokens when
        ``truncate=True``, and rejected with ValueError otherwise."""
        limit = self.max_len - 1
        if len(req.prompt) > limit:
            if not truncate:
                raise ValueError(
                    f"request {req.rid}: prompt of {len(req.prompt)} tokens "
                    f"cannot fit max_len={self.max_len} (needs <= {limit} "
                    f"to leave a decode position); pass truncate=True to "
                    f"keep the last {limit} tokens")
            req.prompt = np.asarray(req.prompt[-limit:])
            req.truncated = True
        for i, s in enumerate(self.slots):
            if s is None:
                self.slots[i] = req
                # prefill this slot (batch-1 prefill; production would batch)
                toks = jnp.asarray(req.prompt[None, :])
                logits, caches = self.model.prefill(
                    self.params, toks, jnp.asarray([len(req.prompt)]))
                self._merge_cache(i, caches, len(req.prompt))
                self.lengths[i] = len(req.prompt)
                self.last_tok[i] = int(jnp.argmax(logits[0, -1]))
                if self.exec_model is not None:
                    self.clock_s += self.exec_model.prefill_s(
                        len(req.prompt))
                return True
        return False

    def _merge_cache(self, slot: int, caches: Any, plen: int) -> None:
        def merge(full, new):
            if full.ndim == new.ndim and new.shape[1] == 1:
                # seq axis position varies per cache family; write via lax
                pad = [(0, 0)] * new.ndim
                idx = [slice(None)] * new.ndim
                idx[1] = slice(slot, slot + 1)
                seq_axis = None
                for ax in range(2, new.ndim):
                    if new.shape[ax] not in (full.shape[ax],):
                        seq_axis = ax
                        break
                if seq_axis is not None:
                    idx[seq_axis] = slice(0, new.shape[seq_axis])
                return full.at[tuple(idx)].set(new)
            return full
        self.caches = jax.tree.map(merge, self.caches, caches)

    # --------------------------------------------------------------- step
    def step(self) -> Dict[int, int]:
        """One decode step for all active slots; returns {rid: token}.
        Finished requests free their slot (state zeroed) so admission
        under slot pressure recycles capacity."""
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return {}
        toks = jnp.asarray(self.last_tok[:, None])
        pos = jnp.asarray(self.lengths[:, None])
        lens = jnp.asarray(self.lengths + 1)
        logits, self.caches = self._decode(self.params, self.caches, toks,
                                           pos, lens)
        if self.exec_model is not None:
            self.clock_s += self.exec_model.decode_step_s(len(active))
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        out: Dict[int, int] = {}
        for i in active:
            req = self.slots[i]
            tok = int(nxt[i])
            req.out.append(tok)
            out[req.rid] = tok
            self.lengths[i] += 1
            self.last_tok[i] = tok
            if len(req.out) >= req.max_new or self.lengths[i] >= self.max_len:
                req.done = True
                self.slots[i] = None
                self.lengths[i] = 0
                self.last_tok[i] = 0
        return out
