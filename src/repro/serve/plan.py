"""Serve plans: the complete model -> CGRA offload artifact.

``build_serve_plan`` closes the loop between the model zoo and the
toolchain (ROADMAP open item 1, the whole-network-on-CGRA direction of
CGRA4ML): it enumerates every GEMM micro-kernel site of a model
(attention projections, MLA low-rank factors, MoE expert FFNs, RWKV and
Mamba projections — ``offload.model_gemm_sites``), chooses a
bank-capacity-feasible tile per site (``offload.choose_gemm_tile``),
compiles every distinct tile through ``Toolchain.compile_many`` (the
content-addressed cache makes this warm across sites, models and
sessions), and bundles the result as a :class:`ServePlan`:

    site -> {compiled-kernel ref, tile, tile counts, modeled latency}

The plan is a serializable artifact like :class:`CompiledKernel` —
``to_json``/``from_json`` round-trip losslessly, with the compiled tiles
embedded (default) or carried as content-address refs re-resolved through
``Toolchain.load_artifact``.  ``spot_check`` pushes at least one site's
compiled tile through the real cycle-accurate simulator against the
bit-exact verification oracle (paper IV-C), so a plan's modeled numbers
are anchored to simulated hardware, not just the cost model.

:class:`CGRAExecutionModel` turns a plan into the per-step latency
provider the serving engine consumes: a decode step for B active slots is
the plan's site sum at M = B; a prefill of P prompt tokens is the site
sum at M = P.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.adl import CGRAArch, cluster_4x4
from ..core.costmodel import F_CLK_HZ
from ..core.kernels_lib import build_gemm
from ..core.offload import (GemmSite, choose_gemm_tile, model_gemm_sites,
                            tile_unroll)
from ..core.toolchain import CompiledKernel, Toolchain, default_toolchain
from ..models.common import ModelConfig

PLAN_VERSION = 1


@dataclass(frozen=True)
class PlanSite:
    """One GEMM site of the plan, bound to a compiled tile.

    ``kernel_ref`` is the tile's content address (``CompiledKernel
    .cache_key``); ``tile_cycles`` is the cycle-accurate cost of ONE full
    tile (every host invocation of the mapped loop: fill + steady state +
    drain per invocation).  Latency for an arbitrary token count M scales
    the tile by the site's tile counts — ``ceil(M/TI) * ceil(K/TK) *
    ceil(N/TJ)`` per GEMM instance, ``count_per_layer * layers``
    instances."""
    name: str
    M: int
    K: int
    N: int
    count_per_layer: int
    layers: int
    tile: Tuple[int, int, int]
    kernel_ref: str
    II: int
    mii: int
    tile_cycles: int
    utilization: float

    def tiles(self, M: Optional[int] = None) -> int:
        TI, TK, TJ = self.tile
        m = self.M if M is None else M
        return (math.ceil(m / TI) * math.ceil(self.K / TK)
                * math.ceil(self.N / TJ))

    def instances(self) -> int:
        return self.count_per_layer * self.layers

    def latency_s(self, M: Optional[int] = None) -> float:
        """Modeled full-site latency at M tokens (whole model: every
        instance in every layer the site appears in)."""
        return (self.tiles(M) * self.instances() * self.tile_cycles
                / F_CLK_HZ)

    def to_json_dict(self) -> dict:
        return {"name": self.name, "M": self.M, "K": self.K, "N": self.N,
                "count_per_layer": self.count_per_layer,
                "layers": self.layers, "tile": list(self.tile),
                "kernel_ref": self.kernel_ref, "II": self.II,
                "mii": self.mii, "tile_cycles": self.tile_cycles,
                "utilization": self.utilization}

    @staticmethod
    def from_json_dict(d: dict) -> "PlanSite":
        return PlanSite(
            name=d["name"], M=d["M"], K=d["K"], N=d["N"],
            count_per_layer=d["count_per_layer"], layers=d["layers"],
            tile=tuple(d["tile"]), kernel_ref=d["kernel_ref"],
            II=d["II"], mii=d["mii"], tile_cycles=d["tile_cycles"],
            utilization=d["utilization"])


@dataclass
class ServePlan:
    """The model's complete CGRA offload plan: every GEMM site bound to a
    compiled tile, with the compiled artifacts bundled (deduplicated by
    content address — most sites share a tile)."""
    model: str
    arch_name: str
    tokens: int
    sites: List[PlanSite]
    kernels: Dict[str, CompiledKernel] = field(default_factory=dict)

    # ------------------------------------------------------------- model
    def site(self, name: str) -> PlanSite:
        for s in self.sites:
            if s.name == name:
                return s
        raise KeyError(f"plan for {self.model}: no site {name!r}")

    def kernel_for(self, site: PlanSite) -> CompiledKernel:
        try:
            return self.kernels[site.kernel_ref]
        except KeyError:
            raise KeyError(
                f"plan for {self.model}: kernel {site.kernel_ref[:12]}… "
                f"for site {site.name} not bundled (ref-only plan; reload "
                f"with a toolchain whose cache holds it)") from None

    def step_latency_s(self, tokens: int) -> float:
        """Modeled whole-model latency of one forward step at ``tokens``
        tokens per sequence position batch (decode: tokens = active
        slots; prefill: tokens = prompt length)."""
        return sum(s.latency_s(M=tokens) for s in self.sites)

    def decode_step_s(self, active: int) -> float:
        return self.step_latency_s(max(1, active))

    def prefill_s(self, prompt_len: int) -> float:
        return self.step_latency_s(max(1, prompt_len))

    def summary(self) -> str:
        lines = [f"serve plan: {self.model} on {self.arch_name} "
                 f"({len(self.sites)} sites, "
                 f"{len(self.kernels)} compiled tiles, "
                 f"plan tokens {self.tokens})",
                 f"{'site':<16} {'MxKxN':>18} {'xinst':>6} "
                 f"{'tile':>10} {'II':>3} {'tiles':>7} {'site_ms':>9}"]
        for s in self.sites:
            dims = f"{s.M}x{s.K}x{s.N}"
            tile = "x".join(str(t) for t in s.tile)
            lines.append(
                f"{s.name:<16} {dims:>18} {s.instances():>6} {tile:>10} "
                f"{s.II:>3} {s.tiles():>7} {s.latency_s() * 1e3:9.3f}")
        lines.append(f"{'decode step (B=8)':<16}  "
                     f"{self.decode_step_s(8) * 1e3:.3f} ms modeled")
        return "\n".join(lines)

    # ------------------------------------------------------ verification
    def spot_check(self, seeds: Sequence[int] = (0,),
                   n_sites: int = 1) -> List[str]:
        """Verify >= ``n_sites`` of the plan's compiled tiles bit-exactly
        against the cycle-accurate simulator (paper IV-C oracle), one
        site per distinct kernel first.  Returns the verified site names;
        raises AssertionError on any mismatch."""
        checked: List[str] = []
        seen: set = set()
        for s in self.sites:
            if s.kernel_ref in seen:
                continue
            self.kernel_for(s).verify_batch(seeds)
            seen.add(s.kernel_ref)
            checked.append(s.name)
            if len(checked) >= n_sites:
                break
        if not checked:
            raise AssertionError(
                f"plan for {self.model}: no site available to spot-check")
        return checked

    # ----------------------------------------------------- serialization
    def to_json(self, embed_kernels: bool = True) -> str:
        """Lossless JSON artifact (byte-deterministic: sorted keys).  With
        ``embed_kernels=False`` only content-address refs are written —
        smaller, but loading needs a toolchain cache holding the tiles."""
        d = {
            "version": PLAN_VERSION,
            "model": self.model,
            "arch_name": self.arch_name,
            "tokens": self.tokens,
            "sites": [s.to_json_dict() for s in self.sites],
            "kernels": ({k: json.loads(ck.to_json())
                         for k, ck in sorted(self.kernels.items())}
                        if embed_kernels else {}),
        }
        return json.dumps(d, sort_keys=True, separators=(",", ":"))

    @staticmethod
    def from_json(s: str,
                  toolchain: Optional[Toolchain] = None) -> "ServePlan":
        d = json.loads(s)
        if d.get("version") != PLAN_VERSION:
            raise ValueError(
                f"serve plan version {d.get('version')} != {PLAN_VERSION}")
        kernels = {k: CompiledKernel.from_json(json.dumps(v))
                   for k, v in d["kernels"].items()}
        sites = [PlanSite.from_json_dict(sd) for sd in d["sites"]]
        if toolchain is not None:
            for st in sites:                 # resolve ref-only plans
                if st.kernel_ref not in kernels:
                    ck = toolchain.load_artifact(st.kernel_ref)
                    if ck is not None:
                        kernels[st.kernel_ref] = ck
        return ServePlan(model=d["model"], arch_name=d["arch_name"],
                         tokens=d["tokens"], sites=sites, kernels=kernels)


# --------------------------------------------------------------------------
def build_serve_plan(model_cfg: ModelConfig,
                     arch: Optional[CGRAArch] = None,
                     toolchain: Optional[Toolchain] = None,
                     tokens: int = 64,
                     sites: Optional[List[GemmSite]] = None,
                     spot_check: bool = True,
                     spot_check_seeds: Sequence[int] = (0,)) -> ServePlan:
    """Model config -> :class:`ServePlan`.

    Enumerates the model's GEMM sites, chooses a feasible tile per site,
    compiles the distinct tiles in one ``compile_many`` fan-out, and
    (by default) spot-checks one compiled tile through the cycle-accurate
    verification oracle before returning."""
    tc = toolchain or default_toolchain()
    arch = arch or tc.arch or cluster_4x4()
    sites = model_gemm_sites(model_cfg, tokens) if sites is None else sites

    chosen = [choose_gemm_tile(arch, s) for s in sites]
    tiles = sorted(set(chosen))
    specs = [build_gemm(TI=TI, TK=TK, TJ=TJ, arch=arch,
                        unroll=tile_unroll(TK), coalesced=False)
             for TI, TK, TJ in tiles]
    compiled = dict(zip(tiles, tc.compile_many(specs)))

    plan_sites: List[PlanSite] = []
    kernels: Dict[str, CompiledKernel] = {}
    for s, tile in zip(sites, chosen):
        ck = compiled[tile]
        kernels[ck.cache_key] = ck
        plan_sites.append(PlanSite(
            name=s.name, M=s.M, K=s.K, N=s.N,
            count_per_layer=s.count_per_layer,
            layers=s.n_layers(model_cfg), tile=tile,
            kernel_ref=ck.cache_key, II=ck.II, mii=ck.mii,
            tile_cycles=len(ck.invocations) * ck.schedule_cycles(),
            utilization=round(ck.utilization, 6)))

    plan = ServePlan(model=model_cfg.name, arch_name=arch.name,
                     tokens=tokens, sites=plan_sites, kernels=kernels)
    if spot_check:
        plan.spot_check(seeds=spot_check_seeds)
    return plan


# --------------------------------------------------------------------------
class CGRAExecutionModel:
    """Plan-derived per-step latency provider for the serving engine.

    The engine's real JAX forward pass produces the tokens; this model
    produces the modeled wall clock those steps would take on the plan's
    CGRA fabric — decode at M = active slots, prefill at M = prompt
    length.  ``overhead_s`` adds a fixed per-step host handshake."""

    def __init__(self, plan: ServePlan, overhead_s: float = 0.0):
        self.plan = plan
        self.overhead_s = overhead_s
        # decode steps hit a handful of distinct M values; memoize them
        self._memo: Dict[int, float] = {}

    def _step_s(self, tokens: int) -> float:
        t = max(1, tokens)
        hit = self._memo.get(t)
        if hit is None:
            hit = self._memo[t] = self.plan.step_latency_s(t)
        return hit + self.overhead_s

    def decode_step_s(self, active: int) -> float:
        return self._step_s(active)

    def prefill_s(self, prompt_len: int) -> float:
        return self._step_s(prompt_len)
