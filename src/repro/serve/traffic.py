"""Synthetic serving traffic: seeded Poisson workload over the engine.

The harness drives :class:`repro.serve.engine.Engine` with a reproducible
open-loop workload — exponential (Poisson-process) interarrival times,
mixed prompt lengths and decode budgets — and measures the episode
against the engine's *modeled* clock (the execution model's per-step CGRA
latency, see ``repro.serve.plan.CGRAExecutionModel``).  Requests that
arrive while every slot is busy wait in an admission queue; slots recycle
as requests finish, so the episode exercises continuous batching under
slot pressure.

Everything is deterministic given the seed: arrivals come from one
``numpy`` Generator, request completion depends only on lengths (never on
token *values*), and the modeled clock is analytic — so the report, and
its JSON rendering, are byte-identical across runs and machines.  That is
what makes ``BENCH_serve_decode.json`` a gateable artifact.

Report schema (all floats rounded before serialization):
  tokens_per_s           decoded tokens / modeled episode seconds
  latency_ms.p50/p95/p99 per-request latency percentiles (finish - arrival)
  queue_wait_ms          admission-queue wait percentiles
  slot_occupancy         mean/max active-slot fraction per decode step
"""
from __future__ import annotations

import json
from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .engine import Engine, Request

REPORT_SCHEMA = 1


@dataclass(frozen=True)
class TrafficConfig:
    seed: int = 0
    n_requests: int = 16
    arrival_rate: float = 50.0          # requests / modeled second
    prompt_len: Tuple[int, int] = (4, 12)    # inclusive range
    max_new: Tuple[int, int] = (4, 12)       # inclusive range
    truncate: bool = True               # overlong prompts: truncate vs drop


class FixedLatencyModel:
    """Constant-rate execution model — the no-CGRA baseline (and the
    model tests use to exercise the harness without compiling)."""

    def __init__(self, decode_step_us: float = 1000.0,
                 prefill_us_per_token: float = 250.0):
        self.decode_step_us = decode_step_us
        self.prefill_us_per_token = prefill_us_per_token

    def decode_step_s(self, active: int) -> float:
        return self.decode_step_us * 1e-6

    def prefill_s(self, prompt_len: int) -> float:
        return self.prefill_us_per_token * prompt_len * 1e-6


def generate_requests(traffic: TrafficConfig, vocab: int
                      ) -> List[Tuple[float, Request]]:
    """The seeded workload: [(arrival time, request)] in arrival order."""
    rng = np.random.default_rng(traffic.seed)
    out: List[Tuple[float, Request]] = []
    t = 0.0
    lo_p, hi_p = traffic.prompt_len
    lo_n, hi_n = traffic.max_new
    for rid in range(traffic.n_requests):
        t += float(rng.exponential(1.0 / traffic.arrival_rate))
        plen = int(rng.integers(lo_p, hi_p + 1))
        max_new = int(rng.integers(lo_n, hi_n + 1))
        prompt = np.asarray(rng.integers(0, vocab, size=plen), np.int32)
        out.append((t, Request(rid=rid, prompt=prompt, max_new=max_new)))
    return out


def _pct(values: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(values, np.float64), q))


def _ms_stats(values: List[float]) -> Dict[str, float]:
    if not values:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0, "mean": 0.0, "max": 0.0}
    return {"p50": round(_pct(values, 50) * 1e3, 6),
            "p95": round(_pct(values, 95) * 1e3, 6),
            "p99": round(_pct(values, 99) * 1e3, 6),
            "mean": round(float(np.mean(values)) * 1e3, 6),
            "max": round(float(np.max(values)) * 1e3, 6)}


def run_traffic(engine: Engine, traffic: TrafficConfig,
                vocab: int) -> Dict[str, Any]:
    """One traffic episode; returns the deterministic report dict.

    The engine must carry an execution model — the episode is measured in
    modeled seconds, and a zero-latency clock would make every rate
    statistic degenerate."""
    if engine.exec_model is None:
        raise ValueError("run_traffic needs an engine with an exec_model "
                         "(CGRAExecutionModel or FixedLatencyModel)")
    arrivals = generate_requests(traffic, vocab)
    pending = deque(arrivals)
    tracked: Dict[int, Request] = {}
    arrival_t: Dict[int, float] = {r.rid: t for t, r in arrivals}
    admit_t: Dict[int, float] = {}
    finish_t: Dict[int, float] = {}
    rejected: List[int] = []
    truncated: List[int] = []
    occupancy: List[float] = []
    steps = 0

    while pending or tracked:
        # admit every arrived request that finds a free slot; the rest
        # wait in the queue (continuous batching under slot pressure)
        while (pending and pending[0][0] <= engine.clock_s
               and engine.has_free_slot()):
            t_arr, req = pending.popleft()
            try:
                ok = engine.admit(req, truncate=traffic.truncate)
            except ValueError:        # overlong prompt, truncate=False
                rejected.append(req.rid)
                continue
            if not ok:                # lost the slot race; retry next round
                pending.appendleft((t_arr, req))
                break
            admit_t[req.rid] = engine.clock_s
            tracked[req.rid] = req
            if req.truncated:
                truncated.append(req.rid)
        if not tracked:
            if not pending:
                break
            engine.advance_clock(pending[0][0])   # idle until next arrival
            continue
        occupancy.append(engine.n_active / engine.batch)
        engine.step()
        steps += 1
        for rid in [rid for rid, r in tracked.items() if r.done]:
            finish_t[rid] = engine.clock_s
            del tracked[rid]

    served = sorted(finish_t)
    latency = [finish_t[r] - arrival_t[r] for r in served]
    qwait = [admit_t[r] - arrival_t[r] for r in served]
    decoded = sum(len(r.out) for _t, r in arrivals if r.rid in finish_t)
    episode_s = engine.clock_s
    return {
        "schema": REPORT_SCHEMA,
        "seed": traffic.seed,
        "requests": traffic.n_requests,
        "served": len(served),
        "rejected": len(rejected),
        "truncated": len(truncated),
        "decode_steps": steps,
        "decoded_tokens": decoded,
        "episode_s": round(episode_s, 9),
        "tokens_per_s": round(decoded / episode_s, 6) if episode_s else 0.0,
        "latency_ms": _ms_stats(latency),
        "queue_wait_ms": _ms_stats(qwait),
        "slot_occupancy": {
            "mean": round(float(np.mean(occupancy)), 6) if occupancy else 0.0,
            "max": round(float(np.max(occupancy)), 6) if occupancy else 0.0,
            "slots": engine.batch,
        },
    }


def report_json(report: Dict[str, Any]) -> str:
    """Canonical byte-deterministic rendering of a traffic report."""
    return json.dumps(report, sort_keys=True, indent=1) + "\n"


def report_bench_rows(report: Dict[str, Any],
                      name: str = "serve_decode",
                      **extra_derived: Any) -> List[Dict[str, Any]]:
    """One ``benchmarks.run``-schema row per episode: ``us`` is the
    modeled episode duration (analytic, so the regression comparator
    gates plan/cost-model quality, not host wall clock)."""
    derived = {
        "tokens_per_s": report["tokens_per_s"],
        "p50_ms": report["latency_ms"]["p50"],
        "p95_ms": report["latency_ms"]["p95"],
        "p99_ms": report["latency_ms"]["p99"],
        "queue_p95_ms": report["queue_wait_ms"]["p95"],
        "occupancy": report["slot_occupancy"]["mean"],
        "served": report["served"],
        "decode_steps": report["decode_steps"],
    }
    derived.update(extra_derived)
    return [{"name": name, "us": round(report["episode_s"] * 1e6, 1),
             "derived": derived}]
