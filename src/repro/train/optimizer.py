"""AdamW with fp32 master weights/moments, global-norm clipping and a
warmup+cosine schedule.  Optimizer state shards exactly like the params
(ZeRO-style: params are already FSDP-sharded, so moments/master follow)."""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: jnp.ndarray
    master: Any     # fp32 copy of params
    m: Any
    v: Any


def schedule(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = cfg.lr * (step + 1) / max(1, cfg.warmup_steps)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.minimum(warm, cfg.lr * cos)


def init(params: Any) -> OptState:
    f32 = lambda t: jax.tree.map(lambda x: x.astype(jnp.float32), t)
    zeros = lambda t: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return OptState(step=jnp.zeros((), jnp.int32), master=f32(params),
                    m=zeros(params), v=zeros(params))


def apply(cfg: OptConfig, grads: Any, state: OptState, params: Any
          ) -> Tuple[Any, OptState, Dict[str, jnp.ndarray]]:
    # global-norm clip (the all-reduce here is part of the collective term)
    gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, state.step)
    b1, b2 = cfg.b1, cfg.b2
    t = state.step + 1
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)

    def upd(g, m, v, w):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        step_ = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        w = w - lr * (step_ + cfg.weight_decay * w)
        return m, v, w

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    flat_w = tdef.flatten_up_to(state.master)
    new_m, new_v, new_w = [], [], []
    for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w):
        m2, v2, w2 = upd(g, m, v, w)
        new_m.append(m2)
        new_v.append(v2)
        new_w.append(w2)
    master = tdef.unflatten(new_w)
    new_params = jax.tree.map(lambda w, p: w.astype(p.dtype), master, params)
    new_state = OptState(step=t, master=master, m=tdef.unflatten(new_m),
                         v=tdef.unflatten(new_v))
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
