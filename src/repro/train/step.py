"""Training step: CE loss (+ MoE aux + MTP aux), gradient accumulation via
microbatch scan, remat-ed layer stack, AdamW update.

The microbatch scan keeps per-microbatch activation peaks bounded while
GSPMD overlaps the weight-gradient reduce-scatter of microbatch i with the
backward compute of microbatch i+1 (compute/comm overlap)."""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.common import ModelConfig
from ..models.zoo import Model
from . import optimizer as optim


class TrainState(NamedTuple):
    params: Any
    opt: optim.OptState


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def make_loss_fn(model: Model, aux_weight: float = 0.01,
                 mtp_weight: float = 0.3, logits_spec=None):
    cfg = model.cfg

    def loss_fn(params, batch):
        inputs, labels = batch["inputs"], batch["labels"]
        logits, aux = model.train_logits(params, inputs)
        if logits_spec is not None:
            # perf knob: pin the (B, T, V) logits sharding so the fp32
            # softmax/CE never materializes an unsharded vocab axis
            logits = jax.lax.with_sharding_constraint(logits, logits_spec)
        loss = cross_entropy(logits, labels)
        metrics = {"ce": loss}
        if cfg.moe:
            loss = loss + aux_weight * aux
            metrics["moe_aux"] = aux
        if cfg.mtp and model.mtp_logits is not None \
                and cfg.input_mode == "tokens":
            # re-derive hidden cheaply is not possible; MTP shares trunk
            # gradients through its own head on the unshifted trunk output.
            pass  # MTP loss handled in train_logits_with_mtp variants
        return loss, metrics

    return loss_fn


def make_train_step(model: Model, opt_cfg: optim.OptConfig,
                    num_microbatches: int = 1, logits_spec=None):
    loss_fn = make_loss_fn(model, logits_spec=logits_spec)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        params = state.params

        if num_microbatches <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                B = x.shape[0]
                mb = B // num_microbatches
                return x.reshape(num_microbatches, mb, *x.shape[1:])

            micro = jax.tree.map(split, batch)
            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc(carry, mb):
                g_acc, l_acc = carry
                (l, _m), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l), None

            (grads, loss), _ = jax.lax.scan(
                acc, (zero_g, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / num_microbatches, grads)
            loss = loss / num_microbatches
            metrics = {"ce": loss}

        new_params, new_opt, opt_metrics = optim.apply(
            opt_cfg, grads, state.opt, params)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return TrainState(new_params, new_opt), metrics

    return train_step


def init_train_state(model: Model, rng) -> TrainState:
    params = model.init(rng)
    return TrainState(params=params, opt=optim.init(params))
