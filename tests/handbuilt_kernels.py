"""Golden fixture: the pre-DSL hand-built Table-I kernel builders.

This is the seed's ``DFGBuilder`` wiring for GEMM/CONV (verbatim), kept as
the reference the traced front end is pinned against: for every legacy
Table-I variant, ``repro.core.kernels_lib`` (now written on the
``repro.frontend`` tracer) must produce a ``KernelSpec`` whose
``spec_cache_key`` — and therefore canonical DFG form — is identical to
the hand-built one.  Do not "improve" this module; it is the contract.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.core.adl import CGRAArch, cluster_4x4
from repro.core.dfg import DFGBuilder, Op, Operand
from repro.core.kernels_lib import (KernelSpec, _conv_golden, _conv_init,
                                    _conv_layout, _gemm_golden, _gemm_init,
                                    _gemm_layout)


def build_gemm_handbuilt(TI: int = 64, TK: int = 16, TJ: int = 64,
                         arch: Optional[CGRAArch] = None,
                         unroll: int = 1, coalesced: bool = False
                         ) -> KernelSpec:
    arch = arch or cluster_4x4()
    assert TK % unroll == 0
    layout = _gemm_layout(arch, TI, TK, TJ)
    pw, pi, po = (layout.placements[k] for k in ("W", "I", "O"))
    U = unroll

    b = DFGBuilder(f"gemm{'-u' if U > 1 else ''}{'-c' if coalesced else ''}")
    cU = b.const(U)

    if not coalesced:
        i = b.livein("i")
        j = b.livein("j")
        k = b.add(Operand(0, 0), cU, name="k")
        b.dfg.nodes[k].operands = (Operand(k, dist=1, init=-U), Operand(cU))
        b.cmpge(k, b.const(TK - U), name="exit")
    else:
        cTK = b.const(TK)
        cTJ_b = b.const(TJ)
        c0 = b.const(0)
        c1 = b.const(1)
        knew = b.add(Operand(0, 0), cU, name="knew")
        kwrap = b.cmpge(knew, cTK, name="kwrap")
        k = b.select(kwrap, c0, knew, name="k")
        b.dfg.nodes[knew].operands = (Operand(k, dist=1, init=-U), Operand(cU))
        jnew = b.add(Operand(0, 0), c1, name="jnew")
        jwrap = b.cmpge(jnew, cTJ_b, name="jwrap")
        jsel = b.select(jwrap, c0, jnew, name="jsel")
        j = b.select(kwrap, jsel, Operand(0, 0), name="j")
        b.dfg.nodes[jnew].operands = (Operand(j, dist=1, init=0), Operand(c1))
        b.dfg.nodes[j].operands = (b.dfg.nodes[j].operands[0],
                                   b.dfg.nodes[j].operands[1],
                                   Operand(j, dist=1, init=0))
        land = b.op(Op.AND, kwrap, jwrap, name="ijcarry")
        inew = b.add(Operand(0, 0), c1, name="inew")
        i = b.select(land, inew, Operand(0, 0), name="i")
        b.dfg.nodes[inew].operands = (Operand(i, dist=1, init=0), Operand(c1))
        b.dfg.nodes[i].operands = (b.dfg.nodes[i].operands[0],
                                   b.dfg.nodes[i].operands[1],
                                   Operand(i, dist=1, init=0))

    wrow = b.mul(i, b.const(TK), name="wrow")
    wa0 = b.add(wrow, k, name="wa0")
    if pw.base:
        wa0 = b.add(wa0, b.const(pw.base))
    waddrs = [wa0] + [b.add(wa0, b.const(u), name=f"wa{u}") for u in range(1, U)]
    wl = [b.load(pw.bank_array, wa, name=f"w{u}") for u, wa in enumerate(waddrs)]

    irow = b.mul(k, b.const(TJ), name="irow")
    ia0 = b.add(irow, j, name="ia0")
    if pi.base:
        ia0 = b.add(ia0, b.const(pi.base))
    iaddrs = [ia0] + [b.add(ia0, b.const(u * TJ), name=f"ia{u}")
                      for u in range(1, U)]
    il = [b.load(pi.bank_array, ia, name=f"i{u}") for u, ia in enumerate(iaddrs)]

    prods = [b.mul(wl[u], il[u], name=f"p{u}") for u in range(U)]
    while len(prods) > 1:
        nxt = [b.add(prods[t], prods[t + 1]) for t in range(0, len(prods) - 1, 2)]
        if len(prods) % 2:
            nxt.append(prods[-1])
        prods = nxt
    psum = prods[0]

    orow = b.mul(i, b.const(TJ), name="orow")
    oaddr = b.add(orow, j, name="oaddr")
    if po.base:
        oaddr = b.add(oaddr, b.const(po.base))
    oval = b.load(po.bank_array, oaddr, name="oval")
    acc = b.add(oval, psum, name="acc")
    st = b.store(po.bank_array, oaddr, acc, name="ost")
    b.mem_dep(st, oval, dist=1)

    dfg = b.build()

    if coalesced:
        mapped_iters = TI * TJ * (TK // U)
        invocations: List[Dict[str, int]] = [{}]
    else:
        mapped_iters = TK // U
        invocations = [{"i": ii, "j": jj} for ii in range(TI) for jj in range(TJ)]

    return KernelSpec(
        name=dfg.name, dfg=dfg, arch=arch, layout=layout,
        mapped_iters=mapped_iters, invocations=invocations,
        golden=_gemm_golden(layout, TI, TK, TJ),
        init_banks=_gemm_init(layout, TI, TK, TJ),
        meta=dict(TI=TI, TK=TK, TJ=TJ, unroll=U, coalesced=int(coalesced),
                  macs_per_iter=U, liveins_per_inv=0 if coalesced else 2),
    )


def build_conv_handbuilt(OH: int = 62, OW: int = 62, K: int = 3,
                         IH: Optional[int] = None, IW: Optional[int] = None,
                         arch: Optional[CGRAArch] = None,
                         variant: str = "base") -> KernelSpec:
    arch = arch or cluster_4x4()
    IH = IH if IH is not None else OH + K - 1
    IW = IW if IW is not None else OW + K - 1
    layout = _conv_layout(arch, IH, IW, OH, OW, K)
    pw, pi, po = (layout.placements[k] for k in ("W", "I", "O"))

    b = DFGBuilder(f"conv-{variant}")

    if variant == "base":
        i = b.livein("i")
        j = b.livein("j")
        k1 = b.livein("k1")
        c1 = b.const(1)
        k2 = b.add(Operand(0, 0), c1, name="k2")
        b.dfg.nodes[k2].operands = (Operand(k2, dist=1, init=-1), Operand(c1))
        b.cmpge(k2, b.const(K - 1), name="exit")

        r = b.add(i, k1, name="r")
        rm = b.mul(r, b.const(IW), name="rm")
        cc = b.add(j, k2, name="cc")
        ia = b.add(rm, cc, name="ia")
        if pi.base:
            ia = b.add(ia, b.const(pi.base))
        ival = b.load(pi.bank_array, ia, name="ival")

        wr = b.mul(k1, b.const(K), name="wr")
        wa = b.add(wr, k2, name="wa")
        if pw.base:
            wa = b.add(wa, b.const(pw.base))
        wval = b.load(pw.bank_array, wa, name="wval")

        prod = b.mul(ival, wval, name="prod")
        om = b.mul(i, b.const(OW), name="om")
        oa = b.add(om, j, name="oa")
        if po.base:
            oa = b.add(oa, b.const(po.base))
        oval = b.load(po.bank_array, oa, name="oval")
        acc = b.add(oval, prod, name="acc")
        st = b.store(po.bank_array, oa, acc, name="ost")
        b.mem_dep(st, oval, dist=1)

        mapped_iters = K
        invocations = [{"i": ii, "j": jj, "k1": kk}
                       for ii in range(OH) for jj in range(OW)
                       for kk in range(K)]
        liveins_per_inv = 3

    elif variant in ("uc1", "uc2"):
        c1 = b.const(1)
        c0 = b.const(0)
        if variant == "uc1":
            i = b.livein("i")
            j = b.add(Operand(0, 0), c1, name="j")
            b.dfg.nodes[j].operands = (Operand(j, dist=1, init=-1), Operand(c1))
            b.cmpge(j, b.const(OW - 1), name="exit")
        else:
            jnew = b.add(Operand(0, 0), c1, name="jnew")
            jwrap = b.cmpge(jnew, b.const(OW), name="jwrap")
            j = b.select(jwrap, c0, jnew, name="j")
            b.dfg.nodes[jnew].operands = (Operand(j, dist=1, init=-1),
                                          Operand(c1))
            inew = b.add(Operand(0, 0), c1, name="inew")
            i = b.select(jwrap, inew, Operand(0, 0), name="i")
            b.dfg.nodes[inew].operands = (Operand(i, dist=1, init=0),
                                          Operand(c1))
            b.dfg.nodes[i].operands = (b.dfg.nodes[i].operands[0],
                                       b.dfg.nodes[i].operands[1],
                                       Operand(i, dist=1, init=0))

        om = b.mul(i, b.const(OW), name="om")
        oa = b.add(om, j, name="oa")
        if po.base:
            oa = b.add(oa, b.const(po.base))
        oval = b.load(po.bank_array, oa, name="oval")

        prods = []
        for kk1 in range(K):
            r = b.add(i, b.const(kk1), name=f"r{kk1}") if kk1 else i
            rm = b.mul(r, b.const(IW), name=f"rm{kk1}")
            for kk2 in range(K):
                cc = b.add(j, b.const(kk2), name=f"cc{kk2}") if kk2 else j
                ia = b.add(rm, cc, name=f"ia{kk1}{kk2}")
                if pi.base:
                    ia = b.add(ia, b.const(pi.base))
                ival = b.load(pi.bank_array, ia, name=f"iv{kk1}{kk2}")
                widx = pw.base + kk1 * K + kk2
                wval = b.load(pw.bank_array, b.const(widx),
                              name=f"wv{kk1}{kk2}")
                prods.append(b.mul(ival, wval, name=f"p{kk1}{kk2}"))
        while len(prods) > 1:
            nxt = [b.add(prods[t], prods[t + 1])
                   for t in range(0, len(prods) - 1, 2)]
            if len(prods) % 2:
                nxt.append(prods[-1])
            prods = nxt

        acc = b.add(oval, prods[0], name="acc")
        st = b.store(po.bank_array, oa, acc, name="ost")
        b.mem_dep(st, oval, dist=1)

        if variant == "uc1":
            mapped_iters = OW
            invocations = [{"i": ii} for ii in range(OH)]
            liveins_per_inv = 1
        else:
            mapped_iters = OH * OW
            invocations = [{}]
            liveins_per_inv = 0
    else:
        raise ValueError(variant)

    dfg = b.build()

    return KernelSpec(
        name=dfg.name, dfg=dfg, arch=arch, layout=layout,
        mapped_iters=mapped_iters, invocations=invocations,
        golden=_conv_golden(layout, IH, IW, OH, OW, K),
        init_banks=_conv_init(layout, IH, IW, OH, OW, K),
        meta=dict(OH=OH, OW=OW, K=K, IH=IH, IW=IW,
                  liveins_per_inv=liveins_per_inv),
    )


def table1_kernels_handbuilt(small: bool = False) -> Dict[str, KernelSpec]:
    if small:
        g = dict(TI=6, TK=8, TJ=6)
        c = dict(OH=5, OW=5, K=3)
    else:
        g = dict(TI=64, TK=16, TJ=64)
        c = dict(OH=62, OW=62, K=3)
    return {
        "GEMM": build_gemm_handbuilt(**g, unroll=1, coalesced=False),
        "GEMM-U": build_gemm_handbuilt(**g, unroll=4, coalesced=False),
        "GEMM-U-C": build_gemm_handbuilt(**g, unroll=4, coalesced=True),
        "CONV": build_conv_handbuilt(**c, variant="base"),
        "CONV-U-C-1": build_conv_handbuilt(**c, variant="uc1"),
        "CONV-U-C-2": build_conv_handbuilt(**c, variant="uc2"),
    }
