"""ADL correctness: bank-id addressing (ids, not list positions),
validate() coverage over untrusted user ADL files, from_json validation,
and deterministic JSON round-trips (the hypothesis property tests live
in test_adl_roundtrip.py behind the importorskip guard)."""
import json

import pytest

from repro.core.adl import CGRAArch, MemBank, cluster_4x4, morpher_8x8
from repro.core.kernels_lib import build_gemm
from repro.core.toolchain import Toolchain


def shuffled_bank_arch(rows: int = 4, cols: int = 4) -> CGRAArch:
    """A 4x4 cluster whose banks are declared out of id order: the bank
    with id 1 (right column) comes first in the list."""
    left = tuple(r * cols + 0 for r in range(rows))
    right = tuple(r * cols + (cols - 1) for r in range(rows))
    arch = CGRAArch(name="shuffled-banks", rows=rows, cols=cols,
                    banks=[MemBank(1, 8 * 1024, right),
                           MemBank(0, 8 * 1024, left)],
                    clusters=[list(range(rows * cols))])
    arch.validate()
    return arch


# --------------------------------------------------------- bank addressing
def test_pes_of_bank_looks_up_by_id_not_position():
    arch = shuffled_bank_arch()
    left = tuple(r * 4 + 0 for r in range(4))
    right = tuple(r * 4 + 3 for r in range(4))
    # regression: positional indexing returned banks[0] (= id 1, right
    # column) for bank id 0
    assert arch.pes_of_bank(0) == left
    assert arch.pes_of_bank(1) == right
    assert arch.bank(1).pes == right
    with pytest.raises(KeyError):
        arch.bank(7)


def test_banks_of_pe_agrees_with_pes_of_bank():
    arch = shuffled_bank_arch()
    for b in arch.banks:
        for p in b.pes:
            assert b.id in arch.banks_of_pe(p)
            assert p in arch.pes_of_bank(b.id)


def test_shuffled_bank_arch_compiles_and_verifies():
    """End to end: layout, mapping bus constraints, config generation and
    simulation all key banks by id, so a reordered declaration maps and
    verifies bit-exactly."""
    spec = build_gemm(TI=4, TK=4, TJ=4, arch=shuffled_bank_arch())
    ck = Toolchain(cache_dir="").compile(spec)
    ck.verify()
    # the placements landed on both declared banks, addressed by id
    banks_used = {p.bank for p in spec.layout.placements.values()}
    assert banks_used == {0, 1}


# ----------------------------------------------------------------- validate
def test_validate_rejects_duplicate_bank_ids():
    arch = cluster_4x4()
    arch.banks = [MemBank(0, 1024, (0,)), MemBank(0, 1024, (3,))]
    with pytest.raises(ValueError, match="duplicate memory bank id"):
        arch.validate()


def test_validate_rejects_degenerate_torus():
    """A 1-wide torus wraps a PE onto itself — an out-of-range neighbour
    reference that used to surface only deep in config generation."""
    arch = CGRAArch(name="t", rows=1, cols=4, torus=True,
                    banks=[MemBank(0, 1024, (0,))])
    with pytest.raises(ValueError, match="wraps a PE onto itself"):
        arch.validate()
    # 2x2 tori are fine (every direction reaches a distinct PE)
    CGRAArch(name="t2", rows=2, cols=2, torus=True,
             banks=[MemBank(0, 1024, (0,))]).validate()


def test_validate_rejects_zero_or_odd_bank_sizes():
    """Zero/odd size_bytes collapse a bank to 0 words, so its derived
    word offsets overlap the next bank's."""
    arch = cluster_4x4()
    arch.banks = [MemBank(0, 0, (0,)), MemBank(1, 1024, (3,))]
    with pytest.raises(ValueError, match="positive multiple of 2"):
        arch.validate()
    arch.banks = [MemBank(0, 1023, (0,))]
    with pytest.raises(ValueError, match="positive multiple of 2"):
        arch.validate()


def test_validate_rejects_duplicate_bus_pes():
    arch = cluster_4x4()
    arch.banks = [MemBank(0, 1024, (0, 4, 0))]
    with pytest.raises(ValueError, match="more than once on its bus"):
        arch.validate()


def test_validate_rejects_out_of_range_cluster_pes():
    arch = cluster_4x4()
    arch.clusters = [[0, 1, 99]]
    with pytest.raises(ValueError, match="cluster 0 references PE 99"):
        arch.validate()


def test_validate_rejects_out_of_range_per_pe_ops():
    arch = cluster_4x4()
    arch.per_pe_ops = {99: frozenset({"add"})}
    with pytest.raises(ValueError, match="per_pe_ops references PE 99"):
        arch.validate()


def test_from_json_validates_malformed_adl():
    """A malformed --arch-file must fail at load, not deep in the mapper."""
    d = json.loads(cluster_4x4().to_json())
    d["banks"][0]["pes"] = [0, 999]
    with pytest.raises(ValueError, match="outside the 16-PE grid"):
        CGRAArch.from_json(json.dumps(d))

    d = json.loads(cluster_4x4().to_json())
    d["rows"] = 0
    with pytest.raises(ValueError, match="must be positive"):
        CGRAArch.from_json(json.dumps(d))

    d = json.loads(cluster_4x4().to_json())
    d["banks"][1]["id"] = d["banks"][0]["id"]
    with pytest.raises(ValueError, match="duplicate memory bank id"):
        CGRAArch.from_json(json.dumps(d))


# --------------------------------------------------------- JSON round-trips
def test_roundtrip_stock_archs():
    for arch in (cluster_4x4(), morpher_8x8(), shuffled_bank_arch()):
        assert CGRAArch.from_json(arch.to_json()) == arch


def test_roundtrip_dse_variants():
    from repro.dse import get_space
    for point in get_space("small"):
        arch = point.build()
        assert CGRAArch.from_json(arch.to_json()) == arch
