"""ADL round-trip property tests: ``from_json(to_json(arch)) == arch``
over randomly drawn architectures — torus and mesh topologies, shuffled
non-contiguous bank ids, heterogeneous per-PE op sets, optional
clustering — plus canonical-form stability of the serialized JSON."""
import json

import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install -e '.[test]')")
from hypothesis import given, settings, strategies as st

from repro.core.adl import CGRAArch, MemBank, cluster_4x4

ALL_OPS = sorted(json.loads(cluster_4x4().to_json())["fu_ops"])


@st.composite
def arch_strategy(draw):
    rows = draw(st.integers(1, 8))
    cols = draw(st.integers(1, 8))
    n_pes = rows * cols
    n_banks = draw(st.integers(0, 4))
    # unique, possibly non-contiguous ids in arbitrary declaration order
    ids = draw(st.lists(st.integers(0, 31), min_size=n_banks,
                        max_size=n_banks, unique=True))
    banks = [MemBank(bid,
                     draw(st.sampled_from((1024, 4096, 8192))),
                     tuple(sorted(draw(st.sets(st.integers(0, n_pes - 1),
                                               min_size=1, max_size=4)))))
             for bid in ids]
    per_pe = draw(st.dictionaries(
        st.integers(0, n_pes - 1),
        st.sets(st.sampled_from(ALL_OPS), min_size=1).map(frozenset),
        max_size=3))
    clusters = [list(range(n_pes))] if draw(st.booleans()) else []
    return CGRAArch(
        name=draw(st.sampled_from(("hyp-a", "hyp-b"))),
        rows=rows, cols=cols,
        datapath_bits=draw(st.sampled_from((8, 16, 32))),
        regfile_size=draw(st.integers(1, 16)),
        livein_regs=draw(st.integers(0, 8)),
        banks=banks, torus=draw(st.booleans()),
        per_pe_ops=per_pe, clusters=clusters)


@settings(max_examples=60, deadline=None)
@given(arch_strategy())
def test_adl_json_roundtrip_property(arch):
    arch.validate()
    again = CGRAArch.from_json(arch.to_json())
    assert again == arch
    # the serialized form is canonical: stable across a round trip
    assert again.to_json() == arch.to_json()


@settings(max_examples=20, deadline=None)
@given(arch_strategy(), st.integers(0, 3))
def test_adl_bank_lookup_is_by_id(arch, k):
    """pes_of_bank returns the declared PEs of the *id*, regardless of
    where the bank sits in the declaration list."""
    if not arch.banks:
        return
    b = arch.banks[k % len(arch.banks)]
    assert arch.pes_of_bank(b.id) == b.pes
