"""Batched verification engine: bit-exactness against the sequential flow
(the golden-equivalence contract), the shape-bucketed executable cache,
and the batched oracles.

The load-bearing test is the property sweep: for every library kernel
(six Table-I + four DSL-only) and >= 4 seeds, ``verify_batch`` and the
batched simulator must agree word-for-word with per-seed ``verify`` /
``run`` — including a batch size that pads up to its bucket boundary."""
import numpy as np
import pytest

from repro.core import simcache
from repro.core.kernels_lib import build_gemm, table1_kernels
from repro.core.refexec import reference_execute_jax
from repro.core.simulator import simulate, simulate_batch
from repro.core.toolchain import CompiledKernel, Toolchain
from repro.core.verify import (generate_test_data, generate_test_data_batch,
                               reference_banks)
from repro.frontend.library import dsl_kernels

SEEDS = [0, 1, 5, 11]


@pytest.fixture(scope="module")
def compiled_all():
    tc = Toolchain(cache_dir="")
    specs = {**table1_kernels(small=True), **dsl_kernels()}
    return dict(zip(specs, tc.compile_many(list(specs.values()))))


def test_batched_matches_sequential_word_for_word(compiled_all):
    """Golden equivalence: every (kernel, seed) pair simulates to the very
    same final memory through the batched engine as through the per-seed
    path, and both verify clean."""
    for name, ck in compiled_all.items():
        datas = [generate_test_data(ck.spec, s) for s in SEEDS]
        seq = [ck.run(d.init_banks) for d in datas]
        bat = ck.run_batch([d.init_banks for d in datas])
        assert len(bat) == len(SEEDS)
        for seed, a, b in zip(SEEDS, seq, bat):
            for bank in a:
                np.testing.assert_array_equal(
                    a[bank], b[bank],
                    err_msg=f"{name} seed {seed} {bank}")
        ck.verify_batch(SEEDS)          # and the full IV-C batched flow
        for s in SEEDS:
            ck.verify(seed=s)


def test_padded_bucket_is_masked_out(compiled_all):
    """batch=3 rounds up to the 4-bucket; the padded row must not leak
    into results."""
    ck = compiled_all["GEMM"]
    assert simcache.bucket_batch(3) == 4
    datas = [generate_test_data(ck.spec, s) for s in (2, 3, 4)]
    bat = ck.run_batch([d.init_banks for d in datas])
    assert len(bat) == 3
    for d, b in zip(datas, bat):
        seq = ck.run(d.init_banks)
        for bank in seq:
            np.testing.assert_array_equal(seq[bank], b[bank])
    ck.verify_batch([2, 3, 4])


def test_verify_batch_artifact_path(compiled_all):
    """A deserialized artifact (no golden-model closures) batch-verifies
    against the DFG reference oracle."""
    ck = CompiledKernel.from_json(compiled_all["GEMM"].to_json())
    assert ck.spec is None
    ck.verify_batch([0, 1, 2])


def test_verify_batch_empty_seeds(compiled_all):
    ck = compiled_all["GEMM"]
    assert ck.verify_batch([]) is ck
    assert simulate_batch(ck.cfg, [], ck.invocations, ck.mapped_iters) == []


def test_verify_many_mixes_specs_programs_and_artifacts():
    from repro.frontend.library import DSL_PROGRAMS
    tc = Toolchain(cache_dir="")
    spec = build_gemm(TI=4, TK=4, TJ=4, unroll=1)
    pre = tc.compile(spec)
    out = tc.verify_many([pre, DSL_PROGRAMS[0]], seeds=[0, 1])
    assert out[0] is pre
    assert out[1].name == DSL_PROGRAMS[0].name


def test_oracles_agree_with_scalar_reference(compiled_all):
    """The numpy batch interpreter and the JAX-lowered executor both
    reproduce the scalar DFG oracle bit-for-bit."""
    for name in ("GEMM", "CONV", "dwconv", "requant-int8"):
        spec = compiled_all[name].spec
        inits = [generate_test_data(spec, s).init_banks for s in SEEDS]
        stacked = {k: np.stack([i[k] for i in inits]) for k in inits[0]}
        bits = spec.arch.datapath_bits
        want = [reference_banks(spec.dfg, i, spec.invocations,
                                spec.mapped_iters, bits) for i in inits]
        got_np = spec.dfg.reference_execute_batch(
            spec.mapped_iters,
            {k: np.asarray(v, dtype=np.int64) for k, v in stacked.items()},
            spec.invocations, bits=bits)
        got_jx = reference_execute_jax(spec.dfg, spec.mapped_iters, stacked,
                                       spec.invocations, bits)
        for i, seed in enumerate(SEEDS):
            for bank in want[i]:
                np.testing.assert_array_equal(
                    np.asarray(want[i][bank]), got_np[bank][i],
                    err_msg=f"{name} seed {seed} {bank} (numpy batch)")
                np.testing.assert_array_equal(
                    np.asarray(want[i][bank]), got_jx[bank][i],
                    err_msg=f"{name} seed {seed} {bank} (jax)")


def test_generate_test_data_batch_rows_match_per_seed():
    spec = build_gemm(TI=4, TK=4, TJ=4, unroll=1)
    db = generate_test_data_batch(spec, SEEDS)
    for i, s in enumerate(SEEDS):
        d = generate_test_data(spec, s)
        for bank in d.init_banks:
            np.testing.assert_array_equal(db.init_banks[bank][i],
                                          d.init_banks[bank])
            np.testing.assert_array_equal(db.expected_banks[bank][i],
                                          np.asarray(d.expected_banks[bank]))


def test_verify_batch_reports_seed_on_mismatch(compiled_all):
    """A corrupted configuration must fail with the offending seed named."""
    src = compiled_all["GEMM"]
    ck = CompiledKernel.from_json(src.to_json())
    ck.cfg.imm[:] = ck.cfg.imm + 1          # corrupt every immediate
    with pytest.raises(AssertionError, match="seed="):
        ck.verify_batch([0, 1])


# ----------------------------------------------------------- simcache unit
def test_bucket_batch_rounds_to_power_of_two():
    assert [simcache.bucket_batch(b) for b in (0, 1, 2, 3, 5, 8, 9)] == \
        [1, 1, 2, 4, 8, 8, 16]
    # degenerate and negative inputs clamp to the 1-bucket, and exact
    # powers of two are fixed points (no gratuitous doubling)
    assert simcache.bucket_batch(-3) == 1
    for p in (1, 2, 4, 64, 1024):
        assert simcache.bucket_batch(p) == p


def test_bucket_cycles_rounds_up_with_bounded_padding():
    for n in (1, 7, 13, 40, 100, 1000, 12345):
        b = simcache.bucket_cycles(n)
        assert b >= n
        assert b <= max(n * 1.125, n + 1), (n, b)
    # buckets quantize: nearby cycle counts share one boundary
    assert simcache.bucket_cycles(121) == simcache.bucket_cycles(127)


def test_bucket_cycles_edges():
    # <= 8 passes through exactly (tiny schedules never pad) except the
    # degenerate 0/negative, which clamps to 1 cycle
    assert [simcache.bucket_cycles(n) for n in (0, -1, 1, 2, 8)] == \
        [1, 1, 1, 2, 8]
    # the first bucketed value and an exact boundary stay put
    assert simcache.bucket_cycles(9) == 9
    assert simcache.bucket_cycles(16) == 16
    # idempotent: a bucket boundary is its own bucket
    for n in (9, 17, 40, 121, 12345):
        assert simcache.bucket_cycles(simcache.bucket_cycles(n)) == \
            simcache.bucket_cycles(n)


def test_bucket_rows_quantizes_like_cycles():
    # the stacked-batch row bucket uses the cycle quantization (<= 12.5%
    # padded rows), not bucket_batch's power of two: 40 rows must not
    # balloon to 64
    assert simcache.bucket_rows(40) == 40
    assert simcache.bucket_rows(41) < simcache.bucket_batch(41)
    for n in (1, 8, 9, 38, 100):
        assert simcache.bucket_rows(n) == simcache.bucket_cycles(n)


def test_bucket_rf_merges_provisioning_classes():
    # every library register-file size folds into the 16-wide class, so
    # rf4/rf8/rf16 search variants share one stacked executable
    assert {simcache.bucket_rf(rf) for rf in (1, 4, 8, 16)} == {16}
    # wider RFs round to the next power of two and are fixed points
    assert simcache.bucket_rf(17) == 32
    assert simcache.bucket_rf(32) == 32
    for rf in (1, 4, 16, 24, 64):
        assert simcache.bucket_rf(rf) >= rf


def test_executable_cache_reuses_signatures(compiled_all):
    simcache.clear()
    ck = compiled_all["GEMM"]
    data = [generate_test_data(ck.spec, s).init_banks for s in SEEDS]
    ck.run_batch(data)
    st = simcache.stats()
    assert st["entries"] == 1 and st["misses"] == 1
    ck.run_batch(data)                       # same signature: cache hit
    st = simcache.stats()
    assert st["entries"] == 1 and st["hits"] >= 1
    # batch=3 pads into the same 4-bucket -> same executable, another hit
    ck.run_batch(data[:3])
    assert simcache.stats()["entries"] == 1
