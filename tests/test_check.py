"""Static legality checker (repro.check): clean-library sweeps, one
pinned test per mutation class, report byte-determinism, the
MORPHER_CHECK=1 verify gate, and the DSE pre-screen."""
import dataclasses

import pytest

from repro.check import (RULES, assert_clean, check_kernel, errors,
                         report_json)
from repro.check.mutate import CLASSES, mutate_one, mutation_gate, run_corpus
from repro.core.adl import cluster_4x4
from repro.core.kernels_lib import table1_kernels
from repro.core.toolchain import Toolchain


@pytest.fixture(scope="module")
def toolchain():
    return Toolchain()


@pytest.fixture(scope="module")
def compiled_small(toolchain):
    """The six Table-I small kernels (shared compile, cache-warm)."""
    specs = table1_kernels(small=True)
    cks = toolchain.compile_many(list(specs.values()))
    return dict(zip(specs, cks))


# ------------------------------------------------------------- clean sweeps
def test_clean_library_two_archs_zero_diagnostics(toolchain):
    """The PR-10 contract: all ten library kernels, on two architectures,
    produce zero diagnostics."""
    from repro.dse.explore import kernel_suite
    torus = dataclasses.replace(cluster_4x4(),
                                name="morpher-cluster-4x4-torus", torus=True)
    for arch in (cluster_4x4(), torus):
        suite = kernel_suite(arch)
        assert len(suite) == 10
        cks = toolchain.compile_many(list(suite.values()))
        for ck in cks:
            diags = errors(check_kernel(ck))
            assert diags == [], (arch.name, ck.name,
                                 [str(d) for d in diags[:5]])


def test_assert_clean_passes_on_clean_artifact(compiled_small):
    for ck in compiled_small.values():
        assert_clean(ck)


def test_toolchain_check_api(toolchain):
    """Toolchain.check compiles (cache hit) and audits in one call."""
    spec = table1_kernels(small=True)["GEMM"]
    assert errors(toolchain.check(spec)) == []


# -------------------------------------------------- mutation corpus: pinned
@pytest.mark.parametrize("cls", sorted(CLASSES))
def test_mutation_class_caught_by_intended_rule(cls, compiled_small):
    """One pinned test per corruption class: the class's intended rule id
    fires on at least one seeded mutant, on every kernel that offers a
    mutation site."""
    from repro.check.mutate import _check_mutant
    layer, intended = CLASSES[cls]
    assert intended in RULES
    sites = 0
    for ck in compiled_small.values():
        made = mutate_one(ck, cls, seed=0, index=0)
        if made is None:
            continue
        sites += 1
        artifact, desc = made
        fired = {d.rule for d in _check_mutant(ck, layer, artifact)}
        assert intended in fired, (ck.name, cls, desc, sorted(fired))
    assert sites > 0, f"no kernel offered a site for class {cls!r}"


def test_mutation_gate_green(compiled_small):
    """The acceptance bar: score >= 0.95, every class caught, and any
    miss proven simulator-invisible (none expected)."""
    report = mutation_gate(list(compiled_small.values()), seed=0,
                           per_class=2)
    assert report.score >= 0.95
    assert report.live_misses == []


def test_corpus_is_seeded_and_reproducible(compiled_small):
    cks = [compiled_small["GEMM"]]
    a = run_corpus(cks, seed=7, per_class=1, probe_dead=False)
    b = run_corpus(cks, seed=7, per_class=1, probe_dead=False)
    assert [o.to_json_dict() for o in a.outcomes] == \
        [o.to_json_dict() for o in b.outcomes]


# --------------------------------------------------------------- the report
def test_report_json_byte_deterministic(compiled_small):
    def build():
        return report_json({
            name: {"II": ck.II, "cache_key": ck.cache_key,
                   "diagnostics": check_kernel(ck)}
            for name, ck in compiled_small.items()})
    one, two = build(), build()
    assert one == two
    assert one.endswith("\n")
    import json
    payload = json.loads(one)
    assert payload["clean"] is True
    assert payload["n_errors"] == 0
    assert set(payload["rules"]) == set(RULES)


# ------------------------------------------------------ MORPHER_CHECK gate
def test_verify_gate_passes_clean(compiled_small, monkeypatch):
    monkeypatch.setenv("MORPHER_CHECK", "1")
    compiled_small["GEMM"].verify(seed=0)


def test_verify_gate_rejects_corrupt_artifact(compiled_small, monkeypatch):
    """Under MORPHER_CHECK=1 a corrupted artifact fails *statically*,
    naming the rule, before any simulation runs."""
    monkeypatch.setenv("MORPHER_CHECK", "1")
    ck = compiled_small["GEMM"]
    cfg, _desc = mutate_one(ck, "store_window", seed=0, index=0)
    bad = dataclasses.replace(ck, cfg=cfg)
    with pytest.raises(AssertionError, match="CFG-STORE-WINDOW"):
        bad.verify(seed=0)
    with pytest.raises(AssertionError, match="CFG-STORE-WINDOW"):
        bad.verify_batch(seeds=(0, 1))


def test_gate_off_by_default(compiled_small, monkeypatch):
    """Without MORPHER_CHECK=1 the corrupt artifact fails dynamically (or
    not at all) — the static gate must be opt-in."""
    monkeypatch.delenv("MORPHER_CHECK", raising=False)
    from repro.core.verify import check_enabled
    assert not check_enabled()


# ---------------------------------------------------------- DSE pre-screen
def test_dse_prescreen_flags_corrupt_point(compiled_small):
    from repro.dse.explore import _prescreen
    ck = compiled_small["GEMM"]
    assert _prescreen(ck) == ""
    cfg, _desc = mutate_one(ck, "opcode_clobber", seed=0, index=0)
    bad = dataclasses.replace(ck, cfg=cfg)
    msg = _prescreen(bad)
    assert "CFG-OPC-RANGE" in msg


def test_dse_evaluate_points_static_check(toolchain):
    """evaluate_points with the static pre-screen enabled: clean points
    keep status ok (the frontier is unchanged when nothing fires)."""
    from repro.dse import tiny_space
    from repro.dse.explore import evaluate_points
    points = list(tiny_space())[:1]
    res = evaluate_points(points, toolchain=toolchain, seeds=(0,),
                          suite_names=("GEMM", "CONV"), static_check=True)
    assert len(res) == 1
    for outcome in res[0].kernels.values():
        assert outcome.status in ("ok", "map_error", "layout_error"), \
            outcome
        assert outcome.status != "check_error"


# ----------------------------------- generator errors share the rule idiom
def test_config_conflict_message_carries_locus_and_rule(compiled_small):
    """Satellite: ConfigConflict messages read like checker diagnostics
    (slot/pe locus + rule id)."""
    from repro.core.config_gen import ConfigConflict, generate_config
    ck = next(c for c in compiled_small.values()
              if c.mapping.reg_assign)
    mapping, _desc = mutate_one(ck, "reg_clobber", seed=0, index=0)
    # drop the colored register entirely: generate_config must name the
    # locus and the MAP-REG-RANGE rule
    key = sorted(mapping.reg_assign)[0]
    del mapping.reg_assign[key]
    with pytest.raises(ConfigConflict, match=r"slot\d+/pe\d+.*MAP-REG-RANGE"):
        generate_config(mapping, ck.layout)


def test_stream_error_message_carries_locus_and_rule(compiled_small):
    from repro.isa.encode import manifest_dict, to_csv
    from repro.isa.interp import StreamError, parse_stream
    ck = compiled_small["GEMM"]
    csv_text = to_csv(ck.cfg)
    lines = csv_text.splitlines()
    dup = "\n".join(lines[:-1] + [lines[1], ""])  # duplicate first record
    with pytest.raises(StreamError, match=r"slot\d+/pe\d+.*STR-PARSE"):
        parse_stream(dup, manifest_dict(ck.cfg, ck.name))
