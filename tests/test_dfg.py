"""DFG IR + kernel-library semantics: the sequential dataflow oracle must
reproduce the numpy golden model for every Table-I kernel variant."""
import numpy as np
import pytest

from repro.core.dfg import DFG, DFGBuilder, Op, Operand, wrap
from repro.core.kernels_lib import build_conv, build_gemm, table1_kernels
from repro.core.verify import check_dfg_semantics, generate_test_data


def test_wrap16():
    assert wrap(32767) == 32767
    assert wrap(32768) == -32768
    assert wrap(-32769) == 32767
    assert wrap(65536) == 0


def test_builder_and_topo():
    b = DFGBuilder("t")
    c1 = b.const(1)
    k = b.add(Operand(0, 0), c1)
    b.dfg.nodes[k].operands = (Operand(k, dist=1, init=-1), Operand(c1))
    st = b.store("bank0", k, k)
    dfg = b.build()
    order = dfg.topo_order()
    assert order.index(c1) < order.index(k) < order.index(st)


def test_carried_init_semantics():
    # k = k_prev + 1, init -1: iteration n must produce n
    b = DFGBuilder("ind")
    c1 = b.const(1)
    k = b.add(Operand(0, 0), c1)
    b.dfg.nodes[k].operands = (Operand(k, dist=1, init=-1), Operand(c1))
    b.store("bank0", k, k)
    dfg = b.build()
    mem = dfg.reference_execute(5, {"bank0": [0] * 8}, {})
    assert mem["bank0"][4] == 4


@pytest.mark.parametrize("name", ["GEMM", "GEMM-U", "GEMM-U-C",
                                  "CONV", "CONV-U-C-1", "CONV-U-C-2"])
def test_kernel_dfg_matches_golden(name):
    spec = table1_kernels(small=True)[name]
    data = generate_test_data(spec, seed=3)
    check_dfg_semantics(spec, data)   # raises on mismatch


def test_node_counts_paper_ballpark():
    full = table1_kernels(small=False)
    paper = {"GEMM": 26, "GEMM-U": 58, "GEMM-U-C": 79,
             "CONV": 27, "CONV-U-C-1": 100, "CONV-U-C-2": 153}
    for name, spec in full.items():
        ours = spec.dfg.n_nodes
        assert 0.3 * paper[name] <= ours <= 1.5 * paper[name], \
            f"{name}: {ours} vs paper {paper[name]}"


def test_small_and_full_same_structure():
    # identical loop structure; +-2 nodes of slack for base-offset adds
    # (the full-dims O tile fills a whole bank, shifting the data layout)
    small = table1_kernels(small=True)
    full = table1_kernels(small=False)
    for name in small:
        assert abs(small[name].dfg.n_nodes - full[name].dfg.n_nodes) <= 2, \
            (name, small[name].dfg.n_nodes, full[name].dfg.n_nodes)
