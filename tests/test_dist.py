"""Fleet robustness tests: supervised work-queue runner (deadlines,
bounded retry, killed-worker recovery, heartbeat eviction with work
stealing), process-pool poisoning recovery, deterministic fault
injection, checkpoint failure warnings — and the headline contract:
a DSE sweep with injected worker loss emits byte-identical artifacts
to an undisturbed single-process run."""
import json
import os
import warnings

import pytest

from repro.core import pool
from repro.dist import faults
from repro.dist.fleet import (DEFAULT_RETRIES, DEFAULT_TIMEOUT_S,
                              FleetConfig, FleetError, backoff_schedule,
                              run_fleet)

needs_pool = pytest.mark.skipif(
    pool.shared_pool() is None,
    reason="process fan-out unavailable in this context")


# ------------------------------------------------------------ pure units
def test_backoff_schedule_is_deterministic_and_capped():
    assert backoff_schedule(4) == (0.05, 0.1, 0.2, 0.4)
    assert backoff_schedule(4) == backoff_schedule(4)
    assert backoff_schedule(0) == ()
    sched = backoff_schedule(8, base_s=0.2, cap_s=1.0)
    assert sched[:3] == (0.2, 0.4, 0.8)
    assert set(sched[3:]) == {1.0}              # capped, never unbounded


def test_fleet_config_env_resolution(monkeypatch):
    monkeypatch.delenv("MORPHER_TASK_TIMEOUT_S", raising=False)
    monkeypatch.delenv("MORPHER_FLEET_RETRIES", raising=False)
    cfg = FleetConfig()
    assert cfg.resolved_timeout_s() == DEFAULT_TIMEOUT_S
    assert cfg.resolved_retries() == DEFAULT_RETRIES
    assert cfg.resolved_heartbeat_s(10.0) == 20.0
    monkeypatch.setenv("MORPHER_TASK_TIMEOUT_S", "7.5")
    monkeypatch.setenv("MORPHER_FLEET_RETRIES", "5")
    assert cfg.resolved_timeout_s() == 7.5
    assert cfg.resolved_retries() == 5
    # explicit values beat the environment
    explicit = FleetConfig(timeout_s=1.0, retries=0,
                           heartbeat_timeout_s=3.0)
    assert explicit.resolved_timeout_s() == 1.0
    assert explicit.resolved_retries() == 0
    assert explicit.resolved_heartbeat_s(1.0) == 3.0


def test_fault_plan_seeded_roundtrip_and_fire_once(tmp_path):
    p1 = faults.FaultPlan.seeded(seed=3, units=10, kills=2, delays=1,
                                 mutes=1, groups=4)
    p2 = faults.FaultPlan.seeded(seed=3, units=10, kills=2, delays=1,
                                 mutes=1, groups=4)
    assert (p1.kill_units, p1.delay_units, p1.mute_groups) == \
        (p2.kill_units, p2.delay_units, p2.mute_groups)
    assert len(p1.kill_units) == 2 and len(p1.delay_units) == 1
    assert p1.state_dir                       # seeded() arms the plan
    rt = faults.FaultPlan.from_json(p1.to_json())
    assert rt == p1

    plan = faults.FaultPlan(kill_units=(1,),
                            state_dir=str(tmp_path)).armed()
    assert plan.state_dir == str(tmp_path)    # armed() is idempotent
    assert plan._fire_once("kill-1") is True
    assert plan._fire_once("kill-1") is False  # exactly once per tag
    assert faults.FaultPlan(kill_units=(1,))._fire_once("kill-1") is False
    assert plan.muted(0) is False


def test_corrupt_file_is_deterministic(tmp_path):
    a, b = tmp_path / "a", tmp_path / "b"
    payload = json.dumps({"k": list(range(40))}).encode()
    a.write_bytes(payload)
    b.write_bytes(payload)
    faults.corrupt_file(str(a), seed=7)
    faults.corrupt_file(str(b), seed=7)
    assert a.read_bytes() == b.read_bytes() != payload


def test_fleet_inline_fallback_in_worker(monkeypatch):
    # inside a pool worker the pool is unavailable: run_fleet degrades
    # to sequential inline execution (and never consults the fault plan)
    monkeypatch.setenv(pool.WORKER_ENV, "1")
    plan = faults.FaultPlan(kill_units=(0, 1, 2)).armed()
    rep = run_fleet(faults.double, [1, 2, 3],
                    FleetConfig(groups=2, faults=plan))
    assert rep.results == [2, 4, 6]
    assert rep.sequential and not rep.quiet()
    rep2 = run_fleet(faults.double, [1, 2, 3],
                     FleetConfig(groups=2), inline_fallback=False)
    assert rep2.results is None and rep2.sequential


def test_fleet_empty_payloads():
    rep = run_fleet(faults.double, [])
    assert rep.results == [] and rep.quiet()


# ------------------------------------------------------- supervised runs
@needs_pool
def test_fleet_parallel_matches_sequential():
    rep = run_fleet(faults.double, list(range(8)),
                    FleetConfig(groups=2, timeout_s=60))
    assert rep.results == [p * 2 for p in range(8)]
    assert not rep.sequential
    assert rep.quiet()


@needs_pool
def test_fleet_recovers_from_killed_worker():
    plan = faults.FaultPlan(kill_units=(1,)).armed()
    rep = run_fleet(faults.double, list(range(8)),
                    FleetConfig(groups=2, timeout_s=60, faults=plan))
    assert rep.results == [p * 2 for p in range(8)]
    assert rep.pool_rebuilds >= 1             # the kill was observed
    assert not rep.quiet()
    # the shared pool is not poisoned for the next caller
    assert pool.process_map(faults.double, [1, 2, 3]) in ([2, 4, 6], None)


@needs_pool
def test_fleet_straggler_times_out_and_result_survives():
    plan = faults.FaultPlan(delay_units=((2, 1.5),)).armed()
    rep = run_fleet(faults.double, list(range(8)),
                    FleetConfig(groups=2, timeout_s=0.4, retries=2,
                                faults=plan))
    assert rep.results == [p * 2 for p in range(8)]
    # the expired deadline is recorded, not silently dropped ...
    assert {"unit": 2, "attempt": 0} in rep.timeouts
    # ... and the re-queue charged the unit's retry budget
    assert rep.retries >= 1


@needs_pool
def test_fleet_exhausted_retry_budget_raises():
    plan = faults.FaultPlan(delay_units=((0, 1.0), (1, 1.0), (2, 1.0),
                                         (3, 1.0))).armed()
    with pytest.raises(FleetError):
        # every delay fires once, but retries=0 leaves no budget
        run_fleet(faults.double, list(range(4)),
                  FleetConfig(groups=2, timeout_s=0.3, retries=0,
                              faults=plan))
    pool.reset_pool(kill=True)    # drop any sleeping orphans


@needs_pool
def test_fleet_evicts_silent_group_and_steals_exactly_once():
    # group 1 (units 1,3,5) goes silent: unit 1 sleeps while the muted
    # group's completions never beat the monitor -> after 0.4s the group
    # is evicted and its *queued* units (3,5) are stolen by group 0
    plan = faults.FaultPlan(delay_units=((1, 1.2),),
                            mute_groups=(1,)).armed()
    rep = run_fleet(faults.double, list(range(6)),
                    FleetConfig(groups=2, timeout_s=30,
                                heartbeat_timeout_s=0.4, max_inflight=2,
                                faults=plan))
    assert rep.results == [p * 2 for p in range(6)]
    assert rep.evicted_groups == [1]
    assert rep.stolen_units == [3, 5]         # each stolen exactly once
    assert sorted(set(rep.stolen_units)) == rep.stolen_units


@needs_pool
def test_process_map_survives_killed_worker():
    # a worker dying mid-batch poisons naive executors; process_map rebuilds
    # and the *next* call gets a healthy pool (regression: a single
    # BrokenProcessPool used to fail every later fan-out)
    out = pool.process_map(faults.kill_worker, [1, 2, 3])
    assert out is None                         # batch unrecoverable: kill
    assert pool.process_map(faults.double, [1, 2, 3]) == [2, 4, 6]


# -------------------------------------------------- checkpoint failures
def test_store_checkpoint_warns_once_per_path(tmp_path):
    from repro.dse import explore
    blocker = tmp_path / "blocker"
    blocker.write_text("a file, not a directory")
    bad = str(blocker / "sub" / "ckpt.json")   # mkdir under a file: OSError
    with pytest.warns(RuntimeWarning, match="NOT being saved"):
        explore._store_checkpoint(bad, {"v": 1}, {})
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        explore._store_checkpoint(bad, {"v": 1}, {})   # silent 2nd time


def test_corrupt_checkpoint_warns_and_recomputes(tmp_path):
    from repro.dse import explore
    fp = {"schema": 1}
    path = tmp_path / "ckpt.json"
    path.write_text(json.dumps({"fingerprint": fp, "variants": {}}))
    assert explore._load_checkpoint(str(path), fp) == {}
    faults.corrupt_file(str(path), seed=0, n_bytes=16)
    with pytest.warns(RuntimeWarning, match="unreadable"):
        assert explore._load_checkpoint(str(path), fp) == {}
    with warnings.catch_warnings():            # once per path only
        warnings.simplefilter("error")
        assert explore._load_checkpoint(str(path), fp) == {}


# ------------------------------------------- headline contract (e2e)
@pytest.fixture(scope="module")
def faulted_sweep(tmp_path_factory):
    """One 2-variant compile-only sweep, run twice from cold caches:
    undisturbed sequential vs. fleet with a killed worker + straggler."""
    from repro.core import MapperOptions, Toolchain
    from repro.dse import get_space, run_sweep
    root = tmp_path_factory.mktemp("dist_e2e")
    points = get_space("tiny")[:2]

    tc_seq = Toolchain(options=MapperOptions(ii_max=20),
                       cache_dir=str(root / "cache_seq"))
    seq = run_sweep(points, toolchain=tc_seq, verify=False,
                    checkpoint=str(root / "ckpt_seq.json"))

    # kill the worker on unit 1, delay unit 2 past its 20s deadline —
    # fire-once each, so the retried attempts run clean
    plan = faults.FaultPlan(kill_units=(1,),
                            delay_units=((2, 45.0),)).armed()
    cfg = FleetConfig(groups=2, timeout_s=20.0, faults=plan)
    tc_fleet = Toolchain(options=MapperOptions(ii_max=20),
                         cache_dir=str(root / "cache_fleet"))
    ckpt = root / "ckpt_fleet.json"
    disturbed = run_sweep(points, toolchain=tc_fleet, verify=False,
                          checkpoint=str(ckpt), fleet=cfg)
    return root, points, seq, disturbed, ckpt


def test_faulted_sweep_results_match(faulted_sweep):
    _root, points, seq, disturbed, _ckpt = faulted_sweep
    assert [r.to_json_dict() for r in disturbed] == \
        [r.to_json_dict() for r in seq]


def test_faulted_sweep_artifacts_byte_identical(faulted_sweep):
    from repro.dse import write_artifacts
    root, _points, seq, disturbed, _ckpt = faulted_sweep
    a = write_artifacts(seq, str(root / "out_seq"), space="dist-e2e",
                        seeds=[0], verified=False)
    b = write_artifacts(disturbed, str(root / "out_fleet"),
                        space="dist-e2e", seeds=[0], verified=False)
    for name in a:
        ab = open(a[name], "rb").read()
        bb = open(b[name], "rb").read()
        assert ab == bb, f"{name} differs between faulted and clean runs"


def test_faulted_sweep_checkpoint_records_recovery(faulted_sweep):
    _root, _points, _seq, _disturbed, ckpt = faulted_sweep
    if pool.shared_pool() is None:            # sequential context: no
        pytest.skip("no process fan-out")      # fleet events to record
    d = json.loads(ckpt.read_text())
    events = d.get("events", [])
    assert events, "disturbed sweep must keep its recovery ledger"
    assert any(e["pool_rebuilds"] >= 1 for e in events)    # the kill
    timeouts = [t for e in events for t in e["timeouts"]]
    assert timeouts, "expired deadline must be recorded, not dropped"
