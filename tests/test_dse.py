"""Design-space explorer: deterministic space enumeration, variant
generation, sweep end-to-end (compile + verify + score) with checkpoint
resume and byte-deterministic artifacts, Pareto frontier math, the
cost-model clusters fix, and compile_many's unmapped tolerance."""
import json
import os

import pytest

from repro.core.costmodel import kernel_cost
from repro.core.kernels_lib import build_gemm
from repro.core.mapper import MapperOptions
from repro.core.toolchain import Toolchain
from repro.dse import (ArchPoint, SUITE_KERNELS, area_units, frontier,
                       frontier_table, get_space, kernel_suite, run_sweep,
                       write_artifacts)
from repro.dse.explore import KernelOutcome, VariantResult


# ------------------------------------------------------------------- space
def test_space_enumeration_is_deterministic_and_unique():
    for name in ("tiny", "small", "full"):
        pts = get_space(name)
        assert pts == get_space(name)
        names = [p.name for p in pts]
        assert len(names) == len(set(names))
    assert len(get_space("tiny")) == 4
    assert len(get_space("small")) >= 14
    # tiny is a strict subset of small: smoke BENCH rows stay comparable
    small = {p.name for p in get_space("small")}
    assert {p.name for p in get_space("tiny")} < small
    with pytest.raises(ValueError, match="unknown space"):
        get_space("bogus")


def test_arch_point_builds_validated_variants():
    p = ArchPoint(4, 4, torus=True, regfile_size=16, bank_kb=4,
                  banks_per_col=2)
    arch = p.build()
    assert arch.name == p.name == "dse-4x4-torus-rf16-b4x4k"
    assert arch.torus and arch.regfile_size == 16
    assert [b.id for b in arch.banks] == [0, 1, 2, 3]
    assert all(b.size_bytes == 4096 for b in arch.banks)
    # id 0 on the left column, id 1 on the right (kernel layout contract)
    assert all(pe % 4 == 0 for pe in arch.pes_of_bank(0))
    assert all(pe % 4 == 3 for pe in arch.pes_of_bank(1))

    lite = ArchPoint(4, 4, het="alulite").build()
    from repro.core.dfg import Op
    interior = [p_ for p_ in range(16) if p_ % 4 not in (0, 3)]
    assert all(not lite.supports(p_, Op.SELECT) for p_ in interior)
    assert all(lite.supports(p_, Op.MUL) for p_ in interior)
    assert lite.supports(0, Op.SELECT)

    with pytest.raises(ValueError, match="2 columns"):
        ArchPoint(4, 1).build()
    with pytest.raises(ValueError, match="banks_per_col"):
        ArchPoint(4, 4, banks_per_col=3).build()
    with pytest.raises(ValueError, match="het"):
        ArchPoint(4, 4, het="quantum").build()


def test_kernel_suite_is_the_ten_kernel_library():
    suite = kernel_suite(ArchPoint(4, 4).build())
    assert tuple(suite) == SUITE_KERNELS
    assert len(suite) == 10


# ------------------------------------------------------------------ pareto
def _variant(name, area, total_ms, ok=True):
    status = "ok" if ok else "map_error"
    v = VariantResult(name=name, point=ArchPoint(4, 4), n_pes=16,
                      clusters=1, area=area)
    v.kernels = {k: KernelOutcome(kernel=k, status=status,
                                  total_ms=total_ms / len(SUITE_KERNELS))
                 for k in SUITE_KERNELS}
    return v


def test_frontier_keeps_only_nondominated_variants():
    a = _variant("a", area=100, total_ms=1.0)   # fast, big
    b = _variant("b", area=50, total_ms=2.0)    # slower, smaller
    c = _variant("c", area=120, total_ms=1.5)   # dominated by a
    d = _variant("d", area=50, total_ms=3.0)    # dominated by b
    e = _variant("e", area=10, total_ms=0.5, ok=False)  # failed: excluded
    front = [r.name for r in frontier([e, d, c, b, a])]
    assert front == ["a", "b"]
    table = frontier_table([e, d, c, b, a])
    assert "dse" not in table.splitlines()[0]  # header row
    assert table.count("*") == 2


def test_area_units_is_a_deterministic_integer():
    arch = ArchPoint(4, 4).build()          # 16 PEs, rf8+li4, 2x8kB banks
    assert area_units(arch) == 16 * (4 + 8 + 4) + 16 * 8 == 384
    bigger = ArchPoint(8, 8).build()
    assert area_units(bigger) > area_units(arch)


# ------------------------------------------------------- costmodel clusters
def test_kernel_cost_divides_compute_across_clusters():
    spec = build_gemm(TI=4, TK=4, TJ=4)
    ck = Toolchain(cache_dir="").compile(spec)
    c1 = kernel_cost(spec, ck.mapping, array_bytes_moved=1000.0,
                     handshake_us=5.0)
    c4 = kernel_cost(spec, ck.mapping, array_bytes_moved=1000.0,
                     handshake_us=5.0, clusters=4)
    # 16 invocations over 4 clusters: compute shrinks exactly 4x ...
    assert c4.compute_ms == pytest.approx(c1.compute_ms / 4)
    assert c4.clusters == 4 and c1.clusters == 1
    # ... while shared-link transfer and handshake stay whole-problem
    assert c4.transfer_ms == pytest.approx(c1.transfer_ms)
    assert c4.total_ms == pytest.approx(c4.compute_ms + c4.transfer_ms)
    # ceil semantics: the slowest cluster bounds compute
    c3 = kernel_cost(spec, ck.mapping, clusters=3)
    assert c3.compute_ms == pytest.approx(
        -(-c1.invocations // 3) * c1.cycles_per_inv / 100e6 * 1e3)
    with pytest.raises(ValueError):
        kernel_cost(spec, ck.mapping, clusters=0)


# ------------------------------------------------- compile_many tolerance
def test_compile_many_allow_unmapped_yields_none(tmp_path):
    ok_spec = build_gemm(TI=4, TK=4, TJ=4)
    tc = Toolchain(options=MapperOptions(ii_max=1),  # < MII: must fail
                   cache_dir=str(tmp_path))
    from repro.core.mapper import MapError
    with pytest.raises(MapError):
        tc.compile_many([ok_spec, build_gemm(TI=4, TK=4, TJ=4, unroll=4)])
    out = tc.compile_many([ok_spec], allow_unmapped=True)
    assert out == [None]
    # mixed outcomes across heterogeneous specs in one fan-out
    tc2 = Toolchain(cache_dir=str(tmp_path))
    specs = [build_gemm(TI=4, TK=4, TJ=4),
             build_gemm(TI=4, TK=4, TJ=4, unroll=2)]
    cks = tc2.compile_many(specs, allow_unmapped=True)
    assert all(ck is not None for ck in cks)


def test_map_failures_are_memoized(tmp_path):
    """Negative results are content-addressed cache entries too: a sweep
    re-run (same spec, same options) must not re-pay the II escalation
    of an infeasible point, in-process or across Toolchain instances."""
    from repro.core.mapper import MapError
    spec = build_gemm(TI=4, TK=4, TJ=4)
    opts = MapperOptions(ii_max=1)
    tc = Toolchain(options=opts, cache_dir=str(tmp_path))
    assert tc.compile_many([spec], allow_unmapped=True) == [None]
    errs = [f for f in tmp_path.iterdir() if f.name.endswith(".err.json")]
    assert len(errs) == 1
    # a fresh Toolchain short-circuits off the disk marker...
    tc2 = Toolchain(options=opts, cache_dir=str(tmp_path))
    assert tc2.compile_many([spec], allow_unmapped=True) == [None]
    with pytest.raises(MapError, match="cached result"):
        tc2.compile(spec)
    # ...and clear_cache forgets it
    tc2.clear_cache()
    assert not any(f.name.endswith(".err.json") for f in tmp_path.iterdir())
    with pytest.raises(MapError) as ei:
        Toolchain(options=opts, cache_dir=str(tmp_path)).compile(spec)
    assert "cached result" not in str(ei.value)
    # budget-limited failures are wall-clock-dependent: never memoized
    n_markers = sum(f.name.endswith(".err.json")
                    for f in tmp_path.iterdir())
    budgeted = MapperOptions(ii_max=1, time_budget_s=120.0)
    tc3 = Toolchain(options=budgeted, cache_dir=str(tmp_path))
    assert tc3.compile_many([spec], allow_unmapped=True) == [None]
    assert sum(f.name.endswith(".err.json")
               for f in tmp_path.iterdir()) == n_markers


# ------------------------------------------------------- sweep end to end
@pytest.fixture(scope="module")
def swept(tmp_path_factory):
    """One 2-variant sweep, shared by the e2e assertions below."""
    root = tmp_path_factory.mktemp("dse")
    points = get_space("tiny")[:2]
    tc = Toolchain(options=MapperOptions(ii_max=20),
                   cache_dir=str(root / "cache"))
    logs = []
    results = run_sweep(points, toolchain=tc,
                        checkpoint=str(root / "ckpt.json"),
                        log=logs.append)
    return root, points, tc, results, logs


def test_sweep_compiles_and_verifies_all_kernels(swept):
    _root, points, _tc, results, _logs = swept
    assert [r.name for r in results] == [p.name for p in points]
    for r in results:
        assert r.ok, {k: o.status for k, o in r.kernels.items()}
        assert set(r.kernels) == set(SUITE_KERNELS)
        assert all(o.II >= o.mii >= 1 for o in r.kernels.values())
        assert r.total_ms > 0 and r.area > 0


def test_sweep_resumes_from_checkpoint_and_is_deterministic(swept):
    root, points, tc, results, _logs = swept
    out1 = root / "out1"
    write_artifacts(results, str(out1), space="test")

    # re-run with the same checkpoint: every variant is skipped ...
    logs2 = []
    results2 = run_sweep(points, toolchain=tc,
                         checkpoint=str(root / "ckpt.json"),
                         log=logs2.append)
    assert any("checkpoint: 2 variant" in s for s in logs2)
    # ... and the artifacts are byte-identical (cold == warm == resumed)
    out2 = root / "out2"
    write_artifacts(results2, str(out2), space="test")
    for name in ("dse_frontier.json", "BENCH_dse_sweep.json"):
        assert (out1 / name).read_bytes() == (out2 / name).read_bytes()

    # a partial checkpoint resumes mid-sweep: drop one variant and the
    # sweep recomputes only that one (mapping cache makes it instant)
    ck = json.loads((root / "ckpt.json").read_text())
    dropped = points[1].name
    del ck["variants"][dropped]
    (root / "ckpt.json").write_text(json.dumps(ck))
    logs3 = []
    results3 = run_sweep(points, toolchain=tc,
                         checkpoint=str(root / "ckpt.json"),
                         log=logs3.append)
    assert any("checkpoint: 1 variant" in s for s in logs3)
    assert [r.to_json_dict() for r in results3] == \
        [r.to_json_dict() for r in results]

    # a stale/corrupt checkpoint is ignored, not fatal
    (root / "ckpt.json").write_text("{ not json")
    results4 = run_sweep(points, toolchain=tc,
                         checkpoint=str(root / "ckpt.json"))
    assert [r.to_json_dict() for r in results4] == \
        [r.to_json_dict() for r in results]

    # a --no-verify checkpoint must not satisfy a verifying sweep: the
    # fingerprint includes the verify flag, so nothing is skipped
    run_sweep(points[:1], toolchain=tc,
              checkpoint=str(root / "ckpt2.json"), verify=False)
    logs5 = []
    run_sweep(points[:1], toolchain=tc,
              checkpoint=str(root / "ckpt2.json"), log=logs5.append)
    assert not any("checkpoint" in s for s in logs5)


def test_bench_rows_cover_verified_variants(swept):
    _root, points, _tc, results, _logs = swept
    from repro.dse import sweep_bench_rows
    rows = sweep_bench_rows(results)
    assert [r["name"] for r in rows] == [p.name for p in points]
    for row in rows:
        assert row["us"] > 0
        assert row["derived"]["mapped"] == len(SUITE_KERNELS)
        assert row["derived"]["pareto"] in (0, 1)
