"""Front-end DSL: tracer semantics, the canonical-form contract pinning
traced Table-I kernels to their hand-built counterparts, and the four
DSL-only kernels (compile + bit-exact verify on cluster_4x4)."""
import numpy as np
import pytest

from handbuilt_kernels import table1_kernels_handbuilt
from repro.core.adl import cluster_4x4
from repro.core.dfg import Op
from repro.core.kernels_lib import table1_kernels
from repro.core.layout import ArrayDecl, assign_layout
from repro.core.mapper import MapperOptions
from repro.core.toolchain import Toolchain, spec_cache_key
from repro.frontend import (KernelContext, KernelProgram, TraceError,
                            build_avgpool2x2, build_dwconv,
                            build_gemm_bias_relu, build_requant_int8,
                            dsl_kernels, trace, unroll)

LEGACY = ["GEMM", "GEMM-U", "GEMM-U-C", "CONV", "CONV-U-C-1", "CONV-U-C-2"]


# ------------------------------------------------- canonical-form contract
@pytest.mark.parametrize("small", [True, False], ids=["small", "full"])
def test_traced_legacy_kernels_match_handbuilt_cache_keys(small):
    """The front-end contract: every legacy Table-I kernel traced through
    the DSL content-addresses identically to its hand-built counterpart,
    so the mapping cache and verify oracles see no churn from the
    front-end redesign."""
    opts = MapperOptions()
    traced = table1_kernels(small=small)
    hand = table1_kernels_handbuilt(small=small)
    for name in LEGACY:
        assert spec_cache_key(traced[name], opts) == \
            spec_cache_key(hand[name], opts), name


def test_traced_legacy_kernels_match_handbuilt_canonical_form():
    traced = table1_kernels(small=True)
    hand = table1_kernels_handbuilt(small=True)
    for name in LEGACY:
        assert traced[name].dfg.canonical_dict() == \
            hand[name].dfg.canonical_dict(), name
        # and the raw serialized forms differ at most in cosmetic names
        a, b = traced[name].dfg.to_json_dict(), hand[name].dfg.to_json_dict()
        for na, nb in zip(a["nodes"], b["nodes"]):
            na.pop("name"), nb.pop("name")
        assert a == b, name


def test_canonical_dict_strips_names_and_compacts_ids():
    def body(ctx):
        X, = ctx.arrays("X")
        n = ctx.counter(stop=3, name="fancy-name")
        X[n] = n * 2

    arch = cluster_4x4()
    layout = assign_layout(arch, [ArrayDecl("X", 4)])
    dfg = trace(body, name="t", layout=layout)
    c = dfg.canonical_dict()
    assert [n["id"] for n in c["nodes"]] == list(range(len(c["nodes"])))
    assert all("name" not in n for n in c["nodes"])
    # names do not perturb the canonical form...
    dfg.nodes[1].name = "renamed"
    assert dfg.canonical_dict() == c
    # ...but structure does
    dfg.nodes[1].imm = 99
    assert dfg.canonical_dict() != c


# ------------------------------------------------------- tracer semantics
@pytest.fixture()
def ctx():
    arch = cluster_4x4()
    layout = assign_layout(arch, [ArrayDecl("A", 16, bank_pref=0),
                                  ArrayDecl("B", 16, bank_pref=1)])
    return KernelContext("t", layout)


def test_int_arithmetic_stays_compile_time(ctx):
    A, = ctx.arrays("A")
    v = A[2 * 3 + 1]          # pure-int index: one CONST + one LOAD
    dfg = ctx._b.dfg
    assert [n.op for n in dfg.nodes.values()] == [Op.CONST, Op.LOAD]
    assert dfg.nodes[0].imm == 7


def test_zero_add_and_unit_mul_fold_away(ctx):
    n = ctx.counter(stop=3)
    before = len(ctx._b.dfg)
    assert (n + 0) is n
    assert (0 + n) is n
    assert (n - 0) is n
    assert (n * 1) is n
    assert (1 * n) is n
    assert len(ctx._b.dfg) == before


def test_consts_and_liveins_are_cse_cached(ctx):
    a, b = ctx.const(5), ctx.const(5)
    assert a.id == b.id
    i1, i2 = ctx.livein("i"), ctx.livein("i")
    assert i1.id == i2.id


def test_array_base_offset_folds_once(ctx):
    B, = ctx.arrays("B")        # bank1, base 0
    i = ctx.livein("i")
    assert B.addr(i) is i       # zero base: no add node
    # nonzero base folds exactly one add
    layout = assign_layout(cluster_4x4(), [ArrayDecl("X", 4, bank_pref=0),
                                           ArrayDecl("Y", 4, bank_pref=0)])
    c2 = KernelContext("t2", layout)
    Y, = c2.arrays("Y")
    j = c2.livein("j")
    a = Y.addr(j)
    assert c2._b.dfg.nodes[a.id].op == Op.ADD
    assert Y.addr(0).id == c2._b.const(4)   # int index -> folded CONST


def test_counter_semantics_via_reference_execution():
    arch = cluster_4x4()
    layout = assign_layout(arch, [ArrayDecl("X", 8, bank_pref=0)])

    def body(ctx):
        X, = ctx.arrays("X")
        n = ctx.counter(stop=7)
        X[n] = n

    dfg = trace(body, name="iota", layout=layout)
    mem = dfg.reference_execute(8, {"bank0": [0] * 4096, "bank1": [0] * 4096},
                                {})
    assert mem["bank0"][:8] == list(range(8))


def test_coalesce_two_level_reference_execution():
    arch = cluster_4x4()
    layout = assign_layout(arch, [ArrayDecl("X", 12, bank_pref=0)])

    def body(ctx):
        X, = ctx.arrays("X")
        ctx.const(1), ctx.const(0)
        j, jwrap = ctx.wrapping_counter(1, 4, init=-1)
        i = ctx.gated_counter(1, jwrap)
        X[i * 4 + j] = i * 10 + j

    dfg = trace(body, name="co2", layout=layout)
    mem = dfg.reference_execute(12, {"bank0": [0] * 4096,
                                     "bank1": [0] * 4096}, {})
    assert mem["bank0"][:12] == [10 * i + j for i in range(3)
                                 for j in range(4)]


def test_coalesce_three_level_matches_gemm_induction():
    arch = cluster_4x4()
    layout = assign_layout(arch, [ArrayDecl("X", 24, bank_pref=0)])

    def body(ctx):
        X, = ctx.arrays("X")
        i, j, k = ctx.coalesce(2, 3, (4, 2))    # k steps by 2
        X[(i * 3 + j) * 2 + (k >> 1)] = (i * 100 + j * 10) + k

    dfg = trace(body, name="co3", layout=layout)
    iters = 2 * 3 * 2
    mem = dfg.reference_execute(iters, {"bank0": [0] * 4096,
                                        "bank1": [0] * 4096}, {})
    want = [i * 100 + j * 10 + k for i in range(2) for j in range(3)
            for k in (0, 2)]
    assert mem["bank0"][:12] == want


def test_clamp_and_relu_semantics():
    arch = cluster_4x4()
    layout = assign_layout(arch, [ArrayDecl("Y", 8, bank_pref=0),
                                  ArrayDecl("X", 8, bank_pref=1)])

    def body(ctx):
        X, Y = ctx.arrays("X", "Y")
        n = ctx.counter(stop=7)
        Y[n] = ctx.clamp(ctx.relu(X[n]) - 5, -3, 40)

    dfg = trace(body, name="cl", layout=layout)
    xs = [-100, -1, 0, 1, 5, 44, 46, 120]
    banks = {"bank0": [0] * 4096, "bank1": [0] * 4096}
    banks["bank1"][:8] = xs
    mem = dfg.reference_execute(8, banks, {})
    assert mem["bank0"][:8] == [min(max(max(x, 0) - 5, -3), 40) for x in xs]


def test_trace_errors():
    arch = cluster_4x4()
    layout = assign_layout(arch, [ArrayDecl("X", 4, bank_pref=0)])
    ctx = KernelContext("e", layout)
    X, = ctx.arrays("X")
    n = ctx.counter(stop=3)
    with pytest.raises(TraceError):
        bool(n)                       # no compile-time truth value
    with pytest.raises(TraceError):
        n + 1.5                       # floats are not datapath values
    with pytest.raises(TraceError):
        ctx.arrays("MISSING")         # not in the layout
    with pytest.raises(TraceError):
        other = KernelContext("o", layout)
        other.emit(Op.ADD, n, 1)      # cross-context value
    with pytest.raises(TraceError):
        unroll(0)


def test_unroll_is_compile_time_range():
    assert list(unroll(3)) == [0, 1, 2]


# ------------------------------------------------------ DSL-only kernels
@pytest.fixture(scope="module")
def tc():
    return Toolchain(cache_dir="")


@pytest.mark.parametrize("build", [build_dwconv, build_avgpool2x2,
                                   build_gemm_bias_relu, build_requant_int8],
                         ids=["dwconv", "avgpool2x2", "gemm-bias-relu",
                              "requant-int8"])
def test_dsl_kernel_compiles_and_verifies_bit_exactly(tc, build):
    """Acceptance: the four DSL-only kernels map onto cluster_4x4 and the
    pipelined simulation reproduces their numpy goldens word-for-word."""
    spec = build(arch=cluster_4x4())
    ck = tc.compile(spec)
    assert ck.II >= ck.mii >= 1
    ck.verify()


def test_dsl_kernel_artifacts_roundtrip(tc):
    from repro.core.toolchain import CompiledKernel
    ck = tc.compile(build_avgpool2x2())
    ck2 = CompiledKernel.from_json(ck.to_json())
    ck2.verify()                      # closure-free oracle, bit-exact


def test_requantize_shares_qgemm_oracle():
    """The CGRA requant kernel and the Pallas int8 datapath share one
    reference: clamp((x * mult) >> shift) over the int range."""
    from repro.kernels.qgemm_int8.ref import requantize_ref
    spec = build_requant_int8(N=48, mult=3, shift=5)
    rng = np.random.default_rng(7)
    banks = spec.init_banks(rng)
    golden = spec.golden(banks)
    px = spec.layout.placements["X"]
    pr = spec.layout.placements["R"]
    x = banks[px.bank_array][px.base:px.base + px.words].astype(np.int64)
    np.testing.assert_array_equal(
        golden[pr.bank_array][pr.base:pr.base + pr.words],
        requantize_ref(x, 3, 5))
    assert np.all(np.abs(golden[pr.bank_array][pr.base:pr.base + 48]) <= 127)


def test_kernel_program_binds_through_toolchain(tc):
    prog = KernelProgram("avgpool2x2",
                         lambda arch=None: build_avgpool2x2(arch=arch))
    ck = tc.compile(prog)             # Toolchain accepts traced programs
    assert ck.name == "avgpool2x2"
    ck.verify()
    reports = dsl_kernels()
    assert set(reports) == {"dwconv", "avgpool2x2", "gemm-bias-relu",
                            "requant-int8"}


def test_analyze_kernel_accepts_programs(tc):
    from repro.core.offload import analyze_kernel
    from repro.frontend import DSL_PROGRAMS
    rep = analyze_kernel(DSL_PROGRAMS[1], toolchain=tc)
    assert rep.site == "avgpool2x2" and rep.II >= rep.mii >= 1
    assert rep.est_tile_us > 0
