"""Instruction-stream backend (repro.isa): mnemonic-table exhaustiveness,
byte-deterministic export, the pinned GEMM golden stream, stream-parser
error reporting, interpreter ≡ simulate() bit-identity over the whole
ten-kernel library × seeds, the MORPHER_XVAL verify hook, and the
canonical SimConfig.to_json / warm-cache round-trip contract."""
import json
import os

import numpy as np
import pytest

from repro.core.config_gen import (KIND_BY_MNEMONIC, KIND_MNEMONIC, MNEMONIC,
                                   OPC, OPC_BY_MNEMONIC, OPC_PASS, SimConfig,
                                   opcode_of)
from repro.core.dfg import Op
from repro.core.kernels_lib import build_gemm, table1_kernels
from repro.core.toolchain import ARTIFACT_VERSION, Toolchain
from repro.core.verify import xval_enabled
from repro.frontend.library import dsl_kernels
from repro.isa import (ASM_NAME, CSV_NAME, MANIFEST_NAME, STREAM_FORMAT,
                       StreamError, cross_validate, cross_validate_dir,
                       encode_kernel, export_streams, interpret, load_stream,
                       parse_stream, stream_for)

GOLDEN_CSV = os.path.join(os.path.dirname(__file__),
                          "golden_gemm_small_instructions.csv")


@pytest.fixture(scope="module")
def compiled_all():
    tc = Toolchain(cache_dir="")
    specs = {**table1_kernels(small=True), **dsl_kernels()}
    return dict(zip(specs, tc.compile_many(list(specs.values()))))


@pytest.fixture(scope="module")
def gemm_ck(compiled_all):
    return compiled_all["GEMM"]


# ------------------------------------------------------- mnemonic tables
def test_every_op_has_an_opcode_encoding():
    """Exhaustiveness: no Op enum member may silently lack an encoding —
    adding an op to the DFG layer without teaching the simulator/exporter
    must fail loudly, not produce a stream with holes."""
    for op in Op:
        code = opcode_of(op)
        assert isinstance(code, int)
        assert MNEMONIC[code] != "nop"
    # CONST / LIVEIN lower to the pass opcode (operand routing does the work)
    assert opcode_of(Op.CONST) == OPC_PASS
    assert opcode_of(Op.LIVEIN) == OPC_PASS


def test_mnemonic_tables_are_bijective():
    assert len(MNEMONIC) == len(OPC)
    for code, m in MNEMONIC.items():
        assert OPC_BY_MNEMONIC[m] == code
    assert len(KIND_BY_MNEMONIC) == len(KIND_MNEMONIC)
    for kind, m in KIND_MNEMONIC.items():
        assert KIND_BY_MNEMONIC[m] == kind
    # mnemonics must survive the CSV select grammar: lowercase, no commas
    for m in list(MNEMONIC.values()) + list(KIND_MNEMONIC.values()):
        assert m == m.lower() and "," not in m and m


# ------------------------------------------------- byte-determinism + golden
def test_export_is_byte_deterministic(gemm_ck, tmp_path):
    a = encode_kernel(gemm_ck)
    b = encode_kernel(gemm_ck)
    assert a == b
    d1, d2 = tmp_path / "one", tmp_path / "two"
    p1 = export_streams(gemm_ck, str(d1))
    p2 = export_streams(gemm_ck, str(d2))
    assert sorted(p1) == sorted(p2) == sorted(
        (CSV_NAME, ASM_NAME, MANIFEST_NAME))
    for fn in p1:
        with open(p1[fn], "rb") as f1, open(p2[fn], "rb") as f2:
            assert f1.read() == f2.read(), fn


def test_csv_shape_contract(gemm_ck):
    """Sorted columns, trailing newline, one record per (slot, pe) in
    (slot, pe) order — the byte-determinism contract's moving parts."""
    csv_text = encode_kernel(gemm_ck)[CSV_NAME]
    assert csv_text.endswith("\n") and not csv_text.endswith("\n\n")
    lines = csv_text.split("\n")[:-1]
    header = lines[0].split(",")
    assert header == sorted(header)
    cfg = gemm_ck.cfg
    assert len(lines) - 1 == cfg.II * cfg.P
    col = {c: i for i, c in enumerate(header)}
    keys = [(int(ln.split(",")[col["slot"]]), int(ln.split(",")[col["pe"]]))
            for ln in lines[1:]]
    assert keys == sorted(keys)


def test_manifest_is_self_describing(gemm_ck):
    man = json.loads(encode_kernel(gemm_ck)[MANIFEST_NAME])
    assert man["artifact_version"] == ARTIFACT_VERSION
    assert man["stream_format"] == STREAM_FORMAT
    assert man["kernel"] == gemm_ck.name
    cfg = gemm_ck.cfg
    assert (man["II"], man["P"], man["RF"], man["LI"]) == (
        cfg.II, cfg.P, cfg.RF, cfg.LI)
    assert man["bits"] == cfg.bits and man["depth"] == cfg.depth
    assert man["columns"] == encode_kernel(gemm_ck)[CSV_NAME].split("\n")[0] \
        .split(",")
    assert {int(k): v for k, v in man["bank_offsets"].items()} == \
        dict(cfg.bank_offsets)
    assert len(man["neighbors"]) == cfg.P
    # canonical json: sorted keys, compact separators, trailing newline
    text = encode_kernel(gemm_ck)[MANIFEST_NAME]
    assert text == json.dumps(man, sort_keys=True,
                              separators=(",", ":")) + "\n"


def test_golden_gemm_small_stream_is_pinned(gemm_ck):
    """The committed GEMM-small stream is the cross-machine determinism
    witness: a mapper/encoder change that alters the artifact must show up
    as a reviewed golden-file diff."""
    with open(GOLDEN_CSV, encoding="utf-8") as f:
        golden = f.read()
    assert encode_kernel(gemm_ck)[CSV_NAME] == golden


# ------------------------------------------------------------ stream parser
def test_parse_rejects_malformed_streams(gemm_ck):
    art = encode_kernel(gemm_ck)
    man = json.loads(art[MANIFEST_NAME])
    csv_text = art[CSV_NAME]
    with pytest.raises(StreamError, match="stream_format"):
        parse_stream(csv_text, {**man, "stream_format": STREAM_FORMAT + 1})
    with pytest.raises(StreamError, match="header"):
        parse_stream(csv_text, {**man, "columns": man["columns"][::-1]})
    lines = csv_text.split("\n")
    with pytest.raises(StreamError, match="records"):
        parse_stream("\n".join(lines[:-2]) + "\n", man)
    dup = "\n".join(lines[:-2] + [lines[1], ""])
    with pytest.raises(StreamError, match="duplicate"):
        parse_stream(dup, man)


def test_tampered_stream_fails_cross_validation(gemm_ck):
    """The oracle has teeth: push every store's validity window past the
    end of time and the interpreter's final memory no longer matches."""
    art = encode_kernel(gemm_ck)
    man = json.loads(art[MANIFEST_NAME])
    lines = art[CSV_NAME].split("\n")
    col = {c: i for i, c in enumerate(lines[0].split(","))}
    out = [lines[0]]
    for ln in lines[1:-1]:
        v = ln.split(",")
        if v[col["opcode"]] == "store":
            v[col["tstart"]] = "1000000"
        out.append(",".join(v))
    stream = parse_stream("\n".join(out) + "\n", man)
    with pytest.raises(AssertionError, match="diverges"):
        cross_validate(gemm_ck, seeds=(0,), stream=stream)


# ----------------------------------------------- interpreter ≡ simulate()
def test_all_library_kernels_bit_identical(compiled_all):
    """The acceptance criterion: every library kernel (six Table-I small +
    four DSL), two seeds each, interpreter final memory bit-identical to
    the cycle-accurate simulator."""
    for name, ck in compiled_all.items():
        assert cross_validate(ck, seeds=(0, 1)) == 2, name


def test_roundtrip_through_disk(gemm_ck, tmp_path):
    export_streams(gemm_ck, str(tmp_path))
    assert cross_validate_dir(gemm_ck, str(tmp_path), seeds=(0,)) == 1
    # and the parsed-from-disk stream equals the in-memory one
    a, b = load_stream(str(tmp_path)), stream_for(gemm_ck)
    assert a == b


def test_interpret_does_not_mutate_inputs(gemm_ck):
    init = gemm_ck.random_banks(seed=7)
    keep = {k: v.copy() for k, v in init.items()}
    out = interpret(stream_for(gemm_ck), init, gemm_ck.invocations,
                    gemm_ck.mapped_iters)
    for k in init:
        np.testing.assert_array_equal(init[k], keep[k])
    assert sorted(out) == sorted(init)


# --------------------------------------------------- verify hook + toolchain
def test_morpher_xval_verify_hook(gemm_ck, monkeypatch):
    monkeypatch.delenv("MORPHER_XVAL", raising=False)
    assert not xval_enabled()
    monkeypatch.setenv("MORPHER_XVAL", "0")
    assert not xval_enabled()
    monkeypatch.setenv("MORPHER_XVAL", "1")
    assert xval_enabled()
    gemm_ck.verify(seed=0)              # simulator + interpreter oracles
    gemm_ck.verify_batch(seeds=(0, 1))


def test_toolchain_level_wrappers(tmp_path):
    tc = Toolchain(cache_dir=str(tmp_path / "cache"))
    spec = build_gemm(TI=4, TK=4, TJ=4, unroll=1)
    paths = tc.export_streams(spec, str(tmp_path / "streams"))
    assert all(os.path.exists(p) for p in paths.values())
    ck = tc.cross_validate(spec, seeds=(0, 1))
    assert ck.name == spec.name


# ------------------------------------------- canonical SimConfig.to_json
def test_simconfig_to_json_is_canonical(gemm_ck):
    text = gemm_ck.cfg.to_json()
    d = json.loads(text)
    assert text == json.dumps(d, sort_keys=True, separators=(",", ":"))
    cfg2 = SimConfig.from_json(text)
    assert cfg2.to_json() == text       # fixed point


def test_warm_cache_reload_roundtrips(tmp_path):
    """ARTIFACT_VERSION v3 contract: a warm-cache reload reproduces the
    configuration byte-for-byte and still verifies/cross-validates."""
    spec = build_gemm(TI=4, TK=4, TJ=4, unroll=1)
    tc = Toolchain(cache_dir=str(tmp_path))
    cold = tc.compile(spec)
    assert not cold.from_cache
    warm = Toolchain(cache_dir=str(tmp_path)).compile(spec)
    assert warm.from_cache
    assert warm.cfg.to_json() == cold.cfg.to_json()
    assert encode_kernel(warm) == encode_kernel(cold)
    cross_validate(warm, seeds=(0,))
