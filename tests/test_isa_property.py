"""Property test: export -> parse -> interpret ≡ simulate() over
randomized traced kernels, not just the fixed library.

Each example draws a small front-end program (a load, an optional live-in,
a random arithmetic chain, a store), compiles it, and asserts the
standalone instruction-stream interpreter reproduces the simulator's
final memory bit-for-bit on two seeds.  Unmappable draws (ii_max
exceeded) are discarded with ``assume``, not failed."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install -e '.[test]')")
from hypothesis import assume, given, settings, strategies as st

from repro.core.adl import cluster_4x4
from repro.core.kernels_lib import KernelSpec, _bank_arrays
from repro.core.layout import ArrayDecl, assign_layout
from repro.core.mapper import MapError, MapperOptions
from repro.core.toolchain import Toolchain
from repro.frontend.tracer import trace
from repro.isa.xval import cross_validate

N = 12          # words per array — small enough to map, big enough to index

# (label, ctx-aware transform) — the op pool the chain draws from
_STEPS = {
    "add": lambda ctx, v, c: v + c,
    "sub": lambda ctx, v, c: v - c,
    "mul": lambda ctx, v, c: v * ((c % 5) - 2),
    "and": lambda ctx, v, c: v & (c & 0xF),
    "or": lambda ctx, v, c: v | (c & 0x7),
    "xor": lambda ctx, v, c: v ^ (c & 0xF),
    "shr": lambda ctx, v, c: v >> (c % 3),
    "shl": lambda ctx, v, c: v << (c % 2),
    "relu": lambda ctx, v, c: ctx.relu(v),
    "clamp": lambda ctx, v, c: ctx.clamp(v, -(c % 16) - 1, (c % 16) + 1),
}


@st.composite
def kernel_draw(draw):
    iters = draw(st.integers(2, 6))
    chain = draw(st.lists(
        st.tuples(st.sampled_from(sorted(_STEPS)), st.integers(-20, 20)),
        min_size=1, max_size=4))
    use_livein = draw(st.booleans())
    bases = draw(st.lists(st.integers(-30, 30), min_size=1, max_size=2))
    return iters, chain, use_livein, bases


def _build_spec(iters, chain, use_livein, bases) -> KernelSpec:
    arch = cluster_4x4()
    layout = assign_layout(arch, [ArrayDecl("A", N, bank_pref=0),
                                  ArrayDecl("B", N, bank_pref=1)])

    def body(ctx):
        A, B = ctx.arrays("A", "B")
        j = ctx.counter(stop=iters - 1, name="j")
        v = A[j]
        if use_livein:
            v = v + ctx.livein("base")
        for kind, c in chain:
            v = _STEPS[kind](ctx, v, c)
        B[j] = v

    dfg = trace(body, name="hyp-isa", layout=layout)

    def init(rng: np.random.Generator):
        banks = _bank_arrays(layout)
        pa = layout.placements["A"]
        banks[pa.bank_array][pa.base:pa.base + pa.words] = \
            rng.integers(-32, 32, size=N)
        return banks

    return KernelSpec(
        name=dfg.name, dfg=dfg, arch=arch, layout=layout,
        mapped_iters=iters,
        invocations=[{"base": b} for b in (bases if use_livein else [0])],
        golden=lambda banks: banks,        # unused: xval has its own oracles
        init_banks=init)


@settings(max_examples=20, deadline=None)
@given(kernel_draw())
def test_random_traced_kernels_interpret_bit_identically(params):
    spec = _build_spec(*params)
    tc = Toolchain(options=MapperOptions(ii_max=12), cache_dir="")
    try:
        ck = tc.compile(spec)
    except MapError:
        assume(False)
        return
    assert cross_validate(ck, seeds=(0, 1)) == 2
