"""Per-kernel interpret-mode allclose sweeps against the ref.py oracles,
plus hypothesis property tests on the GEMM wrapper."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (pip install -e '.[test]')")
from hypothesis import given, settings, strategies as st

from repro.kernels.gemm_os.ops import gemm_os
from repro.kernels.gemm_os.ref import gemm_ref
from repro.kernels.conv2d_os.ops import conv2d_os
from repro.kernels.conv2d_os.ref import conv2d_ref
from repro.kernels.qgemm_int8.ops import qgemm_int8
from repro.kernels.qgemm_int8.ref import qgemm_ref, quantize_rowwise
from repro.kernels.decode_attn.ops import decode_attn
from repro.kernels.decode_attn.ref import decode_attn_ref
from repro.kernels.wkv6.ops import wkv6
from repro.kernels.wkv6.ref import wkv6_ref

RNG = np.random.default_rng(0)


def _rand(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


@pytest.mark.parametrize("M,K,N", [(128, 128, 128), (256, 384, 128),
                                   (64, 200, 96), (8, 128, 257)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("coalesce", [False, True])
def test_gemm_os_shapes(M, K, N, dtype, coalesce):
    a, b = _rand((M, K), dtype), _rand((K, N), dtype)
    got = gemm_os(a, b, interpret=True, coalesce_grid=coalesce)
    want = gemm_ref(a, b)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 8)


@pytest.mark.parametrize("act", [None, "relu", "gelu", "silu"])
def test_gemm_os_fused_epilogue(act):
    a, b = _rand((64, 128)), _rand((128, 64))
    bias = _rand((64,))
    got = gemm_os(a, b, bias, activation=act, interpret=True)
    want = gemm_ref(a, b, bias, act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 100), st.integers(1, 100), st.integers(1, 100))
def test_gemm_os_property_any_shape(M, K, N):
    a = jnp.asarray(np.arange(M * K).reshape(M, K) % 7, jnp.float32)
    b = jnp.asarray(np.arange(K * N).reshape(K, N) % 5, jnp.float32)
    got = gemm_os(a, b, interpret=True, bm=32, bn=32, bk=32)
    want = gemm_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("N,H,W,Cin,Cout,K", [(1, 12, 12, 8, 16, 3),
                                              (2, 9, 9, 4, 32, 3),
                                              (1, 8, 8, 8, 8, 1)])
def test_conv2d_os(N, H, W, Cin, Cout, K):
    x = _rand((N, H, W, Cin))
    w = _rand((K, K, Cin, Cout), scale=0.5)
    got = conv2d_os(x, w, interpret=True)
    want = conv2d_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("M,K,N", [(64, 128, 64), (100, 96, 56)])
def test_qgemm_int8(M, K, N):
    af, bf = _rand((M, K)), _rand((K, N))
    a, sa = quantize_rowwise(af)
    bq, sb = quantize_rowwise(bf.T)
    got = qgemm_int8(a, bq.T, sa, sb, interpret=True)
    want = qgemm_ref(a, bq.T, sa, sb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_qgemm_int8_exact_vs_int_math():
    # int path must be bit-exact before scaling
    a = jnp.asarray(RNG.integers(-127, 127, (32, 64)), jnp.int8)
    b = jnp.asarray(RNG.integers(-127, 127, (64, 48)), jnp.int8)
    ones = jnp.ones((32,), jnp.float32)
    got = qgemm_int8(a, b, ones, jnp.ones((48,), jnp.float32),
                     interpret=True)
    want = a.astype(jnp.int32) @ b.astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(got, np.int64),
                                  np.asarray(want, np.int64))


@pytest.mark.parametrize("B,H,Hkv,S,D,bs", [(2, 8, 2, 256, 64, 64),
                                            (1, 4, 4, 128, 32, 128),
                                            (3, 6, 1, 192, 64, 64)])
def test_decode_attn(B, H, Hkv, S, D, bs):
    q = _rand((B, H, D))
    k = _rand((B, Hkv, S, D))
    v = _rand((B, Hkv, S, D))
    lens = jnp.asarray(RNG.integers(1, S + 1, (B,)), jnp.int32)
    got = decode_attn(q, k, v, lens, bs=bs, interpret=True)
    want = decode_attn_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("B,T,H,D,ct", [(2, 32, 3, 16, 8), (1, 16, 2, 8, 16),
                                        (2, 24, 1, 32, 4)])
def test_wkv6(B, T, H, D, ct):
    r = _rand((B, T, H, D), scale=0.5)
    k = _rand((B, T, H, D), scale=0.5)
    v = _rand((B, T, H, D))
    w = jnp.asarray(RNG.uniform(0.5, 0.99, (B, T, H, D)), jnp.float32)
    u = _rand((H, D), scale=0.3)
    got, gs = wkv6(r, k, v, w, u, ct=ct, interpret=True)
    want, ws = wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ws),
                               rtol=1e-5, atol=1e-5)


def test_wkv6_state_chaining():
    # running two halves with carried state == running whole
    B, T, H, D = 1, 16, 2, 8
    r, k, v = (_rand((B, T, H, D), scale=0.5) for _ in range(3))
    w = jnp.asarray(RNG.uniform(0.6, 0.99, (B, T, H, D)), jnp.float32)
    u = _rand((H, D), scale=0.3)
    full, _ = wkv6(r, k, v, w, u, ct=4, interpret=True)
    h1, s1 = wkv6(r[:, :8], k[:, :8], v[:, :8], w[:, :8], u, ct=4,
                  interpret=True)
    h2, _ = wkv6(r[:, 8:], k[:, 8:], v[:, 8:], w[:, 8:], u, state0=s1,
                 ct=4, interpret=True)
    np.testing.assert_allclose(np.asarray(full[:, 8:]), np.asarray(h2),
                               rtol=1e-5, atol=1e-5)
