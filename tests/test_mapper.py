"""Mapper: MII math, mapping feasibility, schedule/resource invariants
(property-checked over the produced mapping), and the portfolio-search
determinism contract.  Mappings are produced through the Toolchain compile
API (disk cache disabled for hermeticity)."""
import json

import pytest

from repro.core.adl import cluster_4x4
from repro.core.dfg import latency
from repro.core.kernels_lib import build_conv, build_gemm
from repro.core.mapper import (Mapping, MapperOptions, compute_mii,
                               _bank_of_nodes, map_kernel_opts, rec_mii)
from repro.core.toolchain import Toolchain


@pytest.fixture(scope="module")
def gemm_mapping():
    spec = build_gemm(TI=6, TK=8, TJ=6, unroll=1)
    ck = Toolchain(cache_dir="").compile(spec)
    return spec, ck.mapping


def test_mii_gemm_matches_paper():
    spec = build_gemm()  # full dims; same DFG structure
    bank_of = _bank_of_nodes(spec.dfg, spec.layout)
    mii, parts = compute_mii(spec.dfg, spec.arch, bank_of)
    # output-stationary accumulate-through-memory recurrence:
    # load(2) + add(1) + store(1) = 4 — the paper's MII for base GEMM
    assert parts["rec_mii"] == 4
    assert mii == 4


def test_gemm_maps_at_paper_ii(gemm_mapping):
    _spec, m = gemm_mapping
    assert m.II == 4, f"paper maps base GEMM at II=4, got {m.II}"


def test_schedule_respects_dependences(gemm_mapping):
    spec, m = gemm_mapping
    II = m.II
    for src, dst, _slot, opnd in spec.dfg.data_edges():
        spe, st = m.place[src]
        dpe, dt = m.place[dst]
        assert dt + II * opnd.dist >= st + latency(spec.dfg.nodes[src].op), \
            f"edge {src}->{dst} violates timing"
    for md in spec.dfg.mem_deps:
        _, st = m.place[md.src]
        _, dt = m.place[md.dst]
        assert dt + II * md.dist >= st + latency(spec.dfg.nodes[md.src].op)


def test_routes_cover_every_edge(gemm_mapping):
    spec, m = gemm_mapping
    for src, dst, slot, opnd in spec.dfg.data_edges():
        r = m.routes[(src, dst, slot)]
        spe, st = m.place[src]
        dpe, dt = m.place[dst]
        assert r.steps[0][1] == spe
        assert r.steps[-1][1] == dpe
        assert r.steps[-1][2] == dt + m.II * opnd.dist


def test_no_resource_overuse(gemm_mapping):
    spec, m = gemm_mapping
    for key, insts in m.usage.map.items():
        assert len(insts) <= m.usage.cap(key), f"overuse at {key}"


def test_fu_exclusive(gemm_mapping):
    spec, m = gemm_mapping
    seen = {}
    for v, (pe, t) in m.place.items():
        cell = (pe, t % m.II)
        assert cell not in seen, f"FU slot collision {cell}"
        seen[cell] = v


def test_mem_nodes_on_bank_pes(gemm_mapping):
    spec, m = gemm_mapping
    for v, (pe, _t) in m.place.items():
        n = spec.dfg.nodes[v]
        if n.is_mem:
            assert pe in spec.arch.pes_of_bank(m.bank_of[v])


def test_utilization_definition(gemm_mapping):
    spec, m = gemm_mapping
    assert m.utilization == pytest.approx(
        spec.dfg.n_nodes / (16 * m.II))


def test_conv_maps():
    spec = build_conv(OH=5, OW=5, K=3, variant="base")
    ck = Toolchain(cache_dir="").compile(spec)
    assert ck.II == 4  # paper: CONV II=4 (MII 4)


# ------------------------------------------------- portfolio determinism
@pytest.mark.parametrize("regfile", [4, 8])
def test_portfolio_search_is_bit_identical_to_sequential(regfile):
    """The portfolio (II, seed) race selects the lowest II, ties broken by
    the earliest seed in MapperOptions.seeds order — i.e. exactly the
    mapping the sequential search produces, byte for byte.  unroll=2 is a
    case where the first seeds fail, so the raced workers actually decide
    the outcome when process fan-out is available."""
    from repro.core.pool import shared_pool
    if shared_pool() is None:
        pytest.skip("process fan-out unavailable: portfolio would fall "
                    "back to the sequential path and the comparison "
                    "would be vacuous")
    spec = build_gemm(TI=6, TK=8, TJ=6, unroll=2,
                      arch=cluster_4x4(regfile=regfile))
    opts = MapperOptions(ii_max=24)
    seq = map_kernel_opts(spec.dfg, spec.arch, spec.layout, opts,
                          portfolio=False)
    par = map_kernel_opts(spec.dfg, spec.arch, spec.layout, opts,
                          portfolio=True)
    assert json.dumps(seq.to_json_dict()) == json.dumps(par.to_json_dict())
