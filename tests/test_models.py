"""Per-arch smoke tests (reduced configs): one forward/train step on CPU
asserting output shapes + no NaNs, plus decode-vs-full-forward consistency
for each family (the KV-cache / recurrent-state correctness check)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, SHAPES, get_config, runnable
from repro.models.zoo import build_model

RNG = jax.random.PRNGKey(0)


def _inputs(cfg, B, T):
    if cfg.input_mode == "tokens":
        return jax.random.randint(RNG, (B, T), 0, cfg.vocab)
    return jax.random.normal(RNG, (B, T, cfg.d_model), jnp.float32)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(RNG)
    B, T = 2, 16
    logits, aux = model.train_logits(params, _inputs(cfg, B, T))
    assert logits.shape == (B, T, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ["llama3.2-1b", "deepseek-v3-671b",
                                  "rwkv6-1.6b", "zamba2-1.2b"])
def test_decode_matches_full_forward(arch):
    """prefill T0 tokens then decode one-by-one == full causal forward.

    MoE archs use a no-drop capacity factor here: capacity-based routing
    drops tokens under contention in the batched pass but never in
    single-token decode, so exact consistency only holds drop-free (a real
    property of capacity MoE, documented in DESIGN.md)."""
    import dataclasses
    cfg = get_config(arch).reduced()
    if cfg.moe:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(RNG)
    B, T = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)

    full_logits, _ = model.train_logits(params, toks)

    T0 = 5
    # prefill-length caches -> padded decode caches (rwkv state is
    # length-independent; zamba's shared-attn KV and transformer KV pad)
    _lg, pcaches = model.prefill(params, toks[:, :T0], jnp.asarray([T0]))
    if cfg.family == "ssm":
        caches = pcaches
    else:
        caches = model.init_cache(B, T)

        def merge(c, pc):
            if c.ndim != pc.ndim:
                return c
            sl = tuple(slice(0, s) for s in pc.shape)
            return c.at[sl].set(pc)

        caches = jax.tree.map(merge, caches, pcaches)

    for t in range(T0, T):
        pos = jnp.asarray([[t]])
        lens = jnp.asarray([t + 1])
        logits, caches = model.decode(params, caches, toks[:, t:t + 1],
                                      pos, lens)
        np.testing.assert_allclose(
            np.asarray(logits[0, 0], np.float32),
            np.asarray(full_logits[0, t], np.float32),
            rtol=3e-2, atol=3e-2)


def test_runnable_matrix():
    skips = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s, cell in SHAPES.items():
            ok, why = runnable(cfg, cell)
            if not ok:
                skips.append((a, s))
    # exactly the 8 full-attention archs skip long_500k
    assert len(skips) == 8
    assert all(s == "long_500k" for _a, s in skips)
    assert not any(a in ("rwkv6-1.6b", "zamba2-1.2b") for a, _s in skips)


def test_param_count_formulas():
    # analytic 6ND inputs must roughly match realized reduced params scaling
    cfg = get_config("deepseek-v3-671b")
    assert 600e9 < cfg.params_dense < 750e9         # ~671B
    assert 25e9 < cfg.params_active < 60e9          # ~37B active
    dense = get_config("llama3.2-1b")
    assert 1.0e9 < dense.params_dense < 1.6e9
