"""Multi-architecture stacked simulation: the bit-exactness contract of
``simulate_multi`` / ``verify_stacked`` against the per-config batched
path, across configs that differ in register-file provisioning and
memory footprint, plus the shape-bucket guards.

The load-bearing property: per (config, seed) element, stacking many
fabrics' configuration planes into one XLA launch must reproduce
``simulate_batch`` on that config alone word-for-word — including the
RF-bucketed groups where a 4-register fabric runs inside a 16-register
executable on dead padded lanes."""
import numpy as np
import pytest

from repro.core import simcache
from repro.core.simulator import (simulate_batch, simulate_multi,
                                  stack_signature)
from repro.core.toolchain import Toolchain, verify_stacked
from repro.dse import ArchPoint, kernel_suite

SEEDS = [0, 1, 7]

# rf4 / rf8 / rf16 variants of the same 4x4 fabric: distinct SimConfigs
# (and distinct exact-shape executables) that bucket_rf folds into one
# stacked shape class
RF_POINTS = [ArchPoint(rows=4, cols=4, torus=False, regfile_size=rf,
                       bank_kb=4, banks_per_col=2, het="none")
             for rf in (4, 8, 16)]


@pytest.fixture(scope="module")
def rf_cohort():
    """{kernel: [CompiledKernel per RF variant]} for two cheap kernels."""
    tc = Toolchain(cache_dir="")
    out = {}
    for name in ("dwconv", "requant-int8"):
        out[name] = [tc.compile(kernel_suite(p.build())[name])
                     for p in RF_POINTS]
    return out


def _init_batch(ck, seeds):
    from repro.core.toolchain import _batch_oracle
    init, _ = _batch_oracle(ck, seeds, check_dfg=False)
    return init


def test_mixed_rf_variants_share_one_stack_signature(rf_cohort):
    """bucket_rf is what lets a search cohort share executables across
    its register-file axis: all three RF variants of a kernel land in
    one shape bucket, with the bucketed RF (not any config's own) in
    the signature."""
    for name, cks in rf_cohort.items():
        sigs = {stack_signature(ck.cfg, ck.mapped_iters,
                                len(ck.invocations)) for ck in cks}
        assert len(sigs) == 1, (name, sigs)
        assert sigs.pop()[2] == simcache.bucket_rf(16) == 16


def test_stacked_matches_per_config_word_for_word(rf_cohort):
    """Golden equivalence of the stacked launch: every (config, seed)
    element equals simulate_batch on that config alone — the rf4 and
    rf8 rows run with padded dead registers inside the rf16-wide
    executable."""
    for name, cks in rf_cohort.items():
        items, want = [], []
        for ck in cks:
            init = _init_batch(ck, SEEDS)
            items.append((ck.cfg, [dict(b) for b in init], ck.invocations))
            want.append(simulate_batch(ck.cfg, [dict(b) for b in init],
                                       ck.invocations, ck.mapped_iters))
        got = simulate_multi(items, n_iters=cks[0].mapped_iters)
        for ck, w, g in zip(cks, want, got):
            assert len(g) == len(SEEDS)
            for seed, wb, gb in zip(SEEDS, w, g):
                for bank in wb:
                    np.testing.assert_array_equal(
                        wb[bank], gb[bank],
                        err_msg=f"{name} {ck.arch.name} seed {seed} {bank}")


def test_stacked_pads_memory_to_widest_image(rf_cohort):
    """Configs with different total_words stack fine: memory rows pad to
    the group's widest image and each config addresses only its own
    words (the 2 KB-bank fabric rides rows sized for the 4 KB one).
    Per-item seed batches of different sizes stack too."""
    tc = Toolchain(cache_dir="")
    kb2 = ArchPoint(rows=4, cols=4, torus=False, regfile_size=16,
                    bank_kb=2, banks_per_col=2, het="none")
    narrow = tc.compile(kernel_suite(kb2.build())["requant-int8"])
    wide = rf_cohort["requant-int8"][2]
    assert narrow.cfg.total_words < wide.cfg.total_words
    assert (stack_signature(narrow.cfg, narrow.mapped_iters,
                            len(narrow.invocations))
            == stack_signature(wide.cfg, wide.mapped_iters,
                               len(wide.invocations)))
    cks, batches = [narrow, wide], [SEEDS[:1], SEEDS]
    items = [(ck.cfg, _init_batch(ck, s), ck.invocations)
             for ck, s in zip(cks, batches)]
    got = simulate_multi(items, n_iters=narrow.mapped_iters)
    for ck, s, g in zip(cks, batches, got):
        want = simulate_batch(ck.cfg, _init_batch(ck, s),
                              ck.invocations, ck.mapped_iters)
        assert len(g) == len(s)
        for wb, gb in zip(want, g):
            for bank in wb:
                np.testing.assert_array_equal(wb[bank], gb[bank])


def test_mismatched_signatures_are_rejected(rf_cohort):
    """Stacking configs from different shape buckets is a caller bug and
    must fail loudly, not mis-simulate."""
    a = rf_cohort["dwconv"][0]
    b = rf_cohort["requant-int8"][0]
    sig_a = stack_signature(a.cfg, a.mapped_iters, len(a.invocations))
    sig_b = stack_signature(b.cfg, b.mapped_iters, len(b.invocations))
    assert sig_a != sig_b
    with pytest.raises(ValueError, match="shape buckets"):
        simulate_multi(
            [(a.cfg, _init_batch(a, [0]), a.invocations),
             (b.cfg, _init_batch(b, [0]), b.invocations)],
            n_iters=a.mapped_iters)


def test_empty_and_singleton_groups(rf_cohort):
    """Items with no seed batch contribute empty results; a group of one
    degrades to the plain batched path."""
    ck = rf_cohort["dwconv"][0]
    out = simulate_multi([(ck.cfg, [], ck.invocations)],
                         n_iters=ck.mapped_iters)
    assert out == [[]]
    init = _init_batch(ck, SEEDS)
    got = simulate_multi([(ck.cfg, [dict(b) for b in init],
                           ck.invocations)], n_iters=ck.mapped_iters)
    want = simulate_batch(ck.cfg, [dict(b) for b in init],
                          ck.invocations, ck.mapped_iters)
    for wb, gb in zip(want, got[0]):
        for bank in wb:
            np.testing.assert_array_equal(wb[bank], gb[bank])


def test_verify_stacked_passes_and_catches_corruption(rf_cohort):
    """verify_stacked is verify_batch's contract at fewer launches: the
    clean cohort passes, and a corrupted configuration inside a stacked
    group still fails with the offending seed named."""
    cks = rf_cohort["dwconv"]
    assert verify_stacked(cks, seeds=SEEDS) == cks

    from repro.core.toolchain import CompiledKernel
    bad = CompiledKernel.from_json(cks[1].to_json())
    bad.cfg.imm[:] = bad.cfg.imm + 1        # corrupt every immediate
    with pytest.raises(AssertionError, match="seed="):
        verify_stacked([cks[0], bad, cks[2]], seeds=SEEDS[:2])


def test_verify_many_stacked_flag(rf_cohort):
    """Toolchain.verify_many(stacked=True) routes through verify_stacked
    and returns the kernels in input order."""
    tc = Toolchain(cache_dir="")
    cks = rf_cohort["requant-int8"]
    out = tc.verify_many(list(cks), seeds=[0, 1], stacked=True)
    assert out == list(cks)
