"""Packed MRRG router: Usage occupancy semantics over the flat-integer
key space, and routing behavior (fan-out sharing, capacity, holds)."""
import pytest

from repro.core.adl import cluster_4x4
from repro.core.mrrg import (F, R, Usage, commit_route, release_route,
                             route_value, router_tables)


@pytest.fixture()
def arch():
    return cluster_4x4()


# ------------------------------------------------------------------- Usage
def test_pack_is_bijective_over_the_resource_space(arch):
    II = 3
    T = router_tables(arch, II)
    keys = []
    for pe in range(arch.n_pes):
        keys.append(("lireg", pe))
        for s in range(II):
            keys += [("fu", pe, s), ("fuout", pe, s),
                     ("regpool", pe, s), ("wr", pe, s)]
            keys += [("xo", pe, d, s) for d in range(4)]
    for b in range(len(arch.banks)):
        keys += [("bank", b, s) for s in range(II)]
    packed = [T.pack(k) for k in keys]
    assert len(set(packed)) == len(packed)
    assert all(0 <= p < T.n_resources for p in packed)


def test_entries_returns_fresh_set(arch):
    u = Usage(arch, 4)
    key = ("fu", 3, 1)
    u.add(key, (7, 5))
    got = u.entries(key)
    assert got == {(7, 5)}
    got.add((9, 9))          # caller-side mutation must not leak back
    assert u.entries(key) == {(7, 5)}
    # the empty default is equally isolated (regression: the historical
    # implementation handed out a shared mutable default)
    empty = u.entries(("fu", 0, 0))
    assert empty == set()
    empty.add((1, 1))
    assert u.entries(("fu", 0, 0)) == set()
    assert not u.has(("fu", 0, 0), (1, 1))


def test_map_view_uses_typed_keys(arch):
    u = Usage(arch, 4)
    u.add(("xo", 1, 2, 3), (5, 11))
    u.add(("lireg", 2), ("n0", -1))     # string instances stay supported
    assert u.map == {("xo", 1, 2, 3): {(5, 11)},
                     ("lireg", 2): {("n0", -1)}}
    u.remove(("xo", 1, 2, 3), (5, 11))
    assert ("xo", 1, 2, 3) not in u.map
    assert u.map == {("lireg", 2): {("n0", -1)}}


def test_free_for_fanout_sharing_and_capacity(arch):
    u = Usage(arch, 4)
    key = ("xo", 0, 1, 2)
    u.add(key, (5, 6))
    assert u.free_for(key, (5, 6))       # same value instance: free share
    assert not u.free_for(key, (5, 10))  # same value, second live copy
    assert not u.free_for(key, (8, 6))   # other value: capacity 1
    pool = ("regpool", 0, 1)
    for i in range(arch.regfile_size):
        assert u.free_for(pool, (i, 1))
        u.add(pool, (i, 1))
    assert not u.free_for(pool, (99, 1))  # pool capacity R exhausted


def test_clone_shallow_is_isolated(arch):
    u = Usage(arch, 4)
    u.add(("fu", 1, 1), (3, 1))
    v = u.clone_shallow()
    v.add(("fu", 2, 2), (4, 5))
    v.remove(("fu", 1, 1), (3, 1))
    assert u.has(("fu", 1, 1), (3, 1))
    assert not u.has(("fu", 2, 2), (4, 5))
    assert v.has(("fu", 2, 2), (4, 5))


# ------------------------------------------------------------------ routing
def test_route_same_cycle_same_pe(arch):
    u = Usage(arch, 4)
    r = route_value(u, arch, 4, 1, 0, 3, 0, 3)
    assert r is not None and r.steps == [(F, 0, 3)] and r.uses == []
    assert route_value(u, arch, 4, 1, 0, 3, 1, 3) is None  # no 0-cycle hop


def test_route_adjacent_hop_claims_one_xo_port(arch):
    u = Usage(arch, 4)
    r = route_value(u, arch, 4, 1, 0, 0, 1, 1)   # PE0 -> PE1 is an E hop
    assert r is not None
    assert r.steps == [(F, 0, 0), (F, 1, 1)]
    assert r.uses == [(("xo", 0, 1, 0), (1, 0))]


def test_route_hold_claims_write_port_and_regpool(arch):
    u = Usage(arch, 4)
    r = route_value(u, arch, 4, 1, 0, 0, 0, 2)   # wait 2 cycles in place
    assert r is not None
    assert r.steps == [(F, 0, 0), (R, 0, 1), (R, 0, 2)]
    assert (("wr", 0, 0), (1, 0)) in r.uses
    assert (("regpool", 0, 1), (1, 1)) in r.uses
    assert (("regpool", 0, 2), (1, 2)) in r.uses


def test_fanout_sharing_is_free(arch):
    u = Usage(arch, 4)
    r1 = route_value(u, arch, 4, 1, 0, 0, 2, 2)  # two E hops
    assert r1 is not None
    commit_route(u, r1)
    r2 = route_value(u, arch, 4, 1, 0, 0, 2, 2)  # same value, same path
    assert r2 is not None and r2.uses == []      # shares every resource
    release_route(u, r1)
    assert u.map == {}


def test_route_blocked_port_fails_when_no_detour_fits(arch):
    u = Usage(arch, 4)
    u.add(("xo", 0, 1, 0), (9, 0))   # another value owns PE0's E port
    assert route_value(u, arch, 4, 1, 0, 0, 1, 1) is None
    # with one extra cycle the router detours (hold or S-E-N path)
    assert route_value(u, arch, 4, 1, 0, 0, 1, 2) is not None
