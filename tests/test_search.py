"""Seeded multi-objective DSE search (repro.dse.search): determinism of
the whole trajectory, checkpoint-resume replay, halving rung fidelity,
input validation, and the widened search space operators.

The load-bearing property mirrors the sweep's: a search is a pure
function of (universe, SearchConfig, seeds, verify, suite) — cold, warm
and checkpoint-resumed runs must produce identical results, and the CI
``search-smoke`` job extends this to byte-identical artifacts."""
import json

import pytest

from repro.core.mapper import MapperOptions
from repro.core.toolchain import Toolchain
from repro.dse import (HET_KINDS, SEARCH_ALGOS, SearchConfig, axis_domains,
                       crossover, get_space, mutate, run_search, wide_space,
                       write_artifacts)
from repro.dse.space import from_genes, genes, point_valid

SUITE = ["requant-int8"]          # 1-kernel suite: cheap, fully exercised
CFG = SearchConfig(algo="nsga2", seed=3, generations=2, population=3)


@pytest.fixture(scope="module")
def toolchain():
    """One in-memory toolchain for every search in this module: repeat
    runs replay from the compile memo, so determinism tests are cheap."""
    return Toolchain(options=MapperOptions(ii_max=20), cache_dir="")


def _snapshot(sr):
    """Everything a search run decides, as plain data."""
    return {"evaluated": [r.to_json_dict() for r in sr.evaluated],
            "population": sr.population, "history": sr.history}


def test_nsga2_runs_are_identical(toolchain):
    universe = get_space("tiny")
    a = run_search(universe, CFG, toolchain=toolchain, suite=SUITE)
    b = run_search(universe, CFG, toolchain=toolchain, suite=SUITE)
    assert _snapshot(a) == _snapshot(b)
    assert a.n_requested == b.n_requested
    # the trajectory really searched: both generations evaluated points,
    # and every full-fidelity evaluation is on the result list
    assert len(a.history) == CFG.generations
    assert a.population
    assert {r.name for r in a.evaluated} >= set(a.population)
    assert a.n_partial == 0           # nsga2 is always full fidelity


def test_search_seed_changes_the_trajectory(toolchain):
    universe = get_space("tiny")
    a = run_search(universe, CFG, toolchain=toolchain, suite=SUITE)
    b = run_search(universe, SearchConfig(algo="nsga2", seed=4,
                                          generations=2, population=3),
                   toolchain=toolchain, suite=SUITE)
    # different seeds sample/mutate differently (tiny universe still
    # leaves room via offspring knob recombination)
    assert a.history != b.history


def test_resumed_search_equals_cold_run(tmp_path, toolchain):
    """A checkpoint from a shorter run is a valid prefix: resuming a
    2-generation search from the 1-generation ledger replays generation
    one from the ledger and lands on the cold run's exact result."""
    universe = get_space("tiny")
    ckpt = str(tmp_path / "search_ckpt.json")
    short = SearchConfig(algo="nsga2", seed=3, generations=1, population=3)
    run_search(universe, short, toolchain=toolchain, suite=SUITE,
               checkpoint=ckpt)
    cold = run_search(universe, CFG, toolchain=toolchain, suite=SUITE)
    resumed = run_search(universe, CFG, toolchain=toolchain, suite=SUITE,
                         checkpoint=ckpt)
    assert _snapshot(resumed) == _snapshot(cold)


def test_halving_rungs_grow_fidelity(toolchain):
    """Successive halving: candidate counts shrink by eta per rung while
    the kernel-prefix fidelity grows, and only the final full-fidelity
    rung publishes results."""
    universe = get_space("tiny")
    cfg = SearchConfig(algo="halving", seed=1, generations=2,
                       population=2, eta=2)
    a = run_search(universe, cfg, toolchain=toolchain,
                   suite=["requant-int8", "dwconv"])
    b = run_search(universe, cfg, toolchain=toolchain,
                   suite=["requant-int8", "dwconv"])
    assert _snapshot(a) == _snapshot(b)
    assert [h["fidelity"] for h in a.history] == [1, 2]
    sizes = [len(h["evaluated"]) for h in a.history]
    assert sizes[0] == 4 and sizes[1] == 2     # population * eta, halved
    assert a.n_partial == 4                    # rung-1 evals are partial
    # partial rungs never leak into the published results
    assert len(a.evaluated) == 2
    assert all(len(r.kernels) == 2 for r in a.evaluated)


def test_search_input_validation(toolchain):
    universe = get_space("tiny")
    with pytest.raises(ValueError, match="unknown search algo"):
        run_search(universe, SearchConfig(algo="annealing"))
    with pytest.raises(ValueError, match="population"):
        run_search(universe, SearchConfig(population=1))
    with pytest.raises(ValueError, match="generations"):
        run_search(universe, SearchConfig(generations=0))
    with pytest.raises(ValueError, match="eta"):
        run_search(universe, SearchConfig(algo="halving", eta=1))
    with pytest.raises(ValueError, match="empty candidate universe"):
        run_search([], CFG)
    with pytest.raises(ValueError, match="unknown suite kernel"):
        run_search(universe, CFG, suite=["CONV2D"])
    with pytest.raises(ValueError, match="at least one seed"):
        run_search(universe, CFG, seeds=[])
    with pytest.raises(ValueError, match="options conflicts"):
        run_search(universe, CFG, toolchain=toolchain,
                   options=MapperOptions(ii_max=4))


def test_search_artifacts_carry_the_trajectory(tmp_path, toolchain):
    """write_artifacts(bench_name='dse_search', extra=...) produces the
    search-mode artifact pair the CLI and CI rely on."""
    universe = get_space("tiny")
    sr = run_search(universe, CFG, toolchain=toolchain, suite=SUITE)
    extra = {"search": {"config": CFG.to_json_dict(),
                        "population": sr.population,
                        "history": sr.history}}
    paths = write_artifacts(sr.evaluated, str(tmp_path), space="tiny",
                            bench_name="dse_search", extra=extra)
    report = json.loads((tmp_path / "dse_frontier.json").read_text())
    assert report["search"]["config"]["algo"] == "nsga2"
    assert report["search"]["population"] == sr.population
    bench = json.loads((tmp_path / "BENCH_dse_search.json").read_text())
    assert bench["bench"] == "dse_search"
    assert "BENCH_dse_search.json" in paths


# ------------------------------------------------------- widened space
def test_wide_space_is_deterministic_and_heterogeneous():
    pts = wide_space()
    assert pts == wide_space()
    names = [p.name for p in pts]
    assert len(names) == len(set(names))
    assert len(pts) > len(get_space("full"))
    assert {p.het for p in pts} == set(HET_KINDS)
    assert get_space("wide") == pts


def test_genes_roundtrip_and_operators_are_seeded():
    import random
    pts = get_space("wide")
    for p in pts[::97]:
        assert from_genes(genes(p)) == p
        assert point_valid(p)
    domains = axis_domains(pts)
    assert set(HET_KINDS) == set(domains["het"])
    a, b = pts[0], pts[-1]
    r1, r2 = random.Random(7), random.Random(7)
    assert crossover(r1, a, b) == crossover(r2, a, b)
    m1 = mutate(random.Random(5), a, domains, 0.5)
    m2 = mutate(random.Random(5), a, domains, 0.5)
    assert m1 == m2 and point_valid(m1)
    # mutation at probability 1 with a fresh rng actually moves knobs
    assert any(mutate(random.Random(s), a, domains, 1.0) != a
               for s in range(5))
