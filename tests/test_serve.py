"""repro.serve tests: engine admit/step/done lifecycle and admission
robustness, ServePlan build + lossless JSON round-trip + cycle-accurate
spot-check, family-aware GEMM-site enumeration with feasible tiling, and
seeded-traffic determinism (two runs byte-identical)."""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config, serve_smoke_config
from repro.core.adl import cluster_4x4
from repro.core.offload import (GemmSite, analyze_arch_gemms,
                                choose_gemm_tile, model_gemm_sites,
                                site_tile_count, tile_unroll)
from repro.core.toolchain import Toolchain
from repro.models.zoo import build_model
from repro.serve.engine import Engine, Request
from repro.serve.plan import (CGRAExecutionModel, ServePlan,
                              build_serve_plan)
from repro.serve.traffic import (FixedLatencyModel, TrafficConfig,
                                 generate_requests, report_json,
                                 run_traffic)

CFG = serve_smoke_config("llama3.2-1b")


@pytest.fixture(scope="module")
def model_params():
    model = build_model(CFG)
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tc():
    return Toolchain(cache_dir="")


@pytest.fixture(scope="module")
def plan(tc):
    return build_serve_plan(CFG, toolchain=tc, spot_check=False)


def make_engine(model_params, batch=2, max_len=16, exec_model=None):
    model, params = model_params
    return Engine(model, params, batch=batch, max_len=max_len,
                  exec_model=exec_model)


def req(rid, plen, max_new, vocab=None, seed=0):
    rng = np.random.default_rng(seed + rid)
    return Request(rid=rid, prompt=rng.integers(0, vocab or CFG.vocab,
                                                size=(plen,)),
                   max_new=max_new)


# ----------------------------------------------------------------- engine
def test_engine_lifecycle(model_params):
    eng = make_engine(model_params)
    r = req(0, plen=4, max_new=3)
    assert eng.admit(r)
    assert eng.n_active == 1 and eng.has_free_slot()
    toks = []
    while not r.done:
        out = eng.step()
        assert set(out) == {0}
        toks.append(out[0])
    assert r.out == toks and len(r.out) == 3
    assert eng.n_active == 0          # finished request freed its slot
    assert eng.step() == {}


def test_admit_rejects_overlong_prompt(model_params):
    eng = make_engine(model_params, max_len=8)
    with pytest.raises(ValueError, match="cannot fit max_len"):
        eng.admit(req(0, plen=8, max_new=2))     # needs a decode position
    assert eng.n_active == 0

    r = req(1, plen=12, max_new=2)
    tail = np.asarray(r.prompt[-7:])
    assert eng.admit(r, truncate=True)
    assert r.truncated and len(r.prompt) == 7
    np.testing.assert_array_equal(r.prompt, tail)   # keeps the tail
    while not r.done:
        eng.step()
    assert len(r.out) == 1            # 7 prompt + 1 decoded == max_len


def test_decode_stops_at_kv_budget(model_params):
    """A request whose decode budget exceeds the KV cache ends at
    max_len instead of silently overflowing."""
    eng = make_engine(model_params, batch=1, max_len=8)
    r = req(0, plen=5, max_new=100)
    assert eng.admit(r)
    steps = 0
    while not r.done:
        eng.step()
        steps += 1
        assert steps <= 8
    assert len(r.out) == 3            # 5 prompt + 3 decoded == max_len


def test_slot_recycling_under_pressure(model_params):
    eng = make_engine(model_params, batch=1)
    r1, r2 = req(1, plen=3, max_new=2), req(2, plen=3, max_new=2)
    assert eng.admit(r1)
    assert not eng.admit(r2)          # slot pressure: queued by caller
    while not r1.done:
        eng.step()
    assert eng.has_free_slot()        # capacity recycled
    assert eng.admit(r2)
    while not r2.done:
        eng.step()
    assert len(r2.out) == 2


def test_engine_clock_tracks_exec_model(model_params):
    em = FixedLatencyModel(decode_step_us=1000.0, prefill_us_per_token=100.0)
    eng = make_engine(model_params, exec_model=em)
    assert eng.clock_s == 0.0
    eng.admit(req(0, plen=4, max_new=2))
    assert eng.clock_s == pytest.approx(4 * 100e-6)
    eng.step()
    assert eng.clock_s == pytest.approx(4 * 100e-6 + 1000e-6)
    eng.advance_clock(1.0)
    assert eng.clock_s == 1.0
    eng.advance_clock(0.5)            # never backward
    assert eng.clock_s == 1.0


# ------------------------------------------------- site enumeration/tiling
def test_model_gemm_sites_families():
    ssm = {s.name for s in model_gemm_sites(get_config("rwkv6-1.6b"))}
    assert "tmix_rkvo" in ssm and "cmix_in" in ssm and "q_proj" not in ssm

    hyb_cfg = get_config("zamba2-1.2b")
    hyb = {s.name: s for s in model_gemm_sites(hyb_cfg)}
    assert "mamba_in" in hyb and "shared_q" in hyb
    # the shared attention block runs n_layers // attn_every times
    assert (hyb["shared_q"].n_layers(hyb_cfg)
            == hyb_cfg.n_layers // hyb_cfg.attn_every)
    assert hyb["mamba_in"].n_layers(hyb_cfg) == hyb_cfg.n_layers

    moe_cfg = get_config("deepseek-v3-671b")
    moe = {s.name: s for s in model_gemm_sites(moe_cfg)}
    assert "q_lora" in moe and "expert_ffn_in" in moe
    active = moe_cfg.top_k + moe_cfg.n_shared_experts
    assert moe["expert_ffn_in"].count_per_layer == 2 * active
    assert (moe["expert_ffn_in"].n_layers(moe_cfg)
            == moe_cfg.n_layers - moe_cfg.first_k_dense)
    assert moe["dense_ffn_in"].n_layers(moe_cfg) == moe_cfg.first_k_dense


def test_choose_tile_clamps_and_falls_back():
    arch = cluster_4x4()
    assert choose_gemm_tile(arch) == (16, 8, 16)
    # small sites clamp the tile to their dims
    small = GemmSite("lora", M=3, K=2, N=5)
    TI, TK, TJ = choose_gemm_tile(arch, small)
    assert (TI, TK, TJ) == (3, 2, 5)
    assert tile_unroll(TK) == 2
    # capacity-infeasible ladder heads fall through deterministically
    tiny = cluster_4x4(bank_kb=1)
    assert choose_gemm_tile(tiny, ladder=((64, 64, 64), (4, 4, 4))) \
        == (4, 4, 4)
    assert site_tile_count(GemmSite("s", 64, 2048, 512),
                           (16, 8, 16)) == 4 * 256 * 32


def test_analyze_arch_gemms_scales_full_site(tc):
    reports = analyze_arch_gemms("llama3.2-1b", max_kernels=3,
                                 toolchain=tc)
    cfg = get_config("llama3.2-1b")
    sites = model_gemm_sites(cfg)[:3]
    assert [r.site for r in reports] == [s.name for s in sites]
    for r, s in zip(reports, sites):
        assert r.tiles == site_tile_count(s, r.tile)
        assert r.instances == s.count_per_layer * cfg.n_layers
        assert r.est_site_ms == pytest.approx(
            r.tiles * r.instances * r.est_tile_us / 1e3)
    # q_proj and kv_proj share a compiled tile but differ in site latency
    by = {r.site: r for r in reports}
    assert by["q_proj"].est_tile_us == by["kv_proj"].est_tile_us
    assert by["q_proj"].est_site_ms != by["kv_proj"].est_site_ms


# ------------------------------------------------------------------- plan
def test_plan_covers_every_site(plan):
    expected = [s.name for s in model_gemm_sites(CFG)]
    assert [s.name for s in plan.sites] == expected
    assert plan.model == CFG.name
    for s in plan.sites:
        ck = plan.kernel_for(s)
        assert ck.cache_key == s.kernel_ref
        assert s.II >= s.mii >= 1
        assert s.tile_cycles == (len(ck.invocations)
                                 * ck.schedule_cycles())
        assert s.latency_s() > 0


def test_plan_json_roundtrip_lossless(plan):
    blob = plan.to_json()
    plan2 = ServePlan.from_json(blob)
    assert plan2.to_json() == blob               # byte-identical
    assert [s for s in plan2.sites] == [s for s in plan.sites]
    assert plan2.decode_step_s(4) == plan.decode_step_s(4)
    # version guard
    bad = json.dumps({**json.loads(blob), "version": 99})
    with pytest.raises(ValueError, match="version"):
        ServePlan.from_json(bad)


def test_plan_ref_only_roundtrip_resolves_via_toolchain(plan, tc):
    blob = plan.to_json(embed_kernels=False)
    assert len(blob) < len(plan.to_json())
    orphan = ServePlan.from_json(blob)           # no toolchain: refs dangle
    with pytest.raises(KeyError, match="not bundled"):
        orphan.kernel_for(orphan.sites[0])
    resolved = ServePlan.from_json(blob, toolchain=tc)
    ck = resolved.kernel_for(resolved.sites[0])
    assert ck.cache_key == resolved.sites[0].kernel_ref


def test_plan_spot_check_cycle_accurate(plan):
    checked = plan.spot_check(seeds=(0, 1))
    assert len(checked) >= 1 and checked[0] == plan.sites[0].name
    # a reloaded plan spot-checks too (DFG reference-execution oracle)
    reloaded = ServePlan.from_json(plan.to_json())
    assert reloaded.spot_check() == checked[:1]


def test_exec_model_latency(plan):
    em = CGRAExecutionModel(plan)
    assert em.decode_step_s(3) == pytest.approx(plan.step_latency_s(3))
    assert em.decode_step_s(3) == em.decode_step_s(3)   # memoized path
    assert em.prefill_s(0) == pytest.approx(plan.step_latency_s(1))
    # more active slots can never be modeled faster
    assert em.decode_step_s(17) >= em.decode_step_s(1)
    with_overhead = CGRAExecutionModel(plan, overhead_s=1.0)
    assert with_overhead.decode_step_s(1) == pytest.approx(
        em.decode_step_s(1) + 1.0)


# ---------------------------------------------------------------- traffic
def test_generate_requests_seeded():
    cfg = TrafficConfig(seed=7, n_requests=5)
    a = generate_requests(cfg, vocab=64)
    b = generate_requests(cfg, vocab=64)
    assert [t for t, _r in a] == [t for t, _r in b]
    assert all((x.prompt == y.prompt).all() for (_, x), (_, y) in zip(a, b))
    assert [t for t, _ in a] == sorted(t for t, _ in a)
    c = generate_requests(TrafficConfig(seed=8, n_requests=5), vocab=64)
    assert [t for t, _ in a] != [t for t, _ in c]


def test_traffic_requires_exec_model(model_params):
    eng = make_engine(model_params)
    with pytest.raises(ValueError, match="exec_model"):
        run_traffic(eng, TrafficConfig(n_requests=1), CFG.vocab)


def test_traffic_two_runs_byte_identical(model_params):
    cfg = TrafficConfig(seed=3, n_requests=6, arrival_rate=500.0,
                        prompt_len=(3, 8), max_new=(2, 5))

    def episode():
        eng = make_engine(model_params, batch=2, max_len=16,
                          exec_model=FixedLatencyModel())
        return report_json(run_traffic(eng, cfg, CFG.vocab))

    first, second = episode(), episode()
    assert first == second
    report = json.loads(first)
    assert report["served"] == 6 and report["rejected"] == 0
    assert report["tokens_per_s"] > 0
    assert 0 < report["slot_occupancy"]["mean"] <= 1


def test_traffic_queueing_and_truncation(model_params):
    # slot pressure: one slot, bursty arrivals -> nonzero queue wait
    cfg = TrafficConfig(seed=0, n_requests=5, arrival_rate=1e4,
                        prompt_len=(3, 6), max_new=(2, 3))
    eng = make_engine(model_params, batch=1, max_len=16,
                      exec_model=FixedLatencyModel())
    rep = run_traffic(eng, cfg, CFG.vocab)
    assert rep["served"] == 5
    assert rep["queue_wait_ms"]["max"] > 0
    assert rep["slot_occupancy"]["mean"] == 1.0

    # overlong prompts: dropped without truncate, served with it
    long_cfg = TrafficConfig(seed=0, n_requests=4, prompt_len=(20, 30),
                             max_new=(1, 2), truncate=False)
    eng = make_engine(model_params, batch=2, max_len=8,
                      exec_model=FixedLatencyModel())
    rep = run_traffic(eng, long_cfg, CFG.vocab)
    assert rep["rejected"] == 4 and rep["served"] == 0

    eng = make_engine(model_params, batch=2, max_len=8,
                      exec_model=FixedLatencyModel())
    rep = run_traffic(eng, dataclasses.replace(long_cfg, truncate=True),
                      CFG.vocab)
    assert rep["truncated"] == 4 and rep["served"] == 4


def test_traffic_with_cgra_plan_deterministic(model_params, plan):
    """The acceptance path: plan-modeled CGRA latency driving a Poisson
    episode, byte-deterministic given the seed."""
    cfg = TrafficConfig(seed=0, n_requests=4, arrival_rate=100.0,
                        prompt_len=(3, 6), max_new=(2, 4))

    def episode():
        eng = make_engine(model_params, batch=2, max_len=16,
                          exec_model=CGRAExecutionModel(plan))
        return report_json(run_traffic(eng, cfg, CFG.vocab))

    first, second = episode(), episode()
    assert first == second
    rep = json.loads(first)
    assert rep["served"] == 4
    # episode time is the plan's modeled clock, not host wall time
    assert rep["episode_s"] > 0 and rep["tokens_per_s"] > 0
