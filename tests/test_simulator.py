"""Cycle-accurate simulator vs golden memory images — the paper's
functional-verification contract (section IV-C), driven through the
Toolchain compile API (disk cache disabled for hermeticity)."""
import numpy as np
import pytest

from repro.core.kernels_lib import build_conv, build_gemm
from repro.core.toolchain import Toolchain
from repro.core.verify import generate_test_data


@pytest.fixture()
def tc():
    return Toolchain(cache_dir="")


@pytest.mark.parametrize("seed", [0, 7])
def test_gemm_base_verifies(seed, tc):
    spec = build_gemm(TI=6, TK=8, TJ=6, unroll=1)
    ck = tc.compile(spec).verify(seed=seed)
    assert ck.II == ck.mii == 4


def test_conv_base_verifies(tc):
    spec = build_conv(OH=5, OW=5, K=3, variant="base")
    tc.compile(spec).verify()


def test_simulation_is_deterministic(tc):
    spec = build_gemm(TI=4, TK=4, TJ=4, unroll=1)
    ck = tc.compile(spec)
    data = generate_test_data(spec, seed=1)
    a = ck.run(data.init_banks)
    b = ck.run(data.init_banks)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_config_serializes(tc):
    spec = build_gemm(TI=4, TK=4, TJ=4, unroll=1)
    cfg = tc.compile(spec).cfg
    s = cfg.to_json()
    assert len(s) > 100 and '"II"' in s


def test_config_serializes_after_simulation(tc):
    """The simulator caches device-resident planes on the SimConfig; the
    artifact JSON must stay free of those transients."""
    import json
    spec = build_gemm(TI=4, TK=4, TJ=4, unroll=1)
    ck = tc.compile(spec)
    ck.verify()                      # populates the plane cache
    d = json.loads(ck.cfg.to_json())
    assert not [k for k in d if k.startswith("_")]


def test_empty_invocations_returns_initial_banks(tc):
    """A kernel invoked zero times leaves memory untouched (regression:
    np.stack([]) used to raise before the guard)."""
    from repro.core.simulator import simulate, simulate_batch
    spec = build_gemm(TI=4, TK=4, TJ=4, unroll=1)
    ck = tc.compile(spec)
    data = generate_test_data(spec, seed=0)
    out = simulate(ck.cfg, data.init_banks, [], spec.mapped_iters)
    for bank, img in data.init_banks.items():
        np.testing.assert_array_equal(out[bank], img)
    outs = simulate_batch(ck.cfg, [data.init_banks] * 2, [],
                          spec.mapped_iters)
    for out in outs:
        for bank, img in data.init_banks.items():
            np.testing.assert_array_equal(out[bank], img)


def test_tile_budget_counts_every_plane_element(tc):
    """The pre-tiling cap is sized from the actual per-cycle stream
    footprint — every plane's inner dims and narrowed item size — not the
    bare P-words-per-cycle estimate that undercounted wide planes like
    the [P,3+RF+4] mux bank several-fold."""
    import jax.numpy as jnp

    from repro.core.simulator import (_SLOT_PLANES, _as_jnp,
                                      _tile_bytes_per_cycle)
    cfg = tc.compile(build_gemm(TI=4, TK=4, TJ=4, unroll=1)).cfg
    planes = _as_jnp(cfg)
    per_cycle = _tile_bytes_per_cycle(planes, cfg.II)
    manual = sum(int(np.prod(planes[k].shape[1:])) * planes[k].dtype.itemsize
                 for k in _SLOT_PLANES)
    assert per_cycle == manual
    # config-batched planes ([B,II,...]) stream every batch row per
    # cycle: the same accounting scales linearly with B
    stacked = {k: jnp.repeat(v[None], 3, axis=0)
               for k, v in planes.items()}
    assert _tile_bytes_per_cycle(stacked, cfg.II) == 3 * per_cycle
    # the mux-port plane alone is [P, 3+RF+4] — wider than the old
    # one-word-per-PE accounting by an order of magnitude
    assert per_cycle >= cfg.P * (3 + cfg.RF + 4)


def test_plane_dtypes_narrow_exactly(tc):
    """Dtype narrowing is value-exact: every plane demotes to the smallest
    of int8/int16/int32 that round-trips its values."""
    from repro.core.config_gen import SIM_PLANES, narrowed_planes, plane_dtypes
    cfg = tc.compile(build_gemm(TI=4, TK=4, TJ=4, unroll=1)).cfg
    narrowed = narrowed_planes(cfg)
    dtypes = plane_dtypes(cfg)
    assert set(dtypes) == set(SIM_PLANES)
    for k in SIM_PLANES:
        orig = np.asarray(getattr(cfg, k))
        assert str(narrowed[k].dtype) == dtypes[k]
        np.testing.assert_array_equal(narrowed[k], orig)   # value-exact
    # enumeration planes (opcodes, mux kinds) always fit a byte
    assert dtypes["op"] == "int8" and dtypes["src_kind"] == "int8"


def test_config_frozen_after_first_simulation(tc):
    """Simulating caches device planes on the config; in-place plane edits
    afterwards must raise rather than silently diverge from the cache."""
    spec = build_gemm(TI=4, TK=4, TJ=4, unroll=1)
    ck = tc.compile(spec)
    ck.verify()
    with pytest.raises(ValueError):
        ck.cfg.imm[:] = ck.cfg.imm + 1
