"""Cycle-accurate simulator vs golden memory images — the paper's
functional-verification contract (section IV-C), driven through the
Toolchain compile API (disk cache disabled for hermeticity)."""
import numpy as np
import pytest

from repro.core.kernels_lib import build_conv, build_gemm
from repro.core.toolchain import Toolchain
from repro.core.verify import generate_test_data


@pytest.fixture()
def tc():
    return Toolchain(cache_dir="")


@pytest.mark.parametrize("seed", [0, 7])
def test_gemm_base_verifies(seed, tc):
    spec = build_gemm(TI=6, TK=8, TJ=6, unroll=1)
    ck = tc.compile(spec).verify(seed=seed)
    assert ck.II == ck.mii == 4


def test_conv_base_verifies(tc):
    spec = build_conv(OH=5, OW=5, K=3, variant="base")
    tc.compile(spec).verify()


def test_simulation_is_deterministic(tc):
    spec = build_gemm(TI=4, TK=4, TJ=4, unroll=1)
    ck = tc.compile(spec)
    data = generate_test_data(spec, seed=1)
    a = ck.run(data.init_banks)
    b = ck.run(data.init_banks)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_config_serializes(tc):
    spec = build_gemm(TI=4, TK=4, TJ=4, unroll=1)
    cfg = tc.compile(spec).cfg
    s = cfg.to_json()
    assert len(s) > 100 and '"II"' in s
