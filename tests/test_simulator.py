"""Cycle-accurate simulator vs golden memory images — the paper's
functional-verification contract (section IV-C) as CI tests."""
import numpy as np
import pytest

from repro.core.config_gen import generate_config
from repro.core.kernels_lib import build_conv, build_gemm
from repro.core.mapper import map_kernel
from repro.core.verify import generate_test_data, verify_mapping
from repro.core.simulator import simulate


@pytest.mark.parametrize("seed", [0, 7])
def test_gemm_base_verifies(seed):
    spec = build_gemm(TI=6, TK=8, TJ=6, unroll=1)
    m = verify_mapping(spec, seed=seed)
    assert m.II == m.mii == 4


def test_conv_base_verifies():
    spec = build_conv(OH=5, OW=5, K=3, variant="base")
    verify_mapping(spec)


def test_simulation_is_deterministic():
    spec = build_gemm(TI=4, TK=4, TJ=4, unroll=1)
    m = map_kernel(spec.dfg, spec.arch, spec.layout)
    cfg = generate_config(m, spec.layout)
    data = generate_test_data(spec, seed=1)
    a = simulate(cfg, data.init_banks, spec.invocations, spec.mapped_iters)
    b = simulate(cfg, data.init_banks, spec.invocations, spec.mapped_iters)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_config_serializes():
    spec = build_gemm(TI=4, TK=4, TJ=4, unroll=1)
    m = map_kernel(spec.dfg, spec.arch, spec.layout)
    cfg = generate_config(m, spec.layout)
    s = cfg.to_json()
    assert len(s) > 100 and '"II"' in s
