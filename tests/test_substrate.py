"""Substrate tests: optimizer, train step (loss decreases), data pipeline
determinism, checkpoint save/restore (+async, keep-k, elastic restore),
sharding rules, elastic mesh planning, HLO collective parser."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig, Prefetcher, TokenSource
from repro.dist.elastic import (HeartbeatMonitor, best_mesh_shape,
                                resume_plan)
from repro.dist.sharding import batch_spec, cache_spec, param_spec
from repro.models.zoo import build_model
from repro.roofline.hlo import collective_bytes
from repro.train import optimizer as optim
from repro.train.step import init_train_state, make_train_step


class FakeMesh:
    def __init__(self, shape, names):
        self.devices = np.empty(shape)
        self.axis_names = names


MESH = FakeMesh((16, 16), ("data", "model"))
MESH3 = FakeMesh((2, 16, 16), ("pod", "data", "model"))


# ------------------------------------------------------------- sharding
def test_param_spec_tp_prefers_last_dim():
    assert param_spec((4096, 13440), MESH, False, False) == \
        jax.sharding.PartitionSpec("data", "model")


def test_param_spec_odd_heads_falls_back():
    # llama3.2-3b: 24 heads -> fused feature dim 3072 shards fine
    spec = param_spec((3072, 3072), MESH, False, False)
    assert "model" in spec


def test_param_spec_indivisible_replicates():
    spec = param_spec((7, 13), MESH, False, False)
    assert spec == jax.sharding.PartitionSpec(None, None)


def test_param_spec_stacked_skips_layer_axis():
    spec = param_spec((61, 7168, 2048), MESH, True, False)
    assert spec[0] is None


def test_param_spec_expert_axis():
    spec = param_spec((256, 7168, 2048), MESH, False, True)
    assert spec[0] == "model"   # EP


def test_batch_spec_long500k_batch1():
    assert batch_spec((1, 1), MESH) == jax.sharding.PartitionSpec(None, None)


def test_cache_spec_mqa_shards_sequence():
    # granite kv=1: heads axis indivisible -> sequence axis gets model
    spec = cache_spec((88, 128, 1, 32768, 128), MESH)
    assert spec[3] == "model" or spec[4] == "model"


def test_multipod_spec():
    spec = param_spec((8192, 8192), MESH3, False, False)
    flat = [s for s in spec if s is not None]
    assert ("pod", "data") in spec or "data" in flat


# ------------------------------------------------------------ optimizer
def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    cfg = optim.OptConfig(lr=0.3, warmup_steps=1, total_steps=200,
                          weight_decay=0.0, clip_norm=100.0)
    state = optim.init(params)
    for _ in range(120):
        grads = {"w": 2 * state.master["w"]}
        params, state, _m = optim.apply(cfg, grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_train_step_loss_decreases():
    cfg = get_config("llama3.2-1b").reduced()
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    opt_cfg = optim.OptConfig(lr=5e-3, warmup_steps=2, total_steps=100)
    step = jax.jit(make_train_step(model, opt_cfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 17), 0, cfg.vocab)
    batch = {"inputs": toks[:, :-1], "labels": toks[:, 1:]}
    first = None
    for i in range(8):
        state, metrics = step(state, batch)
        if first is None:
            first = float(metrics["loss"])
    assert float(metrics["loss"]) < first, \
        f"loss {first} -> {float(metrics['loss'])}"


def test_train_step_microbatched_matches_unbatched_grads():
    cfg = get_config("llama3.2-1b").reduced()
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0))
    opt_cfg = optim.OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 9), 0, cfg.vocab)
    batch = {"inputs": toks[:, :-1], "labels": toks[:, 1:]}
    s1, m1 = jax.jit(make_train_step(model, opt_cfg, 1))(state, batch)
    s2, m2 = jax.jit(make_train_step(model, opt_cfg, 2))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4)


# ------------------------------------------------------------------ data
def test_data_deterministic_and_host_sharded():
    c1 = DataConfig(seq_len=16, global_batch=8, vocab=100, n_hosts=2,
                    host_id=0)
    c2 = DataConfig(seq_len=16, global_batch=8, vocab=100, n_hosts=2,
                    host_id=1)
    a0 = TokenSource(c1).batch_at(3)
    a0b = TokenSource(c1).batch_at(3)
    b0 = TokenSource(c2).batch_at(3)
    np.testing.assert_array_equal(a0["inputs"], a0b["inputs"])
    assert not np.array_equal(a0["inputs"], b0["inputs"])
    assert a0["inputs"].shape == (4, 16)
    np.testing.assert_array_equal(a0["inputs"][:, 1:], a0["labels"][:, :-1])


def test_prefetcher():
    src = TokenSource(DataConfig(seq_len=8, global_batch=2, vocab=50))
    pf = Prefetcher(src, start_step=0)
    step0, b0 = next(pf)
    step1, b1 = next(pf)
    pf.close()
    assert (step0, step1) == (0, 1)
    np.testing.assert_array_equal(b0["inputs"], src.batch_at(0)["inputs"])


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    ck.save(1, tree, blocking=True)
    ck.save(5, jax.tree.map(lambda x: x * 2, tree), blocking=True)
    out = ck.restore(5, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(tree["a"]) * 2)
    assert ck.latest_step() == 5


def test_checkpoint_async_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros(8)}
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    ck.wait()
    assert ck.all_steps() == [3, 4]


def test_checkpoint_elastic_restore_resharded(tmp_path):
    # restore onto a "different mesh": sharding_fn returns single-device
    ck = Checkpointer(str(tmp_path), keep=1)
    tree = {"w": jnp.arange(16.0)}
    ck.save(7, tree, blocking=True)
    dev = jax.devices()[0]
    sh = jax.sharding.SingleDeviceSharding(dev)
    out = ck.restore(7, tree, sharding_fn=lambda p, s: sh)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))


# --------------------------------------------------------------- elastic
def test_best_mesh_shape():
    assert best_mesh_shape(512, 16) == (32, 16)
    assert best_mesh_shape(496, 16) == (31, 16)  # 496 = 31*16: keep MP
    assert best_mesh_shape(500, 16) == (125, 4)  # lost hosts: shrink MP
    assert best_mesh_shape(13, 16) == (13, 1)


def test_heartbeat_monitor():
    hb = HeartbeatMonitor(timeout_s=10)
    hb.beat(0, now=100.0)
    hb.beat(1, now=100.0)
    assert hb.all_alive(2, now=105.0)
    assert hb.dead_hosts(now=120.0) == [0, 1]


def test_resume_plan():
    assert resume_plan([100, 200, 300]) == 300
    assert resume_plan([100, 200, 300], requested_step=250) == 200
    assert resume_plan([]) is None


# ------------------------------------------------------------------- hlo
def test_collective_bytes_parser():
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(bf16[1,128]{1,0} %x), dimensions={0}
  ROOT %ar = f32[256]{0} all-reduce(f32[256]{0} %y), to_apply=%sum
  %cp = collective-permute(f32[2,2]{1,0} %z)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 8 * 128 * 2
    assert out["all-reduce"] == 256 * 4
    assert out["total"] >= out["all-gather"] + out["all-reduce"]
