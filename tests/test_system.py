"""End-to-end behaviour tests for the paper's system: the full Morpher
flow (DFG -> map -> configure -> simulate -> verify) on the Table-I
kernels, the architecture-adaptive ADL, and the edge-deployment analyzer
over the LM zoo — all through the unified Toolchain compile API."""
import numpy as np
import pytest

from repro.core.adl import CGRAArch, cluster_4x4, morpher_8x8
from repro.core.costmodel import gemm_traffic_bytes, kernel_cost
from repro.core.kernels_lib import build_gemm, table1_kernels
from repro.core.toolchain import Toolchain


@pytest.fixture()
def tc():
    return Toolchain(cache_dir="")


def test_full_flow_gemm_paper_point(tc):
    """The paper's central Table-I row: base GEMM maps at II = MII = 4 and
    the modulo-scheduled pipelined execution reproduces the sequential
    semantics bit-exactly."""
    spec = build_gemm(TI=6, TK=8, TJ=6, unroll=1)
    ck = tc.compile(spec).verify()
    assert ck.II == 4 and ck.mii == 4


def test_adl_roundtrip():
    arch = morpher_8x8()
    arch2 = CGRAArch.from_json(arch.to_json())
    assert arch2.rows == 8 and arch2.cols == 8
    assert len(arch2.banks) == 8
    assert arch2.banks[0].size_bytes == 8 * 1024
    assert arch2.mem_pes == arch.mem_pes


def test_adl_cluster_matches_paper_target():
    c = cluster_4x4()
    assert c.n_pes == 16
    assert c.datapath_bits == 16
    assert [b.size_bytes for b in c.banks] == [8192, 8192]
    # memory access restricted to boundary-column PEs
    assert c.mem_pes == frozenset({0, 4, 8, 12, 3, 7, 11, 15})


def test_architecture_adaptivity_heterogeneous(tc):
    """Morpher's selling point: user-defined architectures.  Restrict
    multiplies to a 2x2 quadrant and verify mapping adapts."""
    arch = cluster_4x4()
    no_mul = frozenset(o for o in arch.fu_ops if o != "mul")
    arch.per_pe_ops = {p: no_mul for p in range(16)
                       if not (p % 4 < 2 and p < 8)}
    spec = build_gemm(TI=4, TK=4, TJ=4, unroll=1, arch=arch)
    ck = tc.compile(spec)
    for v, (pe, _t) in ck.mapping.place.items():
        if spec.dfg.nodes[v].op.value == "mul":
            assert pe in {0, 1, 4, 5}
    ck.verify()


def test_cost_model_table1_shape(tc):
    spec = build_gemm(TI=6, TK=8, TJ=6, unroll=1)
    ck = tc.compile(spec)
    c = kernel_cost(spec, ck.mapping, array_bytes_moved=gemm_traffic_bytes(),
                    handshake_us=20.0)
    assert c.total_ms > 0 and c.compute_ms > 0 and c.transfer_ms > 0
    assert c.II >= c.mii
    row = c.row()
    assert "gemm" in row


def test_offload_analyzer_runs(tc):
    from repro.core.offload import analyze_arch_gemms
    report = analyze_arch_gemms("llama3.2-1b", max_kernels=1, toolchain=tc)
    assert report and report[0].II >= 1


def _load_edge_deploy_module():
    import importlib.util
    import os
    path = os.path.join(os.path.dirname(__file__), "..", "examples",
                        "edge_deploy.py")
    spec = importlib.util.spec_from_file_location("edge_deploy_example", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_edge_deploy_loads_user_defined_adl(tmp_path):
    """The --arch-file path: a user-defined ADL JSON (paper's
    architecture-adaptive claim) round-trips through the example loader,
    including the committed sample file."""
    import os
    mod = _load_edge_deploy_module()
    sample = os.path.join(os.path.dirname(__file__), "..", "examples",
                          "cluster_4x4.adl.json")
    arch = mod.load_arch_file(sample)
    assert arch.n_pes == 16 and len(arch.banks) == 2

    # a modified user architecture loads and drives a real compile
    custom = cluster_4x4(regfile=16, name="user-cgra")
    p = tmp_path / "user.adl.json"
    p.write_text(custom.to_json())
    arch2 = mod.load_arch_file(str(p))
    assert arch2.name == "user-cgra" and arch2.regfile_size == 16
    ck = Toolchain(arch2, cache_dir="").compile(
        build_gemm(TI=4, TK=4, TJ=4, arch=arch2))
    ck.verify()

    # invalid ADLs are rejected by validation (a real ValueError, so the
    # check survives `python -O`), not silently accepted
    bad = custom.to_json().replace('"rows": 4', '"rows": 0')
    pb = tmp_path / "bad.adl.json"
    pb.write_text(bad)
    with pytest.raises(ValueError):
        mod.load_arch_file(str(pb))
