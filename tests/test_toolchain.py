"""Toolchain compile API: serializable CompiledKernel artifacts, the
content-addressed mapping cache, fan-out compiles, and the deprecation
shims for the old free-function flow."""
import json
import os

import numpy as np
import pytest

from repro.core.kernels_lib import build_conv, build_gemm
from repro.core.mapper import MapperOptions, map_kernel
from repro.core.toolchain import (CACHE_ENV, CompiledKernel, Toolchain,
                                  default_cache_dir, spec_cache_key)
from repro.core.verify import verify_mapping


def small_gemm():
    return build_gemm(TI=4, TK=4, TJ=4, unroll=1)


@pytest.fixture()
def tc(tmp_path):
    return Toolchain(options=MapperOptions(), cache_dir=str(tmp_path))


# ----------------------------------------------------------------- compile
def test_compile_produces_verified_artifact(tc):
    ck = tc.compile(small_gemm())
    assert ck.II >= ck.mii >= 1
    assert not ck.from_cache
    ck.verify()


def test_compile_many_matches_individual(tc):
    specs = [small_gemm(), build_conv(OH=5, OW=5, K=3, variant="base")]
    cks = tc.compile_many(specs, jobs=2)
    assert [ck.name for ck in cks] == [s.name for s in specs]
    solo = Toolchain(cache_dir="")
    for spec, ck in zip(specs, cks):
        assert ck.II == solo.compile(spec).II
    for ck in cks:
        ck.verify()     # process-pool results reassemble into working CKs


def test_compile_many_dedups_identical_specs(tc):
    cks = tc.compile_many([small_gemm(), small_gemm()], jobs=2)
    assert cks[0] is cks[1]     # one compile served both indices


# ------------------------------------------------------------ serialization
def test_json_roundtrip_verifies_bit_exactly(tc):
    ck = tc.compile(small_gemm())
    art = ck.to_json()
    ck2 = CompiledKernel.from_json(art)
    assert ck2.spec is None          # no closures travel with the artifact
    ck2.verify(seed=3)               # DFG-reference oracle, bit-exact
    # simulating the same inputs through both artifacts is bit-identical
    init = ck.random_banks(seed=11)
    a = ck.run({k: v.copy() for k, v in init.items()})
    b = ck2.run({k: v.copy() for k, v in init.items()})
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    # and the re-serialized artifact is stable
    assert json.loads(ck2.to_json()) == json.loads(art)


def test_roundtrip_preserves_mapping_structure(tc):
    ck = tc.compile(small_gemm())
    ck2 = CompiledKernel.from_json(ck.to_json())
    assert ck2.II == ck.II and ck2.mii == ck.mii
    assert ck2.mapping.place == ck.mapping.place
    assert ck2.mapping.reg_assign == ck.mapping.reg_assign
    assert ck2.mapping.usage.map == ck.mapping.usage.map
    assert ck2.options == ck.options
    assert ck2.cache_key == ck.cache_key


# ------------------------------------------------------------------- cache
def test_cache_hit_skips_placement(tmp_path, monkeypatch):
    cache = str(tmp_path)
    ck = Toolchain(cache_dir=cache).compile(small_gemm())
    assert not ck.from_cache

    # a fresh Toolchain (empty memo) must satisfy the compile from disk
    # without ever invoking the mapper
    import repro.core.toolchain as toolchain_mod

    def boom(*a, **k):
        raise AssertionError("placement re-ran on a cache hit")

    monkeypatch.setattr(toolchain_mod, "map_kernel_opts", boom)
    ck2 = Toolchain(cache_dir=cache).compile(small_gemm())
    assert ck2.from_cache
    assert ck2.II == ck.II
    assert ck2.cache_key == ck.cache_key
    ck2.verify()                     # the cached artifact still verifies


def test_memo_returns_same_object(tc):
    a = tc.compile(small_gemm())
    b = tc.compile(small_gemm())
    assert a is b


def test_cache_key_sensitivity():
    opts = MapperOptions()
    base = spec_cache_key(small_gemm(), opts)
    assert base == spec_cache_key(small_gemm(), opts)  # deterministic
    assert base != spec_cache_key(build_gemm(TI=4, TK=4, TJ=4, unroll=2),
                                  opts)                 # DFG change
    assert base != spec_cache_key(small_gemm(),
                                  MapperOptions(ii_max=16))  # options change


def test_corrupt_cache_entry_recompiles(tmp_path):
    cache = str(tmp_path)
    tc1 = Toolchain(cache_dir=cache)
    ck = tc1.compile(small_gemm())
    path = os.path.join(cache, f"{ck.cache_key}.json")
    with open(path, "w") as f:
        f.write("{not json")
    ck2 = Toolchain(cache_dir=cache).compile(small_gemm())
    assert not ck2.from_cache        # fell back to a cold compile
    ck2.verify()


@pytest.mark.parametrize("mangle", ["truncate", "garbage", "wrong_schema",
                                    "empty"])
def test_damaged_cache_artifact_recompiles_and_heals(tmp_path, mangle):
    """_cache_load resilience: any unreadable artifact — truncated mid-JSON,
    binary garbage, schema-valid JSON missing artifact fields, or a zero-
    byte file — must fall through to a clean recompile AND be overwritten
    with a valid artifact that the next Toolchain loads."""
    cache = str(tmp_path)
    ck = Toolchain(cache_dir=cache).compile(small_gemm())
    path = os.path.join(cache, f"{ck.cache_key}.json")
    good = open(path, "r", encoding="utf-8").read()
    damaged = {
        "truncate": good[:len(good) // 2],
        "garbage": "\x00\xff not even close",
        "wrong_schema": json.dumps({"version": 1, "name": "x"}),
        "empty": "",
    }[mangle]
    with open(path, "w", encoding="utf-8") as f:
        f.write(damaged)

    ck2 = Toolchain(cache_dir=cache).compile(small_gemm())
    assert not ck2.from_cache            # damaged entry never served
    ck2.verify()
    # the damaged file was overwritten with a parseable, loadable artifact
    healed = open(path, "r", encoding="utf-8").read()
    CompiledKernel.from_json(healed).verify()
    ck3 = Toolchain(cache_dir=cache).compile(small_gemm())
    assert ck3.from_cache                # cache healed


def test_cache_write_failure_never_fails_the_compile(tmp_path, monkeypatch):
    """The cache is an optimization: an OSError while persisting the
    artifact (disk full, permissions) must not propagate out of compile."""
    import repro.core.toolchain as toolchain_mod

    def no_disk(*a, **k):
        raise OSError("disk full")

    monkeypatch.setattr(toolchain_mod.os, "replace", no_disk)
    tc = Toolchain(cache_dir=str(tmp_path))
    ck = tc.compile(small_gemm())
    assert not ck.from_cache
    ck.verify()


def test_cache_load_does_not_mask_unrelated_errors(tmp_path, monkeypatch):
    """_cache_load's fall-through is for artifact-decode failures only; a
    genuine programming error inside artifact loading must still surface,
    not silently degrade every lookup into a recompile."""
    cache = str(tmp_path)
    ck = Toolchain(cache_dir=cache).compile(small_gemm())
    assert os.path.exists(os.path.join(cache, f"{ck.cache_key}.json"))

    def boom(s):
        raise RuntimeError("bug in artifact loading")

    monkeypatch.setattr(CompiledKernel, "from_json", staticmethod(boom))
    with pytest.raises(RuntimeError, match="bug in artifact loading"):
        Toolchain(cache_dir=cache).compile(small_gemm())


def test_cache_disabled_with_empty_dir():
    tc = Toolchain(cache_dir="")
    ck = tc.compile(small_gemm())
    assert not ck.from_cache
    assert tc._cache_path(ck.cache_key) is None


def test_cache_env_var_override(monkeypatch, tmp_path):
    monkeypatch.setenv(CACHE_ENV, str(tmp_path / "envcache"))
    assert default_cache_dir() == str(tmp_path / "envcache")
    Toolchain().compile(small_gemm())
    assert os.path.isdir(str(tmp_path / "envcache"))


# ---------------------------------------------------------- legacy shims
#
# No in-repo caller uses map_kernel / verify_mapping anymore (src/, examples/
# and benchmarks/ all go through Toolchain.compile); the shims survive only
# for external callers and are exercised here.
def test_deprecated_map_kernel_shim_still_works():
    spec = small_gemm()
    with pytest.warns(DeprecationWarning):
        m = map_kernel(spec.dfg, spec.arch, spec.layout)
    assert m.II >= m.mii


@pytest.mark.parametrize("shim", ["map_kernel", "verify_mapping"])
def test_shims_emit_deprecation_warning_exactly_once(shim):
    """One call -> exactly one DeprecationWarning (no double-warn through
    the layered implementations, nothing swallowed)."""
    import warnings as _warnings
    spec = small_gemm()
    with _warnings.catch_warnings(record=True) as rec:
        _warnings.simplefilter("always")
        if shim == "map_kernel":
            map_kernel(spec.dfg, spec.arch, spec.layout)
        else:
            verify_mapping(spec)
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)
           and shim in str(w.message)]
    assert len(dep) == 1


def test_deprecated_verify_mapping_shim_still_works():
    spec = small_gemm()
    with pytest.warns(DeprecationWarning):
        m = map_kernel(spec.dfg, spec.arch, spec.layout)
    with pytest.warns(DeprecationWarning):
        m2 = verify_mapping(spec, mapping=m)
    assert m2.II == m.II


def test_map_kernel_shim_defaults_match_mapper_options():
    # the shim once defaulted ii_max=64 while MapperOptions said 32; the
    # two entry points must escalate identically
    import inspect
    sig = inspect.signature(map_kernel)
    assert sig.parameters["ii_max"].default == MapperOptions().ii_max


def test_mapper_options_roundtrip():
    opts = MapperOptions(ii_max=24, seeds=(5, 6), ii_start=4,
                         time_budget_s=1.5)
    assert MapperOptions.from_json_dict(opts.to_json_dict()) == opts
    # seeds coerce to tuple so options hash/compare structurally
    assert MapperOptions(seeds=[1, 2]) == MapperOptions(seeds=(1, 2))
