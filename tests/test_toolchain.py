"""Toolchain compile API: serializable CompiledKernel artifacts, the
content-addressed mapping cache, fan-out compiles, and the deprecation
shims for the old free-function flow."""
import json
import os

import numpy as np
import pytest

from repro.core.kernels_lib import build_conv, build_gemm
from repro.core.mapper import MapperOptions, map_kernel
from repro.core.toolchain import (CACHE_ENV, CompiledKernel, Toolchain,
                                  default_cache_dir, spec_cache_key)
from repro.core.verify import verify_mapping


def small_gemm():
    return build_gemm(TI=4, TK=4, TJ=4, unroll=1)


@pytest.fixture()
def tc(tmp_path):
    return Toolchain(options=MapperOptions(), cache_dir=str(tmp_path))


# ----------------------------------------------------------------- compile
def test_compile_produces_verified_artifact(tc):
    ck = tc.compile(small_gemm())
    assert ck.II >= ck.mii >= 1
    assert not ck.from_cache
    ck.verify()


def test_compile_many_matches_individual(tc):
    specs = [small_gemm(), build_conv(OH=5, OW=5, K=3, variant="base")]
    cks = tc.compile_many(specs, jobs=2)
    assert [ck.name for ck in cks] == [s.name for s in specs]
    solo = Toolchain(cache_dir="")
    for spec, ck in zip(specs, cks):
        assert ck.II == solo.compile(spec).II
    for ck in cks:
        ck.verify()     # process-pool results reassemble into working CKs


def test_compile_many_dedups_identical_specs(tc):
    cks = tc.compile_many([small_gemm(), small_gemm()], jobs=2)
    assert cks[0] is cks[1]     # one compile served both indices


# ------------------------------------------------------------ serialization
def test_json_roundtrip_verifies_bit_exactly(tc):
    ck = tc.compile(small_gemm())
    art = ck.to_json()
    ck2 = CompiledKernel.from_json(art)
    assert ck2.spec is None          # no closures travel with the artifact
    ck2.verify(seed=3)               # DFG-reference oracle, bit-exact
    # simulating the same inputs through both artifacts is bit-identical
    init = ck.random_banks(seed=11)
    a = ck.run({k: v.copy() for k, v in init.items()})
    b = ck2.run({k: v.copy() for k, v in init.items()})
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    # and the re-serialized artifact is stable
    assert json.loads(ck2.to_json()) == json.loads(art)


def test_roundtrip_preserves_mapping_structure(tc):
    ck = tc.compile(small_gemm())
    ck2 = CompiledKernel.from_json(ck.to_json())
    assert ck2.II == ck.II and ck2.mii == ck.mii
    assert ck2.mapping.place == ck.mapping.place
    assert ck2.mapping.reg_assign == ck.mapping.reg_assign
    assert ck2.mapping.usage.map == ck.mapping.usage.map
    assert ck2.options == ck.options
    assert ck2.cache_key == ck.cache_key


# ------------------------------------------------------------------- cache
def test_cache_hit_skips_placement(tmp_path, monkeypatch):
    cache = str(tmp_path)
    ck = Toolchain(cache_dir=cache).compile(small_gemm())
    assert not ck.from_cache

    # a fresh Toolchain (empty memo) must satisfy the compile from disk
    # without ever invoking the mapper
    import repro.core.toolchain as toolchain_mod

    def boom(*a, **k):
        raise AssertionError("placement re-ran on a cache hit")

    monkeypatch.setattr(toolchain_mod, "map_kernel_opts", boom)
    ck2 = Toolchain(cache_dir=cache).compile(small_gemm())
    assert ck2.from_cache
    assert ck2.II == ck.II
    assert ck2.cache_key == ck.cache_key
    ck2.verify()                     # the cached artifact still verifies


def test_memo_returns_same_object(tc):
    a = tc.compile(small_gemm())
    b = tc.compile(small_gemm())
    assert a is b


def test_cache_key_sensitivity():
    opts = MapperOptions()
    base = spec_cache_key(small_gemm(), opts)
    assert base == spec_cache_key(small_gemm(), opts)  # deterministic
    assert base != spec_cache_key(build_gemm(TI=4, TK=4, TJ=4, unroll=2),
                                  opts)                 # DFG change
    assert base != spec_cache_key(small_gemm(),
                                  MapperOptions(ii_max=16))  # options change


def test_corrupt_cache_entry_recompiles(tmp_path):
    cache = str(tmp_path)
    tc1 = Toolchain(cache_dir=cache)
    ck = tc1.compile(small_gemm())
    path = os.path.join(cache, f"{ck.cache_key}.json")
    with open(path, "w") as f:
        f.write("{not json")
    ck2 = Toolchain(cache_dir=cache).compile(small_gemm())
    assert not ck2.from_cache        # fell back to a cold compile
    ck2.verify()


def test_cache_disabled_with_empty_dir():
    tc = Toolchain(cache_dir="")
    ck = tc.compile(small_gemm())
    assert not ck.from_cache
    assert tc._cache_path(ck.cache_key) is None


def test_cache_env_var_override(monkeypatch, tmp_path):
    monkeypatch.setenv(CACHE_ENV, str(tmp_path / "envcache"))
    assert default_cache_dir() == str(tmp_path / "envcache")
    Toolchain().compile(small_gemm())
    assert os.path.isdir(str(tmp_path / "envcache"))


# ---------------------------------------------------------- legacy shims
def test_deprecated_map_kernel_shim_still_works():
    spec = small_gemm()
    with pytest.warns(DeprecationWarning):
        m = map_kernel(spec.dfg, spec.arch, spec.layout)
    assert m.II >= m.mii


def test_deprecated_verify_mapping_shim_still_works():
    spec = small_gemm()
    with pytest.warns(DeprecationWarning):
        m = map_kernel(spec.dfg, spec.arch, spec.layout)
    with pytest.warns(DeprecationWarning):
        m2 = verify_mapping(spec, mapping=m)
    assert m2.II == m.II


def test_map_kernel_shim_defaults_match_mapper_options():
    # the shim once defaulted ii_max=64 while MapperOptions said 32; the
    # two entry points must escalate identically
    import inspect
    sig = inspect.signature(map_kernel)
    assert sig.parameters["ii_max"].default == MapperOptions().ii_max


def test_mapper_options_roundtrip():
    opts = MapperOptions(ii_max=24, seeds=(5, 6), ii_start=4,
                         time_budget_s=1.5)
    assert MapperOptions.from_json_dict(opts.to_json_dict()) == opts
    # seeds coerce to tuple so options hash/compare structurally
    assert MapperOptions(seeds=[1, 2]) == MapperOptions(seeds=(1, 2))
